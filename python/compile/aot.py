"""AOT driver: lower every ArtifactDef to HLO **text** + emit the manifest.

HLO text (never ``.serialize()``) is the interchange format: the image's
xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit instruction ids); the
text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Usage (from ``python/``):
    python -m compile.aot --out-dir ../artifacts [--only REGEX] [--report]

``--report`` prints the L1 perf-structure report: per-kernel VMEM footprint
of the chosen BlockSpec tiles and the estimated MXU utilization of the
matmul tiles (interpret=True gives no TPU wallclock; structure is the
optimizable signal — DESIGN.md §6).
"""

import argparse
import json
import os
import re
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from .model import all_artifacts


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(art):
    lowered = jax.jit(art.fn).lower(*art.input_specs())
    return to_hlo_text(lowered)


def manifest_entry(art):
    return {
        "name": art.name,
        "file": f"{art.name}.hlo.txt",
        "inputs": [
            {"name": i.name, "shape": list(i.shape), "role": i.role,
             "init": i.init}
            for i in art.inputs
        ],
        "outputs": [{"shape": list(s)} for s in art.output_shapes()],
        "state_count": art.state_count,
        "meta": art.meta,
    }


def vmem_report(arts):
    """Structural perf report for L1 (DESIGN.md §6): VMEM bytes per tile and
    MXU-tile utilization for the matmul artifacts."""
    rows = []
    for art in arts:
        meta = art.meta
        if meta.get("family") != "micro" or meta.get("kernel") != "matmul":
            continue
        bm, bn, bk = meta.get("tile", [64, 64, 64])
        vmem = 4 * (bm * bk + bk * bn + bm * bn)
        # MXU is a 128x128 systolic array; utilization of an (bm x bn)
        # output tile is how much of the array a pass fills.
        mxu = min(bm, 128) * min(bn, 128) / (128.0 * 128.0)
        rows.append((art.name, f"{vmem / 1024.0:.1f} KiB", f"{mxu:.2f}"))
    if rows:
        print(f"{'artifact':40s} {'VMEM/tile':>12s} {'MXU util':>9s}")
        for name, vmem, mxu in rows:
            print(f"{name:40s} {vmem:>12s} {mxu:>9s}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="regex filter over artifact names")
    ap.add_argument("--report", action="store_true",
                    help="print the L1 VMEM/MXU structure report")
    # kept for Makefile compatibility with the scaffold
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    arts = all_artifacts()
    if args.report:
        vmem_report(arts)
        return
    manifest = {"version": 1, "artifacts": []}
    if args.only:
        rx = re.compile(args.only)
        arts = [a for a in arts if rx.search(a.name)]
        # Merge into the existing manifest (a partial relower must not
        # orphan the other artifacts).
        mpath = os.path.join(out_dir, "manifest.json")
        if os.path.exists(mpath):
            with open(mpath) as f:
                old = json.load(f)
            keep = {a.name for a in arts}
            manifest["artifacts"] = [
                e for e in old.get("artifacts", []) if e["name"] not in keep
            ]
    t_total = time.time()
    for art in arts:
        t0 = time.time()
        text = lower_artifact(art)
        path = os.path.join(out_dir, f"{art.name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(manifest_entry(art))
        print(f"  {art.name:32s} {len(text) / 1024.0:8.1f} KiB "
              f"{time.time() - t0:6.2f}s", file=sys.stderr)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"lowered {len(arts)} artifacts to {out_dir} "
          f"in {time.time() - t_total:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
