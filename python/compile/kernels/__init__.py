"""Layer-1 Pallas kernels for HAQA-RS.

Every kernel here is authored with ``jax.experimental.pallas`` and lowered
with ``interpret=True`` so the resulting HLO executes on the CPU PJRT client
(real-TPU lowering emits Mosaic custom-calls the CPU plugin cannot run).
Each kernel has a pure-jnp oracle in :mod:`ref` checked by pytest/hypothesis.

Tunable surface (the TPU analogue of the paper's CUDA launch geometry): each
kernel exposes its BlockSpec tile shape, which is the HBM->VMEM schedule knob
on TPU hardware. See DESIGN.md "Hardware-Adaptation".
"""

from .dorefa import (  # noqa: F401
    quantize_levels,
    dorefa_weight_quant,
    dorefa_act_quant,
)
from .qmatmul import qmatmul  # noqa: F401
from .softmax import softmax  # noqa: F401
from .rmsnorm import rmsnorm  # noqa: F401
from .silu import silu_gate  # noqa: F401
from .rope import rope  # noqa: F401
