"""SiLU-gate Pallas kernel (Table 3 kernel #2).

LLaMA's gated MLP activation: y = silu(g) * u where silu(g) = g * sigmoid(g).
The paper benches the SiLU kernel at the LLaMA FFN width (11008); we fuse the
gate multiply, which is how llama.cpp executes it.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = None  # None => whole array in one VMEM tile (grid=1)


def _silu_gate_kernel(g_ref, u_ref, o_ref):
    g = g_ref[...]
    o_ref[...] = g * jax.nn.sigmoid(g) * u_ref[...]


def silu_gate(gate, up, block_rows=DEFAULT_BLOCK_ROWS):
    """Fused ``silu(gate) * up`` over matching (..., F) arrays."""
    shape = gate.shape
    g2d = gate.reshape((-1, shape[-1]))
    u2d = up.reshape((-1, shape[-1]))
    rows, cols = g2d.shape
    br = rows if block_rows is None else max(1, min(block_rows, rows))
    out = pl.pallas_call(
        _silu_gate_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, cols), g2d.dtype),
        grid=(pl.cdiv(rows, br),),
        in_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        interpret=True,
    )(g2d, u2d)
    return out.reshape(shape)
