"""Rotary position embedding (RoPE) Pallas kernel (Table 3 kernel #4).

x is (S, D) with D even; cos/sin tables are (S, D/2), precomputed in plain
jnp (they are position-only and fold into constants at AOT time).  The
kernel rotates feature pairs (x1, x2) -> (x1*cos - x2*sin, x1*sin + x2*cos)
using the half-split convention (first D/2 features pair with last D/2),
matching the LLaMA/GPT-NeoX layout.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = None  # None => whole array in one VMEM tile (grid=1)


def _rope_kernel(x_ref, cos_ref, sin_ref, o_ref):
    x = x_ref[...]
    d_half = x.shape[-1] // 2
    x1 = x[:, :d_half]
    x2 = x[:, d_half:]
    c = cos_ref[...]
    s = sin_ref[...]
    o_ref[...] = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def rope_tables(seq_len, d, base=10000.0):
    """cos/sin tables of shape (seq_len, d//2)."""
    half = d // 2
    inv_freq = 1.0 / (base ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    ang = pos * inv_freq[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def rope(x, cos, sin, block_rows=DEFAULT_BLOCK_ROWS):
    """Apply rotary embedding to ``x`` (S, D) with tables (S, D/2)."""
    s, d = x.shape
    br = s if block_rows is None else max(1, min(block_rows, s))
    return pl.pallas_call(
        _rope_kernel,
        out_shape=jax.ShapeDtypeStruct((s, d), x.dtype),
        grid=(pl.cdiv(s, br),),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, d // 2), lambda i: (i, 0)),
            pl.BlockSpec((br, d // 2), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        interpret=True,
    )(x, cos, sin)
