"""Pure-jnp oracles for every Pallas kernel — the CORE correctness signal.

pytest (python/tests/test_kernel.py) asserts allclose between each kernel
under interpret=True and its oracle here, across a hypothesis-driven sweep of
shapes and block sizes.
"""

import jax
import jax.numpy as jnp


def quantize_levels(x, levels):
    return jnp.round(x * levels) / levels


def dorefa_weight_quant(w, kbits):
    t = jnp.tanh(w)
    denom = 2.0 * jnp.max(jnp.abs(t)) + 1e-8
    wn = t / denom + 0.5
    levels = jnp.exp2(kbits) - 1.0
    return 2.0 * quantize_levels(wn, levels) - 1.0


def dorefa_act_quant(a, kbits):
    levels = jnp.exp2(kbits) - 1.0
    return quantize_levels(jnp.clip(a, 0.0, 1.0), levels)


def qmatmul(x, w):
    return jnp.matmul(x, w)


def softmax(x):
    return jax.nn.softmax(x, axis=-1)


def rmsnorm(x, gain, eps=1e-5):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gain


def silu_gate(gate, up):
    return gate * jax.nn.sigmoid(gate) * up


def rope(x, cos, sin):
    d_half = x.shape[-1] // 2
    x1 = x[:, :d_half]
    x2 = x[:, d_half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
