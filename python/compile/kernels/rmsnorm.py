"""RMSNorm Pallas kernel (Table 3 kernel #3).

y = x / sqrt(mean(x^2) + eps) * g, rowwise over the last axis.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = None  # None => whole array in one VMEM tile (grid=1)
EPS = 1e-5


def _rmsnorm_kernel(x_ref, g_ref, o_ref):
    x = x_ref[...]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(ms + EPS) * g_ref[...]


def rmsnorm(x, gain, block_rows=DEFAULT_BLOCK_ROWS):
    """RMS-normalize the last axis of ``x`` (..., D) with gain (D,)."""
    shape = x.shape
    d = shape[-1]
    x2d = x.reshape((-1, d))
    rows = x2d.shape[0]
    br = rows if block_rows is None else max(1, min(block_rows, rows))
    g2d = gain.reshape((1, d))
    out = pl.pallas_call(
        _rmsnorm_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, d), x2d.dtype),
        grid=(pl.cdiv(rows, br),),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        interpret=True,
    )(x2d, g2d)
    return out.reshape(shape)
