"""DoReFa fake-quantization Pallas kernels (Zhou et al., 2016).

The paper (HAQA) runs DoReFa QAT on ResNets and selects bit-widths at
deployment time.  A key AOT design decision (DESIGN.md §5): the bit-width is
a *runtime scalar* — uniform quantization ``q = round(x * L) / L`` with
``L = 2^k - 1`` traces cleanly with ``k`` as an f32 input, so one HLO
artifact serves every precision (w8a8 / w4a4 / w2a2 / "fp16" via large k).

Gradients use the straight-through estimator (STE), exactly as DoReFa
prescribes, wired through ``jax.custom_vjp`` so the Pallas forward kernel is
differentiable inside the L2 train-step graphs.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile height for the elementwise quantization kernels.  This is the
# HBM->VMEM block schedule knob: rows are streamed through VMEM in chunks of
# ``block_rows`` full rows.  8x128 lanes per step keeps the VPU saturated.
DEFAULT_BLOCK_ROWS = None  # None => whole array in one VMEM tile (grid=1)


def _quant_kernel(x_ref, levels_ref, o_ref):
    """o = round(x * L) / L  (uniform quantization to L+1 levels in [0,1])."""
    levels = levels_ref[0, 0]
    x = x_ref[...]
    o_ref[...] = jnp.round(x * levels) / levels


def _pallas_quant(x2d, levels, block_rows):
    rows, cols = x2d.shape
    block_rows = rows if block_rows is None else max(1, min(block_rows, rows))
    grid = (pl.cdiv(rows, block_rows),)
    return pl.pallas_call(
        _quant_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, cols), x2d.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        interpret=True,
    )(x2d, levels)


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def quantize_levels(x, levels, block_rows=DEFAULT_BLOCK_ROWS):
    """Uniform fake-quantization of ``x`` (values in [0,1]) to ``levels``
    steps, as a Pallas kernel with an STE backward pass.

    ``levels`` is a scalar f32 array (``2^k - 1``); it is a runtime input so
    the lowered HLO serves every bit-width.
    """
    shape = x.shape
    x2d = x.reshape((-1, shape[-1])) if x.ndim != 2 else x
    lv = jnp.asarray(levels, jnp.float32).reshape((1, 1))
    out = _pallas_quant(x2d, lv, block_rows)
    return out.reshape(shape)


def _quantize_fwd(x, levels, block_rows):
    return quantize_levels(x, levels, block_rows), None


def _quantize_bwd(block_rows, _res, g):
    # Straight-through estimator: d round(x*L)/L / dx ~= 1.
    return (g, jnp.zeros((), jnp.float32))


quantize_levels.defvjp(_quantize_fwd, _quantize_bwd)


def dorefa_weight_quant(w, kbits, block_rows=DEFAULT_BLOCK_ROWS):
    """DoReFa weight quantization.

    w_n = tanh(w) / (2 * max|tanh(w)|) + 0.5   in [0, 1]
    q   = 2 * quantize_k(w_n) - 1              in [-1, 1]

    ``kbits`` is a runtime f32 scalar.  Gradients flow via STE through the
    rounding; tanh/normalization gradients are exact (as in the original
    DoReFa-Net formulation).
    """
    t = jnp.tanh(w)
    denom = 2.0 * jnp.max(jnp.abs(t)) + 1e-8
    wn = t / denom + 0.5
    levels = jnp.exp2(kbits) - 1.0
    q = quantize_levels(wn, levels, block_rows)
    return 2.0 * q - 1.0


def dorefa_act_quant(a, kbits, block_rows=DEFAULT_BLOCK_ROWS):
    """DoReFa activation quantization: quantize_k(clip(a, 0, 1)).

    ``kbits`` is a runtime f32 scalar.  STE through the rounding; the clip is
    exact (zero gradient outside [0,1], as DoReFa prescribes).
    """
    ac = jnp.clip(a, 0.0, 1.0)
    levels = jnp.exp2(kbits) - 1.0
    return quantize_levels(ac, levels, block_rows)
