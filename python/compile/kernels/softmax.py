"""Row-softmax Pallas kernel (Table 3 kernel #1).

Numerically stable (max-subtracted) softmax over the last axis.  The tile
knob is ``block_rows``: how many rows are resident in VMEM per grid step.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = None  # None => whole array in one VMEM tile (grid=1)


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


def softmax(x, block_rows=DEFAULT_BLOCK_ROWS):
    """Softmax over the last axis of a 2-D array ``x`` of shape (R, C)."""
    shape = x.shape
    x2d = x.reshape((-1, shape[-1])) if x.ndim != 2 else x
    rows, cols = x2d.shape
    br = rows if block_rows is None else max(1, min(block_rows, rows))
    out = pl.pallas_call(
        _softmax_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, cols), x2d.dtype),
        grid=(pl.cdiv(rows, br),),
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        interpret=True,
    )(x2d)
    return out.reshape(shape)
