"""Tiled matmul Pallas kernel — the paper's dominant kernel (~90% of LLM
inference runtime, Table 3).

The CUDA version the paper tunes exposes gridDim/blockDim/tiling/unroll; the
TPU analogue is the (block_m, block_n, block_k) tile schedule: each grid step
streams an (bm, bk) x (bk, bn) pair through VMEM and accumulates into an
(bm, bn) output tile, which is exactly what the MXU systolic array consumes.
128x128 tiles are MXU-native; the tuner sweeps these knobs (see
``deploy::tuner`` on the Rust side and the tile-variant artifacts).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (64, 64, 64)


def _matmul_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def qmatmul(x, w, block=DEFAULT_BLOCK):
    """``x @ w`` with an explicit (bm, bn, bk) VMEM tile schedule.

    ``x``: (M, K), ``w``: (K, N) -> (M, N), all f32 (weights are expected to
    be fake-quantized by :func:`dorefa_weight_quant` upstream, which is how
    INT8/INT4 execution is modelled in the interpret-mode artifacts).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm, bn, bk = block
    bm = max(1, min(bm, m))
    bn = max(1, min(bn, n))
    bk = max(1, min(bk, k))
    # Zero-pad ragged edges to tile multiples: interpret-mode pallas fills
    # out-of-bounds input blocks with NaN, and zero K-padding is exact for
    # the accumulation.
    mp, np_, kp = _ceil(m, bm), _ceil(n, bn), _ceil(k, bk)
    if (mp, kp) != (m, k):
        x = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        w = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    out = _call(x, w, (mp, np_, kp), (bm, bn, bk))
    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out


def _ceil(x, b):
    return ((x + b - 1) // b) * b


def _call(x, w, dims, block):
    m, n, k = dims
    bm, bn, bk = block
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        interpret=True,
    )(x, w)
