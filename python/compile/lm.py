"""Layer-2: tiny decoder-only transformer with QLoRA-style training
(paper Table 2 / Figure 4 track) plus the deployment-side decode step
(paper Table 3/4/5, Figure 5 track).

Substitution (DESIGN.md): LLaMA2-7B..LLaMA3-8B + Alpaca become a vocab-64,
d=64, 2-layer LLaMA-architecture decoder (RMSNorm / RoPE / SwiGLU / tied
head) trained on synthetic corpora.  QLoRA mechanics are faithful:

* the base weights are **frozen** and fake-quantized by the DoReFa Pallas
  weight kernel with a *runtime* bit-width scalar (INT4/INT8/FP16-as-high-k);
* trainable state is LoRA adapters on Wq/Wv with rank masked up to R_MAX=64,
  so `lora_r` in [8, 64] is a runtime input (rank mask + alpha/r scale);
* optimizer = Adam with decoupled weight decay, grad clipping; warmup and
  bias correction are folded into scalar inputs computed by the Rust driver.

Two graph families:
* train/eval — differentiable, use pure-jnp math for the transformer body
  (Pallas appears via the custom_vjp DoReFa kernels);
* decode — the inference hot path, built *entirely* from the Pallas kernels
  (qmatmul / softmax / rmsnorm / silu_gate / rope), mirroring the paper's
  kernel-level deployment tuning on llama.cpp.
"""

import jax
import jax.numpy as jnp

from .kernels.dorefa import dorefa_weight_quant
from .kernels import qmatmul as pallas_qmatmul
from .kernels import softmax as pallas_softmax
from .kernels import rmsnorm as pallas_rmsnorm
from .kernels import silu_gate as pallas_silu_gate
from .kernels import rope as pallas_rope
from .kernels.rope import rope_tables
from .kernels import ref

VOCAB = 64
D = 64
HEADS = 4
DH = D // HEADS
LAYERS = 2
FF = 128
SEQ = 32
R_MAX = 64

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def base_spec():
    """Ordered (name, shape, init) for the frozen base weights."""
    spec = [("embed", (VOCAB, D), "embed")]
    for l in range(LAYERS):
        spec += [
            (f"l{l}_wq", (D, D), "he"),
            (f"l{l}_wk", (D, D), "he"),
            (f"l{l}_wv", (D, D), "he"),
            (f"l{l}_wo", (D, D), "he"),
            (f"l{l}_wgate", (D, FF), "he"),
            (f"l{l}_wup", (D, FF), "he"),
            (f"l{l}_wdown", (FF, D), "he"),
            (f"l{l}_rms1", (D,), "ones"),
            (f"l{l}_rms2", (D,), "ones"),
        ]
    spec.append(("rmsf", (D,), "ones"))
    return spec


def lora_spec():
    """Ordered (name, shape, init) for the trainable LoRA adapters (Wq, Wv)."""
    spec = []
    for l in range(LAYERS):
        for tgt in ("q", "v"):
            spec.append((f"l{l}_{tgt}_a", (D, R_MAX), "lora_a"))
            spec.append((f"l{l}_{tgt}_b", (R_MAX, D), "zeros"))
    return spec


def _causal_mask(t):
    i = jnp.arange(t)[:, None]
    j = jnp.arange(t)[None, :]
    return jnp.where(j <= i, 0.0, -1e9).astype(jnp.float32)


def _lora_apply(x, a, b, rank_mask, scale, dropout_mask=None):
    """x (B,T,D) -> (B,T,D) through the masked-rank adapter."""
    xin = x if dropout_mask is None else x * dropout_mask
    z = (xin @ a) * rank_mask[None, None, :]
    return (z @ b) * scale


def forward_train(base, lora, tokens_oh, bits, rank_mask, lora_scale,
                  dropout_mask):
    """Differentiable forward (pure-jnp body + DoReFa Pallas quant).

    tokens_oh: (B, T, V) one-hot.  Returns logits (B, T, V).
    """
    b, t, _ = tokens_oh.shape
    cos, sin = rope_tables(t, DH)
    mask = _causal_mask(t)

    def qw(w):
        return dorefa_weight_quant(w, bits)

    h = tokens_oh @ base["embed"]  # (B,T,D) one-hot matmul (gather-free HLO)
    for l in range(LAYERS):
        x1 = ref.rmsnorm(h, base[f"l{l}_rms1"])
        q = x1 @ qw(base[f"l{l}_wq"]) + _lora_apply(
            x1, lora[f"l{l}_q_a"], lora[f"l{l}_q_b"], rank_mask, lora_scale,
            dropout_mask)
        k = x1 @ qw(base[f"l{l}_wk"])
        v = x1 @ qw(base[f"l{l}_wv"]) + _lora_apply(
            x1, lora[f"l{l}_v_a"], lora[f"l{l}_v_b"], rank_mask, lora_scale,
            dropout_mask)
        q = q.reshape(b, t, HEADS, DH).transpose(0, 2, 1, 3)
        k = k.reshape(b, t, HEADS, DH).transpose(0, 2, 1, 3)
        v = v.reshape(b, t, HEADS, DH).transpose(0, 2, 1, 3)
        q = ref.rope(q.reshape(-1, DH),
                     jnp.tile(cos, (b * HEADS, 1)),
                     jnp.tile(sin, (b * HEADS, 1))).reshape(b, HEADS, t, DH)
        k = ref.rope(k.reshape(-1, DH),
                     jnp.tile(cos, (b * HEADS, 1)),
                     jnp.tile(sin, (b * HEADS, 1))).reshape(b, HEADS, t, DH)
        scores = jnp.einsum("bhid,bhjd->bhij", q, k) / jnp.sqrt(float(DH))
        attn = ref.softmax(scores + mask[None, None])
        out = jnp.einsum("bhij,bhjd->bhid", attn, v)
        out = out.transpose(0, 2, 1, 3).reshape(b, t, D)
        h = h + out @ qw(base[f"l{l}_wo"])
        x2 = ref.rmsnorm(h, base[f"l{l}_rms2"])
        gate = x2 @ qw(base[f"l{l}_wgate"])
        up = x2 @ qw(base[f"l{l}_wup"])
        h = h + ref.silu_gate(gate, up) @ qw(base[f"l{l}_wdown"])
    xf = ref.rmsnorm(h, base["rmsf"])
    return xf @ base["embed"].T  # tied head


def _ce_loss(logits, targets_oh):
    logz = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(targets_oh * logz, axis=-1))


def make_train_step():
    """fn(base..., lora..., m..., v..., tokens, targets, dropout_noise,
    rank_mask, lr, wd, clip, bits, lora_scale, dropout_p, bc1, bc2)
    -> (lora'..., m'..., v'..., loss)

    bc1/bc2 are Adam bias corrections 1/(1-beta^t) computed by the driver;
    lr is the post-warmup effective rate (schedule lives in Rust).
    """
    bnames = [s[0] for s in base_spec()]
    lnames = [s[0] for s in lora_spec()]
    nb, nl = len(bnames), len(lnames)

    def step(*args):
        i = 0
        base = dict(zip(bnames, args[i:i + nb])); i += nb
        lora = dict(zip(lnames, args[i:i + nl])); i += nl
        m = dict(zip(lnames, args[i:i + nl])); i += nl
        v = dict(zip(lnames, args[i:i + nl])); i += nl
        (tokens, targets, noise, rank_mask,
         lr, wd, clip, bits, lora_scale, dropout_p, bc1, bc2) = args[i:]

        keep = (noise >= dropout_p).astype(jnp.float32)
        dropout_mask = keep / jnp.maximum(1.0 - dropout_p, 1e-3)

        def loss_fn(lp):
            logits = forward_train(base, lp, tokens, bits, rank_mask,
                                   lora_scale, dropout_mask)
            return _ce_loss(logits, targets)

        loss, grads = jax.value_and_grad(loss_fn)(lora)

        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()) + 1e-12)
        scale = jnp.minimum(1.0, clip / gnorm)

        new_l, new_m, new_v = [], [], []
        for name in lnames:
            g = grads[name] * scale
            mi = ADAM_B1 * m[name] + (1 - ADAM_B1) * g
            vi = ADAM_B2 * v[name] + (1 - ADAM_B2) * g * g
            upd = (mi * bc1) / (jnp.sqrt(vi * bc2) + ADAM_EPS)
            new_m.append(mi)
            new_v.append(vi)
            new_l.append(lora[name] - lr * (upd + wd * lora[name]))
        return tuple(new_l) + tuple(new_m) + tuple(new_v) + (loss,)

    return step


def make_pretrain_step():
    """Full-parameter Adam pretraining of the base (bits=16, no adapters).

    The paper fine-tunes *pretrained* LLaMA checkpoints; at laptop scale the
    Rust driver pretrains the tiny base once per model variant with this
    graph, then freezes + quantizes it for the QLoRA track.

    fn(base..., m..., v..., tokens, targets, lr, clip, bc1, bc2)
    -> (base'..., m'..., v'..., loss)
    """
    bnames = [s[0] for s in base_spec()]
    nb = len(bnames)
    zero_lora = {n: jnp.zeros(s, jnp.float32) for n, s, _ in lora_spec()}
    rank_mask = jnp.zeros((R_MAX,), jnp.float32)

    def step(*args):
        base = dict(zip(bnames, args[:nb]))
        m = dict(zip(bnames, args[nb:2 * nb]))
        v = dict(zip(bnames, args[2 * nb:3 * nb]))
        tokens, targets, lr, clip, bc1, bc2 = args[3 * nb:]

        def loss_fn(p):
            logits = forward_train(p, zero_lora, tokens, jnp.float32(16.0),
                                   rank_mask, jnp.float32(0.0), None)
            return _ce_loss(logits, targets)

        loss, grads = jax.value_and_grad(loss_fn)(base)
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()) + 1e-12)
        scale = jnp.minimum(1.0, clip / gnorm)
        new_b, new_m, new_v = [], [], []
        for name in bnames:
            g = grads[name] * scale
            mi = ADAM_B1 * m[name] + (1 - ADAM_B1) * g
            vi = ADAM_B2 * v[name] + (1 - ADAM_B2) * g * g
            upd = (mi * bc1) / (jnp.sqrt(vi * bc2) + ADAM_EPS)
            new_m.append(mi)
            new_v.append(vi)
            new_b.append(base[name] - lr * upd)
        return tuple(new_b) + tuple(new_m) + tuple(new_v) + (loss,)

    return step


def make_eval_step():
    """fn(base..., lora..., tokens, targets, rank_mask, bits, lora_scale)
    -> (loss, logits(B,T,V))"""
    bnames = [s[0] for s in base_spec()]
    lnames = [s[0] for s in lora_spec()]
    nb, nl = len(bnames), len(lnames)

    def step(*args):
        base = dict(zip(bnames, args[:nb]))
        lora = dict(zip(lnames, args[nb:nb + nl]))
        tokens, targets, rank_mask, bits, lora_scale = args[nb + nl:]
        logits = forward_train(base, lora, tokens, bits, rank_mask,
                               lora_scale, None)
        return (_ce_loss(logits, targets), logits)

    return step


# ---------------------------------------------------------------------------
# Inference path: every op is a Pallas kernel (the deployment hot spot the
# paper tunes per-kernel on llama.cpp).
# ---------------------------------------------------------------------------

def forward_decode(base, lora, tokens_oh, bits, rank_mask, lora_scale,
                   mm_block=(32, 64, 32)):
    """Pallas-kernel forward for a single sequence (1, T, V); returns the
    next-token logits (V,).  ``mm_block`` is the qmatmul tile schedule —
    the deployment tunable exposed to the L3 tuner."""
    _, t, _ = tokens_oh.shape
    cos, sin = rope_tables(t, DH)
    mask = _causal_mask(t)

    def qw(w):
        return dorefa_weight_quant(w, bits)

    def mm(x2d, w):
        return pallas_qmatmul(x2d, w, mm_block)

    x = tokens_oh.reshape(t, VOCAB)
    h = mm(x, base["embed"])  # (T, D)
    for l in range(LAYERS):
        x1 = pallas_rmsnorm(h, base[f"l{l}_rms1"])
        q = mm(x1, qw(base[f"l{l}_wq"])) + (
            (mm(x1, lora[f"l{l}_q_a"]) * rank_mask[None, :])
            @ lora[f"l{l}_q_b"]) * lora_scale
        k = mm(x1, qw(base[f"l{l}_wk"]))
        v = mm(x1, qw(base[f"l{l}_wv"])) + (
            (mm(x1, lora[f"l{l}_v_a"]) * rank_mask[None, :])
            @ lora[f"l{l}_v_b"]) * lora_scale
        # (T, D) -> per-head (HEADS, T, DH)
        qh = q.reshape(t, HEADS, DH).transpose(1, 0, 2)
        kh = k.reshape(t, HEADS, DH).transpose(1, 0, 2)
        vh = v.reshape(t, HEADS, DH).transpose(1, 0, 2)
        qh = pallas_rope(qh.reshape(-1, DH), jnp.tile(cos, (HEADS, 1)),
                         jnp.tile(sin, (HEADS, 1))).reshape(HEADS, t, DH)
        kh = pallas_rope(kh.reshape(-1, DH), jnp.tile(cos, (HEADS, 1)),
                         jnp.tile(sin, (HEADS, 1))).reshape(HEADS, t, DH)
        scores = jnp.einsum("hid,hjd->hij", qh, kh) / jnp.sqrt(float(DH))
        attn = pallas_softmax((scores + mask[None]).reshape(HEADS * t, t))
        attn = attn.reshape(HEADS, t, t)
        out = jnp.einsum("hij,hjd->hid", attn, vh)
        out = out.transpose(1, 0, 2).reshape(t, D)
        h = h + mm(out, qw(base[f"l{l}_wo"]))
        x2 = pallas_rmsnorm(h, base[f"l{l}_rms2"])
        gate = mm(x2, qw(base[f"l{l}_wgate"]))
        up = mm(x2, qw(base[f"l{l}_wup"]))
        h = h + mm(pallas_silu_gate(gate, up), qw(base[f"l{l}_wdown"]))
    xf = pallas_rmsnorm(h, base["rmsf"])
    logits = mm(xf, base["embed"].T)
    return logits[-1]


def make_decode_step(mm_block=(32, 64, 32)):
    """fn(base..., lora..., tokens(1,T,V), rank_mask, bits, lora_scale)
    -> (next_logits(V,),)"""
    bnames = [s[0] for s in base_spec()]
    lnames = [s[0] for s in lora_spec()]
    nb, nl = len(bnames), len(lnames)

    def step(*args):
        base = dict(zip(bnames, args[:nb]))
        lora = dict(zip(lnames, args[nb:nb + nl]))
        tokens, rank_mask, bits, lora_scale = args[nb + nl:]
        return (forward_decode(base, lora, tokens, bits, rank_mask,
                               lora_scale, mm_block),)

    return step
