"""Layer-2 hub: the complete artifact catalogue.

Every computation the Rust runtime executes is declared here as an
:class:`ArtifactDef` — name, jax function, typed input list (with *roles*
consumed by the generic Rust driver), and metadata.  ``aot.py`` lowers each
one to ``artifacts/<name>.hlo.txt`` and emits ``artifacts/manifest.json``.

Input roles (the contract with ``runtime::artifact`` on the Rust side):
  state  — threaded: output i replaces input i on the next call
  frozen — provided every call, never updated (e.g. QLoRA base weights)
  data   — per-call payload (batches, token windows, noise)
  scalar — per-call f32 scalar hyperparameters

Batch sizes and LoRA max-rank are shape-affecting, hence the variant fan-out
(DESIGN.md §5); every other hyperparameter is a runtime input.
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from . import cnn, lm, micro

CNN_TRAIN_BATCHES = (32, 64, 128, 256)
CNN_EVAL_BATCH = 256
LM_TRAIN_BATCHES = (4, 8, 16)
LM_EVAL_BATCH = 32


@dataclass
class Input:
    name: str
    shape: tuple
    role: str  # state | frozen | data | scalar
    init: str = "none"  # he | zeros | ones | embed | lora_a | none

    def spec(self):
        return jax.ShapeDtypeStruct(tuple(self.shape), jnp.float32)


@dataclass
class ArtifactDef:
    name: str
    fn: object
    inputs: list
    state_count: int = 0
    meta: dict = field(default_factory=dict)

    def input_specs(self):
        return [i.spec() for i in self.inputs]

    def output_shapes(self):
        out = jax.eval_shape(self.fn, *self.input_specs())
        return [tuple(int(d) for d in o.shape) for o in out]


def _scalar(name):
    return Input(name, (), "scalar")


def cnn_artifacts():
    arts = []
    for size_name in cnn.SIZES:
        step, spec = cnn.make_train_step(size_name)
        params = [Input(n, s, "state", init) for n, s, init, _q in spec]
        vels = [Input(f"vel_{n}", s, "state", "zeros") for n, s, _i, _q in spec]
        for b in CNN_TRAIN_BATCHES:
            inputs = (params + vels + [
                Input("x", (b, cnn.IMG, cnn.IMG, 3), "data"),
                Input("y", (b, cnn.NUM_CLASSES), "data"),
                _scalar("lr"), _scalar("momentum"), _scalar("weight_decay"),
                _scalar("grad_clip"), _scalar("wbits"), _scalar("abits"),
            ])
            arts.append(ArtifactDef(
                name=f"{size_name}_train_b{b}",
                fn=step, inputs=inputs, state_count=2 * len(spec),
                meta={"family": "cnn_train", "model": size_name, "batch": b},
            ))
        estep, _ = cnn.make_eval_step(size_name)
        einputs = ([Input(n, s, "frozen", init) for n, s, init, _q in spec] + [
            Input("x", (CNN_EVAL_BATCH, cnn.IMG, cnn.IMG, 3), "data"),
            Input("y", (CNN_EVAL_BATCH, cnn.NUM_CLASSES), "data"),
            _scalar("wbits"), _scalar("abits"),
        ])
        arts.append(ArtifactDef(
            name=f"{size_name}_eval",
            fn=estep, inputs=einputs, state_count=0,
            meta={"family": "cnn_eval", "model": size_name,
                  "batch": CNN_EVAL_BATCH},
        ))
    return arts


def _lm_base_inputs(role="frozen"):
    return [Input(n, s, role, init) for n, s, init in lm.base_spec()]


def lm_artifacts():
    arts = []
    step = lm.make_train_step()
    lspec = lm.lora_spec()
    lora = [Input(n, s, "state", init) for n, s, init in lspec]
    adam_m = [Input(f"m_{n}", s, "state", "zeros") for n, s, _ in lspec]
    adam_v = [Input(f"v_{n}", s, "state", "zeros") for n, s, _ in lspec]
    for b in LM_TRAIN_BATCHES:
        inputs = (_lm_base_inputs() + lora + adam_m + adam_v + [
            Input("tokens", (b, lm.SEQ, lm.VOCAB), "data"),
            Input("targets", (b, lm.SEQ, lm.VOCAB), "data"),
            Input("dropout_noise", (b, lm.SEQ, lm.D), "data"),
            Input("rank_mask", (lm.R_MAX,), "data"),
            _scalar("lr"), _scalar("weight_decay"), _scalar("grad_clip"),
            _scalar("bits"), _scalar("lora_scale"), _scalar("dropout_p"),
            _scalar("bc1"), _scalar("bc2"),
        ])
        # NB: frozen base comes first in the arg list, but state threading on
        # the Rust side is positional over the `state` role, so the driver
        # maps outputs [0..3*len(lspec)) onto the lora/m/v inputs.
        arts.append(ArtifactDef(
            name=f"lm_train_b{b}",
            fn=step, inputs=inputs, state_count=3 * len(lspec),
            meta={"family": "lm_train", "batch": b,
                  "vocab": lm.VOCAB, "seq": lm.SEQ, "r_max": lm.R_MAX},
        ))
    pstep = lm.make_pretrain_step()
    pbase = [Input(n, s, "state", init) for n, s, init in lm.base_spec()]
    pm = [Input(f"m_{n}", s, "state", "zeros") for n, s, _ in lm.base_spec()]
    pv = [Input(f"v_{n}", s, "state", "zeros") for n, s, _ in lm.base_spec()]
    pinputs = (pbase + pm + pv + [
        Input("tokens", (16, lm.SEQ, lm.VOCAB), "data"),
        Input("targets", (16, lm.SEQ, lm.VOCAB), "data"),
        _scalar("lr"), _scalar("grad_clip"), _scalar("bc1"), _scalar("bc2"),
    ])
    arts.append(ArtifactDef(
        name="lm_pretrain_b16", fn=pstep, inputs=pinputs,
        state_count=3 * len(lm.base_spec()),
        meta={"family": "lm_pretrain", "batch": 16,
              "vocab": lm.VOCAB, "seq": lm.SEQ},
    ))
    estep = lm.make_eval_step()
    einputs = (_lm_base_inputs() +
               [Input(n, s, "frozen", init) for n, s, init in lspec] + [
        Input("tokens", (LM_EVAL_BATCH, lm.SEQ, lm.VOCAB), "data"),
        Input("targets", (LM_EVAL_BATCH, lm.SEQ, lm.VOCAB), "data"),
        Input("rank_mask", (lm.R_MAX,), "data"),
        _scalar("bits"), _scalar("lora_scale"),
    ])
    arts.append(ArtifactDef(
        name="lm_eval", fn=estep, inputs=einputs, state_count=0,
        meta={"family": "lm_eval", "batch": LM_EVAL_BATCH,
              "vocab": lm.VOCAB, "seq": lm.SEQ},
    ))
    for tag, block in (("default", (32, 64, 32)),) + tuple(
            (f"mm{bm}x{bn}x{bk}", (bm, bn, bk))
            for bm, bn, bk in ((16, 16, 16), (32, 32, 32), (64, 64, 64))):
        dstep = lm.make_decode_step(block)
        dinputs = (_lm_base_inputs() +
                   [Input(n, s, "frozen", init) for n, s, init in lspec] + [
            Input("tokens", (1, lm.SEQ, lm.VOCAB), "data"),
            Input("rank_mask", (lm.R_MAX,), "data"),
            _scalar("bits"), _scalar("lora_scale"),
        ])
        arts.append(ArtifactDef(
            name=f"lm_decode_{tag}", fn=dstep, inputs=dinputs, state_count=0,
            meta={"family": "lm_decode", "tile": list(block),
                  "vocab": lm.VOCAB, "seq": lm.SEQ},
        ))
    return arts


def micro_artifacts():
    arts = []
    for name, (fn, specs, meta) in micro.all_cases().items():
        inputs = [Input(f"in{i}", tuple(int(d) for d in s.shape), "data")
                  for i, s in enumerate(specs)]
        meta = dict(meta)
        meta["family"] = "micro"
        arts.append(ArtifactDef(name=name, fn=fn, inputs=inputs,
                                state_count=0, meta=meta))
    return arts


def all_artifacts():
    return cnn_artifacts() + lm_artifacts() + micro_artifacts()
