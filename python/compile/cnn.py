"""Layer-2: ResNet-style CNNs with DoReFa QAT (paper Table 1 track).

Stand-ins for ResNet20/32/50 at laptop scale (DESIGN.md substitution table):
three sizes S/M/L of a norm-free residual CNN over 16x16x3 synthetic images,
with every non-boundary conv fake-quantized by the DoReFa Pallas kernel
(bit-widths are runtime scalars) and activations quantized per DoReFa's
clip-[0,1] scheme.

Train step = SGD with momentum, decoupled weight decay, and global-norm
gradient clipping — the hyperparameters the HAQA agent tunes (Appendix D's
ResNet search space).  Batch size is shape-affecting, so `aot.py` emits
variants at batch in {32, 64, 128, 256}.

The graph convention consumed by the Rust runtime (see artifact manifest):
    inputs  = [state..., data..., scalars...]
    outputs = (state'..., metrics...)
where state = params ++ velocities for the train step.
"""

import jax
import jax.numpy as jnp

from .kernels.dorefa import dorefa_weight_quant, dorefa_act_quant

NUM_CLASSES = 10
IMG = 16

SIZES = {
    # name: (stage_channels, blocks_per_stage)  — S/M/L widths mirror the
    # relative capacities of ResNet20/32/50 in the paper.
    "cnn_s": ((8, 16, 24), 1),
    "cnn_m": ((12, 24, 36), 1),
    "cnn_l": ((16, 32, 48), 2),
}


def param_spec(size_name):
    """Ordered list of (name, shape, init, quantized) for a model size."""
    channels, blocks = SIZES[size_name]
    spec = []
    c_in = 3
    spec.append((f"stem", (3, 3, 3, channels[0]), "he", False))
    spec.append((f"stem_g", (channels[0],), "ones", False))
    c_in = channels[0]
    for si, c_out in enumerate(channels):
        for bi in range(blocks):
            pfx = f"s{si}b{bi}"
            spec.append((f"{pfx}_c1", (3, 3, c_in, c_out), "he", True))
            spec.append((f"{pfx}_g1", (c_out,), "ones", False))
            spec.append((f"{pfx}_c2", (3, 3, c_out, c_out), "he", True))
            spec.append((f"{pfx}_g2", (c_out,), "ones", False))
            if c_in != c_out:
                spec.append((f"{pfx}_proj", (1, 1, c_in, c_out), "he", True))
            c_in = c_out
    spec.append(("head_w", (channels[-1], NUM_CLASSES), "he", False))
    spec.append(("head_b", (NUM_CLASSES,), "zeros", False))
    return spec


def _conv(x, w, stride):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _channel_rms(x, gain, eps=1e-5):
    """Stateless normalization over the channel axis (BN stand-in: QAT-safe,
    no running statistics to thread through the AOT boundary)."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gain


def forward(size_name, params, x, wbits, abits):
    """Logits (B, 10).  params is a dict name->array."""
    channels, blocks = SIZES[size_name]

    def qw(w):
        return dorefa_weight_quant(w, wbits)

    def qa(a):
        return dorefa_act_quant(jax.nn.relu(a), abits)

    h = _conv(x, params["stem"], 1)
    h = _channel_rms(h, params["stem_g"])
    h = qa(h)
    c_in = channels[0]
    for si, c_out in enumerate(channels):
        for bi in range(blocks):
            pfx = f"s{si}b{bi}"
            stride = 2 if (si > 0 and bi == 0) else 1
            y = _conv(h, qw(params[f"{pfx}_c1"]), stride)
            y = _channel_rms(y, params[f"{pfx}_g1"])
            y = qa(y)
            y = _conv(y, qw(params[f"{pfx}_c2"]), 1)
            y = _channel_rms(y, params[f"{pfx}_g2"])
            if c_in != c_out:
                skip = _conv(h, qw(params[f"{pfx}_proj"]), stride)
            elif stride != 1:
                skip = h[:, ::stride, ::stride, :]
            else:
                skip = h
            h = qa(y + skip)
            c_in = c_out
    h = jnp.mean(h, axis=(1, 2))  # global average pool (B, C)
    return h @ params["head_w"] + params["head_b"]


def _loss_acc(logits, y_onehot):
    logz = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.sum(y_onehot * logz, axis=-1))
    picked = jnp.sum(y_onehot * logits, axis=-1)
    acc = jnp.mean((picked >= jnp.max(logits, axis=-1) - 1e-6).astype(jnp.float32))
    return loss, acc


def make_train_step(size_name):
    """Returns fn(params..., vel..., x, y, lr, momentum, wd, clip, wbits, abits)
    -> (params'..., vel'..., loss, acc)."""
    spec = param_spec(size_name)
    names = [s[0] for s in spec]
    n = len(names)

    def step(*args):
        params = dict(zip(names, args[:n]))
        vels = dict(zip(names, args[n:2 * n]))
        x, y, lr, momentum, wd, clip, wbits, abits = args[2 * n:]

        def loss_fn(p):
            logits = forward(size_name, p, x, wbits, abits)
            loss, acc = _loss_acc(logits, y)
            return loss, acc

        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)

        # Global-norm gradient clipping (max_grad_norm hyperparameter).
        gnorm = jnp.sqrt(
            sum(jnp.sum(g * g) for g in grads.values()) + 1e-12)
        scale = jnp.minimum(1.0, clip / gnorm)

        new_p, new_v = [], []
        for name in names:
            g = grads[name] * scale + wd * params[name]
            v = momentum * vels[name] + g
            new_v.append(v)
            new_p.append(params[name] - lr * v)
        return tuple(new_p) + tuple(new_v) + (loss, acc)

    return step, spec


def make_eval_step(size_name):
    """Returns fn(params..., x, y, wbits, abits) -> (loss, acc)."""
    spec = param_spec(size_name)
    names = [s[0] for s in spec]
    n = len(names)

    def step(*args):
        params = dict(zip(names, args[:n]))
        x, y, wbits, abits = args[n:]
        logits = forward(size_name, params, x, wbits, abits)
        loss, acc = _loss_acc(logits, y)
        return (loss, acc)

    return step, spec
