"""Layer-2: standalone per-kernel computations at the paper's Table 3 shapes.

Each function wraps exactly one Pallas kernel so the Rust deploy tuner and
criterion-style benches can measure real PJRT-CPU latency per kernel, and so
tile-schedule variants of the dominant matmul can be compared against each
other (the artifact-level analogue of the paper's per-kernel CUDA exec-config
search).

Shape mapping from the paper's [N, B, H] notation (Table 3):
  Softmax [1024, b, 32]  -> rows = 32*b softmaxed over 1024
  SiLU    [11008, b, 1]  -> (b, 11008) gate * up
  RMSNorm [4096, b, 1]   -> (b, 4096)
  RoPE    [128, b, 1]    -> sequence of length b, head dim 128
  MatMul  [2048, b, 2048]-> (b, 2048) @ (2048, 2048)
"""

import jax
import jax.numpy as jnp

from .kernels import softmax, silu_gate, rmsnorm, rope, qmatmul
from .kernels.rope import rope_tables

# (kernel, paper_size_label, builder) — builder returns (fn, [input specs])
F32 = jnp.float32


def _spec(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), F32)


def softmax_case(batch):
    rows = 32 * batch

    def fn(x):
        return (softmax(x),)

    return fn, [_spec(rows, 1024)]


def silu_case(batch):
    def fn(g, u):
        return (silu_gate(g, u),)

    return fn, [_spec(batch, 11008), _spec(batch, 11008)]


def rmsnorm_case(batch):
    def fn(x, g):
        return (rmsnorm(x, g),)

    return fn, [_spec(batch, 4096), _spec(4096)]


def rope_case(batch):
    cos, sin = rope_tables(batch, 128)

    def fn(x):
        return (rope(x, cos, sin),)

    return fn, [_spec(batch, 128)]


def matmul_case(batch, block=(128, 256, 256)):
    def fn(x, w):
        return (qmatmul(x, w, block),)

    return fn, [_spec(batch, 2048), _spec(2048, 2048)]


BATCHES = (1, 64, 128)

# Tile-schedule variants for the dominant kernel at the mid size (b=64):
# the real-artifact half of the deployment tuning demo.
MATMUL_TILE_VARIANTS = {
    "t32": (32, 32, 32),
    "t64": (64, 64, 64),
    "t128": (128, 128, 128),
    "t64w": (64, 128, 64),
}


def all_cases():
    """name -> (fn, input_specs, meta) for every microbench artifact."""
    cases = {}
    for b in BATCHES:
        fn, specs = softmax_case(b)
        cases[f"micro_softmax_b{b}"] = (fn, specs,
                                        {"kernel": "softmax", "batch": b})
        fn, specs = silu_case(b)
        cases[f"micro_silu_b{b}"] = (fn, specs,
                                     {"kernel": "silu", "batch": b})
        fn, specs = rmsnorm_case(b)
        cases[f"micro_rmsnorm_b{b}"] = (fn, specs,
                                        {"kernel": "rmsnorm", "batch": b})
        fn, specs = rope_case(b)
        cases[f"micro_rope_b{b}"] = (fn, specs,
                                     {"kernel": "rope", "batch": b})
        fn, specs = matmul_case(b)
        cases[f"micro_matmul_b{b}"] = (
            fn, specs,
            {"kernel": "matmul", "batch": b, "tile": [128, 256, 256]})
    for tag, block in MATMUL_TILE_VARIANTS.items():
        fn, specs = matmul_case(64, block)
        cases[f"micro_matmul_b64_{tag}"] = (
            fn, specs,
            {"kernel": "matmul", "batch": 64, "tile": list(block)})
    return cases
