"""Layer-2 model graphs: shape contracts, gradient flow, training sanity.

These run the jitted functions directly (pre-AOT) — the same callables that
aot.py lowers — so a failure here localizes to L2 rather than the HLO
interchange.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import cnn, lm, model


def _init_tensor(shape, init, rng):
    if init == "zeros" or init == "none":
        return jnp.zeros(shape, jnp.float32)
    if init == "ones":
        return jnp.ones(shape, jnp.float32)
    if init == "embed":
        return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * 0.02)
    if init == "lora_a":
        scale = 1.0 / np.sqrt(shape[0])
        return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * scale)
    # he
    fan_in = int(np.prod(shape[:-1])) if len(shape) >= 2 else 1
    scale = np.sqrt(2.0 / max(fan_in, 1))
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * scale)


def art_by_name(name):
    for a in model.all_artifacts():
        if a.name == name:
            return a
    raise KeyError(name)


def build_inputs(art, rng, scalars=None):
    scalars = scalars or {}
    out = []
    for inp in art.inputs:
        if inp.role == "scalar":
            out.append(jnp.float32(scalars.get(inp.name, 1.0)))
        elif inp.role in ("state", "frozen"):
            out.append(_init_tensor(inp.shape, inp.init, rng))
        else:  # data
            if inp.name == "rank_mask":
                out.append(jnp.ones(inp.shape, jnp.float32))
            elif inp.name in ("y", "targets", "tokens"):
                # one-hot-ish rows
                t = np.zeros(inp.shape, np.float32)
                idx = rng.integers(0, inp.shape[-1], size=inp.shape[:-1])
                np.put_along_axis(t, idx[..., None], 1.0, axis=-1)
                out.append(jnp.asarray(t))
            else:
                out.append(jnp.asarray(
                    rng.random(inp.shape, dtype=np.float32)))
    return out


CNN_SCALARS = dict(lr=0.05, momentum=0.9, weight_decay=1e-4, grad_clip=1.0,
                   wbits=8.0, abits=8.0)
LM_SCALARS = dict(lr=3e-3, weight_decay=0.0, grad_clip=1.0, bits=8.0,
                  lora_scale=0.5, dropout_p=0.0, bc1=1.0, bc2=1.0)


def test_all_artifacts_output_shapes_declared():
    for art in model.all_artifacts():
        shapes = art.output_shapes()
        assert len(shapes) >= 1, art.name
        if art.state_count:
            ins = [tuple(i.shape) for i in art.inputs if i.role == "state"]
            assert shapes[: art.state_count] == ins, art.name


def test_cnn_train_step_decreases_loss():
    art = art_by_name("cnn_s_train_b32")
    rng = np.random.default_rng(0)
    args = build_inputs(art, rng, CNN_SCALARS)
    step = jax.jit(art.fn)
    n_state = art.state_count
    losses = []
    for _ in range(8):
        outs = step(*args)
        losses.append(float(outs[-2]))
        args[:n_state] = outs[:n_state]
    assert losses[-1] < losses[0], losses


def test_cnn_eval_matches_train_metrics_shape():
    art = art_by_name("cnn_s_eval")
    rng = np.random.default_rng(1)
    args = build_inputs(art, rng, CNN_SCALARS)
    loss, acc = jax.jit(art.fn)(*args)
    assert loss.shape == () and acc.shape == ()
    assert 0.0 <= float(acc) <= 1.0


def test_cnn_low_bits_changes_logits():
    art = art_by_name("cnn_s_eval")
    rng = np.random.default_rng(2)
    args = build_inputs(art, rng, CNN_SCALARS)
    names = [i.name for i in art.inputs]
    iw = names.index("wbits")
    ia = names.index("abits")
    f = jax.jit(art.fn)
    loss8, _ = f(*args)
    args[iw] = jnp.float32(2.0)
    args[ia] = jnp.float32(2.0)
    loss2, _ = f(*args)
    assert not np.isclose(float(loss8), float(loss2)), (loss8, loss2)


def test_lm_train_state_threading_reduces_loss():
    art = art_by_name("lm_train_b8")
    rng = np.random.default_rng(4)
    args = build_inputs(art, rng, LM_SCALARS)
    step = jax.jit(art.fn)
    roles = [i.role for i in art.inputs]
    state_idx = [k for k, r in enumerate(roles) if r == "state"]
    assert len(state_idx) == art.state_count
    losses = []
    for _ in range(12):
        outs = step(*args)
        losses.append(float(outs[-1]))
        for j, k in enumerate(state_idx):
            args[k] = outs[j]
    assert losses[-1] < losses[0], losses


def test_lm_rank_mask_zero_rank_means_no_adapter():
    art = art_by_name("lm_eval")
    rng = np.random.default_rng(5)
    args = build_inputs(art, rng, LM_SCALARS)
    names = [i.name for i in art.inputs]
    f = jax.jit(art.fn)
    im = names.index("rank_mask")
    # Random lora B is zero-initialized per spec, so adapters are inert either
    # way; perturb B to make the mask matter.
    for k, inp in enumerate(art.inputs):
        if inp.name.endswith("_b") and inp.role == "frozen" and "lora" not in inp.name:
            pass
    bidx = [k for k, i in enumerate(art.inputs)
            if i.role == "frozen" and i.name.endswith(("_q_b", "_v_b"))]
    for k in bidx:
        args[k] = jnp.asarray(
            rng.standard_normal(art.inputs[k].shape).astype(np.float32) * 0.1)
    loss_full, _ = f(*args)
    args[im] = jnp.zeros_like(args[im])
    loss_zero, _ = f(*args)
    assert not np.isclose(float(loss_full), float(loss_zero))


def test_lm_decode_logits_shape_and_tile_invariance():
    rng = np.random.default_rng(6)
    art_a = art_by_name("lm_decode_default")
    art_b = art_by_name("lm_decode_mm64x64x64")
    args = build_inputs(art_a, rng, LM_SCALARS)
    la = jax.jit(art_a.fn)(*args)[0]
    lb = jax.jit(art_b.fn)(*args)[0]
    assert la.shape == (lm.VOCAB,)
    np.testing.assert_allclose(la, lb, atol=1e-4, rtol=1e-4)


def test_manifest_roles_are_complete():
    for art in model.all_artifacts():
        for inp in art.inputs:
            assert inp.role in ("state", "frozen", "data", "scalar"), art.name
        n_state = sum(1 for i in art.inputs if i.role == "state")
        assert n_state == art.state_count, art.name


@pytest.mark.parametrize("size", list(cnn.SIZES))
def test_cnn_param_spec_consistency(size):
    spec = cnn.param_spec(size)
    names = [s[0] for s in spec]
    assert len(names) == len(set(names))
    step, spec2 = cnn.make_train_step(size)
    assert spec == spec2
