"""Pallas kernels (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes and block sizes; assert_allclose against ref.py.
This is the CORE correctness signal for Layer 1.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

import compile.kernels as K
from compile.kernels import ref
from compile.kernels.rope import rope_tables

SETTINGS = dict(max_examples=25, deadline=None)


def arr(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


@st.composite
def shape_and_block(draw, max_rows=64, max_cols=96):
    rows = draw(st.integers(1, max_rows))
    cols = draw(st.integers(1, max_cols))
    block = draw(st.one_of(st.none(), st.integers(1, max_rows + 8)))
    seed = draw(st.integers(0, 2**31 - 1))
    return rows, cols, block, seed


# ---------------------------------------------------------------------------
# dorefa
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(shape_and_block(), st.sampled_from([2.0, 4.0, 8.0, 16.0]))
def test_dorefa_weight_matches_ref(sb, kbits):
    rows, cols, block, seed = sb
    rng = np.random.default_rng(seed)
    w = arr(rng, rows, cols)
    got = K.dorefa_weight_quant(w, jnp.float32(kbits), block)
    want = ref.dorefa_weight_quant(w, jnp.float32(kbits))
    np.testing.assert_allclose(got, want, atol=1e-6)


@settings(**SETTINGS)
@given(shape_and_block(), st.sampled_from([2.0, 4.0, 8.0]))
def test_dorefa_act_matches_ref(sb, kbits):
    rows, cols, block, seed = sb
    rng = np.random.default_rng(seed)
    a = arr(rng, rows, cols)
    got = K.dorefa_act_quant(a, jnp.float32(kbits), block)
    want = ref.dorefa_act_quant(a, jnp.float32(kbits))
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_dorefa_weight_levels_and_range():
    rng = np.random.default_rng(0)
    w = arr(rng, 32, 32)
    q = np.asarray(K.dorefa_weight_quant(w, jnp.float32(2.0)))
    # k=2 -> 4 levels in [-1, 1]
    assert np.all(q >= -1.0 - 1e-6) and np.all(q <= 1.0 + 1e-6)
    assert len(np.unique(np.round(q, 5))) <= 4


def test_dorefa_act_is_clipped():
    rng = np.random.default_rng(1)
    a = arr(rng, 16, 16) * 10.0
    q = np.asarray(K.dorefa_act_quant(a, jnp.float32(4.0)))
    assert np.all(q >= 0.0) and np.all(q <= 1.0)


def test_dorefa_ste_gradient_passthrough():
    rng = np.random.default_rng(2)
    x = arr(rng, 8, 8) * 0.4 + 0.5  # interior of [0,1]

    def f(x):
        return jnp.sum(K.quantize_levels(x, jnp.float32(15.0)))

    g = jax.grad(f)(x)
    np.testing.assert_allclose(g, np.ones_like(g), atol=1e-6)


def test_dorefa_high_bits_near_identity():
    rng = np.random.default_rng(3)
    w = arr(rng, 16, 16)
    q16 = np.asarray(K.dorefa_weight_quant(w, jnp.float32(16.0)))
    qref = np.asarray(ref.dorefa_weight_quant(w, jnp.float32(24.0)))
    # High-k quantization ~ the tanh-normalized weights themselves.
    np.testing.assert_allclose(q16, qref, atol=1e-3)


# ---------------------------------------------------------------------------
# qmatmul
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    st.integers(1, 48), st.integers(1, 48), st.integers(1, 48),
    st.tuples(st.integers(1, 64), st.integers(1, 64), st.integers(1, 64)),
    st.integers(0, 2**31 - 1),
)
def test_qmatmul_matches_ref(m, k, n, block, seed):
    rng = np.random.default_rng(seed)
    x = arr(rng, m, k)
    w = arr(rng, k, n)
    got = K.qmatmul(x, w, block)
    np.testing.assert_allclose(got, ref.qmatmul(x, w), atol=1e-4, rtol=1e-4)


def test_qmatmul_tile_bigger_than_shape():
    rng = np.random.default_rng(4)
    x, w = arr(rng, 3, 5), arr(rng, 5, 2)
    got = K.qmatmul(x, w, (128, 128, 128))
    np.testing.assert_allclose(got, ref.qmatmul(x, w), atol=1e-5)


# ---------------------------------------------------------------------------
# softmax / rmsnorm / silu / rope
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(shape_and_block())
def test_softmax_matches_ref(sb):
    rows, cols, block, seed = sb
    rng = np.random.default_rng(seed)
    x = arr(rng, rows, cols) * 4.0
    got = K.softmax(x, block)
    np.testing.assert_allclose(got, ref.softmax(x), atol=1e-6)


def test_softmax_rows_sum_to_one():
    rng = np.random.default_rng(5)
    x = arr(rng, 20, 33) * 50.0  # large logits: stability check
    s = np.asarray(K.softmax(x)).sum(axis=-1)
    np.testing.assert_allclose(s, np.ones(20), atol=1e-5)


@settings(**SETTINGS)
@given(shape_and_block())
def test_rmsnorm_matches_ref(sb):
    rows, cols, block, seed = sb
    rng = np.random.default_rng(seed)
    x = arr(rng, rows, cols)
    g = arr(rng, cols)
    got = K.rmsnorm(x, g, block)
    np.testing.assert_allclose(got, ref.rmsnorm(x, g), atol=1e-5)


@settings(**SETTINGS)
@given(shape_and_block())
def test_silu_matches_ref(sb):
    rows, cols, block, seed = sb
    rng = np.random.default_rng(seed)
    g = arr(rng, rows, cols)
    u = arr(rng, rows, cols)
    got = K.silu_gate(g, u, block)
    np.testing.assert_allclose(got, ref.silu_gate(g, u), atol=1e-6)


@settings(**SETTINGS)
@given(st.integers(1, 48), st.sampled_from([2, 4, 8, 16, 64, 128]),
       st.one_of(st.none(), st.integers(1, 64)), st.integers(0, 2**31 - 1))
def test_rope_matches_ref(s, d, block, seed):
    rng = np.random.default_rng(seed)
    x = arr(rng, s, d)
    cos, sin = rope_tables(s, d)
    got = K.rope(x, cos, sin, block)
    np.testing.assert_allclose(got, ref.rope(x, cos, sin), atol=1e-5)


def test_rope_preserves_norm():
    # Rotation preserves per-pair L2 norm.
    rng = np.random.default_rng(6)
    x = arr(rng, 12, 16)
    cos, sin = rope_tables(12, 16)
    y = np.asarray(K.rope(x, cos, sin))
    nx = np.linalg.norm(np.asarray(x), axis=-1)
    ny = np.linalg.norm(y, axis=-1)
    np.testing.assert_allclose(nx, ny, rtol=1e-5)


def test_rope_position_zero_is_identity():
    rng = np.random.default_rng(7)
    x = arr(rng, 4, 8)
    cos, sin = rope_tables(4, 8)
    y = np.asarray(K.rope(x, cos, sin))
    np.testing.assert_allclose(y[0], np.asarray(x)[0], atol=1e-6)
