//! CLI lifecycle smoke suite: drive the built `haqa` binary end to end
//! through every long-lived surface — fleet, scenario generation, the
//! cache server, the device server, and the resident fleet daemon — in
//! isolated temp dirs, asserting exit codes and the stable output tokens
//! CI greps (never timings or full lines).
//!
//! Everything here is std-only subprocess plumbing: `CARGO_BIN_EXE_haqa`
//! locates the binary Cargo built for this test run, each invocation
//! scrubs inherited `HAQA_*` knobs so an operator's environment cannot
//! leak into an assertion, and servers bind port 0 with their actual
//! address parsed from the announced "listening on" line.

use std::collections::HashSet;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Output, Stdio};
use std::time::{Duration, Instant};

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_haqa")
}

/// A temp dir removed on drop, unique per (test, pid).
struct TempDir(PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        let dir = std::env::temp_dir().join(format!("haqa_cli_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        TempDir(dir)
    }

    fn path(&self) -> &Path {
        &self.0
    }

    fn file(&self, name: &str, contents: &str) -> String {
        let p = self.0.join(name);
        std::fs::write(&p, contents).unwrap();
        p.to_string_lossy().into_owned()
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// Build a `haqa` invocation with every inherited `HAQA_*` knob scrubbed —
/// the suite's assertions must not depend on the operator's environment.
fn cmd(args: &[&str]) -> Command {
    let mut c = Command::new(bin());
    for (k, _) in std::env::vars() {
        if k.starts_with("HAQA_") {
            c.env_remove(k);
        }
    }
    c.args(args);
    c
}

fn run(args: &[&str]) -> Output {
    cmd(args).output().unwrap()
}

fn run_env(args: &[&str], env: &[(&str, &str)]) -> Output {
    let mut c = cmd(args);
    for (k, v) in env {
        c.env(k, v);
    }
    c.output().unwrap()
}

fn stdout(o: &Output) -> String {
    String::from_utf8_lossy(&o.stdout).into_owned()
}

fn stderr(o: &Output) -> String {
    String::from_utf8_lossy(&o.stderr).into_owned()
}

/// A long-lived `haqa` server child, killed (SIGKILL) on drop.  `addr` is
/// parsed from the "… listening on HOST:PORT" line it announces, so every
/// test binds port 0 and runs in parallel without port collisions.
struct Server {
    child: Child,
    addr: String,
}

impl Server {
    fn spawn(args: &[&str]) -> Server {
        let mut child = cmd(args)
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .unwrap();
        let out = child.stdout.take().unwrap();
        let mut lines = BufReader::new(out).lines();
        let deadline = Instant::now() + Duration::from_secs(30);
        let addr = loop {
            assert!(Instant::now() < deadline, "server never announced an address: {args:?}");
            let line = lines.next().expect("server stdout closed before announcing").unwrap();
            if let Some(rest) = line.split("listening on ").nth(1) {
                // The device server appends "(profiles: …)" — keep the
                // first whitespace-delimited token only.
                break rest.split_whitespace().next().unwrap().to_string();
            }
        };
        Server { child, addr }
    }

    /// Wait (bounded) for the child to exit on its own — used after a
    /// graceful drain, where exit code 0 is part of the contract.
    fn wait_exit(&mut self, within: Duration) -> std::process::ExitStatus {
        let deadline = Instant::now() + within;
        loop {
            if let Some(status) = self.child.try_wait().unwrap() {
                return status;
            }
            assert!(Instant::now() < deadline, "server did not exit within {within:?}");
            std::thread::sleep(Duration::from_millis(50));
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// One JSONL round-trip on a fresh connection — the raw-wire client the
/// docs promise `nc` users works.
fn wire(addr: &str, request: &str) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.write_all(request.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();
    let mut reply = String::new();
    BufReader::new(stream).read_line(&mut reply).unwrap();
    reply.trim().to_string()
}

/// A tiny all-simulated kernel batch: fast, deterministic, cache-friendly.
fn small_batch(prefix: &str) -> String {
    format!(
        r#"{{"scenarios": [
  {{"name": "{prefix}_matmul", "task": "kernel", "kernel": "matmul:64", "optimizer": "random", "budget": 3, "seed": 11}},
  {{"name": "{prefix}_softmax", "task": "kernel", "kernel": "softmax:128", "optimizer": "random", "budget": 3, "seed": 12}}
]}}"#
    )
}

/// The per-scenario score lines of a fleet/submit transcript — the rows CI
/// diffs between `haqa fleet` and `haqa submit` for bit-identity (rendered
/// through the same `{:.4}` format, so equal text means equal scores).
fn score_lines(text: &str) -> Vec<String> {
    text.lines()
        .filter(|l| l.contains(": best "))
        .map(|l| l.to_string())
        .collect()
}

/// Find any `fleet_state.jsonl` under a serve state root (the daemon
/// nests them by client slug and batch hash).
fn find_journal(root: &Path) -> Option<PathBuf> {
    let entries = std::fs::read_dir(root).ok()?;
    for entry in entries.flatten() {
        let p = entry.path();
        if p.is_dir() {
            if let Some(found) = find_journal(&p) {
                return Some(found);
            }
        } else if p.file_name() == Some(std::ffi::OsStr::new("fleet_state.jsonl")) {
            return Some(p);
        }
    }
    None
}

// ---------------------------------------------------------------- help --

#[test]
fn help_and_unknown_subcommand_exit_codes() {
    let help = run(&["help"]);
    assert!(help.status.success());
    assert!(stdout(&help).contains("haqa serve"), "help must list the daemon");
    assert!(stdout(&help).contains("haqa submit"));

    let bare = run(&[]);
    assert!(bare.status.success(), "bare `haqa` prints help and exits 0");

    let unknown = run(&["frobnicate"]);
    assert_eq!(unknown.status.code(), Some(1));
    assert!(
        stderr(&unknown).contains("unknown subcommand 'frobnicate'"),
        "{}",
        stderr(&unknown)
    );
}

// --------------------------------------------------------------- fleet --

#[test]
fn fleet_runs_a_batch_and_prints_the_aggregate_lines() {
    let dir = TempDir::new("fleet");
    let batch = dir.file("batch.json", &small_batch("smoke"));
    let out = run(&["fleet", &batch, "--workers", "2"]);
    assert!(out.status.success(), "stderr: {}", stderr(&out));
    let text = stdout(&out);
    assert_eq!(score_lines(&text).len(), 2, "one score line per scenario:\n{text}");
    assert!(text.contains("fleet: 2 scenarios"), "{text}");
    assert!(text.contains("evaluation cache:"), "{text}");
}

#[test]
fn fleet_hard_errors_name_the_cause() {
    let dir = TempDir::new("fleet_err");
    let batch = dir.file("batch.json", &small_batch("err"));

    // Garbage env knob: hard error naming the variable, not a silent default.
    let out = run_env(&["fleet", &batch], &[("HAQA_WORKERS", "three")]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("HAQA_WORKERS"), "{}", stderr(&out));

    // Malformed batch file: named in the error.
    let bad = dir.file("bad.json", "{ this is not json");
    let out = run(&["fleet", &bad]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("bad.json"), "{}", stderr(&out));

    // Missing positional: usage string.
    let out = run(&["fleet"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("usage: haqa fleet"), "{}", stderr(&out));
}

// ----------------------------------------------------------- scenarios --

#[test]
fn scenarios_gen_is_byte_deterministic_and_feeds_fleet() {
    let dir = TempDir::new("gen");
    let a = dir.path().join("a.json").to_string_lossy().into_owned();
    let b = dir.path().join("b.json").to_string_lossy().into_owned();
    for out_path in [&a, &b] {
        let out = run(&["scenarios", "gen", "--count", "4", "--seed", "9", "--out", out_path]);
        assert!(out.status.success(), "stderr: {}", stderr(&out));
    }
    let bytes_a = std::fs::read(&a).unwrap();
    assert!(!bytes_a.is_empty());
    assert_eq!(bytes_a, std::fs::read(&b).unwrap(), "generation must be byte-stable");

    let out = run(&["fleet", &a, "--workers", "2", "--quiet"]);
    assert!(out.status.success(), "generated batch must run: {}", stderr(&out));
    assert!(stdout(&out).contains("fleet: 4 scenarios"), "{}", stdout(&out));
}

// --------------------------------------------------------------- cache --

#[test]
fn cache_journal_compacts_and_serves_a_remote_tier() {
    let dir = TempDir::new("cache");
    let batch = dir.file("batch.json", &small_batch("cache"));
    let cache_dir = dir.path().join("cache").to_string_lossy().into_owned();

    // Two journal-backed fleets: the second both hits the warm entries and
    // gives compact duplicate generations to drop.
    for _ in 0..2 {
        let out = run(&["fleet", &batch, "--cache-dir", &cache_dir, "--quiet"]);
        assert!(out.status.success(), "stderr: {}", stderr(&out));
    }
    let out = run(&["cache", "compact", "--cache-dir", &cache_dir]);
    assert!(out.status.success(), "stderr: {}", stderr(&out));
    assert!(stdout(&out).contains("compacted"), "{}", stdout(&out));

    // A shared cache server over the compacted journal: the fleet's remote
    // tier line must show traffic.
    let server = Server::spawn(&["cache", "serve", "--addr", "127.0.0.1:0", "--cache-dir", &cache_dir]);
    let out = run(&["fleet", &batch, "--cache-addr", &server.addr, "--quiet"]);
    assert!(out.status.success(), "stderr: {}", stderr(&out));
    assert!(stdout(&out).contains("remote cache:"), "{}", stdout(&out));
}

// -------------------------------------------------------------- device --

#[test]
fn device_server_answers_ping_and_closed_ports_fail_fast() {
    let server = Server::spawn(&["device", "serve", "--addr", "127.0.0.1:0"]);
    let out = run(&["device", "ping", "--addr", &server.addr]);
    assert!(out.status.success(), "stderr: {}", stderr(&out));
    assert!(stdout(&out).contains("\"ok\""), "{}", stdout(&out));

    // Port 1 is never listening: a connection error, not a hang.
    let out = run(&["device", "ping", "--addr", "127.0.0.1:1"]);
    assert_eq!(out.status.code(), Some(1));
}

// --------------------------------------------------------------- serve --

#[test]
fn serve_submit_lifecycle_is_bit_identical_and_warm_on_resubmission() {
    let dir = TempDir::new("serve");
    let batch = dir.file("batch.json", &small_batch("serve"));
    let state_dir = dir.path().join("state").to_string_lossy().into_owned();

    // Ground truth: the same batch through `haqa fleet`.
    let fleet = run(&["fleet", &batch, "--workers", "2"]);
    assert!(fleet.status.success(), "stderr: {}", stderr(&fleet));
    let fleet_scores: HashSet<String> = score_lines(&stdout(&fleet)).into_iter().collect();
    assert_eq!(fleet_scores.len(), 2);

    let mut server = Server::spawn(&["serve", "--addr", "127.0.0.1:0", "--workers", "2", "--state-dir", &state_dir]);

    // Cold submission: same score lines as the fleet, misses > 0.
    let cold = run(&["submit", &batch, "--addr", &server.addr, "--client", "smoke"]);
    assert!(cold.status.success(), "stderr: {}", stderr(&cold));
    let cold_scores: HashSet<String> = score_lines(&stdout(&cold)).into_iter().collect();
    assert_eq!(cold_scores, fleet_scores, "served scores must match `haqa fleet`:\n{}", stdout(&cold));

    // Warm resubmission: the daemon's resident cache serves every
    // evaluation — the per-submission cache line reports zero misses.
    let warm = run(&["submit", &batch, "--addr", &server.addr, "--client", "smoke"]);
    assert!(warm.status.success(), "stderr: {}", stderr(&warm));
    let warm_text = stdout(&warm);
    let warm_scores: HashSet<String> = score_lines(&warm_text).into_iter().collect();
    assert_eq!(warm_scores, fleet_scores, "warm scores drifted:\n{warm_text}");
    let cache_line = warm_text
        .lines()
        .find(|l| l.starts_with("evaluation cache:"))
        .unwrap_or_else(|| panic!("no cache line:\n{warm_text}"));
    assert!(cache_line.contains("/ 0 misses"), "resubmission re-evaluated: {cache_line}");

    // Raw-wire lifecycle on the same daemon: status, a cancel of an
    // unknown job (typed error, connection-level success), then drain.
    let status = wire(&server.addr, "{\"op\":\"status\"}");
    assert!(status.contains("\"service\":\"haqa-serve\""), "{status}");
    let cancel = wire(&server.addr, "{\"op\":\"cancel\",\"job\":\"j999\"}");
    assert!(cancel.contains("\"ok\":false"), "{cancel}");
    let drain = wire(&server.addr, "{\"op\":\"drain\"}");
    assert!(drain.contains("\"draining\":true"), "{drain}");

    // The drained daemon exits 0 on its own and refuses nothing silently:
    // a post-drain submission fails with a typed busy error.
    let refused = run(&["submit", &batch, "--addr", &server.addr, "--client", "late"]);
    assert_eq!(refused.status.code(), Some(1));
    let status = server.wait_exit(Duration::from_secs(30));
    assert!(status.success(), "drained daemon must exit 0, got {status:?}");
}

#[test]
fn killed_daemon_resumes_from_its_scoped_journal() {
    let dir = TempDir::new("serve_kill");
    // A slow backend stretches the job so the kill lands mid-flight; the
    // journal record for the first settled scenario is already durable
    // (eager per-settle flushes).
    let batch = dir.file(
        "batch.json",
        r#"{"scenarios": [
  {"name": "kill_a", "task": "kernel", "kernel": "matmul:64", "optimizer": "random", "budget": 2, "seed": 3, "backend": "simulated-slow:150"},
  {"name": "kill_b", "task": "kernel", "kernel": "softmax:128", "optimizer": "random", "budget": 2, "seed": 4, "backend": "simulated-slow:150"},
  {"name": "kill_c", "task": "kernel", "kernel": "silu:64", "optimizer": "random", "budget": 2, "seed": 5, "backend": "simulated-slow:150"}
]}"#,
    );
    let state_dir = dir.path().join("state").to_string_lossy().into_owned();

    let server = Server::spawn(&["serve", "--addr", "127.0.0.1:0", "--workers", "1", "--state-dir", &state_dir]);
    // Submit from a background child (it will die with the daemon — its
    // nonzero exit is expected and unchecked).
    let mut submitter = cmd(&["submit", &batch, "--addr", &server.addr, "--client", "crash"])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();

    // Wait for at least one durably journaled outcome, then SIGKILL the
    // daemon — no Drop, no drain, exactly the crash the journal exists for.
    let deadline = Instant::now() + Duration::from_secs(60);
    let journal = loop {
        assert!(Instant::now() < deadline, "no journal record appeared before the kill");
        if let Some(p) = find_journal(Path::new(&state_dir)) {
            let len = std::fs::metadata(&p).map(|m| m.len()).unwrap_or(0);
            if len > 0 {
                break p;
            }
        }
        std::thread::sleep(Duration::from_millis(25));
    };
    drop(server); // Drop = SIGKILL + reap
    let _ = submitter.wait();
    assert!(journal.exists(), "the journal must survive the kill");

    // A successor daemon on the same state root resumes the journaled
    // outcomes instead of re-running them.
    let server = Server::spawn(&["serve", "--addr", "127.0.0.1:0", "--workers", "1", "--state-dir", &state_dir]);
    let out = run(&["submit", &batch, "--addr", &server.addr, "--client", "crash"]);
    assert!(out.status.success(), "stderr: {}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("resumed: "), "no resume line:\n{text}");
    assert_eq!(score_lines(&text).len(), 3, "every scenario settles exactly once:\n{text}");

    // And the resumed union matches a from-scratch fleet bit for bit.
    let fleet = run(&["fleet", &batch, "--workers", "1"]);
    assert!(fleet.status.success());
    let fleet_scores: HashSet<String> = score_lines(&stdout(&fleet)).into_iter().collect();
    let served_scores: HashSet<String> = score_lines(&text).into_iter().collect();
    assert_eq!(served_scores, fleet_scores, "resumed scores drifted");
}

#[test]
fn serve_and_submit_hard_errors_name_the_cause() {
    let dir = TempDir::new("serve_err");
    let batch = dir.file("batch.json", &small_batch("serve_err"));

    // Malformed bind address: named flag, exit 1, nothing bound.
    let out = run(&["serve", "--addr", "nonsense"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("--addr"), "{}", stderr(&out));

    // Zero queue cap from the environment: hard error naming the knob.
    let out = run_env(&["serve", "--addr", "127.0.0.1:0"], &[("HAQA_QUEUE_CAP", "0")]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("HAQA_QUEUE_CAP"), "{}", stderr(&out));

    // Garbage serve address from the environment, on the client side.
    let out = run_env(&["submit", &batch], &[("HAQA_SERVE_ADDR", "not-an-addr")]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("HAQA_SERVE_ADDR"), "{}", stderr(&out));

    // No daemon at the far end: a connection error, not a hang.
    let out = run(&["submit", &batch, "--addr", "127.0.0.1:1"]);
    assert_eq!(out.status.code(), Some(1));

    // A malformed batch fails before any socket is touched.
    let bad = dir.file("bad.json", "[{ nope");
    let out = run(&["submit", &bad, "--addr", "127.0.0.1:1"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("bad.json"), "{}", stderr(&out));

    // Missing positional: usage string.
    let out = run(&["submit"]);
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr(&out).contains("usage: haqa submit"), "{}", stderr(&out));
}
