//! Integration: manifest → PJRT compile → execute → state threading, across
//! the real artifacts (requires `make artifacts` and `--features pjrt`;
//! the default offline build has no execution backend).
#![cfg(feature = "pjrt")]

use std::collections::HashMap;

use haqa::runtime::{ArtifactSet, Tensor};
use haqa::trainer::data::ImageDataset;
use haqa::util::rng::Rng;

fn set() -> ArtifactSet {
    ArtifactSet::load_default().expect("run `make artifacts` first")
}

#[test]
fn manifest_covers_all_families() {
    let s = set();
    for family in ["cnn_train", "cnn_eval", "lm_train", "lm_eval", "lm_decode",
                   "lm_pretrain", "micro"] {
        assert!(!s.family(family).is_empty(), "no artifacts for {family}");
    }
    assert!(s.names().len() >= 40, "{}", s.names().len());
}

#[test]
fn micro_kernel_executes_and_is_finite() {
    let s = set();
    let exec = s.executor("micro_rmsnorm_b1").unwrap();
    let mut rng = Rng::new(0);
    let mut named = HashMap::new();
    for spec in &exec.artifact.inputs {
        let mut t = Tensor::zeros(&spec.shape);
        rng.fill_uniform(&mut t.data);
        named.insert(spec.name.as_str(), t);
    }
    let (_, out) = exec.step(Vec::new(), &[], &named).unwrap();
    assert_eq!(out[0].shape, vec![1, 4096]);
    assert!(out[0].data.iter().all(|x| x.is_finite()));
}

#[test]
fn cnn_train_state_threading_reduces_loss_on_pjrt() {
    let s = set();
    let exec = s.executor("cnn_s_train_b32").unwrap();
    let mut rng = Rng::new(3);
    let mut state = exec.artifact.init_state(&mut rng);
    let mut data = ImageDataset::new(3);
    let mut named: HashMap<&str, Tensor> = HashMap::new();
    named.insert("lr", Tensor::scalar(0.05));
    named.insert("momentum", Tensor::scalar(0.9));
    named.insert("weight_decay", Tensor::scalar(1e-4));
    named.insert("grad_clip", Tensor::scalar(5.0));
    named.insert("wbits", Tensor::scalar(8.0));
    named.insert("abits", Tensor::scalar(8.0));
    let mut losses = Vec::new();
    for _ in 0..12 {
        let (x, y) = data.batch(32);
        named.insert("x", x);
        named.insert("y", y);
        let (new_state, metrics) = exec.step(state, &[], &named).unwrap();
        state = new_state;
        losses.push(metrics[0].item());
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "{losses:?}"
    );
}

#[test]
fn decode_tile_variants_agree_numerically() {
    // The tile schedule must not change the math (same check as the pytest
    // suite, but through the full HLO-text -> PJRT path).
    let s = set();
    let a = s.executor("lm_decode_default").unwrap();
    let b = s.executor("lm_decode_mm64x64x64").unwrap();
    let mut rng = Rng::new(5);
    let frozen = a.artifact.init_frozen(&mut rng);
    let mut named: HashMap<&str, Tensor> = HashMap::new();
    let tok_spec = a
        .artifact
        .inputs
        .iter()
        .find(|i| i.name == "tokens")
        .unwrap();
    let mut tokens = Tensor::zeros(&tok_spec.shape);
    // valid one-hot rows
    for t in 0..tok_spec.shape[1] {
        tokens.data[t * tok_spec.shape[2] + (t * 7) % tok_spec.shape[2]] = 1.0;
    }
    named.insert("tokens", tokens);
    named.insert("rank_mask", Tensor::ones(&[64]));
    named.insert("bits", Tensor::scalar(8.0));
    named.insert("lora_scale", Tensor::scalar(0.5));
    let (_, la) = a.step(Vec::new(), &frozen, &named).unwrap();
    let (_, lb) = b.step(Vec::new(), &frozen, &named).unwrap();
    for (x, y) in la[0].data.iter().zip(&lb[0].data) {
        assert!((x - y).abs() < 1e-3, "{x} vs {y}");
    }
}

#[test]
fn runtime_bits_scalar_changes_quantization() {
    let s = set();
    let exec = s.executor("lm_eval").unwrap();
    let mut rng = Rng::new(6);
    let frozen = exec.artifact.init_frozen(&mut rng);
    let mut named: HashMap<&str, Tensor> = HashMap::new();
    let tok_spec = exec
        .artifact
        .inputs
        .iter()
        .find(|i| i.name == "tokens")
        .unwrap()
        .clone();
    let mut tokens = Tensor::zeros(&tok_spec.shape);
    for b in 0..tok_spec.shape[0] {
        for t in 0..tok_spec.shape[1] {
            tokens.data[(b * tok_spec.shape[1] + t) * tok_spec.shape[2] + (b + t) % 64] = 1.0;
        }
    }
    named.insert("targets", tokens.clone());
    named.insert("tokens", tokens);
    named.insert("rank_mask", Tensor::ones(&[64]));
    named.insert("lora_scale", Tensor::scalar(0.5));
    named.insert("bits", Tensor::scalar(16.0));
    let (_, hi) = exec.step(Vec::new(), &frozen, &named).unwrap();
    named.insert("bits", Tensor::scalar(2.0));
    let (_, lo) = exec.step(Vec::new(), &frozen, &named).unwrap();
    assert!(
        (hi[0].item() - lo[0].item()).abs() > 1e-4,
        "2-bit quantization should change the loss: {} vs {}",
        hi[0].item(),
        lo[0].item()
    );
}

#[test]
fn executor_rejects_shape_mismatch() {
    let s = set();
    let exec = s.executor("micro_rope_b1").unwrap();
    let mut named: HashMap<&str, Tensor> = HashMap::new();
    named.insert("in0", Tensor::zeros(&[2, 128])); // expected (1, 128)
    assert!(exec.build_args(&[], &[], &named).is_err());
}
