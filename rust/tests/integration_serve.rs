//! Integration: the resident fleet daemon's failure edges.
//!
//! * a `chaos:`-wrapped evaluator served through the daemon stays
//!   bit-identical to a clean in-process fleet (only fault counters move);
//! * a daemon whose predecessor was killed mid-job resumes from the scoped
//!   `fleet_state.jsonl` with no lost or duplicated outcomes;
//! * admission control answers a typed `busy` at the raw wire level.
//!
//! Chaos plans are registered process-wide by plan string, so every test
//! here uses a plan string unique to itself.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::time::Duration;

use haqa::coordinator::scenario::Track;
use haqa::coordinator::serve::{self, FleetDaemon, ServeConfig, SubmitClient};
use haqa::coordinator::{EvalCache, FleetRunner, Scenario};
use haqa::util::json;

fn kernel_scenarios(tag: &str) -> Vec<Scenario> {
    ["matmul:64", "softmax:128", "silu:64", "rmsnorm:1"]
        .iter()
        .enumerate()
        .map(|(i, kernel)| Scenario {
            name: format!("{tag}_{i}"),
            track: Track::Kernel,
            kernel: (*kernel).into(),
            optimizer: "haqa".into(),
            budget: 5,
            seed: i as u64,
            ..Scenario::default()
        })
        .collect()
}

fn temp_root(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("haqa_iserve_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Poll `results` until the job is terminal; returns the final reply.
fn settled(client: &mut SubmitClient, job: &str) -> haqa::util::json::Json {
    for _ in 0..1200 {
        let r = client.results(job, 0).unwrap();
        if r.get("summary").is_some() {
            return r;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("job {job} never settled");
}

fn row_bits(reply: &haqa::util::json::Json) -> Vec<u64> {
    reply
        .get("results")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|row| {
            assert_eq!(row.get("ok").unwrap().as_bool(), Some(true), "{row:?}");
            serve::wire_best(row).unwrap().to_bits()
        })
        .collect()
}

/// Tentpole invariant, daemon edition: a fault plan on the evaluator seam
/// plus a retry budget, served over the socket, yields the exact scores of
/// a clean in-process fleet on the same batch.
#[test]
fn chaos_through_the_daemon_is_bit_identical() {
    let clean = FleetRunner::new(2).quiet().run(&kernel_scenarios("serve_chaos"));
    let clean_bits: Vec<u64> = clean
        .outcomes
        .iter()
        .map(|o| o.as_ref().expect("clean run failed").best_score.to_bits())
        .collect();

    let mut faulted = kernel_scenarios("serve_chaos");
    for sc in &mut faulted {
        sc.evaluator = "chaos:seed:404:3=simulated".into();
    }
    let root = temp_root("chaos");
    let daemon = FleetDaemon::spawn(
        "127.0.0.1:0",
        EvalCache::new(),
        ServeConfig { workers: 2, retries: 4, ..ServeConfig::default() },
        &root,
    )
    .unwrap();
    let mut client = SubmitClient::connect(&daemon.addr().to_string()).unwrap();
    let reply = client.submit("chaos-ci", &faulted).unwrap();
    let job = reply.get("job").unwrap().as_str().unwrap().to_string();
    let r = settled(&mut client, &job);
    assert_eq!(row_bits(&r), clean_bits, "served chaos scores drifted");
    let s = r.get("summary").unwrap();
    assert_eq!(s.get("state").unwrap().as_str(), Some("done"));
    let retries = s
        .get("faults")
        .unwrap()
        .get("retries")
        .unwrap()
        .as_i64()
        .unwrap();
    assert!(retries > 0, "no injected fault fired through the daemon");
    drop(daemon);
    let _ = std::fs::remove_dir_all(&root);
}

/// A predecessor daemon died (SIGKILL — no Drop, no flush beyond the eager
/// per-settle commits) partway through a job.  Emulated by journaling a
/// subset of the batch into the exact scoped state dir the daemon will
/// compute; the successor must restore those outcomes (no re-run), finish
/// the rest, and report the union bit-identically with nothing duplicated.
#[test]
fn successor_daemon_resumes_the_scoped_journal() {
    let scenarios = kernel_scenarios("serve_resume");
    let clean = FleetRunner::new(2).quiet().run(&scenarios);

    let root = temp_root("resume");
    let dir = serve::job_state_dir(&root, "crash-ci", &scenarios);
    // The dead daemon settled the first two scenarios.  Journaling them
    // through a scoped runner writes byte-for-byte what `run_one` would
    // have (same encoder, same scope tag).
    let partial = FleetRunner::new(1)
        .quiet()
        .with_state_dir_scoped(&dir, "crash-ci")
        .unwrap()
        .run(&scenarios[..2]);
    assert_eq!(partial.outcomes.len(), 2);

    let daemon = FleetDaemon::spawn(
        "127.0.0.1:0",
        EvalCache::new(),
        ServeConfig { workers: 2, ..ServeConfig::default() },
        &root,
    )
    .unwrap();
    let mut client = SubmitClient::connect(&daemon.addr().to_string()).unwrap();
    let reply = client.submit("crash-ci", &scenarios).unwrap();
    let job = reply.get("job").unwrap().as_str().unwrap().to_string();
    let r = settled(&mut client, &job);
    let clean_bits: Vec<u64> = clean
        .outcomes
        .iter()
        .map(|o| o.as_ref().unwrap().best_score.to_bits())
        .collect();
    assert_eq!(row_bits(&r), clean_bits, "resumed union drifted");
    assert_eq!(
        r.get("results").unwrap().as_arr().unwrap().len(),
        scenarios.len(),
        "exactly one result per scenario — nothing lost, nothing duplicated"
    );
    let s = r.get("summary").unwrap();
    assert_eq!(s.get("resumed").unwrap().as_i64(), Some(2), "both journaled outcomes restored");
    assert_eq!(s.get("state").unwrap().as_str(), Some("done"));
    drop(daemon);
    let _ = std::fs::remove_dir_all(&root);
}

/// Admission control at the raw wire level: a full queue answers one line
/// of typed `busy` JSON — `ok:false`, `busy:true`, an error naming the
/// cap — and keeps the connection open for the retry.
#[test]
fn queue_full_busy_reply_on_the_raw_wire() {
    let root = temp_root("wire_busy");
    let daemon = FleetDaemon::spawn(
        "127.0.0.1:0",
        EvalCache::new(),
        ServeConfig { workers: 1, queue_cap: 1, ..ServeConfig::default() },
        &root,
    )
    .unwrap();
    let stream = TcpStream::connect(daemon.addr()).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;

    let mut submit = |name: &str| -> haqa::util::json::Json {
        let sc = Scenario {
            name: name.into(),
            track: Track::Kernel,
            optimizer: "random".into(),
            budget: 2,
            backend: "simulated-slow:200".into(),
            ..Scenario::default()
        };
        let line = format!(
            "{{\"op\":\"submit\",\"v\":1,\"client\":\"wire\",\"scenarios\":[{}]}}\n",
            serve::scenario_to_wire(&sc).to_string()
        );
        writer.write_all(line.as_bytes()).unwrap();
        writer.flush().unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        json::parse(reply.trim()).unwrap()
    };

    let mut busy_seen = false;
    for i in 0..3 {
        let reply = submit(&format!("wire/{i}"));
        if reply.get("ok").unwrap().as_bool() == Some(false) {
            busy_seen = true;
            assert_eq!(reply.get("busy").and_then(|v| v.as_bool()), Some(true));
            let msg = reply.get("error").unwrap().as_str().unwrap();
            assert!(msg.starts_with("busy:") && msg.contains("queue cap 1"), "{msg}");
        } else {
            assert!(reply.get("job").unwrap().as_str().unwrap().starts_with('j'));
        }
    }
    assert!(busy_seen, "three rapid submissions must overflow a cap of 1");

    // The same connection still serves status — busy is flow control, not
    // a connection-fatal error.
    writer.write_all(b"{\"op\":\"status\"}\n").unwrap();
    writer.flush().unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    let st = json::parse(reply.trim()).unwrap();
    assert_eq!(st.get("service").unwrap().as_str(), Some("haqa-serve"));
    drop(daemon);
    let _ = std::fs::remove_dir_all(&root);
}
