//! Device-backend fleet integration: the committed `device_fleet.json`
//! batch runs against the in-process `DeviceServer` stub through the
//! unmodified `FleetRunner`, and `device:` scenarios reproduce the
//! direct-simulator runs bit for bit.

use haqa::coordinator::{FleetRunner, Scenario, TrackOutcome};

fn device_fleet() -> Vec<Scenario> {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../scenarios/device_fleet.json");
    Scenario::load_many(path).expect("committed device fleet batch parses")
}

#[test]
fn committed_device_fleet_runs_and_matches_direct_simulator() {
    let scenarios = device_fleet();
    assert!(
        scenarios.iter().any(|s| s.evaluator.starts_with("device:")),
        "batch must exercise device evaluators"
    );
    assert!(
        scenarios.iter().any(|s| s.evaluator == "simulated"),
        "batch must keep direct-simulator controls"
    );
    let report = FleetRunner::new(2).quiet().run(&scenarios);
    let outcome = |name: &str| -> &TrackOutcome {
        let i = scenarios
            .iter()
            .position(|s| s.name == name)
            .unwrap_or_else(|| panic!("scenario '{name}' in device_fleet.json"));
        report.outcomes[i]
            .as_ref()
            .unwrap_or_else(|e| panic!("scenario '{name}' failed: {e:#}"))
    };
    for (sc, out) in scenarios.iter().zip(&report.outcomes) {
        assert!(out.is_ok(), "{}: {:#}", sc.name, out.as_ref().unwrap_err());
    }
    // The committed batch pairs each `device:` scenario with its
    // direct-simulator control (same kernel, seed, platform): the wire
    // path must be invisible in the results.
    for (sim, dev) in [
        ("fleet_sim_matmul64_server", "fleet_dev_matmul64_server"),
        ("fleet_sim_softmax128_mobile", "fleet_dev_softmax128_mobile"),
    ] {
        let (a, b) = (outcome(sim), outcome(dev));
        assert_eq!(
            a.best_score.to_bits(),
            b.best_score.to_bits(),
            "{sim} vs {dev}: best scores must be bit-identical"
        );
        assert_eq!(a.history.len(), b.history.len());
        for (x, y) in a.history.iter().zip(&b.history) {
            assert_eq!(x.score.to_bits(), y.score.to_bits(), "{sim} vs {dev}");
            assert_eq!(x.feedback, y.feedback);
        }
    }
    // Distinct platforms measured over one wire must stay distinct: the
    // shared cache holds separate entries per device scope (no collisions
    // collapsed the batch).
    let cache = report.cache.expect("fleet cache enabled by default");
    assert!(cache.entries > 0);
}

#[test]
fn device_fleet_is_bit_identical_across_workers_and_overlap() {
    // FleetRunner has no device-specific logic, so worker count and
    // in-flight overlap must not change device-measured results — the same
    // guarantee the simulator path has always had.
    let scenarios = device_fleet();
    let serial = FleetRunner::new(1).quiet().without_cache().run(&scenarios);
    let fleet = FleetRunner::new(4)
        .quiet()
        .without_cache()
        .with_inflight(4)
        .run(&scenarios);
    for ((sc, a), b) in scenarios.iter().zip(&serial.outcomes).zip(&fleet.outcomes) {
        let (a, b) = (
            a.as_ref().unwrap_or_else(|e| panic!("{}: {e:#}", sc.name)),
            b.as_ref().unwrap_or_else(|e| panic!("{}: {e:#}", sc.name)),
        );
        assert_eq!(
            a.best_score.to_bits(),
            b.best_score.to_bits(),
            "{}: serial vs overlapped fleet diverged",
            sc.name
        );
    }
}
