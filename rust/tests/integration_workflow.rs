//! Integration: the unified `Evaluator` workflow (agent + evaluators + task
//! logs + cache + fleet) across the kernel-tuning and bit-width tracks.
//!
//! Everything here runs on the analytic hardware simulator — no artifacts
//! and no PJRT — so tier-1 `cargo test` exercises the full coordinator
//! offline.  The fine-tuning track (real PJRT training) is covered by the
//! `pjrt`-gated module at the bottom.

use haqa::coordinator::scenario::Track;
use haqa::coordinator::{EvalCache, FleetRunner, Scenario, Workflow};
use haqa::optimizers::best;

#[test]
fn kernel_track_haqa_beats_default_config() {
    let wf = Workflow::simulated();
    let sc = Scenario {
        name: "it_kernel".into(),
        track: Track::Kernel,
        kernel: "matmul:64".into(),
        optimizer: "haqa".into(),
        budget: 8,
        seed: 1,
        ..Scenario::default()
    };
    let out = wf.run_kernel(&sc).unwrap();
    assert_eq!(out.history.len(), 8);
    let default_lat = -out.history[0].score; // round 0 ≈ informed start
    let best_lat = -best(&out.history).unwrap().score;
    assert!(best_lat <= default_lat + 1e-9);
    // The simulated llama.cpp default for matmul@64 is 52.29 µs; the agent
    // must improve on it within 8 rounds.
    assert!(best_lat < 52.29, "best {best_lat}");
    // The agent's cost report threads through the generic loop.
    assert!(out.cost_report.unwrap().contains("tokens"));
}

#[test]
fn bitwidth_track_agent_matches_analytic_choice() {
    let wf = Workflow::simulated();
    for (device, limit, expect) in [
        ("a6000", 12.0, "INT4"),
        ("a6000", 28.0, "INT4"),
        ("adreno740", 10.0, "INT8"),
    ] {
        let sc = Scenario {
            name: format!("it_bw_{device}_{limit}"),
            track: Track::Bitwidth,
            model: "llama2-13b".into(),
            device: device.into(),
            memory_limit_gb: limit,
            ..Scenario::default()
        };
        let out = wf.run_bitwidth(&sc).unwrap();
        let pick = out.history[0]
            .config
            .get("quant")
            .and_then(|v| v.as_str().map(|s| s.to_string()))
            .unwrap();
        if device == "adreno740" && limit == 10.0 {
            // 13B INT8 (~14 GB) does not fit 10 GB: INT4 is the only fit,
            // but mobile prefers INT8 — the agent must respect memory first.
            assert_eq!(pick, "INT4", "{device}/{limit}");
        } else {
            assert_eq!(pick, expect, "{device}/{limit}");
        }
        assert!(out.history[0].feedback.contains("analytic_choice"));
    }
}

#[test]
fn baseline_optimizers_run_through_the_same_workflow() {
    let wf = Workflow::simulated();
    for opt in ["random", "local", "bayesian", "nsga2", "human"] {
        let sc = Scenario {
            name: format!("it_k_{opt}"),
            track: Track::Kernel,
            kernel: "softmax:64".into(),
            optimizer: opt.into(),
            budget: 4,
            seed: 3,
            ..Scenario::default()
        };
        let out = wf.run_kernel(&sc).unwrap();
        assert_eq!(out.history.len(), 4, "{opt}");
        assert!(out.history.iter().all(|o| o.score.is_finite()), "{opt}");
        assert!(out.cost_report.is_none(), "{opt} is not agent-backed");
    }
}

#[test]
fn malformed_kernel_batch_is_a_hard_error() {
    let wf = Workflow::simulated();
    let sc = Scenario {
        name: "it_badbatch".into(),
        track: Track::Kernel,
        kernel: "matmul:banana".into(),
        budget: 2,
        ..Scenario::default()
    };
    let err = wf.run_kernel(&sc).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("matmul:banana"), "{msg}");
    // A missing batch still uses the documented default of 64.
    let ok = wf.run_kernel(&Scenario {
        name: "it_nobatch".into(),
        track: Track::Kernel,
        kernel: "softmax".into(),
        budget: 2,
        ..Scenario::default()
    });
    assert!(ok.is_ok());
}

/// Acceptance: a mixed-track fleet of ≥ 6 scenarios run with 4 workers
/// yields bit-identical best scores to the serial (1-worker) run.
#[test]
fn fleet_matches_serial_bit_for_bit() {
    let mut scenarios = Vec::new();
    let kernel_cells: [(&str, &str, &str); 6] = [
        ("haqa", "matmul:64", "a6000"),
        ("random", "softmax:128", "adreno740"),
        ("bayesian", "silu:64", "a6000"),
        ("nsga2", "rmsnorm:1", "adreno740"),
        ("local", "rope:64", "a6000"),
        ("human", "matmul:128", "a6000"),
    ];
    for (i, (opt, kernel, dev)) in kernel_cells.iter().enumerate() {
        scenarios.push(Scenario {
            name: format!("fleet_k{i}"),
            track: Track::Kernel,
            kernel: (*kernel).into(),
            device: (*dev).into(),
            optimizer: (*opt).into(),
            budget: 5,
            seed: i as u64,
            ..Scenario::default()
        });
    }
    scenarios.push(Scenario {
        name: "fleet_bw0".into(),
        track: Track::Bitwidth,
        model: "llama2-13b".into(),
        memory_limit_gb: 12.0,
        ..Scenario::default()
    });
    scenarios.push(Scenario {
        name: "fleet_bw1".into(),
        track: Track::Bitwidth,
        model: "openllama-3b".into(),
        device: "adreno740".into(),
        memory_limit_gb: 10.0,
        ..Scenario::default()
    });

    let parallel = FleetRunner::new(4).run(&scenarios);
    let serial = FleetRunner::new(1).run(&scenarios);
    assert_eq!(parallel.outcomes.len(), scenarios.len());
    for (i, (p, s)) in parallel.outcomes.iter().zip(&serial.outcomes).enumerate() {
        let (p, s) = (p.as_ref().unwrap(), s.as_ref().unwrap());
        assert_eq!(
            p.best_score.to_bits(),
            s.best_score.to_bits(),
            "scenario {} diverged between parallel and serial",
            scenarios[i].name
        );
        assert_eq!(p.history.len(), s.history.len());
    }
}

/// Acceptance: the family-sharded work queue plus the lock-striped,
/// journal-backed cache stay bit-identical to serial — and a *fresh* cache
/// instance (the process-boundary equivalent) serves the whole batch from
/// the journal without recomputing anything.
#[test]
fn sharded_fleet_with_persistent_cache_matches_serial() {
    let dir = std::env::temp_dir().join(format!("haqa_it_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // Three families: kernel/a6000, kernel/adreno740, bitwidth.
    let mut scenarios = Vec::new();
    for (i, (opt, kernel, dev)) in [
        ("haqa", "matmul:64", "a6000"),
        ("random", "softmax:128", "adreno740"),
        ("bayesian", "silu:64", "a6000"),
        ("local", "rmsnorm:1", "adreno740"),
    ]
    .iter()
    .enumerate()
    {
        scenarios.push(Scenario {
            name: format!("shard_k{i}"),
            track: Track::Kernel,
            kernel: (*kernel).into(),
            device: (*dev).into(),
            optimizer: (*opt).into(),
            budget: 4,
            seed: i as u64,
            ..Scenario::default()
        });
    }
    scenarios.push(Scenario {
        name: "shard_bw".into(),
        track: Track::Bitwidth,
        model: "llama2-13b".into(),
        memory_limit_gb: 12.0,
        ..Scenario::default()
    });

    let serial = FleetRunner::new(1).run(&scenarios);
    let cold = FleetRunner::new(3)
        .with_cache(EvalCache::with_dir(&dir).unwrap())
        .run(&scenarios);
    assert_eq!(cold.families, 3, "grouped into three artifact families");
    for (i, (s, c)) in serial.outcomes.iter().zip(&cold.outcomes).enumerate() {
        let (s, c) = (s.as_ref().unwrap(), c.as_ref().unwrap());
        assert_eq!(
            s.best_score.to_bits(),
            c.best_score.to_bits(),
            "scenario {} diverged under sharding",
            scenarios[i].name
        );
    }

    // Warm re-run through a brand-new cache instance: everything must be
    // served from the journal, still bit-identical.
    let warm = FleetRunner::new(3)
        .with_cache(EvalCache::with_dir(&dir).unwrap())
        .run(&scenarios);
    let st = warm.cache.unwrap();
    assert_eq!(st.misses, 0, "warm fleet must not recompute: {st:?}");
    assert!(st.hits > 0);
    for (s, w) in serial.outcomes.iter().zip(&warm.outcomes) {
        assert_eq!(
            s.as_ref().unwrap().best_score.to_bits(),
            w.as_ref().unwrap().best_score.to_bits()
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance: the cache reports > 0 hits on a repeated-method sweep —
/// identical (track, scenario knobs, config) evaluate once fleet-wide.
#[test]
fn cache_hits_on_repeated_method_sweep() {
    let cache = EvalCache::new();
    let sweep = |name: &str| Scenario {
        name: name.into(),
        track: Track::Kernel,
        kernel: "matmul:64".into(),
        optimizer: "default".into(), // proposes the same config every round
        budget: 3,
        seed: 9,
        ..Scenario::default()
    };
    let wf = Workflow::simulated().with_cache(cache.clone());
    let a = wf.run(&sweep("sweep_a")).unwrap();
    assert_eq!((a.cache_misses, a.cache_hits), (1, 2));
    // A second method over the same knobs re-proposes the same config:
    // everything is served from the cache.
    let b = wf.run(&sweep("sweep_b")).unwrap();
    assert_eq!((b.cache_misses, b.cache_hits), (0, 3));
    assert_eq!(a.best_score.to_bits(), b.best_score.to_bits());
    let st = cache.stats();
    assert_eq!((st.hits, st.misses, st.entries), (5, 1, 1));
}

/// The fine-tuning track needs PJRT + `make artifacts`; keep it exercised
/// in `--features pjrt` builds.
#[cfg(feature = "pjrt")]
mod pjrt_tracks {
    use super::*;
    use haqa::runtime::ArtifactSet;

    #[test]
    fn finetune_track_runs_and_logs() {
        let set = ArtifactSet::load_default().expect("run `make artifacts` first");
        let wf = Workflow::new(&set);
        let sc = Scenario {
            name: "it_ft".into(),
            track: Track::FinetuneCnn,
            model: "cnn_s".into(),
            optimizer: "haqa".into(),
            budget: 2,
            steps_per_epoch: 1,
            seed: 2,
            ..Scenario::default()
        };
        let out = wf.run_finetune(&sc).unwrap();
        assert_eq!(out.history.len(), 2);
        assert!(out.best_score > 0.05, "accuracy {}", out.best_score);
        let log = out.log_path.expect("task log written");
        let text = std::fs::read_to_string(log).unwrap();
        let j = haqa::util::json::parse(&text).unwrap();
        assert_eq!(j.req_arr("rounds").unwrap().len(), 2);
    }
}
