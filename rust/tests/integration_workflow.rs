//! Integration: the full HAQA workflow (agent + evaluators + task logs)
//! across the kernel-tuning, bit-width and fine-tuning tracks.

use haqa::coordinator::scenario::Track;
use haqa::coordinator::{Scenario, Workflow};
use haqa::optimizers::best;
use haqa::runtime::ArtifactSet;

fn set() -> ArtifactSet {
    ArtifactSet::load_default().expect("run `make artifacts` first")
}

#[test]
fn kernel_track_haqa_beats_default_config() {
    let set = set();
    let wf = Workflow::new(&set);
    let sc = Scenario {
        name: "it_kernel".into(),
        track: Track::Kernel,
        kernel: "matmul:64".into(),
        optimizer: "haqa".into(),
        budget: 8,
        seed: 1,
        ..Scenario::default()
    };
    let out = wf.run_kernel(&sc).unwrap();
    assert_eq!(out.history.len(), 8);
    let default_lat = -out.history[0].score; // round 0 ≈ informed start
    let best_lat = -best(&out.history).unwrap().score;
    assert!(best_lat <= default_lat + 1e-9);
    // The simulated llama.cpp default for matmul@64 is 52.29 µs; the agent
    // must improve on it within 8 rounds.
    assert!(best_lat < 52.29, "best {best_lat}");
}

#[test]
fn bitwidth_track_agent_matches_analytic_choice() {
    let set = set();
    let wf = Workflow::new(&set);
    for (device, limit, expect) in [
        ("a6000", 12.0, "INT4"),
        ("a6000", 28.0, "INT4"),
        ("adreno740", 10.0, "INT8"),
    ] {
        let sc = Scenario {
            name: format!("it_bw_{device}_{limit}"),
            track: Track::Bitwidth,
            model: "llama2-13b".into(),
            device: device.into(),
            memory_limit_gb: limit,
            ..Scenario::default()
        };
        let out = wf.run_bitwidth(&sc).unwrap();
        let pick = out.history[0]
            .config
            .get("quant")
            .and_then(|v| v.as_str().map(|s| s.to_string()))
            .unwrap();
        if device == "adreno740" && limit == 10.0 {
            // 13B INT8 (~14 GB) does not fit 10 GB: INT4 is the only fit,
            // but mobile prefers INT8 — the agent must respect memory first.
            assert_eq!(pick, "INT4", "{device}/{limit}");
        } else {
            assert_eq!(pick, expect, "{device}/{limit}");
        }
        assert!(out.history[0].feedback.contains("analytic_choice"));
    }
}

#[test]
fn finetune_track_runs_and_logs() {
    let set = set();
    let wf = Workflow::new(&set);
    let sc = Scenario {
        name: "it_ft".into(),
        track: Track::FinetuneCnn,
        model: "cnn_s".into(),
        optimizer: "haqa".into(),
        budget: 2,
        steps_per_epoch: 1,
        seed: 2,
        ..Scenario::default()
    };
    let out = wf.run_finetune(&sc).unwrap();
    assert_eq!(out.history.len(), 2);
    assert!(out.best_score > 0.05, "accuracy {}", out.best_score);
    let log = out.log_path.expect("task log written");
    let text = std::fs::read_to_string(log).unwrap();
    let j = haqa::util::json::parse(&text).unwrap();
    assert_eq!(j.req_arr("rounds").unwrap().len(), 2);
}

#[test]
fn baseline_optimizers_run_through_the_same_workflow() {
    let set = set();
    let wf = Workflow::new(&set);
    for opt in ["random", "local", "bayesian", "nsga2", "human"] {
        let sc = Scenario {
            name: format!("it_k_{opt}"),
            track: Track::Kernel,
            kernel: "softmax:64".into(),
            optimizer: opt.into(),
            budget: 4,
            seed: 3,
            ..Scenario::default()
        };
        let out = wf.run_kernel(&sc).unwrap();
        assert_eq!(out.history.len(), 4, "{opt}");
        assert!(out.history.iter().all(|o| o.score.is_finite()), "{opt}");
    }
}
