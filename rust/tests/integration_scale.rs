//! Integration: the fleet at generated-matrix scale — bounded LRU cache
//! tier, group-committed journal, and the scenario-matrix generator wired
//! end to end.  Everything runs on the analytic simulator (kernel +
//! bit-width tracks only), so tier-1 `cargo test` exercises the whole
//! 10k-scenario machinery offline at a CI-sized count.

use haqa::coordinator::matrix::{render_batch, MatrixSpec};
use haqa::coordinator::{EvalCache, FleetRunner, Scenario};
use haqa::util::json;

/// A small but eviction-heavy matrix: two devices, both tracks, cheap
/// baseline optimizers, enough distinct evaluation keys to overflow a
/// tight cap many times over.
fn small_matrix(count: usize) -> MatrixSpec {
    let j = json::parse(&format!(
        r#"{{"count": {count}, "seed": 9,
             "devices": ["a6000", "adreno740"],
             "kernels": ["matmul:64", "softmax:128"],
             "optimizers": ["random", "local"],
             "models": ["tinyllama-1.1b", "openllama-3b"],
             "memory_limits_gb": [8, 12],
             "budget": 3}}"#
    ))
    .unwrap();
    MatrixSpec::from_json(&j).unwrap()
}

fn best_bits(report: &haqa::coordinator::FleetReport) -> Vec<u64> {
    report
        .outcomes
        .iter()
        .map(|o| o.as_ref().expect("scenario failed").best_score.to_bits())
        .collect()
}

#[test]
fn capped_fleet_is_bit_identical_to_unbounded_and_stays_within_cap() {
    let scenarios = small_matrix(40).expand();
    let unbounded = FleetRunner::new(4).quiet().run(&scenarios);
    let cap = 8;
    let capped = FleetRunner::new(4)
        .quiet()
        .with_cache(EvalCache::bounded(cap))
        .run(&scenarios);
    assert_eq!(
        best_bits(&unbounded),
        best_bits(&capped),
        "LRU eviction must never change a score, only hit rates"
    );
    let st = capped.cache.unwrap();
    assert!(st.evictions > 0, "a cap of {cap} over this matrix must evict");
    assert!(
        st.peak_entries <= cap,
        "peak {} exceeded the cap {cap} under concurrent workers",
        st.peak_entries
    );
    assert!(st.entries <= cap, "resident {} exceeded the cap {cap}", st.entries);
    // The unbounded control never evicts and peaks at its full size.
    let un = unbounded.cache.unwrap();
    assert_eq!(un.evictions, 0);
    assert_eq!(un.capacity, None);
    assert!(un.peak_entries >= un.entries);
}

#[test]
fn capped_journal_coalesces_writes_and_warms_across_instances() {
    let dir = std::env::temp_dir().join(format!("haqa_it_scale_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let scenarios = small_matrix(30).expand();
    // 64 splits to 4 per stripe: every shard keeps its MRU keys resident,
    // so the warm rerun is guaranteed at least one journal-served hit.
    let cap = 64;

    let cold = FleetRunner::new(3)
        .quiet()
        .with_cache(EvalCache::with_dir_capped(&dir, Some(cap)).unwrap())
        .run(&scenarios);
    let cold_st = cold.cache.unwrap();
    assert!(cold_st.journal_records > 0);
    assert!(
        cold_st.journal_writes < cold_st.journal_records,
        "group commit must use fewer write calls ({}) than records ({})",
        cold_st.journal_writes,
        cold_st.journal_records
    );
    assert!(cold_st.peak_entries <= cap);

    // A fresh instance (the process boundary) streams the journal back in
    // through the cap: still bit-identical, and at least partly served
    // from disk — even though most loaded entries evicted on the way in.
    let warm = FleetRunner::new(3)
        .quiet()
        .with_cache(EvalCache::with_dir_capped(&dir, Some(cap)).unwrap())
        .run(&scenarios);
    let warm_st = warm.cache.unwrap();
    assert_eq!(best_bits(&cold), best_bits(&warm));
    assert!(warm_st.hits > 0, "warm capped run saw zero journal hits");
    assert!(warm_st.peak_entries <= cap);
    assert_eq!(
        warm_st.journal_records, 0,
        "re-running the same matrix must append nothing new"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn generated_file_runs_through_the_fleet_like_the_in_memory_matrix() {
    // `haqa scenarios gen` writes render_batch() output; `haqa fleet` can
    // also expand the {"matrix": …} wrapper itself.  Both paths must
    // produce the same fleet results.
    let spec = small_matrix(16);
    let dir = std::env::temp_dir();
    let gen_path = dir.join(format!("haqa_it_gen_{}.json", std::process::id()));
    std::fs::write(&gen_path, render_batch(&spec.expand())).unwrap();
    let from_file = Scenario::load_many(gen_path.to_str().unwrap()).unwrap();

    let wrapper_path = dir.join(format!("haqa_it_wrap_{}.json", std::process::id()));
    std::fs::write(
        &wrapper_path,
        r#"{"matrix": {"count": 16, "seed": 9,
                       "devices": ["a6000", "adreno740"],
                       "kernels": ["matmul:64", "softmax:128"],
                       "optimizers": ["random", "local"],
                       "models": ["tinyllama-1.1b", "openllama-3b"],
                       "memory_limits_gb": [8, 12],
                       "budget": 3}}"#,
    )
    .unwrap();
    let from_wrapper = Scenario::load_many(wrapper_path.to_str().unwrap()).unwrap();

    let a = FleetRunner::new(2).quiet().run(&from_file);
    let b = FleetRunner::new(2).quiet().run(&from_wrapper);
    assert_eq!(best_bits(&a), best_bits(&b));
    let _ = std::fs::remove_file(gen_path);
    let _ = std::fs::remove_file(wrapper_path);
}

#[test]
fn fleet_report_emits_per_platform_pareto_fronts() {
    let spec = small_matrix(32);
    let scenarios = spec.expand();
    let report = FleetRunner::new(4).quiet().run(&scenarios);
    let fronts = report.pareto(&scenarios);
    assert!(!fronts.is_empty());
    // Grouping is device/track; this matrix covers both tracks on both
    // devices, so all four groups must appear (sorted by key).
    let groups: Vec<&str> = fronts.iter().map(|f| f.group.as_str()).collect();
    assert!(groups.contains(&"a6000/kernel"), "{groups:?}");
    assert!(groups.contains(&"a6000/bitwidth"), "{groups:?}");
    assert!(groups.contains(&"adreno740/kernel"), "{groups:?}");
    assert!(groups.contains(&"adreno740/bitwidth"), "{groups:?}");
    for f in &fronts {
        assert!(!f.members.is_empty(), "empty front for {}", f.group);
        assert!(f.members.len() <= f.total);
        // Bit-width fronts carry [tokens/s, -footprint]; kernel fronts a
        // single maximized score.
        let arity = if f.group.ends_with("/bitwidth") { 2 } else { 1 };
        for (name, objs) in &f.members {
            assert_eq!(objs.len(), arity, "{name} in {}", f.group);
            assert!(objs.iter().all(|v| v.is_finite()));
        }
    }
    // The fronts must be deterministic for a deterministic fleet.
    let report2 = FleetRunner::new(2).quiet().run(&scenarios);
    let fronts2 = report2.pareto(&scenarios);
    assert_eq!(fronts.len(), fronts2.len());
    for (x, y) in fronts.iter().zip(&fronts2) {
        assert_eq!(x.group, y.group);
        assert_eq!(
            x.members.iter().map(|(n, _)| n).collect::<Vec<_>>(),
            y.members.iter().map(|(n, _)| n).collect::<Vec<_>>()
        );
    }
}
