//! Integration: the traffic-shaped serving simulator wired end to end —
//! `traffic:` scenarios through the agent round loop, the fleet, the
//! eval cache, the resume journal and the Pareto report.  Everything is
//! analytic (no artifacts), so tier-1 `cargo test` covers the whole
//! serving path offline.

use haqa::coordinator::matrix::MatrixSpec;
use haqa::coordinator::scenario::Track;
use haqa::coordinator::{EvalCache, FleetRunner, Scenario};
use haqa::util::json;

/// Traffic-scored bit-width scenarios across every named profile on two
/// models, one per (model, profile) cell.  Distinct seeds shape distinct
/// arrival streams.
fn traffic_scenarios(tag: &str) -> Vec<Scenario> {
    let mut v = Vec::new();
    for (i, model) in ["llama2-7b", "tinyllama-1.1b"].iter().enumerate() {
        for (j, profile) in haqa::coordinator::traffic::PROFILE_NAMES.iter().enumerate() {
            v.push(Scenario {
                name: format!("{tag}_{model}_{profile}"),
                track: Track::Bitwidth,
                model: (*model).into(),
                device: "a6000".into(),
                memory_limit_gb: 24.0,
                traffic: (*profile).into(),
                budget: 5,
                seed: 11 + (i * 16 + j) as u64,
                ..Scenario::default()
            });
        }
    }
    v
}

fn score_bits(report: &haqa::coordinator::FleetReport) -> Vec<u64> {
    report
        .outcomes
        .iter()
        .map(|o| o.as_ref().expect("scenario failed").best_score.to_bits())
        .collect()
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("haqa_it_traffic_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The acceptance gate: a traffic-scored fleet is bit-identical run
/// serially, run on a worker pool, and resumed from a torn journal (the
/// SIGKILL shape: a prefix is journaled, the rest runs under `--resume`).
#[test]
fn traffic_fleet_is_bit_identical_serial_vs_parallel_vs_resumed() {
    let scenarios = traffic_scenarios("tr_ident");
    let serial = FleetRunner::new(1).quiet().run(&scenarios);
    let parallel = FleetRunner::new(4).quiet().run(&scenarios);
    assert_eq!(
        score_bits(&serial),
        score_bits(&parallel),
        "worker parallelism changed a serving score"
    );
    // Serving scores are negated p99 latencies: finite and negative for a
    // deployment that completes requests.
    for out in &serial.outcomes {
        let best = out.as_ref().unwrap().best_score;
        assert!(best.is_finite() && best < 0.0, "score {best} is not a -p99");
    }

    // "Crash" after half the fleet, then resume over the full list.
    let dir = temp_dir("resume");
    let partial = FleetRunner::new(2)
        .quiet()
        .with_state_dir(&dir)
        .unwrap()
        .run(&scenarios[..3]);
    assert_eq!(partial.journal.map(|(records, _)| records), Some(3));
    let resumed = FleetRunner::new(2)
        .quiet()
        .with_state_dir(&dir)
        .unwrap()
        .run(&scenarios);
    assert_eq!(resumed.resumed, 3, "the journaled prefix must be skipped");
    assert_eq!(
        score_bits(&serial),
        score_bits(&resumed),
        "journal replay changed a serving score"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// A traffic-scored scenario and its kernel-only twin (identical except
/// `traffic: ""`) must never share cache entries or journal rows: they
/// answer different questions (p99 under load vs lone-request
/// throughput) and their scores have opposite signs.
#[test]
fn traffic_scenario_never_collides_with_its_kernel_only_twin() {
    let mut plain = Scenario {
        name: "tr_twin".into(),
        track: Track::Bitwidth,
        model: "llama2-7b".into(),
        device: "a6000".into(),
        memory_limit_gb: 24.0,
        budget: 5,
        seed: 11,
        ..Scenario::default()
    };
    let mut traffic = plain.clone();
    traffic.traffic = "chat-burst".into();
    // Same name on purpose: only the `traffic` field separates the keys.
    let scenarios = vec![plain.clone(), traffic.clone()];
    let report = FleetRunner::new(2)
        .quiet()
        .with_cache(EvalCache::new())
        .run(&scenarios);
    let bits = score_bits(&report);
    assert_ne!(bits[0], bits[1], "twin scenarios returned one score");
    let plain_best = report.outcomes[0].as_ref().unwrap().best_score;
    let traffic_best = report.outcomes[1].as_ref().unwrap().best_score;
    assert!(plain_best > 0.0, "bit-width score {plain_best} should be tokens/s");
    assert!(traffic_best < 0.0, "serving score {traffic_best} should be -p99");

    // And the resume journal separates them too: a state dir written by
    // the plain twin must not satisfy the traffic twin.
    let dir = temp_dir("twin");
    plain.name = "tr_twin2".into();
    traffic.name = "tr_twin2".into();
    let first = FleetRunner::new(1)
        .quiet()
        .with_state_dir(&dir)
        .unwrap()
        .run(std::slice::from_ref(&plain));
    assert_eq!(first.resumed, 0);
    let second = FleetRunner::new(1)
        .quiet()
        .with_state_dir(&dir)
        .unwrap()
        .run(std::slice::from_ref(&traffic));
    assert_eq!(second.resumed, 0, "the traffic twin replayed the plain journal row");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Serving evaluations flow through the persistent eval-cache journal
/// like any other track: a fresh cache instance over the same directory
/// replays them bit-identically with hits.
#[test]
fn serving_scores_warm_from_the_persistent_cache() {
    let dir = temp_dir("warm");
    std::fs::create_dir_all(&dir).unwrap();
    let scenarios = traffic_scenarios("tr_warm");
    let cold = FleetRunner::new(2)
        .quiet()
        .with_cache(EvalCache::with_dir(&dir).unwrap())
        .run(&scenarios);
    let warm = FleetRunner::new(2)
        .quiet()
        .with_cache(EvalCache::with_dir(&dir).unwrap())
        .run(&scenarios);
    assert_eq!(score_bits(&cold), score_bits(&warm));
    let st = warm.cache.unwrap();
    assert!(st.hits > 0, "warm run over serving scenarios saw zero cache hits");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The matrix `traffic` axis flows through generation, the fleet and the
/// report: generated serving scenarios run like hand-written ones and
/// surface as `device/serving` Pareto groups with
/// `[-p99, tokens/s]` objective vectors.
#[test]
fn matrix_traffic_axis_flows_through_fleet_and_pareto() {
    let j = json::parse(
        r#"{"count": 12, "seed": 9,
             "devices": ["a6000"],
             "kernels": ["matmul:64"],
             "optimizers": ["random"],
             "models": ["tinyllama-1.1b"],
             "memory_limits_gb": [24],
             "traffic": ["chat-burst", "mobile-single-user"],
             "budget": 3}"#,
    )
    .unwrap();
    let spec = MatrixSpec::from_json(&j).unwrap();
    let scenarios = spec.expand();
    let serving: Vec<&Scenario> = scenarios.iter().filter(|s| !s.traffic.is_empty()).collect();
    assert!(!serving.is_empty(), "the matrix generated no serving scenarios");
    for sc in &serving {
        assert_eq!(sc.track, Track::Bitwidth);
        assert!(sc.name.starts_with("gen/tr/"), "{}", sc.name);
    }

    let report = FleetRunner::new(2).quiet().run(&scenarios);
    for (sc, out) in scenarios.iter().zip(&report.outcomes) {
        assert!(out.is_ok(), "{} failed: {:?}", sc.name, out.as_ref().err());
    }
    let fronts = report.pareto(&scenarios);
    let serving_front = fronts
        .iter()
        .find(|f| f.group == "a6000/serving")
        .expect("no a6000/serving Pareto group");
    assert!(!serving_front.members.is_empty());
    for (name, objs) in &serving_front.members {
        assert_eq!(objs.len(), 2, "{name}: serving objectives are [-p99, tokens/s]");
        assert!(objs[0] < 0.0, "{name}: -p99 must be negative, got {}", objs[0]);
        assert!(objs[1] >= 0.0, "{name}: tokens/s must be non-negative");
    }
}
