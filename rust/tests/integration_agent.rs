//! Integration tests for the async agent pipeline: overlapped in-flight
//! fleet runs stay bit-identical to serial, recorded transcripts replay
//! offline, and per-round agent cost lands in the task logs.
//!
//! Everything here runs on the simulator tracks (kernel / bit-width), so
//! no artifacts are needed and the suite stays offline.

use haqa::coordinator::scenario::Track;
use haqa::coordinator::{FleetRunner, Scenario, Workflow};
use haqa::util::json;

fn kernel_scenarios(backend: &str, tag: &str) -> Vec<Scenario> {
    let mut v: Vec<Scenario> = ["matmul:64", "softmax:128", "rmsnorm:64"]
        .iter()
        .enumerate()
        .map(|(i, kernel)| Scenario {
            name: format!("agent_{tag}_{}", kernel.replace(':', "_")),
            track: Track::Kernel,
            kernel: (*kernel).into(),
            optimizer: if i == 1 { "random".into() } else { "haqa".into() },
            budget: 4,
            seed: 5 + i as u64,
            backend: backend.into(),
            ..Scenario::default()
        })
        .collect();
    v.push(Scenario {
        name: format!("agent_{tag}_bw"),
        track: Track::Bitwidth,
        model: "llama2-13b".into(),
        memory_limit_gb: 12.0,
        backend: backend.into(),
        ..Scenario::default()
    });
    v
}

fn score_bits(report: &haqa::coordinator::FleetReport) -> Vec<u64> {
    report
        .outcomes
        .iter()
        .map(|o| o.as_ref().expect("scenario failed").best_score.to_bits())
        .collect()
}

/// The tentpole guarantee: a fleet that overlaps many in-flight agent
/// queries (with real request latency) produces exactly the scores of the
/// serial blocking path — and of the plain no-latency backend.
#[test]
fn pipelined_fleet_is_bit_identical_to_serial() {
    // 2 ms of simulated API latency: enough that requests are genuinely
    // in flight when polled, cheap enough for CI.
    let slow = kernel_scenarios("simulated-slow:2", "bitid");
    let serial = FleetRunner::new(1).quiet().without_cache().run(&slow);
    let pipelined = FleetRunner::new(2)
        .with_inflight(4)
        .quiet()
        .without_cache()
        .run(&slow);
    assert_eq!(
        score_bits(&serial),
        score_bits(&pipelined),
        "overlapped in-flight agent queries must not change results"
    );
    // The latency wrapper itself must be transparent: the same scenarios
    // on the instant simulated backend give the same scores.
    let instant = kernel_scenarios("simulated", "bitid");
    let plain = FleetRunner::new(2).quiet().without_cache().run(&instant);
    assert_eq!(score_bits(&serial), score_bits(&plain));
}

/// With one worker, overlapping agent queries across scenarios must beat
/// the blocking path by construction: the blocking wall is at least the
/// sum of every request's latency, the pipelined wall only the slowest
/// chain's.
#[test]
fn inflight_overlap_reduces_wall_clock() {
    let scenarios: Vec<Scenario> = (0..4)
        .map(|i| Scenario {
            name: format!("agent_overlap_wall_{i}"),
            track: Track::Kernel,
            kernel: "matmul:64".into(),
            optimizer: "haqa".into(),
            budget: 3,
            seed: 40 + i,
            backend: "simulated-slow:20".into(),
            ..Scenario::default()
        })
        .collect();
    let timed = |runner: FleetRunner| {
        let t0 = std::time::Instant::now();
        let report = runner.run(&scenarios);
        (t0.elapsed(), score_bits(&report))
    };
    let (blocking, blocking_bits) = timed(FleetRunner::new(1).quiet().without_cache());
    let (pipelined, pipelined_bits) =
        timed(FleetRunner::new(1).with_inflight(4).quiet().without_cache());
    assert_eq!(blocking_bits, pipelined_bits);
    // Blocking: ≥ 4 scenarios × 3 rounds × 20 ms = 240 ms serialized.
    // Pipelined: ~3 rounds × 20 ms + evaluation time.  Generous margin so
    // loaded CI runners never flake.
    assert!(
        pipelined < blocking.mul_f64(0.8),
        "overlap produced no speedup: blocking {blocking:?} vs pipelined {pipelined:?}"
    );
}

/// A session recorded through `record:<path>` replays bit-identically —
/// scores AND cost accounting — with no live backend.
#[test]
fn recorded_agent_run_replays_bit_identically() {
    let dir = std::env::temp_dir().join(format!("haqa_agent_replay_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let journal = dir.join("transcripts.jsonl");
    let sc = |backend: String| Scenario {
        name: "agent_replay_kernel".into(),
        track: Track::Kernel,
        kernel: "silu:64".into(),
        optimizer: "haqa".into(),
        budget: 5,
        seed: 17,
        backend,
        ..Scenario::default()
    };
    let wf = Workflow::simulated().quiet();
    let live = wf
        .run(&sc(format!("record:{}", journal.display())))
        .expect("recorded run");
    assert!(journal.exists(), "transcript journal written");

    let replayed = wf
        .run(&sc(format!("replay:{}", journal.display())))
        .expect("replayed run");
    assert_eq!(live.history.len(), replayed.history.len());
    for (a, b) in live.history.iter().zip(&replayed.history) {
        assert_eq!(a.score.to_bits(), b.score.to_bits(), "scores replay bit-exactly");
        assert_eq!(a.feedback, b.feedback);
    }
    assert_eq!(
        live.cost_report, replayed.cost_report,
        "token/latency accounting replays bit-exactly"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// A replayed run that diverges from its recording must fail loudly: the
/// never-stall default-config fallback is for live backends only —
/// degrading a replay to defaults would silently report wrong results.
#[test]
fn diverged_replay_is_a_hard_error_not_a_silent_default() {
    let dir = std::env::temp_dir().join(format!("haqa_agent_diverge_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let journal = dir.join("transcripts.jsonl");
    let sc = |budget: usize, backend: String| Scenario {
        name: "agent_diverge_kernel".into(),
        track: Track::Kernel,
        kernel: "softmax:64".into(),
        optimizer: "haqa".into(),
        budget,
        seed: 31,
        backend,
        ..Scenario::default()
    };
    let wf = Workflow::simulated().quiet();
    wf.run(&sc(3, format!("record:{}", journal.display())))
        .expect("recorded run");
    // Two extra rounds whose prompts were never recorded: the replay must
    // surface the divergence as an error, not default configs.
    let err = wf
        .run(&sc(5, format!("replay:{}", journal.display())))
        .expect_err("diverged replay must fail");
    assert!(
        format!("{err:#}").contains("no recorded completion"),
        "{err:#}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The §3.3 audit trail: every haqa round in the task log carries its own
/// prompt/completion token counts and API latency, not just the final
/// Appendix-C summary line.
#[test]
fn task_log_records_per_round_agent_cost() {
    let sc = Scenario {
        name: "agent_roundcost_kernel".into(),
        track: Track::Kernel,
        kernel: "rope:64".into(),
        optimizer: "haqa".into(),
        budget: 3,
        seed: 23,
        ..Scenario::default()
    };
    let out = Workflow::simulated().run(&sc).expect("kernel run");
    let path = out.log_path.expect("task log written");
    let log = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let rounds = log.req_arr("rounds").unwrap();
    assert_eq!(rounds.len(), 3);
    for r in rounds {
        let cost = r.get("cost").expect("per-round cost entry");
        assert!(cost.req_f64("queries").unwrap() >= 1.0);
        assert!(cost.req_f64("prompt_tokens").unwrap() > 0.0);
        assert!(cost.req_f64("completion_tokens").unwrap() > 0.0);
        assert!(cost.req_f64("api_seconds").unwrap() > 0.0);
    }
    // Baselines stay cost-free in their logs.
    let sc = Scenario {
        name: "agent_roundcost_baseline".into(),
        optimizer: "random".into(),
        track: Track::Kernel,
        kernel: "rope:64".into(),
        budget: 2,
        seed: 23,
        ..Scenario::default()
    };
    let out = Workflow::simulated().run(&sc).expect("baseline run");
    let log = json::parse(&std::fs::read_to_string(out.log_path.unwrap()).unwrap()).unwrap();
    for r in log.req_arr("rounds").unwrap() {
        assert!(r.get("cost").is_none(), "baselines have no agent cost");
    }
}

/// The batching tentpole's guarantee: coalescing many scenarios' in-flight
/// proposals into shared provider batches changes the number of provider
/// round-trips and nothing else — scores are bit-identical to the
/// unbatched (batch 1) run over the same shared pipeline.
#[test]
fn batched_fleet_is_bit_identical_with_fewer_provider_requests() {
    let scenarios = kernel_scenarios("simulated", "batch");
    let run = |batch: usize| {
        FleetRunner::new(1)
            .with_inflight(scenarios.len())
            .with_batch(batch)
            .quiet()
            .without_cache()
            .run(&scenarios)
    };
    let unbatched = run(1);
    let batched = run(4);
    assert_eq!(
        score_bits(&unbatched),
        score_bits(&batched),
        "provider batching must not change results"
    );
    let u = unbatched.agent.expect("batch mode reports agent stats");
    let b = batched.agent.expect("batch mode reports agent stats");
    assert_eq!(u.submitted, b.submitted, "same request stream either way");
    assert_eq!(
        u.provider_requests, u.submitted,
        "batch 1 is the one-call-per-request control"
    );
    assert!(
        b.provider_requests < u.provider_requests,
        "batching must amortize round-trips: {} -> {}",
        u.provider_requests,
        b.provider_requests
    );
    assert!(b.max_batch > 1, "batches actually filled past size 1");
}

/// A batched run recorded through the shared pool replays bit-identically
/// offline — completions, cost accounting AND batch boundaries (the
/// journal's `{"batch": …}` records are enforced on replay).
#[test]
fn recorded_batched_run_replays_bit_identically() {
    let dir = std::env::temp_dir().join(format!("haqa_agent_batchrec_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let journal = dir.join("transcripts.jsonl");
    let scenarios = |backend: String| -> Vec<Scenario> {
        ["matmul:64", "softmax:128", "rmsnorm:64"]
            .iter()
            .enumerate()
            .map(|(i, kernel)| Scenario {
                name: format!("batchrec_{}", kernel.replace(':', "_")),
                track: Track::Kernel,
                kernel: (*kernel).into(),
                optimizer: "haqa".into(),
                budget: 4,
                seed: 50 + i as u64,
                backend: backend.clone(),
                ..Scenario::default()
            })
            .collect()
    };
    // One worker: the sweep order — and therefore the recorded batch
    // composition — is deterministic, so the replay reproduces it exactly.
    let run = |scs: &[Scenario]| {
        FleetRunner::new(1)
            .with_inflight(4)
            .with_batch(4)
            .quiet()
            .without_cache()
            .run(scs)
    };
    let live = run(&scenarios(format!("record:{}", journal.display())));
    assert!(journal.exists(), "batched transcript journal written");
    let replayed = run(&scenarios(format!("replay:{}", journal.display())));
    assert_eq!(score_bits(&live), score_bits(&replayed));
    for (a, b) in live.outcomes.iter().zip(&replayed.outcomes) {
        let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
        assert_eq!(
            a.cost_report, b.cost_report,
            "token/latency accounting replays bit-exactly"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A scenario with an unknown backend spec fails loudly (not by silently
/// falling back to the simulated policy).
#[test]
fn unknown_backend_spec_is_a_hard_error() {
    let sc = Scenario {
        name: "agent_bad_backend".into(),
        track: Track::Kernel,
        kernel: "matmul:64".into(),
        optimizer: "haqa".into(),
        budget: 2,
        backend: "telepathy".into(),
        ..Scenario::default()
    };
    let err = Workflow::simulated().run(&sc).unwrap_err();
    assert!(format!("{err:#}").contains("telepathy"), "{err:#}");
}
