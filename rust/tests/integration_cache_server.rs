//! Integration: the remote eval-cache tier end to end — wire failure
//! edges (torn replies, clients dying mid-request), first-write-wins
//! under concurrent writers, journal rotation under load, and
//! remote-tier-vs-local bit-identity through the public cache API.

use std::cell::Cell;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{Arc, Barrier};
use std::time::Duration;

use anyhow::Result;
use haqa::coordinator::{CacheServer, EvalCache, Evaluation, Evaluator, RemoteCacheTier};
use haqa::search::{spaces, Config, Space};
use haqa::util::json::{self, Json};
use haqa::util::rng::Rng;

/// A deterministic toy evaluator that counts real evaluations, so tests
/// can tell "served by the remote tier" from "silently recomputed".
struct ToyEval {
    space: Space,
    calls: Cell<usize>,
}

impl ToyEval {
    fn new() -> ToyEval {
        ToyEval {
            space: spaces::kernel_exec(),
            calls: Cell::new(0),
        }
    }
}

impl Evaluator for ToyEval {
    fn track(&self) -> &'static str {
        "it_remote"
    }
    fn space(&self) -> &Space {
        &self.space
    }
    fn scope(&self) -> Json {
        json::parse(r#"{"suite": "cache_server"}"#).unwrap()
    }
    fn evaluate(&self, cfg: &Config) -> Result<Evaluation> {
        self.calls.set(self.calls.get() + 1);
        let score: f64 = self
            .space
            .encode(cfg)
            .iter()
            .enumerate()
            .map(|(i, v)| v * (i as f64 + 1.0))
            .sum();
        Ok(Evaluation {
            score,
            extra: vec![score * 0.5],
            feedback: "{\"note\": \"toy\"}".into(),
        })
    }
}

/// One raw request line → one parsed reply (a fresh connection each call,
/// speaking the wire protocol directly).
fn raw_request(addr: SocketAddr, line: &str) -> Json {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.write_all(line.as_bytes()).unwrap();
    stream.write_all(b"\n").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    json::parse(reply.trim()).unwrap()
}

/// A `put` request line for `key` carrying a bit-exact `score`.
fn put_line(key: u128, score: f64) -> String {
    format!(
        "{{\"op\":\"put\",\"v\":1,\"key\":\"{key:032x}\",\
         \"result\":{{\"score\":{score},\"bits\":\"{:016x}\",\"feedback\":\"it\"}}}}",
        score.to_bits()
    )
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("haqa_it_srv_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn torn_reply_mid_batch_get_is_a_hard_error() {
    // A fake server that answers the sweep's batch_get with half a reply
    // line and hangs up — the worst-timed crash a client can observe.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let fake = std::thread::spawn(move || {
        let (mut stream, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"batch_get\""), "expected a batch_get, got: {line}");
        stream.write_all(b"{\"ok\":true,\"results\":[").unwrap();
        stream.flush().unwrap();
        // Dropping the stream tears the line.
    });

    let cache = EvalCache::with_remote(RemoteCacheTier::new(&addr.to_string()).unwrap(), None);
    let ev = ToyEval::new();
    let cfgs: Vec<Config> = (0..3).map(|i| ev.space.sample(&mut Rng::new(i))).collect();
    let err = cache
        .get_or_evaluate_batch(&ev, &cfgs)
        .expect_err("a torn reply must be a hard error");
    let msg = format!("{err:#}");
    assert!(msg.contains("torn"), "error must name the torn reply: {msg}");
    assert_eq!(
        ev.calls.get(),
        0,
        "the cache must never silently recompute around a torn reply"
    );
    fake.join().unwrap();
}

#[test]
fn client_disconnect_mid_request_leaves_the_server_serving() {
    let server = CacheServer::spawn("127.0.0.1:0", EvalCache::new()).unwrap();
    {
        // A client that dies halfway through writing its request line.
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream.write_all(b"{\"op\":\"get\",\"v\":1,\"key\":\"00").unwrap();
        stream.flush().unwrap();
    }
    // The half-written line concerns that connection only: fresh clients
    // get full service.
    let j = raw_request(server.addr(), &put_line(5, 1.5));
    assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(j.get("stored").unwrap().as_bool(), Some(true));
    let j = raw_request(
        server.addr(),
        &format!("{{\"op\":\"get\",\"v\":1,\"key\":\"{:032x}\"}}", 5u128),
    );
    assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(j.get("found").unwrap().as_bool(), Some(true));
}

#[test]
fn concurrent_puts_are_first_write_wins() {
    let server = CacheServer::spawn("127.0.0.1:0", EvalCache::new()).unwrap();
    let addr = server.addr();
    const KEYS: u128 = 48;
    // Both writers race the identical pipelined put batch; the shard
    // mutex must hand exactly one `stored: true` per key across them.
    let barrier = Arc::new(Barrier::new(2));
    let mut handles = Vec::new();
    for _ in 0..2 {
        let barrier = Arc::clone(&barrier);
        handles.push(std::thread::spawn(move || -> usize {
            let stream = TcpStream::connect(addr).unwrap();
            stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut writer = stream;
            let mut lines = String::new();
            for k in 1..=KEYS {
                lines.push_str(&put_line(k, 4.25));
                lines.push('\n');
            }
            barrier.wait();
            writer.write_all(lines.as_bytes()).unwrap();
            writer.flush().unwrap();
            let mut stored = 0usize;
            for _ in 0..KEYS {
                let mut reply = String::new();
                reader.read_line(&mut reply).unwrap();
                let j = json::parse(reply.trim()).unwrap();
                assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{reply}");
                if j.get("stored").unwrap().as_bool() == Some(true) {
                    stored += 1;
                }
            }
            stored
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(
        total as u128, KEYS,
        "exactly one racing writer may win the first write for each key"
    );
}

#[test]
fn rotate_under_load_never_loses_records() {
    let dir = temp_dir("rotate_load");
    let server = CacheServer::spawn("127.0.0.1:0", EvalCache::with_dir(&dir).unwrap()).unwrap();
    let addr = server.addr();
    const WRITERS: u128 = 3;
    const PER: u128 = 40;
    let mut handles = Vec::new();
    for w in 0..WRITERS {
        handles.push(std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut writer = stream;
            for i in 0..PER {
                let key = w * 1000 + i + 1;
                writer.write_all(put_line(key, key as f64).as_bytes()).unwrap();
                writer.write_all(b"\n").unwrap();
                writer.flush().unwrap();
                let mut reply = String::new();
                reader.read_line(&mut reply).unwrap();
                let j = json::parse(reply.trim()).unwrap();
                assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{reply}");
                assert_eq!(j.get("stored").unwrap().as_bool(), Some(true), "{reply}");
            }
        }));
    }
    // Generation rotations race the writers on live connections.
    for _ in 0..4 {
        let j = raw_request(addr, "{\"op\":\"rotate\",\"v\":1}");
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{j:?}");
        std::thread::sleep(Duration::from_millis(5));
    }
    for h in handles {
        h.join().unwrap();
    }
    let j = raw_request(addr, "{\"op\":\"rotate\",\"v\":1}");
    assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(j.get("generation").and_then(|v| v.as_f64()), Some(5.0));
    server.flush();
    drop(server);
    // The journal that survived five mid-load rotations must still hold
    // every record any writer was told `stored: true` for.
    let reloaded = EvalCache::with_dir(&dir).unwrap();
    assert_eq!(
        reloaded.len(),
        (WRITERS * PER) as usize,
        "rotation under load lost journal records"
    );
    drop(reloaded);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn remote_tier_is_bit_identical_and_skips_evaluation_when_warm() {
    let ev = ToyEval::new();
    let cfgs: Vec<Config> = (0..8).map(|i| ev.space.sample(&mut Rng::new(100 + i))).collect();
    let local = EvalCache::new();
    let baseline: Vec<u64> = local
        .get_or_evaluate_batch(&ev, &cfgs)
        .unwrap()
        .iter()
        .map(|(e, _)| e.score.to_bits())
        .collect();

    let server = CacheServer::spawn("127.0.0.1:0", EvalCache::new()).unwrap();
    let addr = server.addr().to_string();

    // A cold client evaluates everything itself and publishes it.
    let ev_a = ToyEval::new();
    let a = EvalCache::with_remote(RemoteCacheTier::new(&addr).unwrap(), None);
    let got_a: Vec<u64> = a
        .get_or_evaluate_batch(&ev_a, &cfgs)
        .unwrap()
        .iter()
        .map(|(e, _)| e.score.to_bits())
        .collect();
    assert_eq!(baseline, got_a, "the remote tier must be score-invariant");
    assert!(ev_a.calls.get() > 0, "a cold shared cache cannot serve anything");

    // A second cold client — fresh memory tier, fresh evaluator — is
    // served entirely by the shared server: zero real evaluations.
    let ev_b = ToyEval::new();
    let b = EvalCache::with_remote(RemoteCacheTier::new(&addr).unwrap(), None);
    let got_b: Vec<u64> = b
        .get_or_evaluate_batch(&ev_b, &cfgs)
        .unwrap()
        .iter()
        .map(|(e, _)| e.score.to_bits())
        .collect();
    assert_eq!(baseline, got_b, "remote-served scores must be bit-identical");
    assert_eq!(ev_b.calls.get(), 0, "a warm server must eliminate evaluation");
    let st = b.stats();
    assert!(st.remote_hits > 0, "{st:?}");
    assert_eq!(st.remote_misses, 0, "{st:?}");
    assert_eq!(st.misses, 0, "remote hits must not count as real evaluations");
    assert_eq!(b.remote_addr(), Some(addr.as_str()));
}
