//! Cross-module property tests (mini-proptest; coordinator / simulator /
//! agent invariants), plus exhaustive every-byte-offset crash-truncation
//! sweeps over both group-committed journals (`eval_cache.jsonl` and
//! `fleet_state.jsonl`).

use haqa::agent::simulated::SimulatedLlm;
use haqa::agent::{Agent, TaskContext, TaskKind};
use haqa::coordinator::fleet_state::{self, FleetJournal};
use haqa::coordinator::scenario::Track;
use haqa::coordinator::workflow::TrackOutcome;
use haqa::coordinator::{EvalCache, FleetRunner, Scenario};
use haqa::hardware::{kernel_latency_us, DeviceProfile, ExecConfig, KernelKind, Workload};
use haqa::hardware::{memory, ModelProfile};
use haqa::optimizers::Observation;
use haqa::quant::Scheme;
use haqa::search::spaces;
use haqa::search::Value;
use haqa::util::json::Json;
use haqa::util::proptest::{check, Gen, I64Range, PairGen};
use haqa::util::rng::Rng;

/// Generator: a random valid kernel_exec configuration.
struct ExecGen;

impl Gen for ExecGen {
    type Value = haqa::search::Config;
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        spaces::kernel_exec().sample(rng)
    }
}

#[test]
fn prop_simulated_latency_positive_and_bounded() {
    // Latency is positive, finite, and never better than the calibrated
    // HAQA optimum for that workload (the model's floor).
    check(1, 300, &ExecGen, |cfg| {
        let exec = ExecConfig::from_config(cfg);
        for kernel in KernelKind::ALL {
            for batch in [1usize, 64, 128] {
                let w = Workload::new(kernel, batch);
                for dev in [DeviceProfile::a6000(), DeviceProfile::adreno740()] {
                    let lat = kernel_latency_us(&w, &dev, &exec, None);
                    if !(lat.is_finite() && lat > 0.0) {
                        return Err(format!("latency {lat}"));
                    }
                    let floor =
                        haqa::hardware::workload::calibrated(&w).1 * dev.kernel_scale;
                    if lat < floor - 1e-9 {
                        return Err(format!("below floor: {lat} < {floor}"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_memory_monotone_in_bits_and_size() {
    check(
        2,
        100,
        &PairGen(I64Range(0, 6), I64Range(0, 6)),
        |(a, b)| {
            let all = [
                ModelProfile::llama2_7b(),
                ModelProfile::llama2_13b(),
                ModelProfile::llama32_3b(),
                ModelProfile::llama3_8b(),
                ModelProfile::openllama_3b(),
                ModelProfile::tinyllama_1_1b(),
                ModelProfile::gpt2_large(),
            ];
            let (ma, mb) = (&all[*a as usize], &all[*b as usize]);
            // fewer bits => less memory
            let f = memory::footprint_gb(ma, Scheme::FP16);
            let i8 = memory::footprint_gb(ma, Scheme::INT8);
            let i4 = memory::footprint_gb(ma, Scheme::INT4);
            if !(i4 < i8 && i8 < f) {
                return Err(format!("not monotone in bits: {i4} {i8} {f}"));
            }
            // bigger model => more memory at the same scheme
            if ma.params_b > mb.params_b {
                let (xa, xb) = (
                    memory::footprint_gb(ma, Scheme::INT8),
                    memory::footprint_gb(mb, Scheme::INT8),
                );
                if xa <= xb {
                    return Err(format!("not monotone in size: {xa} <= {xb}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_agent_always_returns_valid_config_despite_failures() {
    // Whatever the failure-injection seed does, the retry/repair loop must
    // deliver an in-range config — the §3.3 no-stall guarantee.
    check(3, 25, &I64Range(0, 10_000), |seed| {
        let space = spaces::resnet_qat();
        let backend = SimulatedLlm::new(*seed as u64).with_failure_rate(0.8);
        let mut agent = Agent::blocking(backend);
        let mut history = Vec::new();
        for round in 0..4 {
            let ctx = TaskContext {
                kind: TaskKind::Finetune,
                space: &space,
                history: &history,
                rounds_left: 4 - round,
                hardware: None,
                objective: Json::obj(),
            };
            let (cfg, _) = agent.propose(&ctx).map_err(|e| e.to_string())?;
            if !space.is_valid(&cfg) {
                return Err(format!("invalid config: {cfg:?}"));
            }
            history.push(Observation::new(cfg, 0.5 + round as f64 * 0.01));
        }
        Ok(())
    });
}

#[test]
fn prop_history_window_monotone_and_budgeted() {
    check(4, 100, &PairGen(I64Range(1, 60), I64Range(80, 4000)), |(n, budget)| {
        let space = spaces::llama_qlora();
        let hist: Vec<Observation> = (0..*n)
            .map(|i| {
                let mut o = Observation::new(space.default_config(), i as f64);
                o.feedback = "f".repeat(200);
                o
            })
            .collect();
        let mgr = haqa::agent::history::HistoryManager {
            max_tokens: *budget as usize,
            max_entries: 16,
        };
        let w = mgr.window(&hist);
        if w.is_empty() {
            return Err("empty window".into());
        }
        if w[0].0 != 0 {
            return Err("anchor not kept".into());
        }
        if w.last().unwrap().0 != (*n as usize) - 1 {
            return Err("latest round dropped".into());
        }
        if !w.windows(2).all(|p| p[0].0 < p[1].0) {
            return Err("not strictly increasing".into());
        }
        if w.len() > 16 {
            return Err("entry cap violated".into());
        }
        Ok(())
    });
}

#[test]
fn prop_exec_roundtrip_through_space() {
    // Config -> ExecConfig -> Config is stable (idempotent repair).
    check(5, 200, &ExecGen, |cfg| {
        let space = spaces::kernel_exec();
        let e1 = ExecConfig::from_config(cfg);
        let back = e1.to_config(&space);
        let e2 = ExecConfig::from_config(&back);
        if e1 != e2 {
            return Err(format!("{e1:?} != {e2:?}"));
        }
        Ok(())
    });
}

/// A distinct scenario per index: name and seed both vary, so every
/// journal record carries a different [`fleet_state::scenario_key`].
fn trunc_scenario(i: usize) -> Scenario {
    Scenario {
        name: format!("trunc_{i}"),
        seed: i as u64,
        ..Scenario::default()
    }
}

/// A float-heavy outcome whose payload would not survive decimal JSON —
/// the truncation sweep doubles as a bit-exactness check on the survivors.
fn trunc_outcome(i: usize) -> TrackOutcome {
    let mut config = haqa::search::Config::new();
    config.insert("lr".into(), Value::Float(0.3 + i as f64 * 1e-13));
    config.insert("rank".into(), Value::Int(i as i64));
    TrackOutcome {
        history: vec![Observation {
            config,
            score: (i as f64 + 0.1) / 3.0,
            extra: vec![1.0 / (i as f64 + 3.0)],
            feedback: format!("r{i}"),
        }],
        best_score: (i as f64 + 0.1) / 3.0,
        cost_report: None,
        log_path: None,
        cache_hits: i,
        cache_misses: 1,
    }
}

/// Crash-truncate `fleet_state.jsonl` at **every** byte offset inside a
/// group-committed flush: recovery must deliver exactly the records whose
/// terminating newline survived (plus a newline-less-but-complete tail,
/// which append-healing legitimately recovers), count exactly one skipped
/// line for a mid-record tear, and — after the healed reopen appends a new
/// record — never duplicate, merge or lose anything else.
#[test]
fn prop_fleet_state_survives_truncation_at_every_byte() {
    fleet_state_truncation_sweep(None, "state");
}

/// The identical sweep over a **scoped** journal — the per-client records
/// `haqa serve` writes.  The `"client"` tag lengthens every line (moving
/// each torn-byte window) but must change nothing about recovery.
#[test]
fn prop_scoped_serve_journal_survives_truncation_at_every_byte() {
    fleet_state_truncation_sweep(Some("ci-client"), "scoped");
}

fn fleet_state_truncation_sweep(scope: Option<&str>, tag: &str) {
    let open = |dir: &std::path::Path| {
        let j = FleetJournal::open(dir).unwrap();
        match scope {
            Some(s) => j.with_scope(s),
            None => j,
        }
    };
    let base =
        std::env::temp_dir().join(format!("haqa_props_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);

    let n = 6usize;
    let full_dir = base.join("full");
    {
        let mut j = open(&full_dir);
        for i in 0..n {
            j.append(&trunc_scenario(i), &trunc_outcome(i));
        }
    } // drop group-commits the whole batch
    let bytes = std::fs::read(full_dir.join(fleet_state::STATE_FILE)).unwrap();
    if let Some(s) = scope {
        let text = String::from_utf8_lossy(&bytes);
        let tagged = format!("\"client\":\"{s}\"");
        assert!(
            text.lines().all(|l| l.contains(&tagged)),
            "every scoped record carries the client tag"
        );
    }
    // Offset just past each record's '\n': record i is complete in a
    // prefix of length `cut` iff ends[i] <= cut.
    let ends: Vec<usize> = bytes
        .iter()
        .enumerate()
        .filter(|(_, &b)| b == b'\n')
        .map(|(i, _)| i + 1)
        .collect();
    assert_eq!(ends.len(), n, "one line per record");

    let (extra_sc, extra_out) = (trunc_scenario(99), trunc_outcome(99));
    let dir = base.join("cut");
    std::fs::create_dir_all(&dir).unwrap();
    for cut in 0..=bytes.len() {
        std::fs::write(dir.join(fleet_state::STATE_FILE), &bytes[..cut]).unwrap();
        let complete = ends.iter().filter(|&&e| e <= cut).count();
        let torn = cut > 0 && ends.binary_search(&cut).is_err();
        // The tail is a whole record missing only its newline: healing
        // (appending '\n') legitimately recovers it on the next load.
        let recoverable = ends.binary_search(&(cut + 1)).is_ok();

        let (map, scan) = fleet_state::load(&dir).unwrap();
        assert_eq!(map.len(), complete, "cut={cut}");
        assert_eq!(scan.torn_tail, torn, "cut={cut}");
        assert_eq!(scan.skipped, usize::from(torn), "cut={cut}");
        for i in 0..complete {
            assert!(
                map.contains_key(&fleet_state::scenario_key(&trunc_scenario(i))),
                "cut={cut}: record {i} must survive"
            );
        }

        // Reopen append-healed and journal one more outcome — the crashed
        // run's successor. The torn line stays lost (skipped), the healed
        // tail stays recovered, nothing duplicates.
        {
            let mut j = open(&dir);
            j.append(&extra_sc, &extra_out);
        }
        let (map, scan) = fleet_state::load(&dir).unwrap();
        assert!(!scan.torn_tail, "cut={cut}: reopen healed the tail");
        assert_eq!(scan.skipped, usize::from(torn && !recoverable), "cut={cut}");
        assert_eq!(
            map.len(),
            complete + usize::from(recoverable) + 1,
            "cut={cut}: survivors + healed tail + new append"
        );
        assert!(map.contains_key(&fleet_state::scenario_key(&extra_sc)));
    }
    let _ = std::fs::remove_dir_all(&base);
}

/// The same every-byte-offset crash sweep over the eval-cache journal:
/// `EvalCache::with_dir` must load exactly the surviving records at any
/// truncation point, heal idempotently, and — when the fleet re-runs over
/// the truncated tier — recompute only what was lost, bit-identically,
/// converging the journal back to one record per key.
#[test]
fn prop_eval_cache_journal_survives_truncation_at_every_byte() {
    let base = std::env::temp_dir().join(format!("haqa_props_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);

    let scenarios: Vec<Scenario> = (0..2)
        .map(|i| Scenario {
            name: format!("cache_trunc_{i}"),
            track: Track::Kernel,
            kernel: "matmul:64".into(),
            optimizer: if i == 0 { "haqa" } else { "random" }.into(),
            budget: 3,
            seed: i as u64,
            ..Scenario::default()
        })
        .collect();
    let full_dir = base.join("full");
    let full_scores: Vec<u64> = {
        let report = FleetRunner::new(2)
            .with_cache(EvalCache::with_dir(&full_dir).unwrap())
            .run(&scenarios);
        report
            .outcomes
            .iter()
            .map(|o| o.as_ref().unwrap().best_score.to_bits())
            .collect()
    };
    let bytes = std::fs::read(full_dir.join(haqa::coordinator::cache::JOURNAL_FILE)).unwrap();
    let ends: Vec<usize> = bytes
        .iter()
        .enumerate()
        .filter(|(_, &b)| b == b'\n')
        .map(|(i, _)| i + 1)
        .collect();
    let records = ends.len();
    assert!(records >= 4, "expected a non-trivial journal, got {records} records");

    let dir = base.join("cut");
    std::fs::create_dir_all(&dir).unwrap();
    let journal = dir.join(haqa::coordinator::cache::JOURNAL_FILE);
    for cut in 0..=bytes.len() {
        std::fs::write(&journal, &bytes[..cut]).unwrap();
        let complete = ends.iter().filter(|&&e| e <= cut).count();
        let recoverable = ends.binary_search(&(cut + 1)).is_ok();
        let expect = complete + usize::from(recoverable);
        let cache = EvalCache::with_dir(&dir).unwrap();
        assert_eq!(cache.len(), expect, "cut={cut}");
        drop(cache);
        // Heal-then-open is idempotent: a second open sees the same tier.
        let again = EvalCache::with_dir(&dir).unwrap();
        assert_eq!(again.len(), expect, "cut={cut}: reload after healing");
    }

    // At a few representative tears (clean, mid-file, mid-final-record,
    // intact), re-run the fleet over the truncated tier: scores stay
    // bit-identical and the journal converges back to one record per key
    // — loaded keys are never re-appended, lost keys are re-journaled.
    for cut in [0, bytes.len() / 3, bytes.len() - 2, bytes.len()] {
        std::fs::write(&journal, &bytes[..cut]).unwrap();
        let report = FleetRunner::new(2)
            .with_cache(EvalCache::with_dir(&dir).unwrap())
            .run(&scenarios);
        for (o, &bits) in report.outcomes.iter().zip(&full_scores) {
            assert_eq!(
                o.as_ref().unwrap().best_score.to_bits(),
                bits,
                "cut={cut}: truncation changed a score"
            );
        }
        let reloaded = EvalCache::with_dir(&dir).unwrap();
        assert_eq!(reloaded.len(), records, "cut={cut}: no duplicates, no losses");
    }
    let _ = std::fs::remove_dir_all(&base);
}

#[test]
fn prop_dorefa_quant_within_levels() {
    check(6, 200, &PairGen(I64Range(2, 8), I64Range(1, 512)), |(k, n)| {
        let mut rng = Rng::new((*k as u64) << 16 | *n as u64);
        let w: Vec<f32> = (0..*n).map(|_| rng.normal_f32() * 2.0).collect();
        let q = haqa::quant::dorefa::weight_quant(&w, *k as f32);
        let levels = haqa::quant::dorefa::weight_levels(*k as u32);
        let mut distinct: Vec<i64> = q.iter().map(|x| (x * 1e5).round() as i64).collect();
        distinct.sort_unstable();
        distinct.dedup();
        if distinct.len() > levels {
            return Err(format!("{} levels at k={k}", distinct.len()));
        }
        if q.iter().any(|x| !(-1.0..=1.0).contains(x)) {
            return Err("out of [-1,1]".into());
        }
        Ok(())
    });
}
