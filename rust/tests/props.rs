//! Cross-module property tests (mini-proptest; coordinator / simulator /
//! agent invariants).

use haqa::agent::simulated::SimulatedLlm;
use haqa::agent::{Agent, TaskContext, TaskKind};
use haqa::hardware::{kernel_latency_us, DeviceProfile, ExecConfig, KernelKind, Workload};
use haqa::hardware::{memory, ModelProfile};
use haqa::optimizers::Observation;
use haqa::quant::Scheme;
use haqa::search::spaces;
use haqa::util::json::Json;
use haqa::util::proptest::{check, Gen, I64Range, PairGen};
use haqa::util::rng::Rng;

/// Generator: a random valid kernel_exec configuration.
struct ExecGen;

impl Gen for ExecGen {
    type Value = haqa::search::Config;
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        spaces::kernel_exec().sample(rng)
    }
}

#[test]
fn prop_simulated_latency_positive_and_bounded() {
    // Latency is positive, finite, and never better than the calibrated
    // HAQA optimum for that workload (the model's floor).
    check(1, 300, &ExecGen, |cfg| {
        let exec = ExecConfig::from_config(cfg);
        for kernel in KernelKind::ALL {
            for batch in [1usize, 64, 128] {
                let w = Workload::new(kernel, batch);
                for dev in [DeviceProfile::a6000(), DeviceProfile::adreno740()] {
                    let lat = kernel_latency_us(&w, &dev, &exec, None);
                    if !(lat.is_finite() && lat > 0.0) {
                        return Err(format!("latency {lat}"));
                    }
                    let floor =
                        haqa::hardware::workload::calibrated(&w).1 * dev.kernel_scale;
                    if lat < floor - 1e-9 {
                        return Err(format!("below floor: {lat} < {floor}"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_memory_monotone_in_bits_and_size() {
    check(
        2,
        100,
        &PairGen(I64Range(0, 6), I64Range(0, 6)),
        |(a, b)| {
            let all = [
                ModelProfile::llama2_7b(),
                ModelProfile::llama2_13b(),
                ModelProfile::llama32_3b(),
                ModelProfile::llama3_8b(),
                ModelProfile::openllama_3b(),
                ModelProfile::tinyllama_1_1b(),
                ModelProfile::gpt2_large(),
            ];
            let (ma, mb) = (&all[*a as usize], &all[*b as usize]);
            // fewer bits => less memory
            let f = memory::footprint_gb(ma, Scheme::FP16);
            let i8 = memory::footprint_gb(ma, Scheme::INT8);
            let i4 = memory::footprint_gb(ma, Scheme::INT4);
            if !(i4 < i8 && i8 < f) {
                return Err(format!("not monotone in bits: {i4} {i8} {f}"));
            }
            // bigger model => more memory at the same scheme
            if ma.params_b > mb.params_b {
                let (xa, xb) = (
                    memory::footprint_gb(ma, Scheme::INT8),
                    memory::footprint_gb(mb, Scheme::INT8),
                );
                if xa <= xb {
                    return Err(format!("not monotone in size: {xa} <= {xb}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_agent_always_returns_valid_config_despite_failures() {
    // Whatever the failure-injection seed does, the retry/repair loop must
    // deliver an in-range config — the §3.3 no-stall guarantee.
    check(3, 25, &I64Range(0, 10_000), |seed| {
        let space = spaces::resnet_qat();
        let backend = SimulatedLlm::new(*seed as u64).with_failure_rate(0.8);
        let mut agent = Agent::blocking(backend);
        let mut history = Vec::new();
        for round in 0..4 {
            let ctx = TaskContext {
                kind: TaskKind::Finetune,
                space: &space,
                history: &history,
                rounds_left: 4 - round,
                hardware: None,
                objective: Json::obj(),
            };
            let (cfg, _) = agent.propose(&ctx).map_err(|e| e.to_string())?;
            if !space.is_valid(&cfg) {
                return Err(format!("invalid config: {cfg:?}"));
            }
            history.push(Observation::new(cfg, 0.5 + round as f64 * 0.01));
        }
        Ok(())
    });
}

#[test]
fn prop_history_window_monotone_and_budgeted() {
    check(4, 100, &PairGen(I64Range(1, 60), I64Range(80, 4000)), |(n, budget)| {
        let space = spaces::llama_qlora();
        let hist: Vec<Observation> = (0..*n)
            .map(|i| {
                let mut o = Observation::new(space.default_config(), i as f64);
                o.feedback = "f".repeat(200);
                o
            })
            .collect();
        let mgr = haqa::agent::history::HistoryManager {
            max_tokens: *budget as usize,
            max_entries: 16,
        };
        let w = mgr.window(&hist);
        if w.is_empty() {
            return Err("empty window".into());
        }
        if w[0].0 != 0 {
            return Err("anchor not kept".into());
        }
        if w.last().unwrap().0 != (*n as usize) - 1 {
            return Err("latest round dropped".into());
        }
        if !w.windows(2).all(|p| p[0].0 < p[1].0) {
            return Err("not strictly increasing".into());
        }
        if w.len() > 16 {
            return Err("entry cap violated".into());
        }
        Ok(())
    });
}

#[test]
fn prop_exec_roundtrip_through_space() {
    // Config -> ExecConfig -> Config is stable (idempotent repair).
    check(5, 200, &ExecGen, |cfg| {
        let space = spaces::kernel_exec();
        let e1 = ExecConfig::from_config(cfg);
        let back = e1.to_config(&space);
        let e2 = ExecConfig::from_config(&back);
        if e1 != e2 {
            return Err(format!("{e1:?} != {e2:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_dorefa_quant_within_levels() {
    check(6, 200, &PairGen(I64Range(2, 8), I64Range(1, 512)), |(k, n)| {
        let mut rng = Rng::new((*k as u64) << 16 | *n as u64);
        let w: Vec<f32> = (0..*n).map(|_| rng.normal_f32() * 2.0).collect();
        let q = haqa::quant::dorefa::weight_quant(&w, *k as f32);
        let levels = haqa::quant::dorefa::weight_levels(*k as u32);
        let mut distinct: Vec<i64> = q.iter().map(|x| (x * 1e5).round() as i64).collect();
        distinct.sort_unstable();
        distinct.dedup();
        if distinct.len() > levels {
            return Err(format!("{} levels at k={k}", distinct.len()));
        }
        if q.iter().any(|x| !(-1.0..=1.0).contains(x)) {
            return Err("out of [-1,1]".into());
        }
        Ok(())
    });
}
