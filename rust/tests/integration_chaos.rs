//! Integration: deterministic fault injection (`chaos:` wrappers), the
//! bounded retry policy, and crash-safe `--resume` over the fleet-state
//! journal.
//!
//! The load-bearing invariant throughout: a faulted or interrupted run
//! produces **bit-identical** scores to a clean one, differing only in the
//! retry/fault counters of the report.  Comparisons are therefore on
//! `best_score.to_bits()` — never on cache-hit counts, which legitimately
//! shift when a retry replays a scenario against a warmer cache.
//!
//! Chaos plans are registered process-wide by plan string, so every test
//! here uses a plan string unique to itself (distinct seeds or indices).

use haqa::coordinator::scenario::Track;
use haqa::coordinator::{FleetReport, FleetRunner, Scenario};

/// Four kernel scenarios on distinct kernels (distinct evaluator scopes,
/// so the shared cache never dedups across them and the chaos call stream
/// stays long enough for every scheduled fault to fire).
fn kernel_scenarios(tag: &str) -> Vec<Scenario> {
    ["matmul:64", "softmax:128", "silu:64", "rmsnorm:1"]
        .iter()
        .enumerate()
        .map(|(i, kernel)| Scenario {
            name: format!("{tag}_{i}"),
            track: Track::Kernel,
            kernel: (*kernel).into(),
            optimizer: "haqa".into(),
            budget: 5,
            seed: i as u64,
            ..Scenario::default()
        })
        .collect()
}

fn score_bits(report: &FleetReport) -> Vec<u64> {
    report
        .outcomes
        .iter()
        .map(|o| o.as_ref().expect("scenario failed").best_score.to_bits())
        .collect()
}

/// Acceptance (tentpole invariant): an evaluator-seam fault plan plus a
/// retry budget yields the exact scores of a fault-free fleet; only the
/// fault counters differ.
#[test]
fn faulted_evaluator_fleet_is_bit_identical_under_retries() {
    let clean = FleetRunner::new(2).run(&kernel_scenarios("chaos_ev"));
    assert!(!clean.faults.any(), "clean run must report no faults");

    let mut faulted_scs = kernel_scenarios("chaos_ev");
    for sc in &mut faulted_scs {
        sc.evaluator = "chaos:seed:101:3=simulated".into();
    }
    let faulted = FleetRunner::new(2).with_retries(4).run(&faulted_scs);

    assert_eq!(score_bits(&clean), score_bits(&faulted), "scores drifted");
    assert!(faulted.faults.retries > 0, "no fault fired: {:?}", faulted.faults);
    assert!(faulted.faults.transient > 0, "{:?}", faulted.faults);
    assert_eq!(faulted.faults.fatal, 0, "{:?}", faulted.faults);
}

/// The same invariant on the **backend** seam: agent-query faults
/// (refused connects, timeouts) restart the scenario, never change it.
#[test]
fn faulted_backend_fleet_is_bit_identical_under_retries() {
    let clean = FleetRunner::new(2).run(&kernel_scenarios("chaos_be"));

    let mut faulted_scs = kernel_scenarios("chaos_be");
    for sc in &mut faulted_scs {
        sc.backend = "chaos:seed:202:2=simulated".into();
    }
    let faulted = FleetRunner::new(2).with_retries(4).run(&faulted_scs);

    assert_eq!(score_bits(&clean), score_bits(&faulted), "scores drifted");
    assert!(faulted.faults.retries > 0, "no fault fired: {:?}", faulted.faults);
    assert_eq!(faulted.faults.fatal, 0, "{:?}", faulted.faults);
}

/// A panic inside a session is caught by the worker, classified
/// `Panicked`, and retried like a transient — the fleet survives and the
/// score matches the clean run.
#[test]
fn panic_fault_is_caught_and_retried() {
    let sc = Scenario {
        name: "chaos_panic".into(),
        track: Track::Kernel,
        kernel: "matmul:64".into(),
        budget: 3,
        ..Scenario::default()
    };
    let clean = FleetRunner::new(1).run(std::slice::from_ref(&sc));

    let mut faulted_sc = sc.clone();
    faulted_sc.evaluator = "chaos:panic@2=simulated".into();
    let faulted = FleetRunner::new(1)
        .with_retries(2)
        .run(std::slice::from_ref(&faulted_sc));

    assert_eq!(score_bits(&clean), score_bits(&faulted));
    assert_eq!(faulted.faults.panicked, 1, "{:?}", faulted.faults);
    assert_eq!(faulted.faults.retries, 1, "{:?}", faulted.faults);
}

/// Failure surfacing: with `--retries 0` a transient fault is reported
/// (fail fast is the default), and a fatal failure never consumes the
/// retry budget no matter how large it is.
#[test]
fn zero_retries_and_fatal_failures_surface_immediately() {
    // Transient fault, no retry budget: the error surfaces.
    let mut sc = Scenario {
        name: "chaos_surface".into(),
        track: Track::Kernel,
        kernel: "matmul:64".into(),
        budget: 2,
        ..Scenario::default()
    };
    sc.evaluator = "chaos:transient@1=simulated".into();
    let report = FleetRunner::new(1).run(std::slice::from_ref(&sc));
    let err = report.outcomes[0].as_ref().expect_err("must fail with retries=0");
    assert!(format!("{err:#}").contains("chaos"), "{err:#}");
    assert_eq!(report.faults.transient, 1, "{:?}", report.faults);
    assert_eq!(report.faults.retries, 0, "{:?}", report.faults);

    // Deterministic failure (bogus inner spec): retrying would reproduce
    // it, so even a generous budget is not spent.
    let mut fatal_sc = sc.clone();
    fatal_sc.name = "chaos_fatal".into();
    fatal_sc.evaluator = "chaos:none=bogus".into();
    let report = FleetRunner::new(1)
        .with_retries(8)
        .run(std::slice::from_ref(&fatal_sc));
    assert!(report.outcomes[0].is_err(), "bogus spec must fail");
    assert_eq!(report.faults.fatal, 1, "{:?}", report.faults);
    assert_eq!(report.faults.retries, 0, "fatal failures never retry");
}

/// A retryable failure that exhausts the budget surfaces the last error,
/// annotated with the attempt count.
#[test]
fn exhausted_retry_budget_reports_the_attempt_count() {
    let mut sc = Scenario {
        name: "chaos_exhaust".into(),
        track: Track::Kernel,
        kernel: "matmul:64".into(),
        budget: 2,
        ..Scenario::default()
    };
    // Faults at calls 1 and 2: the first attempt and its single retry both
    // fault, and the budget is spent.
    sc.evaluator = "chaos:refuse@1,refuse@2=simulated".into();
    let report = FleetRunner::new(1)
        .with_retries(1)
        .run(std::slice::from_ref(&sc));
    let err = report.outcomes[0].as_ref().expect_err("budget exhausted");
    let msg = format!("{err:#}");
    assert!(msg.contains("gave up after 2 attempt(s)"), "{msg}");
    assert_eq!(report.faults.retries, 1, "{:?}", report.faults);
    assert_eq!(report.faults.transient, 2, "{:?}", report.faults);
}

fn temp_state_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("haqa_it_chaos_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Full resume: a second run over a completed state directory replays
/// every outcome from the journal — zero fresh work, bit-identical
/// report.
#[test]
fn resume_replays_completed_runs_bit_identically() {
    let dir = temp_state_dir("full");
    let scenarios = kernel_scenarios("resume_full");

    let first = FleetRunner::new(2)
        .with_state_dir(&dir)
        .unwrap()
        .run(&scenarios);
    assert_eq!(first.resumed, 0);
    assert_eq!(first.journal, Some((4, 1)), "4 records, one group commit");

    let second = FleetRunner::new(2)
        .with_state_dir(&dir)
        .unwrap()
        .run(&scenarios);
    assert_eq!(second.resumed, 4, "every scenario replayed from the journal");
    assert_eq!(second.journal, Some((0, 0)), "nothing re-journaled");
    assert_eq!(score_bits(&first), score_bits(&second));
    for (a, b) in first.outcomes.iter().zip(&second.outcomes) {
        let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
        assert_eq!(a.history.len(), b.history.len());
        for (x, y) in a.history.iter().zip(&b.history) {
            assert_eq!(x.score.to_bits(), y.score.to_bits(), "history drifted");
        }
        assert_eq!(a.cost_report, b.cost_report);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Partial resume — the interrupted-run shape: a prefix of the fleet is
/// journaled, then the full list runs with `--resume`.  Journaled
/// scenarios are skipped, the rest run fresh, and the merged report is
/// bit-identical to an uninterrupted fleet.
#[test]
fn partial_resume_runs_only_the_missing_scenarios() {
    let dir = temp_state_dir("partial");
    let scenarios = kernel_scenarios("resume_part");
    let uninterrupted = FleetRunner::new(2).run(&scenarios);

    // "Crash" after the first two scenarios: journal exactly that prefix.
    let partial = FleetRunner::new(2)
        .with_state_dir(&dir)
        .unwrap()
        .run(&scenarios[..2]);
    assert_eq!(partial.journal, Some((2, 1)));

    let resumed = FleetRunner::new(2)
        .with_state_dir(&dir)
        .unwrap()
        .run(&scenarios);
    assert_eq!(resumed.resumed, 2, "the journaled prefix is skipped");
    assert_eq!(
        resumed.journal.map(|(records, _)| records),
        Some(2),
        "only the missing half is journaled"
    );
    assert_eq!(score_bits(&uninterrupted), score_bits(&resumed));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Editing a scenario invalidates its checkpoint: the key hashes every
/// field, so a resumed run with a changed knob re-runs that scenario.
#[test]
fn resume_rekeys_on_any_scenario_edit() {
    let dir = temp_state_dir("rekey");
    let scenarios = kernel_scenarios("resume_rekey");
    let first = FleetRunner::new(2)
        .with_state_dir(&dir)
        .unwrap()
        .run(&scenarios);
    assert_eq!(first.resumed, 0);

    let mut edited = kernel_scenarios("resume_rekey");
    edited[0].budget += 1; // any field edit rekeys
    let second = FleetRunner::new(2)
        .with_state_dir(&dir)
        .unwrap()
        .run(&edited);
    assert_eq!(second.resumed, 3, "the edited scenario must re-run");
    assert!(second.outcomes[0].is_ok());
    let _ = std::fs::remove_dir_all(&dir);
}
