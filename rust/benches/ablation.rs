//! Ablation bench — the design choices DESIGN.md calls out:
//!
//! 1. **Response validation + retry (§3.2)**: with the backend's failure
//!    injection at the paper-observed rate, disable the retry loop and
//!    measure how many rounds fall back to defaults vs recover.
//! 2. **History management (§3.3)**: shrink the dynamic-prompt window and
//!    measure the effect on tuning quality (the policy loses the incumbent
//!    trail) and on prompt tokens (the cost the paper manages).
//!
//! Runs entirely on the simulated kernel-tuning surface (fast, no PJRT).

use haqa::agent::history::HistoryManager;
use haqa::agent::simulated::SimulatedLlm;
use haqa::agent::{Agent, TaskContext, TaskKind};
use haqa::deploy::tuner::KernelTuner;
use haqa::hardware::{DeviceProfile, KernelKind, Workload};
use haqa::optimizers::Observation;
use haqa::search::spaces;
use haqa::util::json::Json;
use haqa::util::table::Table;

fn run_tuning(
    failure_rate: f64,
    max_retries: usize,
    history: HistoryManager,
    seed: u64,
) -> (f64, usize, usize) {
    let space = spaces::kernel_exec();
    let profile = DeviceProfile::a6000();
    let tuner = KernelTuner {
        profile: &profile,
        workload: Workload::new(KernelKind::MatMul, 64),
        noise_seed: seed,
    };
    let mut agent = Agent::blocking(SimulatedLlm::new(seed).with_failure_rate(failure_rate));
    agent.max_retries = max_retries;
    agent.history_mgr = history;
    let mut hist: Vec<Observation> = Vec::new();
    for round in 0..10 {
        let mut obj = Json::obj();
        obj.set("kernel", Json::Str("matmul".into()));
        let ctx = TaskContext {
            kind: TaskKind::KernelTuning,
            space: &space,
            history: &hist,
            rounds_left: 10 - round,
            hardware: Some(profile.to_json()),
            objective: obj,
        };
        let (cfg, _) = agent.propose(&ctx).unwrap();
        let lat = tuner.measure(&cfg);
        let mut obs = Observation::new(cfg, -lat);
        obs.feedback = format!("{{\"latency_us\": {lat:.3}}}");
        hist.push(obs);
    }
    let best = -haqa::optimizers::best(&hist).unwrap().score;
    (best, agent.cost.retries, agent.cost.prompt_tokens)
}

fn main() {
    let seeds: [u64; 4] = [1, 2, 3, 4];

    let mut t1 = Table::new(
        "Ablation 1 — §3.2 validation+retry under injected agent failures \
         (matmul@64, 10 rounds; paper default latency 52.29 µs)",
        &["failure rate", "retries", "best µs (mean over seeds)", "recovered retries"],
    );
    for (rate, retries) in [(0.0, 3usize), (0.3, 3), (0.3, 0)] {
        let runs: Vec<(f64, usize, usize)> = seeds
            .iter()
            .map(|&s| run_tuning(rate, retries, HistoryManager::default(), s))
            .collect();
        let best = runs.iter().map(|r| r.0).sum::<f64>() / runs.len() as f64;
        let recov = runs.iter().map(|r| r.1).sum::<usize>();
        t1.row(vec![
            format!("{rate}"),
            format!("{retries}"),
            format!("{best:.2}"),
            format!("{recov}"),
        ]);
    }
    t1.emit("ablation_retry.csv");

    let mut t2 = Table::new(
        "Ablation 2 — §3.3 history-window budget (same task)",
        &["max tokens", "max entries", "best µs", "prompt tokens/10 rounds"],
    );
    for (tokens, entries) in [(3000usize, 16usize), (600, 4), (120, 1)] {
        let runs: Vec<(f64, usize, usize)> = seeds
            .iter()
            .map(|&s| {
                run_tuning(
                    0.0,
                    3,
                    HistoryManager {
                        max_tokens: tokens,
                        max_entries: entries,
                    },
                    s,
                )
            })
            .collect();
        let best = runs.iter().map(|r| r.0).sum::<f64>() / runs.len() as f64;
        let ptok = runs.iter().map(|r| r.2).sum::<usize>() / runs.len();
        t2.row(vec![
            format!("{tokens}"),
            format!("{entries}"),
            format!("{best:.2}"),
            format!("{ptok}"),
        ]);
    }
    t2.emit("ablation_history.csv");
    println!(
        "\n(expected: retries recover injected failures at no quality cost; \
         a 1-entry window degrades tuning and barely saves tokens)"
    );
}
