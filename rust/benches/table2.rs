//! Table 2/6 regenerator — QLoRA accuracy across the eight-task suite for
//! INT4/INT8 frozen bases, per HPO method (paper §4.2, Appendix B).
//!
//! Real training: the tiny-LM base is pretrained once per variant via the
//! `lm_pretrain_b16` artifact, then every cell runs the QLoRA train-step
//! artifacts on PJRT for `budget` rounds per method.
//!
//! Flags: `--quick`, `--variants=N`, `--rounds=N`, `--pretrain=N`,
//! `--step-scale=F`.

use haqa::optimizers::{self, best, Observation};
use haqa::report::acc_pm;
use haqa::runtime::ArtifactSet;
use haqa::search::spaces;
use haqa::trainer::data::LmTaskKind;
use haqa::trainer::lm::{LmBase, QloraJob};
use haqa::util::bench;
use haqa::util::json::Json;
use haqa::util::rng::Rng;
use haqa::util::table::Table;

/// Table 2's method roster (no "Default" column in the paper's Table 2).
const METHODS: [&str; 6] = ["human", "local", "bayesian", "random", "nsga2", "haqa"];

fn main() -> anyhow::Result<()> {
    let full = bench::flag("full");
    let quick = bench::flag("quick");
    let variants: u64 = bench::opt("variants")
        .and_then(|s| s.parse().ok())
        .unwrap_or(if full { 2 } else { 1 });
    let rounds: usize = bench::opt("rounds")
        .and_then(|s| s.parse().ok())
        .unwrap_or(if full { 8 } else { 5 });
    let pretrain: usize = bench::opt("pretrain")
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let step_scale: f64 = bench::opt("step-scale")
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.25);
    let bits_list: Vec<f32> = if quick { vec![4.0] } else { vec![4.0, 8.0] };

    let set = ArtifactSet::load_default()?;
    let space = spaces::llama_qlora();
    let mut headers: Vec<&str> = vec!["Model", "Precision", "Method"];
    for t in LmTaskKind::ALL {
        headers.push(t.label());
    }
    headers.push("AVG");
    let mut table = Table::new(
        "Table 2 — QLoRA accuracy (%) across tasks by HPO method",
        &headers,
    );

    let t_start = std::time::Instant::now();
    for variant in 0..variants {
        let base = LmBase::pretrained(&set, variant, pretrain)?;
        for &bits in &bits_list {
            for method in METHODS {
                let job = QloraJob {
                    set: &set,
                    base: &base,
                    bits,
                    seed: variant,
                    step_scale,
                };
                let mut opt = if method == "haqa" {
                    let mut o = Json::obj();
                    o.set("model", Json::Str(format!("tiny-lm-v{variant}")));
                    o.set("bits", Json::Num(bits as f64));
                    Box::new(
                        optimizers::haqa::HaqaOptimizer::with_seed(variant)
                            .with_objective(o),
                    ) as Box<dyn optimizers::Optimizer>
                } else {
                    optimizers::by_name(method)?
                };
                let mut rng = Rng::new(variant).split(0x7b2);
                let mut hist: Vec<Observation> = Vec::new();
                let mut best_report = None;
                for _ in 0..rounds {
                    let cfg = opt.propose(&space, &hist, &mut rng);
                    let r = job.run(&cfg)?;
                    let score = r.score();
                    let mut obs = Observation::new(cfg, score);
                    obs.feedback = r.feedback();
                    hist.push(obs);
                    let is_best = best(&hist).map(|b| b.score == score).unwrap_or(false);
                    if is_best || best_report.is_none() {
                        best_report = Some(r.report.clone());
                    }
                }
                let report = best_report.unwrap();
                let mut cells = vec![
                    format!("tiny-lm-v{variant}"),
                    format!("INT{}", bits as u32),
                    method.to_string(),
                ];
                for (_, acc) in &report.tasks {
                    cells.push(format!("{:.2}", acc * 100.0));
                }
                cells.push(acc_pm(report.average, 0.0));
                eprintln!(
                    "  [{:5.0}s] v{variant} INT{} {method}: avg {:.2}%",
                    t_start.elapsed().as_secs_f64(),
                    bits as u32,
                    report.average * 100.0
                );
                table.row(cells);
            }
        }
    }
    table.emit("table2_qlora_accuracy.csv");
    println!("\n(paper shape: HAQA best on AVG; INT4 close to INT8 after tuning)");
    Ok(())
}
