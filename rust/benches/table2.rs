//! Table 2/6 regenerator — QLoRA accuracy across the eight-task suite for
//! INT4/INT8 frozen bases, per HPO method (paper §4.2, Appendix B).
//!
//! Real training: the tiny-LM base is pretrained once per variant (the
//! disk cache is written atomically, so parallel workers share it), then
//! every (variant × bits × method) cell runs as a fleet scenario on the
//! QLoRA train-step artifacts, with the shared evaluation cache
//! deduplicating identical configurations across methods.
//!
//! Flags: `--quick`, `--variants=N`, `--rounds=N`, `--pretrain=N`,
//! `--step-scale=F`; env `HAQA_WORKERS`.

use haqa::coordinator::scenario::Track;
use haqa::coordinator::{FleetRunner, Scenario};
use haqa::optimizers::best;
use haqa::report::acc_pm;
use haqa::trainer::data::LmTaskKind;
use haqa::util::bench;
use haqa::util::json;
use haqa::util::table::Table;

/// Table 2's method roster (no "Default" column in the paper's Table 2).
const METHODS: [&str; 6] = ["human", "local", "bayesian", "random", "nsga2", "haqa"];

fn main() -> anyhow::Result<()> {
    let full = bench::flag("full");
    let quick = bench::flag("quick");
    let variants: u64 = bench::opt("variants")
        .and_then(|s| s.parse().ok())
        .unwrap_or(if full { 2 } else { 1 });
    let rounds: usize = bench::opt("rounds")
        .and_then(|s| s.parse().ok())
        .unwrap_or(if full { 8 } else { 5 });
    let pretrain: usize = bench::opt("pretrain")
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let step_scale: f64 = bench::opt("step-scale")
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.25);
    let bits_list: Vec<f32> = if quick { vec![4.0] } else { vec![4.0, 8.0] };

    let mut scenarios = Vec::new();
    for variant in 0..variants {
        for &bits in &bits_list {
            for method in METHODS {
                scenarios.push(Scenario {
                    name: format!("t2_v{variant}_int{}_{method}", bits as u32),
                    track: Track::FinetuneLm,
                    model: format!("tiny-lm-v{variant}"),
                    bits,
                    optimizer: method.to_string(),
                    budget: rounds,
                    seed: variant,
                    step_scale,
                    pretrain_steps: pretrain,
                    ..Scenario::default()
                });
            }
        }
    }

    let workers = FleetRunner::workers_from_env(None)?;
    let t_start = std::time::Instant::now();
    let report = FleetRunner::new(workers).run(&scenarios);
    eprintln!(
        "  [{:5.0}s] fleet: {} scenarios on {workers} workers",
        t_start.elapsed().as_secs_f64(),
        scenarios.len()
    );

    let mut headers: Vec<&str> = vec!["Model", "Precision", "Method"];
    for t in LmTaskKind::ALL {
        headers.push(t.label());
    }
    headers.push("AVG");
    let mut table = Table::new(
        "Table 2 — QLoRA accuracy (%) across tasks by HPO method",
        &headers,
    );

    let mut i = 0usize;
    for variant in 0..variants {
        for &bits in &bits_list {
            for method in METHODS {
                let out = report.outcomes[i]
                    .as_ref()
                    .map_err(|e| anyhow::anyhow!("{}: {e:#}", scenarios[i].name))?;
                i += 1;
                // Per-task accuracies ride in the best round's feedback.
                let b = best(&out.history).expect("non-empty history");
                let fb = json::parse(&b.feedback)
                    .map_err(|e| anyhow::anyhow!("feedback not JSON: {e}"))?;
                let tasks = fb.get("tasks").cloned().unwrap_or(json::Json::obj());
                let mut cells = vec![
                    format!("tiny-lm-v{variant}"),
                    format!("INT{}", bits as u32),
                    method.to_string(),
                ];
                for t in LmTaskKind::ALL {
                    cells.push(
                        tasks
                            .get(t.label())
                            .and_then(|v| v.as_f64())
                            .map(|a| format!("{:.2}", a * 100.0))
                            .unwrap_or_else(|| "-".into()),
                    );
                }
                cells.push(acc_pm(out.best_score, 0.0));
                eprintln!(
                    "  [{:5.0}s] v{variant} INT{} {method}: avg {:.2}%",
                    t_start.elapsed().as_secs_f64(),
                    bits as u32,
                    out.best_score * 100.0
                );
                table.row(cells);
            }
        }
    }
    table.emit("table2_qlora_accuracy.csv");
    if let Some(st) = report.cache {
        println!(
            "evaluation cache: {} hits / {} misses ({} entries) across the sweep",
            st.hits, st.misses, st.entries
        );
    }
    println!("\n(paper shape: HAQA best on AVG; INT4 close to INT8 after tuning)");
    Ok(())
}
