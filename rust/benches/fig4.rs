//! Figure 4 regenerator — convergence curves (best-so-far accuracy per
//! round) of every HPO method on the QLoRA INT4 task (paper uses
//! LLaMA3.2-3B INT4; here the tiny-LM variant, real training on PJRT).
//!
//! Emits one CSV series per method plus an ASCII sparkline summary.
//!
//! Flags: `--quick`, `--rounds=N`, `--pretrain=N`.

use haqa::optimizers::{self, Observation};
use haqa::runtime::ArtifactSet;
use haqa::search::spaces;
use haqa::trainer::lm::{LmBase, QloraJob};
use haqa::util::bench;
use haqa::util::json::Json;
use haqa::util::rng::Rng;
use haqa::util::stats::running_max;
use haqa::util::table::Table;

const METHODS: [&str; 6] = ["human", "local", "bayesian", "random", "nsga2", "haqa"];

fn main() -> anyhow::Result<()> {
    let quick = bench::flag("quick");
    let rounds: usize = bench::opt("rounds")
        .and_then(|s| s.parse().ok())
        .unwrap_or(if quick { 5 } else { 8 });
    let pretrain: usize = bench::opt("pretrain")
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);

    let set = ArtifactSet::load_default()?;
    let base = LmBase::pretrained(&set, 0, pretrain)?;
    let space = spaces::llama_qlora();

    let mut headers = vec!["Method".to_string()];
    headers.extend((0..rounds).map(|r| format!("r{r}")));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        "Figure 4 — best-so-far accuracy (%) per round, tiny-LM INT4 QLoRA",
        &hdr_refs,
    );
    for method in METHODS {
        let job = QloraJob {
            set: &set,
            base: &base,
            bits: 4.0,
            seed: 0,
            step_scale: 0.25,
        };
        let mut opt = if method == "haqa" {
            let mut o = Json::obj();
            o.set("bits", Json::Num(4.0));
            Box::new(optimizers::haqa::HaqaOptimizer::with_seed(0).with_objective(o))
                as Box<dyn optimizers::Optimizer>
        } else {
            optimizers::by_name(method)?
        };
        let mut rng = Rng::new(0).split(0xf4);
        let mut hist: Vec<Observation> = Vec::new();
        let mut scores = Vec::new();
        for _ in 0..rounds {
            let cfg = opt.propose(&space, &hist, &mut rng);
            let r = job.run(&cfg)?;
            let mut obs = Observation::new(cfg, r.score());
            obs.feedback = r.feedback();
            scores.push(r.score());
            hist.push(obs);
        }
        let curve = running_max(&scores);
        let mut cells = vec![method.to_string()];
        cells.extend(curve.iter().map(|v| format!("{:.2}", v * 100.0)));
        eprintln!(
            "  {method:9} final best {:.2}%",
            curve.last().unwrap() * 100.0
        );
        table.row(cells);
    }
    table.emit("fig4_convergence.csv");
    println!("\n(paper shape: HAQA converges fastest and highest; NSGA2/Random slowest)");
    Ok(())
}
