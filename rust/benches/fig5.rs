//! Figure 5 regenerator — end-to-end token-generation speed of the four
//! LLaMA models under FP16/INT8/INT4, llama.cpp default vs the agent-tuned
//! execution configuration (simulated A6000), plus the real PJRT engine
//! measurement for the tiny LM.
//!
//! Flags: `--rounds=N` (agent budget), `--skip-real`, `--tokens=N`.

use haqa::agent::TaskKind;
use haqa::deploy::e2e;
use haqa::deploy::tuner::KernelTuner;
use haqa::deploy::TokenEngine;
use haqa::hardware::{DeviceProfile, ExecConfig, KernelKind, ModelProfile, Workload};
use haqa::optimizers::haqa::HaqaOptimizer;
use haqa::quant::Scheme;
use haqa::runtime::ArtifactSet;
use haqa::search::spaces;
use haqa::trainer::lm::LmBase;
use haqa::util::bench;
use haqa::util::json::Json;
use haqa::util::rng::Rng;
use haqa::util::table::Table;

fn main() -> anyhow::Result<()> {
    let rounds: usize = bench::opt("rounds")
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);
    let dev = DeviceProfile::a6000();
    let space = spaces::kernel_exec();

    // The agent tunes the dominant kernel's exec config once; Fig. 5 applies
    // it model-wide (matmul is ~90% of decode time, §4.3).
    let tuner = KernelTuner {
        profile: &dev,
        workload: Workload::new(KernelKind::MatMul, 64),
        noise_seed: 5,
    };
    let mut obj = Json::obj();
    obj.set("kernel", Json::Str("matmul".into()));
    let mut agent = HaqaOptimizer::with_seed(21)
        .for_task(TaskKind::KernelTuning)
        .with_hardware(dev.to_json())
        .with_objective(obj);
    agent.budget = rounds;
    let mut rng = Rng::new(9);
    let hist = tuner.tune(&mut agent, &space, rounds, &mut rng);
    let (best_cfg, _) = KernelTuner::best(&hist);
    let tuned = ExecConfig::from_config(&best_cfg);

    let mut table = Table::new(
        "Figure 5 — token generation speed (tokens/s), simulated A6000",
        &["Model", "Quant", "Defaults", "HAQA", "Speed-up"],
    );
    for m in ModelProfile::figure5_models() {
        for s in Scheme::ALL {
            let (d, t) = e2e::default_vs_tuned(&m, s, &dev, &tuned);
            table.row(vec![
                m.name.clone(),
                s.label().to_string(),
                format!("{d:.1}"),
                format!("{t:.1}"),
                format!("{:.2}×", t / d),
            ]);
        }
    }
    table.emit("fig5_token_speed.csv");

    if !bench::flag("skip-real") {
        // Real measurement: the tiny LM served by the PJRT token engine,
        // default tile vs the fastest AOT'd tile variant.
        let n_tokens: usize = bench::opt("tokens")
            .and_then(|s| s.parse().ok())
            .unwrap_or(24);
        let set = ArtifactSet::load_default()?;
        let base = LmBase::pretrained(&set, 0, 200)?;
        let art = set.get("lm_train_b8")?;
        let mut rng = Rng::new(1);
        let lora: Vec<_> = art
            .inputs_with_role(haqa::runtime::InputRole::State)
            .iter()
            .take(8)
            .map(|s| s.init_tensor(&mut rng))
            .collect();
        let mut real = Table::new(
            "Figure 5b — real PJRT token engine (tiny LM), per decode-tile variant",
            &["Decode artifact", "bits", "tokens/s", "median µs/token"],
        );
        for tile in ["default", "mm16x16x16", "mm32x32x32", "mm64x64x64"] {
            for bits in [16.0f32, 8.0, 4.0] {
                let engine = TokenEngine::new(
                    &set,
                    &format!("lm_decode_{tile}"),
                    &base.tensors,
                    &lora,
                    bits,
                    16,
                    8.0,
                )?;
                let stats = engine.generate(&[1, 2, 3], n_tokens)?;
                real.row(vec![
                    format!("lm_decode_{tile}"),
                    format!("{}", bits as u32),
                    format!("{:.1}", stats.tokens_per_sec()),
                    format!("{:.0}", stats.median_token_us()),
                ]);
            }
        }
        real.emit("fig5b_real_engine.csv");
    }
    println!("\n(paper shape: INT4 > INT8 > FP16 on A6000; HAQA 1.2–1.5× over defaults)");
    Ok(())
}
