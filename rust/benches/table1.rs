//! Table 1 regenerator — ResNet-style DoReFa QAT accuracy under
//! {w8a8, w4a4, w2a2} across all seven HPO methods (paper §4.2).
//!
//! Real training: every cell drives the AOT'd CNN train-step artifacts on
//! the PJRT CPU client for `budget` rounds per method.  The method sweep
//! runs as a **scenario fleet**: all (model × precision × method × seed)
//! cells execute across a worker pool sharing one content-addressed
//! evaluation cache, so identical configurations proposed by different
//! methods (e.g. every optimizer's default-config round) train once.
//!
//! Flags: `--quick` (cnn_s only, fewer rounds), `--models=s,m,l`,
//! `--rounds=N`, `--seeds=N`, `--epoch-steps=N`; env `HAQA_WORKERS`.

use haqa::coordinator::scenario::Track;
use haqa::coordinator::{FleetRunner, Scenario};
use haqa::optimizers;
use haqa::quant::QatPrecision;
use haqa::report::acc_pm;
use haqa::util::bench;
use haqa::util::stats;
use haqa::util::table::Table;

fn main() -> anyhow::Result<()> {
    let full = bench::flag("full");
    let quick = bench::flag("quick");
    let models: Vec<String> = bench::opt("models")
        .unwrap_or_else(|| if full { "s,m,l".into() } else { "s".into() })
        .split(',')
        .map(|m| format!("cnn_{m}"))
        .collect();
    let rounds: usize = bench::opt("rounds")
        .and_then(|s| s.parse().ok())
        .unwrap_or(if full { 8 } else { 5 });
    let seeds: u64 = bench::opt("seeds")
        .and_then(|s| s.parse().ok())
        .unwrap_or(if full { 2 } else { 1 });
    let epoch_steps: usize = bench::opt("epoch-steps")
        .and_then(|s| s.parse().ok())
        .unwrap_or(if full { 3 } else { 2 });
    let precisions: Vec<QatPrecision> = if quick {
        vec![QatPrecision::W4A4]
    } else {
        QatPrecision::TABLE1.to_vec()
    };

    // One scenario per table cell per seed, flattened in table order.
    let mut scenarios = Vec::new();
    for model in &models {
        for prec in &precisions {
            for method in optimizers::METHODS {
                for seed in 0..seeds {
                    scenarios.push(Scenario {
                        name: format!("t1_{model}_{}_{}_s{seed}", prec.label(), method),
                        track: Track::FinetuneCnn,
                        model: model.clone(),
                        precision: *prec,
                        optimizer: method.to_string(),
                        // "Default" evaluates the default config once.
                        budget: if *method == "default" { 1 } else { rounds },
                        seed,
                        steps_per_epoch: epoch_steps,
                        ..Scenario::default()
                    });
                }
            }
        }
    }

    let workers = FleetRunner::workers_from_env(None)?;
    let t_start = std::time::Instant::now();
    let report = FleetRunner::new(workers).run(&scenarios);
    eprintln!(
        "  [{:5.0}s] fleet: {} scenarios on {workers} workers",
        t_start.elapsed().as_secs_f64(),
        scenarios.len()
    );

    let mut table = Table::new(
        "Table 1 — QAT accuracy (%) by HPO method (mean ± std over seeds)",
        &["Model", "Precision", "Default", "Human", "Local search",
          "Bayesian opt.", "Random search", "NSGA2", "HAQA"],
    );
    let mut i = 0usize;
    for model in &models {
        for prec in &precisions {
            let mut cells = vec![model.clone(), prec.label()];
            for method in optimizers::METHODS {
                let mut bests = Vec::new();
                for _seed in 0..seeds {
                    let out = report.outcomes[i]
                        .as_ref()
                        .map_err(|e| anyhow::anyhow!("{}: {e:#}", scenarios[i].name))?;
                    bests.push(out.best_score);
                    i += 1;
                }
                cells.push(acc_pm(stats::mean(&bests), stats::std(&bests)));
                eprintln!(
                    "  [{:5.0}s] {model} {} {method}: {}",
                    t_start.elapsed().as_secs_f64(),
                    prec.label(),
                    cells.last().unwrap()
                );
            }
            table.row(cells);
        }
    }
    table.emit("table1_qat_accuracy.csv");
    if let Some(st) = report.cache {
        println!(
            "evaluation cache: {} hits / {} misses ({} entries) across the sweep",
            st.hits, st.misses, st.entries
        );
    }
    println!(
        "\n(paper shape: HAQA > Human/Local/Bayesian > Random/NSGA2 > Default; \
         gaps widen at w2a2)"
    );
    Ok(())
}
