//! Table 1 regenerator — ResNet-style DoReFa QAT accuracy under
//! {w8a8, w4a4, w2a2} across all seven HPO methods (paper §4.2).
//!
//! Real training: every cell drives the AOT'd CNN train-step artifacts on
//! the PJRT CPU client for `budget` rounds per method.
//!
//! Flags: `--quick` (cnn_s only, fewer rounds), `--models=s,m,l`,
//! `--rounds=N`, `--seeds=N`, `--epoch-steps=N`.

use haqa::optimizers::{self, best, Observation};
use haqa::quant::QatPrecision;
use haqa::report::acc_pm;
use haqa::runtime::ArtifactSet;
use haqa::search::spaces;
use haqa::trainer::qat::QatJob;
use haqa::util::bench;
use haqa::util::rng::Rng;
use haqa::util::stats;
use haqa::util::table::Table;

fn main() -> anyhow::Result<()> {
    let full = bench::flag("full");
    let quick = bench::flag("quick");
    let models: Vec<String> = bench::opt("models")
        .unwrap_or_else(|| if full { "s,m,l".into() } else { "s".into() })
        .split(',')
        .map(|m| format!("cnn_{m}"))
        .collect();
    let rounds: usize = bench::opt("rounds")
        .and_then(|s| s.parse().ok())
        .unwrap_or(if full { 8 } else { 5 });
    let seeds: u64 = bench::opt("seeds")
        .and_then(|s| s.parse().ok())
        .unwrap_or(if full { 2 } else { 1 });
    let epoch_steps: usize = bench::opt("epoch-steps")
        .and_then(|s| s.parse().ok())
        .unwrap_or(if full { 3 } else { 2 });
    let precisions: Vec<QatPrecision> = if quick {
        vec![QatPrecision::W4A4]
    } else {
        QatPrecision::TABLE1.to_vec()
    };

    let set = ArtifactSet::load_default()?;
    let space = spaces::resnet_qat();
    let mut table = Table::new(
        "Table 1 — QAT accuracy (%) by HPO method (mean ± std over seeds)",
        &["Model", "Precision", "Default", "Human", "Local search",
          "Bayesian opt.", "Random search", "NSGA2", "HAQA"],
    );
    let t_start = std::time::Instant::now();
    for model in &models {
        for prec in &precisions {
            let mut cells = vec![model.clone(), prec.label()];
            for method in optimizers::METHODS {
                let mut bests = Vec::new();
                for seed in 0..seeds {
                    let job = QatJob {
                        set: &set,
                        model,
                        precision: *prec,
                        seed,
                        steps_per_epoch: epoch_steps,
                    };
                    let mut opt = if *method == "haqa" {
                        Box::new(
                            optimizers::haqa::HaqaOptimizer::with_seed(seed)
                                .with_objective({
                                    let mut o = haqa::util::json::Json::obj();
                                    o.set("model", haqa::util::json::Json::Str(model.clone()));
                                    o.set("bits", haqa::util::json::Json::Num(prec.wbits as f64));
                                    o
                                }),
                        ) as Box<dyn optimizers::Optimizer>
                    } else {
                        optimizers::by_name(method)?
                    };
                    let mut rng = Rng::new(seed).split(0x7b1);
                    let mut hist: Vec<Observation> = Vec::new();
                    // "Default" evaluates the default config once.
                    let budget = if *method == "default" { 1 } else { rounds };
                    for _ in 0..budget {
                        let cfg = opt.propose(&space, &hist, &mut rng);
                        let r = job.run(&cfg)?;
                        let mut obs = Observation::new(cfg, r.accuracy);
                        obs.feedback = r.feedback();
                        hist.push(obs);
                    }
                    bests.push(best(&hist).unwrap().score);
                }
                cells.push(acc_pm(stats::mean(&bests), stats::std(&bests)));
                eprintln!(
                    "  [{:5.0}s] {model} {} {method}: {}",
                    t_start.elapsed().as_secs_f64(),
                    prec.label(),
                    cells.last().unwrap()
                );
            }
            table.row(cells);
        }
    }
    table.emit("table1_qat_accuracy.csv");
    println!(
        "\n(paper shape: HAQA > Human/Local/Bayesian > Random/NSGA2 > Default; \
         gaps widen at w2a2)"
    );
    Ok(())
}
