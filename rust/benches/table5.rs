//! Table 5 regenerator — HAQA-selected quantization configurations for
//! LLaMA2-13B under 4/12/20/28 GB memory budgets (paper §4.3).
//!
//! Each cell is the memory model's feasibility check; the agent's bit-width
//! choice per budget is cross-checked against the analytic selector.

use haqa::agent::simulated::SimulatedLlm;
use haqa::agent::{Agent, TaskContext, TaskKind};
use haqa::hardware::{adaptive, memory, DeviceProfile, ModelProfile};
use haqa::quant::Scheme;
use haqa::report::check_cell;
use haqa::util::json::Json;
use haqa::util::table::Table;

fn main() -> anyhow::Result<()> {
    let model = ModelProfile::llama2_13b();
    let dev = DeviceProfile::a6000();
    let space = haqa::search::spaces::bitwidth();
    let mut table = Table::new(
        "Table 5 — feasible quantization for LLaMA2-13B by memory budget",
        &["Memory (GB)", "FP16", "INT8", "INT4", "agent pick", "analytic pick"],
    );
    for budget in memory::TABLE5_BUDGETS_GB {
        let cells: Vec<String> = Scheme::ALL
            .iter()
            .map(|&s| check_cell(memory::fits(&model, s, budget)))
            .collect();

        // Agent decision for this budget.
        let mut objective = Json::obj();
        objective.set("model", Json::Str(model.name.clone()));
        objective.set("memory_limit_gb", Json::Num(budget));
        let mut mem = Json::obj();
        for s in Scheme::ALL {
            mem.set(s.label(), Json::Num(memory::footprint_gb(&model, s)));
        }
        objective.set("mem_gb", mem);
        let mut agent = Agent::blocking(SimulatedLlm::new(1));
        let ctx = TaskContext {
            kind: TaskKind::Bitwidth,
            space: &space,
            history: &[],
            rounds_left: 1,
            hardware: Some(dev.to_json()),
            objective,
        };
        let (cfg, _) = agent.propose(&ctx)?;
        let agent_pick = match cfg.get("quant").and_then(|v| v.as_str()) {
            Some("NONE") | None => "×".to_string(),
            Some(s) => s.to_string(),
        };
        let analytic = adaptive::select(&model, &dev, budget);
        let analytic_pick = analytic
            .scheme
            .map(|s| s.label().to_string())
            .unwrap_or_else(|| "×".into());
        table.row(vec![
            format!("{budget}"),
            cells[0].clone(),
            cells[1].clone(),
            cells[2].clone(),
            agent_pick,
            analytic_pick,
        ]);
    }
    table.emit("table5_memory_constraints.csv");
    println!("\n(paper: 4 GB → none; 12 GB → INT4 only; 20 GB → INT8+INT4; 28 GB → all)");
    Ok(())
}
