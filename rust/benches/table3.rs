//! Table 3 regenerator — kernel-level latency, llama.cpp default vs
//! HAQA-tuned execution configuration, on the simulated A6000 (paper §4.3).
//!
//! Also prints the real-artifact section: PJRT-CPU latencies of the AOT'd
//! qmatmul Pallas tile variants (the TPU-analogue of the same tuning loop).
//!
//! Flags: `--rounds=N` (agent budget per kernel, default 10), `--skip-real`.

use haqa::agent::TaskKind;
use haqa::deploy::tuner::{KernelTuner, PallasTuner};
use haqa::hardware::{DeviceProfile, ExecConfig, KernelKind, Workload};
use haqa::optimizers::haqa::HaqaOptimizer;
use haqa::report::{speedup, us};
use haqa::runtime::ArtifactSet;
use haqa::search::spaces;
use haqa::util::bench;
use haqa::util::json::Json;
use haqa::util::rng::Rng;
use haqa::util::table::Table;

fn main() -> anyhow::Result<()> {
    let rounds: usize = bench::opt("rounds")
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);
    let profile = DeviceProfile::a6000();
    let space = spaces::kernel_exec();
    let mut table = Table::new(
        "Table 3 — kernel latency, default vs HAQA (simulated A6000)",
        &["Kernel", "Input Size", "Default (µs)", "HAQA (µs)", "Speed-up"],
    );
    for kernel in KernelKind::ALL {
        for batch in [1usize, 64, 128] {
            let w = Workload::new(kernel, batch);
            let tuner = KernelTuner {
                profile: &profile,
                workload: w,
                noise_seed: 7,
            };
            let default_lat =
                tuner.measure(&ExecConfig::llamacpp_default().to_config(&space));
            let mut obj = Json::obj();
            obj.set("kernel", Json::Str(kernel.label().to_lowercase()));
            obj.set("size", Json::Str(w.size_label()));
            let mut agent = HaqaOptimizer::with_seed(11 + batch as u64)
                .for_task(TaskKind::KernelTuning)
                .with_hardware(profile.to_json())
                .with_objective(obj);
            agent.budget = rounds;
            let mut rng = Rng::new(3);
            let hist = tuner.tune(&mut agent, &space, rounds, &mut rng);
            let (_, tuned_lat) = KernelTuner::best(&hist);
            table.row(vec![
                kernel.label().to_string(),
                w.size_label(),
                us(default_lat),
                us(tuned_lat),
                speedup(default_lat, tuned_lat),
            ]);
        }
    }
    table.emit("table3_kernel_latency.csv");

    if !bench::flag("skip-real") {
        let set = ArtifactSet::load_default()?;
        let tuner = PallasTuner { set: &set };
        let ms = tuner.measure_variants(5)?;
        let mut real = Table::new(
            "Table 3b — real PJRT-CPU latency of the Pallas qmatmul tile \
             variants (64x2048 @ 2048x2048)",
            &["Variant", "Tile (bm,bn,bk)", "Median (µs)", "vs slowest"],
        );
        let slowest = ms.last().map(|m| m.median_us).unwrap_or(1.0);
        for m in &ms {
            real.row(vec![
                m.variant.clone(),
                format!("{:?}", m.tile),
                us(m.median_us),
                speedup(slowest, m.median_us),
            ]);
        }
        real.emit("table3b_pallas_tiles.csv");
    }
    println!("\n(paper shape: 1.07–2.31× speedups; SiLU@64 most tunable, RoPE least)");
    Ok(())
}
