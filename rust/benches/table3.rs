//! Table 3 regenerator — kernel-level latency, llama.cpp default vs
//! HAQA-tuned execution configuration, on the simulated A6000 (paper §4.3).
//!
//! All 15 (kernel × size) cells run as a parallel scenario fleet through
//! the unified kernel evaluator; the default-config latency comes from the
//! same evaluator, so the two columns share one measurement path.
//!
//! Also prints the real-artifact section: PJRT-CPU latencies of the AOT'd
//! qmatmul Pallas tile variants (the TPU-analogue of the same loop;
//! requires `--features pjrt` + `make artifacts`).
//!
//! Flags: `--rounds=N` (agent budget per kernel, default 10), `--skip-real`;
//! env `HAQA_WORKERS`.

use haqa::coordinator::evaluator::KernelEvaluator;
use haqa::coordinator::scenario::Track;
use haqa::coordinator::{Evaluator, FleetRunner, Scenario};
use haqa::deploy::tuner::PallasTuner;
use haqa::hardware::{ExecConfig, KernelKind, Workload};
use haqa::report::{speedup, us};
use haqa::runtime::ArtifactSet;
use haqa::search::spaces;
use haqa::util::bench;
use haqa::util::table::Table;

const NOISE_SEED: u64 = 7;

fn main() -> anyhow::Result<()> {
    let rounds: usize = bench::opt("rounds")
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);
    let space = spaces::kernel_exec();

    let mut scenarios = Vec::new();
    for kernel in KernelKind::ALL {
        for batch in [1usize, 64, 128] {
            scenarios.push(Scenario {
                name: format!("t3_{}_{batch}", kernel.label().to_lowercase()),
                track: Track::Kernel,
                kernel: format!("{}:{batch}", kernel.label().to_lowercase()),
                device: "a6000".into(),
                optimizer: "haqa".into(),
                budget: rounds,
                seed: NOISE_SEED,
                ..Scenario::default()
            });
        }
    }
    let workers = FleetRunner::workers_from_env(None)?;
    let report = FleetRunner::new(workers).run(&scenarios);

    let mut table = Table::new(
        "Table 3 — kernel latency, default vs HAQA (simulated A6000)",
        &["Kernel", "Input Size", "Default (µs)", "HAQA (µs)", "Speed-up"],
    );
    let mut i = 0usize;
    for kernel in KernelKind::ALL {
        for batch in [1usize, 64, 128] {
            let w = Workload::new(kernel, batch);
            // The default column runs through the same batched evaluator
            // path as the fleet (one latency-model build per cell).
            let ev = KernelEvaluator::from_scenario(&scenarios[i])?;
            let default_lat =
                -ev.evaluate_batch(&[ExecConfig::llamacpp_default().to_config(&space)])?[0].score;
            let out = report.outcomes[i]
                .as_ref()
                .map_err(|e| anyhow::anyhow!("{}: {e:#}", scenarios[i].name))?;
            i += 1;
            let tuned_lat = -out.best_score;
            table.row(vec![
                kernel.label().to_string(),
                w.size_label(),
                us(default_lat),
                us(tuned_lat),
                speedup(default_lat, tuned_lat),
            ]);
        }
    }
    table.emit("table3_kernel_latency.csv");
    if let Some(st) = report.cache {
        println!(
            "evaluation cache: {} hits / {} misses ({} entries); \
             fleet of {} cells on {workers} workers",
            st.hits,
            st.misses,
            st.entries,
            scenarios.len()
        );
    }

    if !bench::flag("skip-real") {
        let set = ArtifactSet::load_default()?;
        let tuner = PallasTuner { set: &set };
        let ms = tuner.measure_variants(5)?;
        let mut real = Table::new(
            "Table 3b — real PJRT-CPU latency of the Pallas qmatmul tile \
             variants (64x2048 @ 2048x2048)",
            &["Variant", "Tile (bm,bn,bk)", "Median (µs)", "vs slowest"],
        );
        let slowest = ms.last().map(|m| m.median_us).unwrap_or(1.0);
        for m in &ms {
            real.row(vec![
                m.variant.clone(),
                format!("{:?}", m.tile),
                us(m.median_us),
                speedup(slowest, m.median_us),
            ]);
        }
        real.emit("table3b_pallas_tiles.csv");
    }
    println!("\n(paper shape: 1.07–2.31× speedups; SiLU@64 most tunable, RoPE least)");
    Ok(())
}
