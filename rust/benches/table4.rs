//! Table 4 regenerator — mobile (Adreno 740) throughput under FP16/INT8/
//! INT4: the §4.4 counterintuitive result (INT8 ≥ FP16 > INT4, because the
//! Adreno has no native INT4 path).

use haqa::deploy::e2e;
use haqa::hardware::{DeviceProfile, ExecConfig, ModelProfile};
use haqa::quant::Scheme;
use haqa::util::table::Table;

fn main() {
    let dev = DeviceProfile::adreno740();
    let exec = ExecConfig::llamacpp_default();
    let mut table = Table::new(
        "Table 4 — model throughput (tokens/s) on the simulated Adreno 740",
        &["Model", "FP16", "INT8", "INT4"],
    );
    let paper: &[(&str, [f64; 3])] = &[
        ("openllama-3B", [5.11, 5.25, 4.95]),
        ("tinylama-1.1B", [11.17, 11.23, 10.43]),
        ("gpt2-large-774M", [13.41, 13.20, 12.29]),
    ];
    for (m, (paper_name, paper_rates)) in
        ModelProfile::table4_models().iter().zip(paper)
    {
        let rates: Vec<f64> = Scheme::ALL
            .iter()
            .map(|&s| e2e::tokens_per_sec(m, s, &dev, &exec))
            .collect();
        table.row(vec![
            m.name.clone(),
            format!("{:.2}", rates[0]),
            format!("{:.2}", rates[1]),
            format!("{:.2}", rates[2]),
        ]);
        // Shape assertions (who wins), printed for EXPERIMENTS.md.
        let int8_beats_int4 = rates[1] > rates[2];
        let fp16_beats_int4 = rates[0] > rates[2];
        println!(
            "shape {paper_name}: INT8>INT4 {} (paper {}), FP16>INT4 {} (paper {})",
            int8_beats_int4,
            paper_rates[1] > paper_rates[2],
            fp16_beats_int4,
            paper_rates[0] > paper_rates[2],
        );
    }
    table.emit("table4_mobile_throughput.csv");
    println!("\n(paper: INT4 loses on mobile despite the smaller bit-width — no native INT4 path)");
}
