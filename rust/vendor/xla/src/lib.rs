//! Offline API stub of the `xla` (xla_extension 0.5.1) binding surface that
//! haqa's `pjrt` feature compiles against.
//!
//! The build image has no network access and no libxla_extension, so this
//! crate provides just enough of the binding's types for
//! `cargo build --features pjrt` to type-check; every operation that would
//! touch PJRT returns an error at runtime.  To execute the AOT'd HLO
//! artifacts for real, point Cargo at the real binding:
//!
//! ```toml
//! [patch."crates-io"]            # or a workspace [patch] on the path dep
//! xla = { git = "https://github.com/LaurentMazare/xla-rs" }
//! ```
//!
//! Host-side `Literal` construction/reshape is implemented for real (it is
//! pure bookkeeping), which keeps the conversion layer in
//! `haqa::runtime::tensor` testable even under this stub.

/// The binding's error type; formatted with `{:?}` at every call site.
#[derive(Clone)]
pub struct XlaError(pub String);

impl std::fmt::Debug for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

fn unavailable<T>(what: &str) -> Result<T, XlaError> {
    Err(XlaError(format!(
        "{what} requires the real xla_extension binding — this offline build \
         links the API stub (see rust/vendor/xla/src/lib.rs)"
    )))
}

/// Element types `Literal::to_vec` can produce (f32 is all haqa uses).
pub trait NativeType: Copy {
    fn from_f32(x: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(x: f32) -> f32 {
        x
    }
}

/// Host-side literal: shape + row-major f32 buffer.
#[derive(Clone, Debug)]
pub struct Literal {
    dims: Vec<i64>,
    data: Vec<f32>,
}

impl Literal {
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: data.to_vec(),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaError> {
        let n: i64 = dims.iter().product();
        let want = if dims.is_empty() { 1 } else { n.max(0) as usize };
        if want != self.data.len() {
            return Err(XlaError(format!(
                "reshape to {dims:?} ({} elements) from {} elements",
                want,
                self.data.len()
            )));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            data: self.data.clone(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape, XlaError> {
        Ok(ArrayShape {
            dims: self.dims.clone(),
        })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        Ok(self.data.iter().map(|&x| T::from_f32(x)).collect())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        unavailable("Literal::to_tuple")
    }
}

pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[derive(Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable("PjRtClient::compile")
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_bookkeeping_works_offline() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
        // () scalar reshape
        let s = Literal::vec1(&[7.0]).reshape(&[]).unwrap();
        assert!(s.array_shape().unwrap().dims().is_empty());
    }

    #[test]
    fn pjrt_paths_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
    }
}
