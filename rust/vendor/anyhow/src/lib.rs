//! Offline stand-in for the `anyhow` crate.
//!
//! The build image has no network access to crates.io, so this vendored
//! path crate provides the (small) `anyhow` API subset the workspace uses:
//! [`Error`], the `Result<T>` alias, the [`Context`] extension trait, and
//! the `anyhow! / bail! / ensure!` macros.  Errors carry a flattened cause
//! chain of strings; `{:#}` Display joins the chain with `: ` exactly like
//! the real crate, and `{:?}` prints a `Caused by:` block.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-chain error: `chain[0]` is the outermost message, each
/// following entry a deeper cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message (what `anyhow!` expands to).
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error {
            chain: vec![msg.to_string()],
        }
    }

    /// Prepend a context message (outermost position in the chain).
    pub fn context(mut self, ctx: impl fmt::Display) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    fn from_std(err: &(dyn std::error::Error + 'static)) -> Error {
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if f.alternate() {
            for cause in &self.chain[1..] {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

/// Any std error converts implicitly (what `?` relies on).  `Error` itself
/// deliberately does not implement `std::error::Error`, mirroring the real
/// crate, which is what keeps this blanket impl coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(&e)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results
/// whose error is a std error.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e: Error = Error::from(io_err()).context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
    }

    #[test]
    fn debug_shows_cause_block() {
        let e: Error = Error::from(io_err()).context("outer");
        let d = format!("{e:?}");
        assert!(d.contains("outer"), "{d}");
        assert!(d.contains("Caused by:"), "{d}");
        assert!(d.contains("gone"), "{d}");
    }

    #[test]
    fn macros_compose() {
        fn fails(n: usize) -> Result<usize> {
            ensure!(n < 10, "n too big: {n}");
            if n == 3 {
                bail!("unlucky {n}");
            }
            Ok(n)
        }
        assert_eq!(fails(2).unwrap(), 2);
        assert_eq!(format!("{}", fails(3).unwrap_err()), "unlucky 3");
        assert_eq!(format!("{}", fails(11).unwrap_err()), "n too big: 11");
        let e = crate::anyhow!("x = {}", 7);
        assert_eq!(e.to_string(), "x = 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 1)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 1: gone");
        let o: Option<u8> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
    }
}
