//! Pure-Rust host literal — the `pjrt`-free stand-in for `xla::Literal` on
//! the `Tensor` interop boundary.
//!
//! The PJRT path converts `Tensor` ⇄ `xla::Literal` at the executor
//! boundary; this type mirrors that contract (shape bookkeeping + row-major
//! f32 buffer) with zero external dependencies, so the conversion layer
//! stays covered by tests in the default offline build.  It does **not**
//! execute graphs — without `pjrt`, `Executor::run_raw` errors; this is the
//! data-interchange half of the fallback only, and the seam future CPU
//! interpreters plug into.

use anyhow::{ensure, Result};

/// Shape + row-major f32 buffer, the same payload an `xla::Literal` carries
/// for every artifact in this repo (one dtype end-to-end; DESIGN.md §5).
#[derive(Debug, Clone, PartialEq)]
pub struct HostLiteral {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostLiteral {
    /// Rank-1 literal over a buffer (mirror of `xla::Literal::vec1`).
    pub fn vec1(data: &[f32]) -> HostLiteral {
        HostLiteral {
            shape: vec![data.len()],
            data: data.to_vec(),
        }
    }

    /// Reinterpret the buffer under a new shape (element count must match;
    /// `[]` is the rank-0 scalar).
    pub fn reshape(&self, shape: &[usize]) -> Result<HostLiteral> {
        let want: usize = shape.iter().product();
        ensure!(
            want == self.data.len(),
            "reshape to {:?} ({} elements) from {} elements",
            shape,
            want,
            self.data.len()
        );
        Ok(HostLiteral {
            shape: shape.to_vec(),
            data: self.data.clone(),
        })
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_and_reshape() {
        let l = HostLiteral::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.shape, vec![6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.shape, vec![2, 3]);
        assert_eq!(r.data, l.data);
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn scalar_rank0() {
        let s = HostLiteral::vec1(&[2.5]).reshape(&[]).unwrap();
        assert!(s.shape.is_empty());
        assert_eq!(s.element_count(), 1);
    }
}
