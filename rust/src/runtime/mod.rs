//! PJRT runtime: load `artifacts/*.hlo.txt`, compile once, execute from the
//! Rust hot path.  Python never runs here — this is the deployment side of
//! the AOT boundary (see DESIGN.md §3).
//!
//! * [`tensor`] — host-side f32 tensor type ⇄ `xla::Literal`.
//! * [`literal`] — pure-Rust literal fallback (no-`pjrt` builds).
//! * `client` — process-wide PJRT CPU client singleton (module exists
//!   only under the `pjrt` feature).
//! * [`artifact`] — manifest-driven artifact registry + executable cache +
//!   the generic state-threading executor every trainer/engine uses.
//!
//! The `xla` dependency is gated behind the default-off `pjrt` feature:
//! without it, manifests, shapes, argument assembly and literal interop all
//! work, and only actual HLO execution returns an error.

pub mod artifact;
#[cfg(feature = "pjrt")]
pub mod client;
pub mod literal;
pub mod tensor;

pub use artifact::{Artifact, ArtifactSet, Executor, InputRole};
#[cfg(feature = "pjrt")]
pub use client::global_client;
pub use literal::HostLiteral;
pub use tensor::Tensor;
