//! PJRT runtime: load `artifacts/*.hlo.txt`, compile once, execute from the
//! Rust hot path.  Python never runs here — this is the deployment side of
//! the AOT boundary (see DESIGN.md §3).
//!
//! * [`tensor`] — host-side f32 tensor type ⇄ `xla::Literal`.
//! * [`client`] — process-wide PJRT CPU client singleton.
//! * [`artifact`] — manifest-driven artifact registry + executable cache +
//!   the generic state-threading executor every trainer/engine uses.

pub mod artifact;
pub mod client;
pub mod tensor;

pub use artifact::{Artifact, ArtifactSet, Executor, InputRole};
pub use client::global_client;
pub use tensor::Tensor;
