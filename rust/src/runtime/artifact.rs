//! Manifest-driven artifact registry + executable cache.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` describing every
//! lowered graph: typed input list (name / shape / role / init) and output
//! shapes.  This module loads the manifest, compiles HLO text on demand
//! through the shared PJRT client (caching executables), and provides the
//! generic state-threading call convention used by the trainer and the
//! token-generation engine:
//!
//! * inputs = `[state..., frozen..., data..., scalars...]` in manifest order
//! * outputs `[0..state_count)` replace the `state` inputs on the next call

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

#[cfg(feature = "pjrt")]
use super::client::global_client;
use super::tensor::Tensor;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InputRole {
    State,
    Frozen,
    Data,
    Scalar,
}

impl InputRole {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "state" => InputRole::State,
            "frozen" => InputRole::Frozen,
            "data" => InputRole::Data,
            "scalar" => InputRole::Scalar,
            other => bail!("unknown input role '{other}'"),
        })
    }
}

#[derive(Debug, Clone)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub role: InputRole,
    pub init: String,
}

impl InputSpec {
    /// Build the initial tensor for a state/frozen input per its init spec.
    pub fn init_tensor(&self, rng: &mut Rng) -> Tensor {
        match self.init.as_str() {
            "he" => Tensor::he_normal(&self.shape, rng),
            "zeros" | "none" => Tensor::zeros(&self.shape),
            "ones" => Tensor::ones(&self.shape),
            "embed" => Tensor::embed_init(&self.shape, rng),
            "lora_a" => Tensor::lora_a_init(&self.shape, rng),
            other => {
                debug_assert!(false, "unknown init '{other}'");
                Tensor::zeros(&self.shape)
            }
        }
    }
}

#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<InputSpec>,
    pub output_shapes: Vec<Vec<usize>>,
    pub state_count: usize,
    pub meta: Json,
}

impl Artifact {
    fn from_json(dir: &Path, v: &Json) -> Result<Artifact> {
        let name = v.req_str("name")?.to_string();
        let file = dir.join(v.req_str("file")?);
        let mut inputs = Vec::new();
        for item in v.req_arr("inputs")? {
            inputs.push(InputSpec {
                name: item.req_str("name")?.to_string(),
                shape: shape_of(item.req_arr("shape")?),
                role: InputRole::parse(item.req_str("role")?)?,
                init: item
                    .get("init")
                    .and_then(|j| j.as_str())
                    .unwrap_or("none")
                    .to_string(),
            });
        }
        let output_shapes = v
            .req_arr("outputs")?
            .iter()
            .map(|o| Ok(shape_of(o.req_arr("shape")?)))
            .collect::<Result<Vec<_>>>()?;
        Ok(Artifact {
            name,
            file,
            inputs,
            output_shapes,
            state_count: v.req_f64("state_count")? as usize,
            meta: v.get("meta").cloned().unwrap_or(Json::obj()),
        })
    }

    pub fn inputs_with_role(&self, role: InputRole) -> Vec<&InputSpec> {
        self.inputs.iter().filter(|i| i.role == role).collect()
    }

    /// Initial tensors for every `state` input (threaded params/opt-state).
    pub fn init_state(&self, rng: &mut Rng) -> Vec<Tensor> {
        self.inputs_with_role(InputRole::State)
            .iter()
            .map(|s| s.init_tensor(rng))
            .collect()
    }

    /// Initial tensors for every `frozen` input (e.g. QLoRA base weights).
    pub fn init_frozen(&self, rng: &mut Rng) -> Vec<Tensor> {
        self.inputs_with_role(InputRole::Frozen)
            .iter()
            .map(|s| s.init_tensor(rng))
            .collect()
    }
}

fn shape_of(arr: &[Json]) -> Vec<usize> {
    arr.iter()
        .map(|d| d.as_f64().unwrap_or(0.0) as usize)
        .collect()
}

/// The registry: manifest + lazily compiled executables.
pub struct ArtifactSet {
    pub dir: PathBuf,
    artifacts: HashMap<String, Artifact>,
    // PJRT handles are Rc-backed (single-threaded); the cache follows suit.
    cache: RefCell<HashMap<String, Rc<Executor>>>,
}

impl ArtifactSet {
    /// Load `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactSet> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let v = json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        let mut artifacts = HashMap::new();
        for item in v.req_arr("artifacts")? {
            let art = Artifact::from_json(&dir, item)?;
            artifacts.insert(art.name.clone(), art);
        }
        Ok(ArtifactSet {
            dir,
            artifacts,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Default location: `$HAQA_ARTIFACTS` or `artifacts/` under the cwd
    /// (walking up so `cargo test` from anywhere in the workspace works).
    pub fn load_default() -> Result<ArtifactSet> {
        if let Ok(dir) = std::env::var("HAQA_ARTIFACTS") {
            return ArtifactSet::load(dir);
        }
        let mut cur = std::env::current_dir()?;
        loop {
            let cand = cur.join("artifacts");
            if cand.join("manifest.json").exists() {
                return ArtifactSet::load(cand);
            }
            if !cur.pop() {
                bail!("artifacts/manifest.json not found — run `make artifacts`");
            }
        }
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.artifacts.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn get(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))
    }

    /// Artifacts whose meta.family matches.
    pub fn family(&self, family: &str) -> Vec<&Artifact> {
        let mut v: Vec<&Artifact> = self
            .artifacts
            .values()
            .filter(|a| a.meta.get("family").and_then(|j| j.as_str()) == Some(family))
            .collect();
        v.sort_by(|a, b| a.name.cmp(&b.name));
        v
    }

    /// Compile (or fetch the cached) executable for an artifact.  Without
    /// the `pjrt` feature the returned executor carries metadata only
    /// (shapes, roles, argument assembly) and errors on execution.
    pub fn executor(&self, name: &str) -> Result<Rc<Executor>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let art = self.get(name)?.clone();
        #[cfg(feature = "pjrt")]
        let executor = {
            let client = global_client()?;
            let proto = xla::HloModuleProto::from_text_file(
                art.file
                    .to_str()
                    .ok_or_else(|| anyhow!("non-utf8 path {:?}", art.file))?,
            )
            .map_err(|e| anyhow!("parse {}: {e:?}", art.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            Rc::new(Executor { artifact: art, exe })
        };
        #[cfg(not(feature = "pjrt"))]
        let executor = Rc::new(Executor { artifact: art });
        self.cache
            .borrow_mut()
            .insert(name.to_string(), executor.clone());
        Ok(executor)
    }
}

/// A compiled artifact plus its typed calling convention.
pub struct Executor {
    pub artifact: Artifact,
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
}

impl Executor {
    /// Assemble the full positional argument list from role-sorted sources.
    ///
    /// * `state`  — current threaded state (order = manifest order of
    ///   `state` inputs); must match `state_count` tensors.
    /// * `frozen` — tensors for `frozen` inputs (manifest order).
    /// * `named`  — `data` and `scalar` inputs by name.
    pub fn build_args(
        &self,
        state: &[Tensor],
        frozen: &[Tensor],
        named: &HashMap<&str, Tensor>,
    ) -> Result<Vec<Tensor>> {
        let mut out = Vec::with_capacity(self.artifact.inputs.len());
        let (mut si, mut fi) = (0usize, 0usize);
        for spec in &self.artifact.inputs {
            let t = match spec.role {
                InputRole::State => {
                    let t = state
                        .get(si)
                        .ok_or_else(|| anyhow!("missing state tensor #{si}"))?;
                    si += 1;
                    t.clone()
                }
                InputRole::Frozen => {
                    let t = frozen
                        .get(fi)
                        .ok_or_else(|| anyhow!("missing frozen tensor #{fi}"))?;
                    fi += 1;
                    t.clone()
                }
                InputRole::Data | InputRole::Scalar => named
                    .get(spec.name.as_str())
                    .ok_or_else(|| anyhow!("missing input '{}'", spec.name))?
                    .clone(),
            };
            if t.shape != spec.shape {
                bail!(
                    "input '{}' shape {:?} != expected {:?}",
                    spec.name,
                    t.shape,
                    spec.shape
                );
            }
            out.push(t);
        }
        Ok(out)
    }

    /// Execute with a fully assembled positional argument list.
    #[cfg(feature = "pjrt")]
    pub fn run_raw(&self, args: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let bufs = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.artifact.name))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal_sync: {e:?}"))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow!("to_tuple: {e:?}"))?;
        parts.iter().map(Tensor::from_literal).collect()
    }

    /// Without the `pjrt` feature there is no execution backend; artifact
    /// metadata and argument assembly still work, execution errors.
    #[cfg(not(feature = "pjrt"))]
    pub fn run_raw(&self, _args: &[Tensor]) -> Result<Vec<Tensor>> {
        bail!(
            "cannot execute artifact '{}': haqa was built without the `pjrt` \
             feature (rebuild with `--features pjrt` and the real `xla` \
             binding to run AOT graphs)",
            self.artifact.name
        )
    }

    /// The common call: thread state, return (new_state, metrics).
    ///
    /// Outputs `[0..state_count)` become the next state; the rest are
    /// returned as metrics/payload.
    pub fn step(
        &self,
        state: Vec<Tensor>,
        frozen: &[Tensor],
        named: &HashMap<&str, Tensor>,
    ) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
        let args = self.build_args(&state, frozen, named)?;
        let mut outs = self.run_raw(&args)?;
        let metrics = outs.split_off(self.artifact.state_count);
        Ok((outs, metrics))
    }
}
