//! Thread-local PJRT CPU client.
//!
//! `PjRtClient` is `Rc`-backed (not `Send`/`Sync`), so the shared-client
//! pattern is per-thread: each thread that touches the runtime gets one
//! client, created on first use.  Creating a client per executable would be
//! slow (TFRT thread-pool spin-up) and noisy; cloning the handle is an `Rc`
//! bump.

use std::cell::RefCell;

use anyhow::Result;

thread_local! {
    static CLIENT: RefCell<Option<xla::PjRtClient>> = const { RefCell::new(None) };
}

/// The thread's PJRT CPU client (created on first use; handle clone is cheap).
pub fn global_client() -> Result<xla::PjRtClient> {
    CLIENT.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            let c = xla::PjRtClient::cpu()
                .map_err(|e| anyhow::anyhow!("PjRtClient::cpu failed: {e:?}"))?;
            *slot = Some(c);
        }
        Ok(slot.as_ref().expect("set above").clone())
    })
}
