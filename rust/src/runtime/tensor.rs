//! Host-side f32 tensor: the currency between the coordinator and PJRT.
//!
//! Deliberately minimal — row-major f32 with shape — because everything the
//! AOT graphs consume/produce is f32 (DESIGN.md §5: one dtype end-to-end
//! keeps the HLO-text interchange with xla_extension 0.5.1 trivially safe).

use anyhow::Result;

use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn ones(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![1.0; n],
        }
    }

    pub fn scalar(x: f32) -> Self {
        Tensor {
            shape: vec![],
            data: vec![x],
        }
    }

    pub fn filled(shape: &[usize], x: f32) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![x; n],
        }
    }

    /// He-normal init: N(0, sqrt(2 / fan_in)).  fan_in = product of all but
    /// the last dim (conv HWIO and dense (in, out) both satisfy this).
    pub fn he_normal(shape: &[usize], rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        let fan_in: usize = if shape.len() >= 2 {
            shape[..shape.len() - 1].iter().product()
        } else {
            1
        };
        let scale = (2.0 / fan_in.max(1) as f64).sqrt() as f32;
        let mut data = vec![0.0; n];
        rng.fill_normal(&mut data, scale);
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    /// Embedding init: N(0, 0.02) (GPT-style).
    pub fn embed_init(shape: &[usize], rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        let mut data = vec![0.0; n];
        rng.fill_normal(&mut data, 0.02);
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    /// LoRA-A init: N(0, 1/sqrt(d_in)) (Hu et al.; B stays zero so the
    /// adapter starts as the identity).
    pub fn lora_a_init(shape: &[usize], rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        let fan_in = shape.first().copied().unwrap_or(1);
        let scale = (1.0 / fan_in.max(1) as f64).sqrt() as f32;
        let mut data = vec![0.0; n];
        rng.fill_normal(&mut data, scale);
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn item(&self) -> f32 {
        debug_assert_eq!(self.data.len(), 1, "item() on non-scalar tensor");
        self.data[0]
    }

    /// Row-major argmax over the last axis; returns indices per row.
    pub fn argmax_last(&self) -> Vec<usize> {
        let cols = *self.shape.last().unwrap_or(&1);
        self.data
            .chunks(cols)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }

    // ---- Literal interop ---------------------------------------------------

    /// Pure-Rust literal (the `pjrt`-free stand-in on this boundary; same
    /// shape/buffer contract as the xla path below).
    pub fn to_host_literal(&self) -> Result<super::literal::HostLiteral> {
        super::literal::HostLiteral::vec1(&self.data).reshape(&self.shape)
    }

    pub fn from_host_literal(lit: &super::literal::HostLiteral) -> Tensor {
        Tensor::new(lit.shape.clone(), lit.data.clone())
    }

    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.shape.is_empty() {
            // () scalar: reshape to rank-0
            lit.reshape(&[])
                .map_err(|e| anyhow::anyhow!("scalar reshape: {e:?}"))
        } else {
            let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
            lit.reshape(&dims)
                .map_err(|e| anyhow::anyhow!("reshape {:?}: {e:?}", self.shape))
        }
    }

    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow::anyhow!("array_shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("to_vec<f32>: {e:?}"))?;
        Ok(Tensor::new(dims, data))
    }
}

/// Save a tensor list to a simple little-endian binary container
/// (`HAQT` magic; used for the pretrained-base cache).
pub fn save_tensors(path: &std::path::Path, tensors: &[Tensor]) -> Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(b"HAQT");
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for t in tensors {
        buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
        for &d in &t.shape {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for &x in &t.data {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    // Atomic publish: parallel fleet workers can race to materialize the
    // same disk-cached base, so each writer lands on a private temp file and
    // renames — a concurrent `load_tensors` never sees a partial file.
    static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let tmp = path.with_extension(format!("tmp.{}.{seq}", std::process::id()));
    std::fs::write(&tmp, buf)?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Load a tensor list saved by [`save_tensors`].  Bounds-checked: a
/// truncated or corrupt file is an error, never a panic.
pub fn load_tensors(path: &std::path::Path) -> Result<Vec<Tensor>> {
    fn take<'a>(buf: &'a [u8], off: &mut usize, n: usize) -> Result<&'a [u8]> {
        let end = off
            .checked_add(n)
            .filter(|&e| e <= buf.len())
            .ok_or_else(|| anyhow::anyhow!("truncated tensor file"))?;
        let s = &buf[*off..end];
        *off = end;
        Ok(s)
    }
    let buf = std::fs::read(path)?;
    anyhow::ensure!(
        buf.len() >= 8 && &buf[..4] == b"HAQT",
        "bad tensor file {}",
        path.display()
    );
    let mut off = 4usize;
    let count = u32::from_le_bytes(take(&buf, &mut off, 4)?.try_into().unwrap()) as usize;
    let mut out = Vec::with_capacity(count.min(4096));
    for _ in 0..count {
        let ndim = u32::from_le_bytes(take(&buf, &mut off, 4)?.try_into().unwrap()) as usize;
        anyhow::ensure!(ndim <= 16, "implausible tensor rank {ndim}");
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let d = u64::from_le_bytes(take(&buf, &mut off, 8)?.try_into().unwrap());
            shape.push(d as usize);
        }
        let n: usize = shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .ok_or_else(|| anyhow::anyhow!("tensor size overflow"))?;
        let nbytes = n
            .checked_mul(4)
            .ok_or_else(|| anyhow::anyhow!("tensor size overflow"))?;
        let bytes = take(&buf, &mut off, nbytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        out.push(Tensor::new(shape, data));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_item() {
        let t = Tensor::scalar(3.5);
        assert_eq!(t.item(), 3.5);
        let z = Tensor::zeros(&[2, 3]);
        assert_eq!(z.len(), 6);
        assert_eq!(z.shape, vec![2, 3]);
    }

    #[test]
    fn argmax_rows() {
        let t = Tensor::new(vec![2, 3], vec![0.0, 5.0, 1.0, 9.0, 2.0, 3.0]);
        assert_eq!(t.argmax_last(), vec![1, 0]);
    }

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Rng::new(9);
        let tensors = vec![
            Tensor::he_normal(&[3, 4], &mut rng),
            Tensor::scalar(2.5),
            Tensor::zeros(&[2, 2, 2]),
        ];
        let path = std::env::temp_dir().join("haqa_tensor_test.bin");
        save_tensors(&path, &tensors).unwrap();
        let back = load_tensors(&path).unwrap();
        assert_eq!(tensors, back);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn host_literal_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        let lit = t.to_host_literal().unwrap();
        assert_eq!(lit.shape, vec![2, 3]);
        assert_eq!(Tensor::from_host_literal(&lit), t);
        // scalars reshape to rank-0 like the xla path
        let s = Tensor::scalar(1.5);
        let sl = s.to_host_literal().unwrap();
        assert!(sl.shape.is_empty());
        assert_eq!(Tensor::from_host_literal(&sl).item(), 1.5);
    }

    #[test]
    fn load_rejects_truncated_file() {
        let mut rng = Rng::new(11);
        let tensors = vec![Tensor::he_normal(&[4, 4], &mut rng)];
        let path = std::env::temp_dir().join("haqa_tensor_trunc_test.bin");
        save_tensors(&path, &tensors).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        assert!(load_tensors(&path).is_err(), "truncated file must not load");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn he_normal_scale() {
        let mut rng = Rng::new(1);
        let t = Tensor::he_normal(&[64, 64], &mut rng);
        let var: f32 =
            t.data.iter().map(|x| x * x).sum::<f32>() / t.len() as f32;
        let expect = 2.0 / 64.0;
        assert!((var - expect).abs() < expect * 0.3, "var {var} vs {expect}");
    }
}
