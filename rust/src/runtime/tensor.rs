//! Host-side f32 tensor: the currency between the coordinator and PJRT.
//!
//! Deliberately minimal — row-major f32 with shape — because everything the
//! AOT graphs consume/produce is f32 (DESIGN.md §5: one dtype end-to-end
//! keeps the HLO-text interchange with xla_extension 0.5.1 trivially safe).

use anyhow::Result;

use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn ones(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![1.0; n],
        }
    }

    pub fn scalar(x: f32) -> Self {
        Tensor {
            shape: vec![],
            data: vec![x],
        }
    }

    pub fn filled(shape: &[usize], x: f32) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![x; n],
        }
    }

    /// He-normal init: N(0, sqrt(2 / fan_in)).  fan_in = product of all but
    /// the last dim (conv HWIO and dense (in, out) both satisfy this).
    pub fn he_normal(shape: &[usize], rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        let fan_in: usize = if shape.len() >= 2 {
            shape[..shape.len() - 1].iter().product()
        } else {
            1
        };
        let scale = (2.0 / fan_in.max(1) as f64).sqrt() as f32;
        let mut data = vec![0.0; n];
        rng.fill_normal(&mut data, scale);
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    /// Embedding init: N(0, 0.02) (GPT-style).
    pub fn embed_init(shape: &[usize], rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        let mut data = vec![0.0; n];
        rng.fill_normal(&mut data, 0.02);
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    /// LoRA-A init: N(0, 1/sqrt(d_in)) (Hu et al.; B stays zero so the
    /// adapter starts as the identity).
    pub fn lora_a_init(shape: &[usize], rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        let fan_in = shape.first().copied().unwrap_or(1);
        let scale = (1.0 / fan_in.max(1) as f64).sqrt() as f32;
        let mut data = vec![0.0; n];
        rng.fill_normal(&mut data, scale);
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn item(&self) -> f32 {
        debug_assert_eq!(self.data.len(), 1, "item() on non-scalar tensor");
        self.data[0]
    }

    /// Row-major argmax over the last axis; returns indices per row.
    pub fn argmax_last(&self) -> Vec<usize> {
        let cols = *self.shape.last().unwrap_or(&1);
        self.data
            .chunks(cols)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }

    // ---- Literal interop ---------------------------------------------------

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.shape.is_empty() {
            // () scalar: reshape to rank-0
            lit.reshape(&[])
                .map_err(|e| anyhow::anyhow!("scalar reshape: {e:?}"))
        } else {
            let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
            lit.reshape(&dims)
                .map_err(|e| anyhow::anyhow!("reshape {:?}: {e:?}", self.shape))
        }
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow::anyhow!("array_shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("to_vec<f32>: {e:?}"))?;
        Ok(Tensor::new(dims, data))
    }
}

/// Save a tensor list to a simple little-endian binary container
/// (`HAQT` magic; used for the pretrained-base cache).
pub fn save_tensors(path: &std::path::Path, tensors: &[Tensor]) -> Result<()> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(b"HAQT");
    buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
    for t in tensors {
        buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
        for &d in &t.shape {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        for &x in &t.data {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, buf)?;
    Ok(())
}

/// Load a tensor list saved by [`save_tensors`].
pub fn load_tensors(path: &std::path::Path) -> Result<Vec<Tensor>> {
    let buf = std::fs::read(path)?;
    anyhow::ensure!(buf.len() >= 8 && &buf[..4] == b"HAQT", "bad tensor file");
    let mut off = 4usize;
    let rd_u32 = |b: &[u8], o: &mut usize| {
        let v = u32::from_le_bytes(b[*o..*o + 4].try_into().unwrap());
        *o += 4;
        v
    };
    let count = rd_u32(&buf, &mut off) as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let ndim = rd_u32(&buf, &mut off) as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let d = u64::from_le_bytes(buf[off..off + 8].try_into().unwrap());
            off += 8;
            shape.push(d as usize);
        }
        let n: usize = shape.iter().product();
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(f32::from_le_bytes(buf[off..off + 4].try_into().unwrap()));
            off += 4;
        }
        out.push(Tensor::new(shape, data));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_item() {
        let t = Tensor::scalar(3.5);
        assert_eq!(t.item(), 3.5);
        let z = Tensor::zeros(&[2, 3]);
        assert_eq!(z.len(), 6);
        assert_eq!(z.shape, vec![2, 3]);
    }

    #[test]
    fn argmax_rows() {
        let t = Tensor::new(vec![2, 3], vec![0.0, 5.0, 1.0, 9.0, 2.0, 3.0]);
        assert_eq!(t.argmax_last(), vec![1, 0]);
    }

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Rng::new(9);
        let tensors = vec![
            Tensor::he_normal(&[3, 4], &mut rng),
            Tensor::scalar(2.5),
            Tensor::zeros(&[2, 2, 2]),
        ];
        let path = std::env::temp_dir().join("haqa_tensor_test.bin");
        save_tensors(&path, &tensors).unwrap();
        let back = load_tensors(&path).unwrap();
        assert_eq!(tensors, back);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn he_normal_scale() {
        let mut rng = Rng::new(1);
        let t = Tensor::he_normal(&[64, 64], &mut rng);
        let var: f32 =
            t.data.iter().map(|x| x * x).sum::<f32>() / t.len() as f32;
        let expect = 2.0 / 64.0;
        assert!((var - expect).abs() < expect * 0.3, "var {var} vs {expect}");
    }
}
