//! Synthetic datasets (DESIGN.md §2 substitutions for CIFAR-10/ImageNet and
//! Alpaca + the lm-eval task suite).
//!
//! * [`ImageDataset`] — 10-class 16x16x3 images: smooth class templates +
//!   per-sample spatial jitter + noise.  Non-trivially separable, so QAT
//!   hyperparameters (lr/momentum/wd/bits) move accuracy the way they do on
//!   CIFAR.
//! * [`LmTaskKind`] — eight structured sequence families standing in for
//!   the paper's eight eval tasks (BoolQ … MathQA): copy, shift, reverse,
//!   majority, markov, induction, fibonacci-mod, periodic.  The training
//!   corpus is a uniform mixture; each eval task scores next-token accuracy
//!   on its predictable positions.

use crate::runtime::Tensor;
use crate::util::rng::Rng;

pub const IMG: usize = 16;
pub const NUM_CLASSES: usize = 10;
pub const VOCAB: usize = 64;
pub const SEQ: usize = 32;

// ---------------------------------------------------------------------------
// images
// ---------------------------------------------------------------------------

pub struct ImageDataset {
    /// Per-class low-frequency templates, (C, 16*16*3).
    templates: Vec<Vec<f32>>,
    rng: Rng,
}

impl ImageDataset {
    pub fn new(seed: u64) -> ImageDataset {
        let mut rng = Rng::new(seed).split(0x1317);
        let mut templates = Vec::with_capacity(NUM_CLASSES);
        for _ in 0..NUM_CLASSES {
            templates.push(Self::template(&mut rng));
        }
        ImageDataset {
            templates,
            rng: rng.split(7),
        }
    }

    /// Smooth template: 4x4 random grid bilinearly upsampled to 16x16, per
    /// channel.
    fn template(rng: &mut Rng) -> Vec<f32> {
        let g = 4usize;
        let mut grid = vec![0.0f32; g * g * 3];
        rng.fill_normal(&mut grid, 1.0);
        let mut out = vec![0.0f32; IMG * IMG * 3];
        for y in 0..IMG {
            for x in 0..IMG {
                let fy = y as f32 / IMG as f32 * (g - 1) as f32;
                let fx = x as f32 / IMG as f32 * (g - 1) as f32;
                let (y0, x0) = (fy.floor() as usize, fx.floor() as usize);
                let (y1, x1) = ((y0 + 1).min(g - 1), (x0 + 1).min(g - 1));
                let (dy, dx) = (fy - y0 as f32, fx - x0 as f32);
                for c in 0..3 {
                    let v00 = grid[(y0 * g + x0) * 3 + c];
                    let v01 = grid[(y0 * g + x1) * 3 + c];
                    let v10 = grid[(y1 * g + x0) * 3 + c];
                    let v11 = grid[(y1 * g + x1) * 3 + c];
                    let v = v00 * (1.0 - dy) * (1.0 - dx)
                        + v01 * (1.0 - dy) * dx
                        + v10 * dy * (1.0 - dx)
                        + v11 * dy * dx;
                    out[(y * IMG + x) * 3 + c] = v;
                }
            }
        }
        out
    }

    /// One sample of class `label`: template shifted (wrap) by up to ±3 px,
    /// scaled by U[0.8, 1.2], plus N(0, 0.8) pixel noise.
    fn sample_into(&mut self, label: usize, out: &mut [f32]) {
        let sy = self.rng.int(-3, 3);
        let sx = self.rng.int(-3, 3);
        let scale = self.rng.uniform(0.8, 1.2) as f32;
        let t = &self.templates[label];
        for y in 0..IMG {
            for x in 0..IMG {
                let yy = (y as i64 + sy).rem_euclid(IMG as i64) as usize;
                let xx = (x as i64 + sx).rem_euclid(IMG as i64) as usize;
                for c in 0..3 {
                    out[(y * IMG + x) * 3 + c] = t[(yy * IMG + xx) * 3 + c] * scale
                        + self.rng.normal_f32() * 0.8;
                }
            }
        }
    }

    /// A batch: x (B,16,16,3), y one-hot (B,10).
    pub fn batch(&mut self, b: usize) -> (Tensor, Tensor) {
        let mut x = Tensor::zeros(&[b, IMG, IMG, 3]);
        let mut y = Tensor::zeros(&[b, NUM_CLASSES]);
        let px = IMG * IMG * 3;
        for i in 0..b {
            let label = self.rng.usize(NUM_CLASSES);
            self.sample_into(label, &mut x.data[i * px..(i + 1) * px]);
            y.data[i * NUM_CLASSES + label] = 1.0;
        }
        (x, y)
    }

    /// A fixed, reproducible eval set (separate RNG stream).
    pub fn eval_set(seed: u64, b: usize) -> (Tensor, Tensor) {
        let mut ds = ImageDataset::new(seed);
        ds.rng = Rng::new(seed).split(0xe7a1);
        ds.batch(b)
    }
}

// ---------------------------------------------------------------------------
// language-model tasks
// ---------------------------------------------------------------------------

/// Eight synthetic task families (stand-ins for the paper's eight tasks).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LmTaskKind {
    Copy,
    Shift,
    Reverse,
    Majority,
    Markov,
    Induction,
    FibMod,
    Periodic,
}

impl LmTaskKind {
    pub const ALL: [LmTaskKind; 8] = [
        LmTaskKind::Copy,
        LmTaskKind::Shift,
        LmTaskKind::Reverse,
        LmTaskKind::Majority,
        LmTaskKind::Markov,
        LmTaskKind::Induction,
        LmTaskKind::FibMod,
        LmTaskKind::Periodic,
    ];

    /// Display names keep the paper's column order recognizable.
    pub fn label(&self) -> &'static str {
        match self {
            LmTaskKind::Copy => "Copy",
            LmTaskKind::Shift => "Shift",
            LmTaskKind::Reverse => "Reverse",
            LmTaskKind::Majority => "Majority",
            LmTaskKind::Markov => "Markov",
            LmTaskKind::Induction => "Induction",
            LmTaskKind::FibMod => "FibMod",
            LmTaskKind::Periodic => "Periodic",
        }
    }

    /// Positions scored for accuracy (where the continuation is determined
    /// by the context).  Index into the *target* sequence (t predicts
    /// token[t+1]).
    pub fn scored_positions(&self) -> std::ops::Range<usize> {
        match self {
            LmTaskKind::Majority => SEQ - 2..SEQ - 1,
            _ => SEQ / 2..SEQ - 1,
        }
    }

    /// Generate one sequence of SEQ+1 tokens (window + next-token targets).
    pub fn generate(&self, rng: &mut Rng) -> Vec<u8> {
        let n = SEQ + 1;
        let half = (n + 1) / 2;
        let mut s = vec![0u8; n];
        match self {
            LmTaskKind::Copy => {
                for i in 0..half {
                    s[i] = rng.usize(VOCAB) as u8;
                }
                for i in half..n {
                    s[i] = s[i - half];
                }
            }
            LmTaskKind::Shift => {
                for i in 0..half {
                    s[i] = rng.usize(VOCAB) as u8;
                }
                for i in half..n {
                    s[i] = ((s[i - half] as usize + 1) % VOCAB) as u8;
                }
            }
            LmTaskKind::Reverse => {
                for i in 0..half {
                    s[i] = rng.usize(VOCAB) as u8;
                }
                for i in half..n {
                    s[i] = s[half - 1 - (i - half)];
                }
            }
            LmTaskKind::Majority => {
                // Tokens from {1, 2}; the last token is the majority symbol.
                let mut ones = 0;
                for item in s.iter_mut().take(n - 1) {
                    let v = if rng.bool(0.5) { 1u8 } else { 2u8 };
                    if v == 1 {
                        ones += 1;
                    }
                    *item = v;
                }
                s[n - 1] = if 2 * ones > n - 1 { 1 } else { 2 };
            }
            LmTaskKind::Markov => {
                // Deterministic chain: next = (3*cur + 7) % VOCAB, entered
                // from a random start — fully learnable as a lookup.
                s[0] = rng.usize(VOCAB) as u8;
                for i in 1..n {
                    s[i] = ((3 * s[i - 1] as usize + 7) % VOCAB) as u8;
                }
            }
            LmTaskKind::Induction => {
                // Random K-V pairs repeated: A x B y A ? -> x …
                let a = rng.usize(VOCAB / 2) as u8;
                let b = (VOCAB / 2 + rng.usize(VOCAB / 2)) as u8;
                for i in 0..n {
                    s[i] = if i % 2 == 0 { a } else { b };
                }
            }
            LmTaskKind::FibMod => {
                s[0] = rng.usize(32) as u8;
                s[1] = rng.usize(32) as u8;
                for i in 2..n {
                    s[i] = ((s[i - 1] as usize + s[i - 2] as usize) % 48) as u8;
                }
            }
            LmTaskKind::Periodic => {
                let period = 2 + rng.usize(3); // 2..=4
                let motif: Vec<u8> =
                    (0..period).map(|_| rng.usize(VOCAB) as u8).collect();
                for i in 0..n {
                    s[i] = motif[i % period];
                }
            }
        }
        s
    }
}

/// The "generic corpus" subset used for base pretraining: the paper
/// pretrains on generic text and fine-tunes on instruction data, so the
/// base sees only these families and QLoRA must teach the rest (Induction,
/// FibMod, Copy/Shift/Reverse) — that headroom is what the Table 2 / Fig. 4
/// hyperparameter search optimizes over.
pub const PRETRAIN_TASKS: [LmTaskKind; 3] =
    [LmTaskKind::Markov, LmTaskKind::Majority, LmTaskKind::Periodic];

/// A batch of LM training data as one-hot tensors: tokens (B,T,V),
/// targets (B,T,V).  Tasks are mixed uniformly unless `only` is given.
pub fn lm_batch(
    rng: &mut Rng,
    b: usize,
    only: Option<LmTaskKind>,
) -> (Tensor, Tensor) {
    lm_batch_from(rng, b, only, &LmTaskKind::ALL)
}

/// Like [`lm_batch`] but drawing the mixture from `tasks`.
pub fn lm_batch_from(
    rng: &mut Rng,
    b: usize,
    only: Option<LmTaskKind>,
    tasks: &[LmTaskKind],
) -> (Tensor, Tensor) {
    let mut tokens = Tensor::zeros(&[b, SEQ, VOCAB]);
    let mut targets = Tensor::zeros(&[b, SEQ, VOCAB]);
    for i in 0..b {
        let task = only.unwrap_or_else(|| *rng.choice(tasks));
        let s = task.generate(rng);
        for t in 0..SEQ {
            tokens.data[(i * SEQ + t) * VOCAB + s[t] as usize] = 1.0;
            targets.data[(i * SEQ + t) * VOCAB + s[t + 1] as usize] = 1.0;
        }
    }
    (tokens, targets)
}

/// Raw token ids for a batch (used by accuracy scoring).
pub fn lm_batch_ids(rng: &mut Rng, b: usize, task: LmTaskKind) -> Vec<Vec<u8>> {
    (0..b).map(|_| task.generate(rng)).collect()
}

/// Convert raw ids to (tokens, targets) one-hot tensors.
pub fn ids_to_tensors(ids: &[Vec<u8>]) -> (Tensor, Tensor) {
    let b = ids.len();
    let mut tokens = Tensor::zeros(&[b, SEQ, VOCAB]);
    let mut targets = Tensor::zeros(&[b, SEQ, VOCAB]);
    for (i, s) in ids.iter().enumerate() {
        for t in 0..SEQ {
            tokens.data[(i * SEQ + t) * VOCAB + s[t] as usize] = 1.0;
            targets.data[(i * SEQ + t) * VOCAB + s[t + 1] as usize] = 1.0;
        }
    }
    (tokens, targets)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_batches_are_onehot_and_deterministic() {
        let mut a = ImageDataset::new(3);
        let mut b = ImageDataset::new(3);
        let (xa, ya) = a.batch(8);
        let (xb, yb) = b.batch(8);
        assert_eq!(xa, xb);
        assert_eq!(ya, yb);
        for row in ya.data.chunks(NUM_CLASSES) {
            assert_eq!(row.iter().filter(|v| **v == 1.0).count(), 1);
        }
    }

    #[test]
    fn eval_set_differs_from_train_stream() {
        let (xe, _) = ImageDataset::eval_set(3, 8);
        let mut ds = ImageDataset::new(3);
        let (xt, _) = ds.batch(8);
        assert_ne!(xe, xt);
    }

    #[test]
    fn tasks_are_predictable_on_scored_positions() {
        let mut rng = Rng::new(5);
        for task in LmTaskKind::ALL {
            // Two sequences with the same context prefix must agree on
            // scored positions — check determinism given the full prefix by
            // regenerating and comparing self-consistency.
            let s = task.generate(&mut rng);
            assert_eq!(s.len(), SEQ + 1);
            assert!(s.iter().all(|&t| (t as usize) < VOCAB));
            let r = task.scored_positions();
            assert!(r.start < r.end && r.end <= SEQ);
        }
    }

    #[test]
    fn copy_task_actually_copies() {
        let mut rng = Rng::new(6);
        let s = LmTaskKind::Copy.generate(&mut rng);
        let half = (s.len() + 1) / 2;
        for i in half..s.len() {
            assert_eq!(s[i], s[i - half]);
        }
    }

    #[test]
    fn onehot_encoding_consistent() {
        let mut rng = Rng::new(7);
        let ids = lm_batch_ids(&mut rng, 4, LmTaskKind::Markov);
        let (tokens, targets) = ids_to_tensors(&ids);
        assert_eq!(tokens.shape, vec![4, SEQ, VOCAB]);
        // targets at t == tokens at t+1
        for (i, s) in ids.iter().enumerate() {
            for t in 0..SEQ - 1 {
                let tok_next = s[t + 1] as usize;
                assert_eq!(targets.data[(i * SEQ + t) * VOCAB + tok_next], 1.0);
                assert_eq!(tokens.data[(i * SEQ + t + 1) * VOCAB + tok_next], 1.0);
            }
        }
    }
}
