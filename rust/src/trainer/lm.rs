//! QLoRA fine-tuning loop (paper Table 2 / Figure 4 track).
//!
//! Drives `lm_train_b{4,8,16}`: the frozen DoReFa-quantized base is a
//! `frozen` input (bit-width is a runtime scalar), the LoRA adapters plus
//! Adam moments are the threaded state, and every paper hyperparameter maps
//! to a runtime input:
//!
//! * `lora_r`      → rank mask over the rank-64 adapter,
//! * `lora_alpha`  → the `lora_scale = alpha / r` scalar,
//! * `warmup_ratio`→ the per-step effective lr schedule computed here,
//! * `max_steps`   → optimizer updates (scaled by `step_scale` to laptop
//!   size), and `gradient_accumulation_steps` trades updates for effective
//!   batch exactly as under a fixed sample budget: updates ≍ 1/accum.

use std::collections::HashMap;

use anyhow::Result;

use crate::runtime::{ArtifactSet, Tensor};
use crate::search::Config;
use crate::util::rng::Rng;

use super::data::{lm_batch, SEQ};
use super::evalsuite::{self, EvalReport};
use super::qat::snap_batch;

pub const LM_BATCHES: [usize; 3] = [4, 8, 16];
pub const R_MAX: usize = 64;
const ADAM_B1: f64 = 0.9;
const ADAM_B2: f64 = 0.999;
const D_MODEL: usize = 64;

/// The frozen quantized base weights of one model variant.
pub struct LmBase {
    pub tensors: Vec<Tensor>,
    pub seed: u64,
}

impl LmBase {
    /// Initialize from the manifest's frozen-input specs (deterministic in
    /// `seed`; different seeds = the different "model variants" of Table 2).
    pub fn new(set: &ArtifactSet, seed: u64) -> Result<LmBase> {
        let art = set.get("lm_train_b8")?;
        let mut rng = Rng::new(seed).split(0xba5e);
        Ok(LmBase {
            tensors: art.init_frozen(&mut rng),
            seed,
        })
    }

    /// A *pretrained* base: full-parameter Adam training on the task
    /// mixture via the `lm_pretrain_b16` artifact (the paper fine-tunes
    /// pretrained checkpoints, so the QLoRA track starts from one too).
    /// Cached on disk under `artifacts/cache/`, keyed by (seed, steps).
    ///
    /// Pretraining is the most expensive step in a fleet sweep, so
    /// same-key requests are serialized process-wide: the first fleet
    /// worker trains and publishes the disk cache, concurrent workers wait
    /// on the per-key lock and then load it.
    pub fn pretrained(set: &ArtifactSet, seed: u64, steps: usize) -> Result<LmBase> {
        use std::collections::HashMap;
        use std::sync::{Arc, Mutex, OnceLock};

        let cache = set
            .dir
            .join("cache")
            .join(format!("lm_base_s{seed}_t{steps}.bin"));
        if let Ok(tensors) = crate::runtime::tensor::load_tensors(&cache) {
            return Ok(LmBase { tensors, seed });
        }
        static LOCKS: OnceLock<Mutex<HashMap<(u64, usize), Arc<Mutex<()>>>>> = OnceLock::new();
        let key_lock = {
            let mut map = LOCKS
                .get_or_init(|| Mutex::new(HashMap::new()))
                .lock()
                .unwrap_or_else(|p| p.into_inner());
            map.entry((seed, steps)).or_default().clone()
        };
        let _guard = key_lock.lock().unwrap_or_else(|p| p.into_inner());
        // Re-check after acquiring the lock: a concurrent worker may have
        // finished pretraining and published the cache while we waited.
        if let Ok(tensors) = crate::runtime::tensor::load_tensors(&cache) {
            return Ok(LmBase { tensors, seed });
        }
        let exec = set.executor("lm_pretrain_b16")?;
        let mut rng = Rng::new(seed).split(0xba5e);
        let mut state = exec.artifact.init_state(&mut rng);
        let mut data_rng = Rng::new(seed).split(0x9e7a);
        let mut named: HashMap<&str, Tensor> = HashMap::new();
        named.insert("lr", Tensor::scalar(3e-3));
        named.insert("grad_clip", Tensor::scalar(1.0));
        for t in 1..=steps {
            // Pretraining sees only the "generic corpus" subset; QLoRA
            // fine-tuning sees the full mixture (see data::PRETRAIN_TASKS).
            let (tokens, targets) = super::data::lm_batch_from(
                &mut data_rng, 16, None, &super::data::PRETRAIN_TASKS);
            named.insert("tokens", tokens);
            named.insert("targets", targets);
            named.insert(
                "bc1",
                Tensor::scalar((1.0 / (1.0 - ADAM_B1.powi(t as i32))) as f32),
            );
            named.insert(
                "bc2",
                Tensor::scalar((1.0 / (1.0 - ADAM_B2.powi(t as i32))) as f32),
            );
            let (new_state, metrics) = exec.step(state, &[], &named)?;
            state = new_state;
            let loss = metrics[0].item();
            anyhow::ensure!(loss.is_finite(), "pretraining diverged at step {t}");
        }
        // Base weights are the first third of the state (base, m, v).
        let nb = exec.artifact.state_count / 3;
        let tensors: Vec<Tensor> = state[..nb].to_vec();
        let _ = crate::runtime::tensor::save_tensors(&cache, &tensors);
        Ok(LmBase { tensors, seed })
    }
}

#[derive(Debug, Clone)]
pub struct QloraResult {
    pub report: EvalReport,
    pub loss_curve: Vec<f64>,
    pub diverged: bool,
    pub updates: usize,
}

impl QloraResult {
    pub fn score(&self) -> f64 {
        self.report.average
    }

    pub fn feedback(&self) -> String {
        let n = self.loss_curve.len();
        let tail = &self.loss_curve[n - (n / 3).max(1)..];
        let slope = if tail.len() >= 2 {
            (tail[tail.len() - 1] - tail[0]) / tail.len() as f64
        } else {
            0.0
        };
        format!(
            "{{\"final_loss\": {:.4}, \"loss_slope\": {:.5}, \"diverged\": {}, \
             \"tasks\": {}}}",
            self.loss_curve.last().copied().unwrap_or(f64::NAN),
            slope,
            self.diverged,
            self.report.to_json().to_string(),
        )
    }
}

pub struct QloraJob<'a> {
    pub set: &'a ArtifactSet,
    pub base: &'a LmBase,
    /// Deployment bit-width for the frozen base (4 / 8 / 16).
    pub bits: f32,
    pub seed: u64,
    /// Fraction of the paper's `max_steps` actually run (laptop scale).
    pub step_scale: f64,
}

impl<'a> QloraJob<'a> {
    pub fn run(&self, cfg: &Config) -> Result<QloraResult> {
        let get = |k: &str, d: f64| cfg.get(k).map(|v| v.as_f64()).unwrap_or(d);
        let lr0 = get("learning_rate", 4e-4);
        let wd = get("weight_decay", 0.01);
        let clip = get("max_grad_norm", 0.3);
        let max_steps = get("max_steps", 400.0);
        let accum = get("gradient_accumulation_steps", 8.0).max(1.0);
        let lora_r = get("lora_r", 16.0).clamp(1.0, R_MAX as f64) as usize;
        let lora_alpha = get("lora_alpha", 8.0);
        let dropout_p = get("lora_dropout", 0.05);
        let warmup = get("warmup_ratio", 0.03);
        let batch = snap_batch(
            cfg.get("per_device_train_batch_size")
                .map(|v| v.as_i64())
                .unwrap_or(8),
            &LM_BATCHES,
        );
        // Fixed sample budget: more accumulation -> fewer, larger-effective-
        // batch updates (reference point accum=8).
        let updates = ((max_steps * self.step_scale * 8.0 / accum).round() as usize).max(4);

        let train = self.set.executor(&format!("lm_train_b{batch}"))?;
        let mut rng = Rng::new(self.seed).split(0x10ad);
        let mut state = train.artifact.init_state(&mut rng);

        let mut rank_mask = Tensor::zeros(&[R_MAX]);
        for i in 0..lora_r {
            rank_mask.data[i] = 1.0;
        }
        let lora_scale = (lora_alpha / lora_r as f64) as f32;

        let mut named: HashMap<&str, Tensor> = HashMap::new();
        named.insert("weight_decay", Tensor::scalar(wd as f32));
        named.insert("grad_clip", Tensor::scalar(clip as f32));
        named.insert("bits", Tensor::scalar(self.bits));
        named.insert("lora_scale", Tensor::scalar(lora_scale));
        named.insert("dropout_p", Tensor::scalar(dropout_p as f32));
        named.insert("rank_mask", rank_mask.clone());

        let warmup_steps = (warmup * updates as f64).ceil().max(1.0);
        let mut loss_curve = Vec::with_capacity(updates);
        let mut diverged = false;
        let mut data_rng = Rng::new(self.seed).split(0xda7a);
        for t in 1..=updates {
            let (tokens, targets) = lm_batch(&mut data_rng, batch, None);
            let mut noise = Tensor::zeros(&[batch, SEQ, D_MODEL]);
            data_rng.fill_uniform(&mut noise.data);
            let lr_t = lr0 * (t as f64 / warmup_steps).min(1.0);
            named.insert("tokens", tokens);
            named.insert("targets", targets);
            named.insert("dropout_noise", noise);
            named.insert("lr", Tensor::scalar(lr_t as f32));
            named.insert(
                "bc1",
                Tensor::scalar((1.0 / (1.0 - ADAM_B1.powi(t as i32))) as f32),
            );
            named.insert(
                "bc2",
                Tensor::scalar((1.0 / (1.0 - ADAM_B2.powi(t as i32))) as f32),
            );
            let (new_state, metrics) = train.step(state, &self.base.tensors, &named)?;
            state = new_state;
            let loss = metrics[0].item() as f64;
            loss_curve.push(loss);
            if !loss.is_finite() || loss > 50.0 {
                diverged = true;
                break;
            }
        }

        // LoRA adapters are the first third of the state (lora, m, v).
        let n_lora = train.artifact.state_count / 3;
        let lora = &state[..n_lora];
        let mut report = evalsuite::evaluate(
            self.set,
            &self.base.tensors,
            lora,
            self.bits,
            &rank_mask,
            lora_scale,
            self.seed,
        )?;
        if diverged {
            report.average = 1.0 / 64.0; // chance level
        }
        Ok(QloraResult {
            report,
            loss_curve,
            diverged,
            updates,
        })
    }
}
