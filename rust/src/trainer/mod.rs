//! Training substrate: synthetic datasets + the QAT/QLoRA loops that drive
//! the AOT-lowered train-step artifacts through PJRT.

pub mod data;
pub mod evalsuite;
pub mod lm;
pub mod qat;

pub use data::{ImageDataset, LmTaskKind};
