//! The eight-task evaluation suite (the paper's BoolQ…MathQA stand-ins).
//!
//! Each task is scored as next-token accuracy over its predictable
//! positions using the `lm_eval` artifact's logits, mirroring how the paper
//! feeds per-task accuracies back into the dynamic prompt.

use std::collections::HashMap;

use anyhow::Result;

use crate::runtime::{ArtifactSet, Tensor};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::data::{ids_to_tensors, lm_batch_ids, LmTaskKind, SEQ, VOCAB};

pub const EVAL_BATCH: usize = 32;

#[derive(Debug, Clone)]
pub struct EvalReport {
    /// (task label, accuracy in [0,1]) per task, suite order.
    pub tasks: Vec<(String, f64)>,
    pub average: f64,
    pub mean_loss: f64,
}

impl EvalReport {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        for (name, acc) in &self.tasks {
            o.set(name, Json::Num((*acc * 1e4).round() / 1e4));
        }
        o.set("average", Json::Num((self.average * 1e4).round() / 1e4));
        o
    }
}

/// Evaluate (base, lora) across all eight tasks.
#[allow(clippy::too_many_arguments)]
pub fn evaluate(
    set: &ArtifactSet,
    base: &[Tensor],
    lora: &[Tensor],
    bits: f32,
    rank_mask: &Tensor,
    lora_scale: f32,
    seed: u64,
) -> Result<EvalReport> {
    let eval = set.executor("lm_eval")?;
    // frozen inputs = base ++ lora (manifest order).
    let mut frozen: Vec<Tensor> = Vec::with_capacity(base.len() + lora.len());
    frozen.extend_from_slice(base);
    frozen.extend_from_slice(lora);

    let mut tasks = Vec::new();
    let mut loss_sum = 0.0;
    for task in LmTaskKind::ALL {
        // Fixed per-task eval stream (independent of the training stream).
        let mut rng = Rng::new(seed).split(0xe5 + task as u64);
        let ids = lm_batch_ids(&mut rng, EVAL_BATCH, task);
        let (tokens, targets) = ids_to_tensors(&ids);
        let mut named: HashMap<&str, Tensor> = HashMap::new();
        named.insert("tokens", tokens);
        named.insert("targets", targets);
        named.insert("rank_mask", rank_mask.clone());
        named.insert("bits", Tensor::scalar(bits));
        named.insert("lora_scale", Tensor::scalar(lora_scale));
        let (_, metrics) = eval.step(Vec::new(), &frozen, &named)?;
        let loss = metrics[0].item() as f64;
        let logits = &metrics[1]; // (B, T, V)
        loss_sum += loss;

        let preds = logits.argmax_last(); // B*T entries
        let range = task.scored_positions();
        let mut correct = 0usize;
        let mut total = 0usize;
        for (i, s) in ids.iter().enumerate() {
            for t in range.clone() {
                let want = s[t + 1] as usize;
                if preds[i * SEQ + t] == want {
                    correct += 1;
                }
                total += 1;
            }
        }
        tasks.push((
            task.label().to_string(),
            correct as f64 / total.max(1) as f64,
        ));
    }
    let average = tasks.iter().map(|(_, a)| a).sum::<f64>() / tasks.len() as f64;
    Ok(EvalReport {
        tasks,
        average,
        mean_loss: loss_sum / LmTaskKind::ALL.len() as f64,
    })
}

/// Chance-level accuracy for the suite (uniform next-token guessing).
pub fn chance_level() -> f64 {
    1.0 / VOCAB as f64
}
