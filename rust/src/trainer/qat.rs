//! DoReFa QAT training loop (paper Table 1 track).
//!
//! Drives the AOT-lowered `cnn_{s,m,l}_train_b{32,64,128,256}` artifacts:
//! Rust owns the step loop, the dataset stream, and the hyperparameter →
//! scalar-input mapping; the fused train-step graph (fwd + bwd + SGD update
//! with runtime wbits/abits) runs on PJRT.  One "epoch" of the paper's
//! search space maps to `steps_per_epoch` optimizer steps at laptop scale.

use std::collections::HashMap;

use anyhow::Result;

use crate::quant::QatPrecision;
use crate::runtime::{ArtifactSet, Tensor};
use crate::search::Config;
use crate::util::rng::Rng;

use super::data::ImageDataset;

pub const CNN_BATCHES: [usize; 4] = [32, 64, 128, 256];
pub const EVAL_BATCH: usize = 256;

/// Snap a requested batch size to the nearest AOT'd variant (log distance).
pub fn snap_batch(b: i64, options: &[usize]) -> usize {
    let lb = (b.max(1) as f64).ln();
    *options
        .iter()
        .min_by(|x, y| {
            let dx = ((**x as f64).ln() - lb).abs();
            let dy = ((**y as f64).ln() - lb).abs();
            dx.partial_cmp(&dy).unwrap()
        })
        .unwrap()
}

#[derive(Debug, Clone)]
pub struct QatResult {
    /// Held-out accuracy in [0,1] — the optimization objective.
    pub accuracy: f64,
    pub eval_loss: f64,
    pub loss_curve: Vec<f64>,
    pub diverged: bool,
    pub steps: usize,
}

impl QatResult {
    /// The structured feedback string surfaced to the agent (parsed by the
    /// simulated policy; readable by a real LLM).
    pub fn feedback(&self) -> String {
        let n = self.loss_curve.len();
        let tail = &self.loss_curve[n - (n / 3).max(1)..];
        let slope = if tail.len() >= 2 {
            (tail[tail.len() - 1] - tail[0]) / tail.len() as f64
        } else {
            0.0
        };
        format!(
            "{{\"final_loss\": {:.4}, \"loss_slope\": {:.5}, \"diverged\": {}, \
             \"eval_loss\": {:.4}}}",
            self.loss_curve.last().copied().unwrap_or(f64::NAN),
            slope,
            self.diverged,
            self.eval_loss
        )
    }
}

pub struct QatJob<'a> {
    pub set: &'a ArtifactSet,
    /// `cnn_s` | `cnn_m` | `cnn_l`.
    pub model: &'a str,
    pub precision: QatPrecision,
    pub seed: u64,
    /// Steps per search-space "epoch" (laptop-scale mapping; see DESIGN.md).
    pub steps_per_epoch: usize,
}

impl<'a> QatJob<'a> {
    /// Train under `cfg` (a `resnet_qat` configuration) and evaluate.
    pub fn run(&self, cfg: &Config) -> Result<QatResult> {
        let lr = cfg.get("learning_rate").map(|v| v.as_f64()).unwrap_or(0.01);
        let momentum = cfg.get("momentum").map(|v| v.as_f64()).unwrap_or(0.9);
        let wd = cfg.get("weight_decay").map(|v| v.as_f64()).unwrap_or(5e-4);
        let epochs = cfg.get("num_epochs").map(|v| v.as_i64()).unwrap_or(12).max(1);
        let batch = snap_batch(
            cfg.get("batch_size").map(|v| v.as_i64()).unwrap_or(128),
            &CNN_BATCHES,
        );
        let steps = epochs as usize * self.steps_per_epoch;

        let train = self.set.executor(&format!("{}_train_b{batch}", self.model))?;
        let mut rng = Rng::new(self.seed).split(0x7a7);
        let mut state = train.artifact.init_state(&mut rng);
        let mut data = ImageDataset::new(self.seed);

        let mut named: HashMap<&str, Tensor> = HashMap::new();
        named.insert("lr", Tensor::scalar(lr as f32));
        named.insert("momentum", Tensor::scalar(momentum as f32));
        named.insert("weight_decay", Tensor::scalar(wd as f32));
        named.insert("grad_clip", Tensor::scalar(5.0));
        named.insert("wbits", Tensor::scalar(self.precision.wbits as f32));
        named.insert("abits", Tensor::scalar(self.precision.abits as f32));

        let mut loss_curve = Vec::with_capacity(steps);
        let mut diverged = false;
        for _ in 0..steps {
            let (x, y) = data.batch(batch);
            named.insert("x", x);
            named.insert("y", y);
            let (new_state, metrics) = train.step(state, &[], &named)?;
            state = new_state;
            let loss = metrics[0].item() as f64;
            loss_curve.push(loss);
            if !loss.is_finite() || loss > 50.0 {
                diverged = true;
                break;
            }
        }

        // Evaluation on the fixed held-out set (params = first half of the
        // threaded state: [params..., velocities...]).
        let eval = self.set.executor(&format!("{}_eval", self.model))?;
        let n_params = train.artifact.state_count / 2;
        let params = &state[..n_params];
        let (xe, ye) = ImageDataset::eval_set(self.seed, EVAL_BATCH);
        let mut enamed: HashMap<&str, Tensor> = HashMap::new();
        enamed.insert("x", xe);
        enamed.insert("y", ye);
        enamed.insert("wbits", Tensor::scalar(self.precision.wbits as f32));
        enamed.insert("abits", Tensor::scalar(self.precision.abits as f32));
        let (_, metrics) = eval.step(Vec::new(), params, &enamed)?;
        let eval_loss = metrics[0].item() as f64;
        let mut accuracy = metrics[1].item() as f64;
        if diverged || !accuracy.is_finite() {
            accuracy = 1.0 / super::data::NUM_CLASSES as f64; // chance
        }
        Ok(QatResult {
            accuracy,
            eval_loss,
            loss_curve,
            diverged,
            steps,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snap_batch_picks_nearest_log() {
        assert_eq!(snap_batch(32, &CNN_BATCHES), 32);
        assert_eq!(snap_batch(45, &CNN_BATCHES), 32);
        assert_eq!(snap_batch(46, &CNN_BATCHES), 64);
        assert_eq!(snap_batch(100, &CNN_BATCHES), 128);
        assert_eq!(snap_batch(256, &CNN_BATCHES), 256);
        assert_eq!(snap_batch(10_000, &CNN_BATCHES), 256);
    }
}
