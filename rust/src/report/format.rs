//! Formatting helpers shared by the table regenerators.

/// "92.80 ± 0.22" accuracy cell (paper Tables 1/2/6 style).
pub fn acc_pm(mean_frac: f64, std_frac: f64) -> String {
    format!("{:.2} ± {:.2}", mean_frac * 100.0, std_frac * 100.0)
}

/// "1.85×" speedup cell (paper Table 3 style).
pub fn speedup(default_us: f64, tuned_us: f64) -> String {
    format!("{:.2}×", default_us / tuned_us)
}

/// "52.87" latency cell.
pub fn us(v: f64) -> String {
    format!("{v:.2}")
}

/// Table 5 cell.
pub fn check_cell(fits: bool) -> String {
    (if fits { "✓" } else { "×" }).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats() {
        assert_eq!(acc_pm(0.9280, 0.0022), "92.80 ± 0.22");
        assert_eq!(speedup(51.70, 27.96), "1.85×");
        assert_eq!(check_cell(true), "✓");
    }
}
