//! Paper table/figure emitters (stdout markdown + `results/*.csv`).

pub mod format;

pub use format::{acc_pm, check_cell, speedup, us};
