//! Paper table/figure emitters (stdout markdown + `results/*.csv`).

pub mod format;
pub mod pareto;

pub use format::{acc_pm, check_cell, speedup, us};
pub use pareto::{group_fronts, GroupFront, ParetoItem};
