//! Fleet-level Pareto fronts — the "counterintuitive wins" report.
//!
//! The paper's core claim is that hardware-aware quantization picks
//! *per-platform* winners a global heuristic misses (the W4A16-on-mobile
//! style upsets).  At fleet scale that claim is a per-platform
//! non-dominated front: group every scenario outcome by platform, build an
//! all-maximized objective vector per outcome, and keep front 0 of the
//! in-tree NSGA-II non-dominated sort
//! ([`crate::optimizers::nsga2::non_dominated_fronts`]).  This module is
//! the generic half — plain (group, name, objectives) in, sorted fronts
//! out; [`FleetReport::pareto`](crate::coordinator::FleetReport::pareto)
//! supplies the fleet-specific objective vectors.

use crate::optimizers::nsga2;

/// One candidate for front computation: a named point in some group's
/// objective space.  Objectives are **all maximized** (negate costs like
/// memory footprints before building the vector).
#[derive(Debug, Clone)]
pub struct ParetoItem {
    /// Grouping key — fronts are computed independently per group
    /// (platform × track for the fleet).
    pub group: String,
    /// Display name of the candidate (scenario name for the fleet).
    pub name: String,
    /// All-maximized objective vector; every item in a group must use the
    /// same objective arity.
    pub objectives: Vec<f64>,
}

/// The non-dominated front of one group.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupFront {
    /// The group key the front was computed within.
    pub group: String,
    /// `(name, objectives)` of every front-0 member, in input order.
    pub members: Vec<(String, Vec<f64>)>,
    /// Candidates considered in this group (front + dominated).
    pub total: usize,
}

/// Compute the per-group non-dominated fronts.  Groups come back sorted by
/// key and members keep input order, so the report is deterministic for a
/// deterministic fleet run.  Items whose objective vector contains a NaN
/// are dropped (NaN is incomparable under Pareto dominance and would
/// poison the sort).
pub fn group_fronts(items: &[ParetoItem]) -> Vec<GroupFront> {
    let mut groups: Vec<&str> = items.iter().map(|i| i.group.as_str()).collect();
    groups.sort_unstable();
    groups.dedup();
    groups
        .iter()
        .map(|g| {
            let members: Vec<&ParetoItem> = items
                .iter()
                .filter(|i| i.group == *g && i.objectives.iter().all(|v| !v.is_nan()))
                .collect();
            let objs: Vec<Vec<f64>> = members.iter().map(|i| i.objectives.clone()).collect();
            let fronts = nsga2::non_dominated_fronts(&objs);
            GroupFront {
                group: g.to_string(),
                members: members
                    .iter()
                    .zip(&fronts)
                    .filter(|&(_, f)| *f == 0)
                    .map(|(i, _)| (i.name.clone(), i.objectives.clone()))
                    .collect(),
                total: members.len(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(group: &str, name: &str, objectives: &[f64]) -> ParetoItem {
        ParetoItem {
            group: group.into(),
            name: name.into(),
            objectives: objectives.to_vec(),
        }
    }

    #[test]
    fn fronts_are_per_group_and_sorted() {
        let items = vec![
            // Group b: `slow_small` trades throughput for memory — on the
            // front alongside `fast_big`; `worst` is dominated by both.
            item("b", "fast_big", &[10.0, -8.0]),
            item("b", "slow_small", &[6.0, -2.0]),
            item("b", "worst", &[5.0, -9.0]),
            // Group a: single objective — only the max survives.
            item("a", "lo", &[1.0]),
            item("a", "hi", &[3.0]),
        ];
        let fronts = group_fronts(&items);
        assert_eq!(fronts.len(), 2);
        assert_eq!(fronts[0].group, "a", "groups sorted");
        assert_eq!(fronts[0].total, 2);
        assert_eq!(fronts[0].members, vec![("hi".to_string(), vec![3.0])]);
        let names: Vec<&str> = fronts[1].members.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["fast_big", "slow_small"], "trade-offs both survive");
        assert_eq!(fronts[1].total, 3);
    }

    #[test]
    fn ties_survive_and_nans_are_dropped() {
        let items = vec![
            item("g", "tie1", &[2.0, -1.0]),
            item("g", "tie2", &[2.0, -1.0]),
            item("g", "poisoned", &[f64::NAN, -1.0]),
        ];
        let fronts = group_fronts(&items);
        assert_eq!(fronts.len(), 1);
        assert_eq!(fronts[0].total, 2, "NaN item dropped before sorting");
        assert_eq!(fronts[0].members.len(), 2, "equal points dominate nobody");
    }
}
