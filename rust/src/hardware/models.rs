//! LLM descriptors for the deployment experiments (Tables 4-5, Figure 5).

/// Architecture summary of the paper's deployment models.
#[derive(Debug, Clone)]
pub struct ModelProfile {
    pub name: String,
    /// Parameters, billions.
    pub params_b: f64,
    pub layers: u32,
    pub hidden: u32,
    pub ffn: u32,
    pub heads: u32,
    pub vocab: u32,
}

impl ModelProfile {
    fn new(name: &str, params_b: f64, layers: u32, hidden: u32, ffn: u32,
           heads: u32, vocab: u32) -> ModelProfile {
        ModelProfile {
            name: name.into(),
            params_b,
            layers,
            hidden,
            ffn,
            heads,
            vocab,
        }
    }

    // Figure 5 / Table 5 models (A6000 track).
    pub fn llama2_7b() -> Self {
        Self::new("LLaMA2-7B", 6.74, 32, 4096, 11008, 32, 32000)
    }
    pub fn llama2_13b() -> Self {
        Self::new("LLaMA2-13B", 13.02, 40, 5120, 13824, 40, 32000)
    }
    pub fn llama32_3b() -> Self {
        Self::new("LLaMA3.2-3B", 3.21, 28, 3072, 8192, 24, 128256)
    }
    pub fn llama3_8b() -> Self {
        Self::new("LLaMA3-8B", 8.03, 32, 4096, 14336, 32, 128256)
    }

    // Table 4 models (mobile track).
    pub fn openllama_3b() -> Self {
        Self::new("openllama-3B", 3.43, 26, 3200, 8640, 32, 32000)
    }
    pub fn tinyllama_1_1b() -> Self {
        Self::new("tinylama-1.1B", 1.10, 22, 2048, 5632, 32, 32000)
    }
    pub fn gpt2_large() -> Self {
        Self::new("gpt2-large-774M", 0.774, 36, 1280, 5120, 20, 50257)
    }

    pub fn figure5_models() -> Vec<ModelProfile> {
        vec![
            Self::llama32_3b(),
            Self::llama2_7b(),
            Self::llama3_8b(),
            Self::llama2_13b(),
        ]
    }

    pub fn table4_models() -> Vec<ModelProfile> {
        vec![Self::openllama_3b(), Self::tinyllama_1_1b(), Self::gpt2_large()]
    }

    /// KV-cache bytes per token at fp16 (2 tensors * layers * hidden * 2B).
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * self.layers as f64 * self.hidden as f64 * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rosters_complete() {
        assert_eq!(ModelProfile::figure5_models().len(), 4);
        assert_eq!(ModelProfile::table4_models().len(), 3);
    }

    #[test]
    fn params_ordering_sane() {
        assert!(ModelProfile::llama2_13b().params_b > ModelProfile::llama2_7b().params_b);
        assert!(ModelProfile::tinyllama_1_1b().params_b < ModelProfile::openllama_3b().params_b);
    }
}
