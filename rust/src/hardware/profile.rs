//! Device profiles.  Field values mirror the hardware spec blocks the
//! paper's prompts embed (Fig. 2a and Appendix F).
//!
//! Profiles are reachable two ways: directly via the constructors
//! ([`DeviceProfile::a6000`] & friends) or by name through the [`preset`]
//! registry, which is what scenario `device` fields and
//! `device:<profile-name>` evaluator specs resolve against.

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceKind {
    DesktopGpu,
    MobileGpu,
    Cpu,
}

#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: String,
    pub kind: DeviceKind,
    /// Streaming multiprocessors (or shader core clusters).
    pub sm_count: u32,
    pub cuda_cores: u32,
    pub tensor_cores: bool,
    pub int8_native: bool,
    pub int4_native: bool,
    pub fp16_tflops: f64,
    /// Effective DRAM bandwidth for the decode path, GB/s.
    pub mem_bw_gbps: f64,
    pub shared_mem_kb: u32,
    pub registers_per_sm: u32,
    pub dram_gb: f64,
    /// Per-layer kernel-launch overhead on the decode path, ms.
    pub launch_overhead_ms: f64,
    /// Per-parameter compute overhead (dequant/MMA issue), picoseconds, by
    /// scheme — the §4.4 mechanism: INT4 without native support pays
    /// unpack + FP16-convert ALU work that outweighs its bandwidth savings.
    pub ov_ps_fp16: f64,
    pub ov_ps_int8: f64,
    pub ov_ps_int4: f64,
    /// Kernel-latency scale relative to the A6000 (1.0 = A6000).
    pub kernel_scale: f64,
}

impl DeviceProfile {
    /// NVIDIA RTX A6000 (Ampere): the paper's desktop testbed (§4.1).
    pub fn a6000() -> DeviceProfile {
        DeviceProfile {
            name: "NVIDIA A6000".into(),
            kind: DeviceKind::DesktopGpu,
            sm_count: 84,
            cuda_cores: 10752,
            tensor_cores: true,
            int8_native: true,
            int4_native: true,
            fp16_tflops: 309.0,
            mem_bw_gbps: 600.0,
            shared_mem_kb: 100,
            registers_per_sm: 65536,
            dram_gb: 48.0,
            launch_overhead_ms: 0.02,
            ov_ps_fp16: 0.5,
            ov_ps_int8: 0.8,
            ov_ps_int4: 1.2,
            kernel_scale: 1.0,
        }
    }

    /// Qualcomm Adreno 740 (Snapdragon 8 Gen 2, OnePlus 11): the paper's
    /// mobile testbed (§4.4, Appendix F).  No native INT4; INT4 elements
    /// must be unpacked (shift/AND/OR) and converted to FP16 before
    /// accumulation — hence the large `ov_ps_int4`.
    pub fn adreno740() -> DeviceProfile {
        DeviceProfile {
            name: "Adreno 740 (Snapdragon 8 Gen 2)".into(),
            kind: DeviceKind::MobileGpu,
            sm_count: 6,
            cuda_cores: 768,
            tensor_cores: false,
            int8_native: true,
            int4_native: false,
            fp16_tflops: 8.0,
            mem_bw_gbps: 36.0,
            shared_mem_kb: 32,
            registers_per_sm: 16384,
            dram_gb: 16.0,
            launch_overhead_ms: 0.8,
            ov_ps_fp16: 1.0,
            ov_ps_int8: 21.0,
            ov_ps_int4: 45.0,
            kernel_scale: 9.0,
        }
    }

    /// The host CPU (PJRT CPU client) — the device the real-latency path
    /// actually runs on.
    pub fn host_cpu() -> DeviceProfile {
        DeviceProfile {
            name: "host CPU (PJRT)".into(),
            kind: DeviceKind::Cpu,
            sm_count: 1,
            cuda_cores: 16,
            tensor_cores: false,
            int8_native: true,
            int4_native: false,
            fp16_tflops: 0.5,
            mem_bw_gbps: 20.0,
            shared_mem_kb: 512,
            registers_per_sm: 0,
            dram_gb: 32.0,
            launch_overhead_ms: 0.05,
            ov_ps_fp16: 4.0,
            ov_ps_int8: 8.0,
            ov_ps_int4: 16.0,
            kernel_scale: 30.0,
        }
    }

    /// NVIDIA A100 SXM (Ampere datacenter): the server-class preset for
    /// `device:` scenarios.  Everything the A6000 has, scaled up — more
    /// SMs, HBM2e bandwidth, native INT8/INT4 MMA — so tuned kernels land
    /// measurably faster (`kernel_scale` < 1) while the same occupancy /
    /// tiling / coalescing mechanisms steer the search.
    pub fn a100() -> DeviceProfile {
        DeviceProfile {
            name: "NVIDIA A100 SXM".into(),
            kind: DeviceKind::DesktopGpu,
            sm_count: 108,
            cuda_cores: 6912,
            tensor_cores: true,
            int8_native: true,
            int4_native: true,
            fp16_tflops: 312.0,
            mem_bw_gbps: 2039.0,
            shared_mem_kb: 164,
            registers_per_sm: 65536,
            dram_gb: 80.0,
            launch_overhead_ms: 0.015,
            ov_ps_fp16: 0.4,
            ov_ps_int8: 0.6,
            ov_ps_int4: 0.9,
            kernel_scale: 0.55,
        }
    }

    /// NVIDIA Jetson Orin (embedded SoC): the edge preset for `device:`
    /// scenarios.  Ampere-generation cores behind a LPDDR5 bus — native
    /// INT8, *no* native INT4 (the §4.4 asymmetry, like the Adreno), and a
    /// kernel-latency scale between the mobile GPU and the host CPU.
    pub fn orin() -> DeviceProfile {
        DeviceProfile {
            name: "NVIDIA Jetson Orin".into(),
            kind: DeviceKind::MobileGpu,
            sm_count: 16,
            cuda_cores: 2048,
            tensor_cores: true,
            int8_native: true,
            int4_native: false,
            fp16_tflops: 21.0,
            mem_bw_gbps: 204.0,
            shared_mem_kb: 48,
            registers_per_sm: 65536,
            dram_gb: 32.0,
            launch_overhead_ms: 0.3,
            ov_ps_fp16: 0.9,
            ov_ps_int8: 9.0,
            ov_ps_int4: 28.0,
            kernel_scale: 4.5,
        }
    }

    /// Per-parameter decode-time overhead for a scheme (ps).
    pub fn ov_ps(&self, scheme: crate::quant::Scheme) -> f64 {
        match scheme {
            crate::quant::Scheme::FP16 => self.ov_ps_fp16,
            crate::quant::Scheme::INT8 => self.ov_ps_int8,
            crate::quant::Scheme::INT4 => self.ov_ps_int4,
        }
    }

    /// The hardware spec block for the agent prompt (mirrors Fig. 2a /
    /// Appendix F formatting).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", Json::Str(self.name.clone()));
        o.set(
            "kind",
            Json::Str(
                match self.kind {
                    DeviceKind::DesktopGpu => "desktop_gpu",
                    DeviceKind::MobileGpu => "mobile_gpu",
                    DeviceKind::Cpu => "cpu",
                }
                .into(),
            ),
        );
        o.set("sm_count", Json::Num(self.sm_count as f64));
        o.set("cuda_cores", Json::Num(self.cuda_cores as f64));
        o.set("tensor_cores", Json::Bool(self.tensor_cores));
        o.set("int8_native", Json::Bool(self.int8_native));
        o.set("int4_native", Json::Bool(self.int4_native));
        o.set("fp16_tflops", Json::Num(self.fp16_tflops));
        o.set("mem_bw_gbps", Json::Num(self.mem_bw_gbps));
        o.set("shared_mem_kb", Json::Num(self.shared_mem_kb as f64));
        o.set("dram_gb", Json::Num(self.dram_gb));
        o
    }
}

/// Canonical preset names, one per distinct profile (aliases excluded) —
/// used for error messages and the device-server `hello` reply.
pub const PRESET_NAMES: &[&str] = &["a6000", "adreno740", "cpu", "a100", "orin"];

/// Resolve a named hardware-profile preset.
///
/// This is the registry `device:<profile-name>` evaluator specs and the
/// scenario `device` field resolve against.  Each profile answers to its
/// canonical name (see [`PRESET_NAMES`]) plus platform-class aliases, so a
/// scenario file can say what it means (`server-gpu` vs `mobile-soc`)
/// without hard-coding part numbers:
///
/// | canonical | aliases | profile |
/// |---|---|---|
/// | `a6000` | `server`, `server-gpu`, `desktop` | [`DeviceProfile::a6000`] |
/// | `adreno740` | `mobile`, `mobile-soc` | [`DeviceProfile::adreno740`] |
/// | `cpu` | `host-cpu`, `edge-cpu` | [`DeviceProfile::host_cpu`] |
/// | `a100` | `datacenter-gpu` | [`DeviceProfile::a100`] |
/// | `orin` | `jetson-orin`, `embedded` | [`DeviceProfile::orin`] |
///
/// Returns `None` for unknown names; callers that must not guess (the
/// `device:` evaluator spec parser) turn that into a hard error, while
/// [`Scenario::device_profile`](crate::coordinator::scenario::Scenario::device_profile)
/// keeps its historical fall-back to the A6000.
pub fn preset(name: &str) -> Option<DeviceProfile> {
    match name.trim().to_ascii_lowercase().as_str() {
        "a6000" | "server" | "server-gpu" | "desktop" => Some(DeviceProfile::a6000()),
        "adreno740" | "mobile" | "mobile-soc" => Some(DeviceProfile::adreno740()),
        "cpu" | "host-cpu" | "edge-cpu" => Some(DeviceProfile::host_cpu()),
        "a100" | "datacenter-gpu" => Some(DeviceProfile::a100()),
        "orin" | "jetson-orin" | "embedded" => Some(DeviceProfile::orin()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_expose_the_4_4_asymmetry() {
        let gpu = DeviceProfile::a6000();
        let mob = DeviceProfile::adreno740();
        assert!(gpu.int4_native && !mob.int4_native);
        // Mobile INT4 overhead per param exceeds its INT8 overhead by more
        // than the bandwidth it saves (the §4.4 mechanism).
        assert!(mob.ov_ps_int4 > 2.0 * mob.ov_ps_int8 * 0.5);
    }

    #[test]
    fn json_block_has_prompt_fields() {
        let j = DeviceProfile::a6000().to_json();
        assert_eq!(j.get("tensor_cores").unwrap().as_bool(), Some(true));
        assert!(j.req_f64("mem_bw_gbps").unwrap() > 0.0);
    }

    #[test]
    fn preset_registry_resolves_canonical_names_and_aliases() {
        for name in PRESET_NAMES {
            assert!(preset(name).is_some(), "canonical preset '{name}' missing");
        }
        assert_eq!(preset("server-gpu").unwrap().name, DeviceProfile::a6000().name);
        assert_eq!(preset("mobile-soc").unwrap().name, DeviceProfile::adreno740().name);
        assert_eq!(preset("datacenter-gpu").unwrap().name, DeviceProfile::a100().name);
        assert_eq!(preset("  Jetson-Orin ").unwrap().name, DeviceProfile::orin().name);
        assert!(preset("tpu-v5").is_none());
    }

    #[test]
    fn new_presets_keep_the_platform_ordering() {
        // The datacenter part outruns the desktop part; the embedded SoC
        // sits between the mobile GPU and the host CPU.
        let a6000 = DeviceProfile::a6000();
        let a100 = DeviceProfile::a100();
        let orin = DeviceProfile::orin();
        let adreno = DeviceProfile::adreno740();
        assert!(a100.kernel_scale < a6000.kernel_scale);
        assert!(orin.kernel_scale > a6000.kernel_scale);
        assert!(orin.kernel_scale < adreno.kernel_scale);
        assert!(a100.int4_native && !orin.int4_native, "§4.4 asymmetry on the edge");
    }
}
