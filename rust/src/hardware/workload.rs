//! Kernel workloads at the paper's Table 3 shapes, plus the calibration
//! table (the paper's measured default/HAQA latencies on the A6000).

/// The five LLM kernels the paper tunes (Table 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    Softmax,
    Silu,
    RmsNorm,
    Rope,
    MatMul,
}

impl KernelKind {
    pub const ALL: [KernelKind; 5] = [
        KernelKind::Softmax,
        KernelKind::Silu,
        KernelKind::RmsNorm,
        KernelKind::Rope,
        KernelKind::MatMul,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            KernelKind::Softmax => "Softmax",
            KernelKind::Silu => "SiLU",
            KernelKind::RmsNorm => "RMSNorm",
            KernelKind::Rope => "RoPE",
            KernelKind::MatMul => "MatMul",
        }
    }

    pub fn parse(s: &str) -> Option<KernelKind> {
        match s.to_ascii_lowercase().as_str() {
            "softmax" => Some(KernelKind::Softmax),
            "silu" => Some(KernelKind::Silu),
            "rmsnorm" => Some(KernelKind::RmsNorm),
            "rope" => Some(KernelKind::Rope),
            "matmul" => Some(KernelKind::MatMul),
            _ => None,
        }
    }

    pub fn is_matmul(&self) -> bool {
        matches!(self, KernelKind::MatMul)
    }
}

/// A kernel at a concrete Table 3 size (`batch` is the paper's middle
/// dimension: 1, 64 or 128).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Workload {
    pub kernel: KernelKind,
    pub batch: usize,
}

impl Workload {
    pub fn new(kernel: KernelKind, batch: usize) -> Workload {
        Workload { kernel, batch }
    }

    /// The paper's [N, B, H] size label.
    pub fn size_label(&self) -> String {
        match self.kernel {
            KernelKind::Softmax => format!("[1024,{},32]", self.batch),
            KernelKind::Silu => format!("[11008,{},1]", self.batch),
            KernelKind::RmsNorm => format!("[4096,{},1]", self.batch),
            KernelKind::Rope => format!("[128,{},1]", self.batch),
            KernelKind::MatMul => format!("[2048,{},2048]", self.batch),
        }
    }

    /// Independent row-level work items (drives occupancy in the model).
    pub fn rows(&self) -> usize {
        match self.kernel {
            KernelKind::Softmax => 32 * self.batch,
            KernelKind::Silu => self.batch,
            KernelKind::RmsNorm => self.batch,
            KernelKind::Rope => self.batch,
            KernelKind::MatMul => self.batch,
        }
    }

    /// Elements touched (drives the memory side of the roofline).
    pub fn elements(&self) -> usize {
        match self.kernel {
            KernelKind::Softmax => 1024 * 32 * self.batch,
            KernelKind::Silu => 11008 * self.batch * 2,
            KernelKind::RmsNorm => 4096 * self.batch,
            KernelKind::Rope => 128 * self.batch,
            KernelKind::MatMul => 2048 * 2048 + 2048 * self.batch * 2,
        }
    }

    /// Floating-point operations.
    pub fn flops(&self) -> usize {
        match self.kernel {
            KernelKind::Softmax => 1024 * 32 * self.batch * 5,
            KernelKind::Silu => 11008 * self.batch * 4,
            KernelKind::RmsNorm => 4096 * self.batch * 3,
            KernelKind::Rope => 128 * self.batch * 6,
            KernelKind::MatMul => 2 * 2048 * 2048 * self.batch,
        }
    }
}

/// Paper Table 3 on the A6000: (kernel, batch, default µs, HAQA µs).
/// The latency model self-calibrates to this table (see `latency.rs`).
pub const PAPER_TABLE3: &[(KernelKind, usize, f64, f64)] = &[
    (KernelKind::Softmax, 1, 3.45, 2.57),
    (KernelKind::Softmax, 64, 51.70, 27.96),
    (KernelKind::Softmax, 128, 98.15, 52.87),
    (KernelKind::Silu, 1, 6.29, 5.11),
    (KernelKind::Silu, 64, 10.44, 4.51),
    (KernelKind::Silu, 128, 31.02, 19.71),
    (KernelKind::RmsNorm, 1, 10.19, 8.61),
    (KernelKind::RmsNorm, 64, 10.75, 8.95),
    (KernelKind::RmsNorm, 128, 11.11, 9.10),
    (KernelKind::Rope, 1, 6.75, 6.32),
    (KernelKind::Rope, 64, 9.04, 8.00),
    (KernelKind::Rope, 128, 11.70, 9.62),
    (KernelKind::MatMul, 1, 16.49, 12.24),
    (KernelKind::MatMul, 64, 52.29, 36.86),
    (KernelKind::MatMul, 128, 63.20, 38.85),
];

/// Calibration lookup: paper (default, haqa) µs for a workload on A6000.
pub fn paper_latencies(w: &Workload) -> Option<(f64, f64)> {
    PAPER_TABLE3
        .iter()
        .find(|(k, b, _, _)| *k == w.kernel && *b == w.batch)
        .map(|(_, _, d, h)| (*d, *h))
}

/// Interpolated calibration for batches outside the table (geometric in
/// batch, clamped to table endpoints).
///
/// Allocation-free: this sits under every simulated kernel measurement
/// (via [`super::latency::kernel_latency_us`] and latency-model setup), so
/// it scans `PAPER_TABLE3` directly instead of collecting and sorting a
/// `Vec` per call.  The table rows are grouped by kernel with batches
/// ascending (asserted in tests), which is all the bracketing scan needs.
pub fn calibrated(w: &Workload) -> (f64, f64) {
    if let Some(v) = paper_latencies(w) {
        return v;
    }
    let b = w.batch as f64;
    let mut first: Option<(usize, f64, f64)> = None;
    let mut last: Option<(usize, f64, f64)> = None;
    let mut bracket: Option<((usize, f64, f64), (usize, f64, f64))> = None;
    for &(k, bb, d, h) in PAPER_TABLE3 {
        if k != w.kernel {
            continue;
        }
        if first.is_none() {
            first = Some((bb, d, h));
        }
        if let Some(prev) = last {
            if bracket.is_none() && b >= prev.0 as f64 && b <= bb as f64 {
                bracket = Some((prev, (bb, d, h)));
            }
        }
        last = Some((bb, d, h));
    }
    let lo = first.expect("kernel present in the calibration table");
    let hi = last.expect("kernel present in the calibration table");
    if b <= lo.0 as f64 {
        let s = b / lo.0 as f64;
        return (lo.1 * s.max(0.25), lo.2 * s.max(0.25));
    }
    if b >= hi.0 as f64 {
        let s = b / hi.0 as f64;
        return (hi.1 * s, hi.2 * s);
    }
    if let Some(((b0, d0, h0), (b1, d1, h1))) = bracket {
        let t = (b.ln() - (b0 as f64).ln()) / ((b1 as f64).ln() - (b0 as f64).ln());
        return (
            (d0.ln() + t * (d1.ln() - d0.ln())).exp(),
            (h0.ln() + t * (h1.ln() - h0.ln())).exp(),
        );
    }
    (lo.1, lo.2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_has_all_15_rows() {
        assert_eq!(PAPER_TABLE3.len(), 15);
        for k in KernelKind::ALL {
            for b in [1usize, 64, 128] {
                assert!(paper_latencies(&Workload::new(k, b)).is_some());
            }
        }
    }

    #[test]
    fn table_grouped_by_kernel_with_ascending_batches() {
        // The allocation-free bracketing scan in `calibrated` relies on
        // this layout; keep the invariant explicit for future table edits.
        for k in KernelKind::ALL {
            let batches: Vec<usize> = PAPER_TABLE3
                .iter()
                .filter(|(kk, _, _, _)| *kk == k)
                .map(|(_, b, _, _)| *b)
                .collect();
            assert!(
                batches.windows(2).all(|w| w[0] < w[1]),
                "{}: batches {batches:?} not strictly ascending",
                k.label()
            );
        }
    }

    #[test]
    fn ratios_match_paper_range() {
        for (k, b, d, h) in PAPER_TABLE3 {
            let r = d / h;
            assert!(
                (1.0..=2.4).contains(&r),
                "{}@{b}: ratio {r}",
                k.label()
            );
        }
    }

    #[test]
    fn interpolation_bracketed_and_monotone() {
        let (d32, _) = calibrated(&Workload::new(KernelKind::Softmax, 32));
        let (d1, _) = calibrated(&Workload::new(KernelKind::Softmax, 1));
        let (d64, _) = calibrated(&Workload::new(KernelKind::Softmax, 64));
        assert!(d1 < d32 && d32 < d64, "{d1} {d32} {d64}");
    }

    #[test]
    fn matmul_flops_dominant() {
        let mm = Workload::new(KernelKind::MatMul, 64).flops();
        let sm = Workload::new(KernelKind::Softmax, 64).flops();
        assert!(mm > 10 * sm);
    }
}
