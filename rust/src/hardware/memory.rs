//! Deployment memory-footprint model (paper Table 5).
//!
//! `footprint = weights + quantization-group overhead + KV cache +
//! activations/runtime`.  The paper's worked example: LLaMA2-13B at INT8
//! needs 13 GB, so a 12 GB budget rejects INT8 but admits INT4 (Table 5).

use crate::quant::Scheme;

use super::models::ModelProfile;

/// Default evaluation context (paper §4.1: input 128 + output 256 tokens).
pub const DEFAULT_CONTEXT_TOKENS: usize = 128 + 256;

#[derive(Debug, Clone, Copy)]
pub struct MemoryBreakdown {
    pub weights_gb: f64,
    pub kv_cache_gb: f64,
    pub runtime_gb: f64,
}

impl MemoryBreakdown {
    pub fn total_gb(&self) -> f64 {
        self.weights_gb + self.kv_cache_gb + self.runtime_gb
    }
}

/// Footprint of deploying `model` under `scheme` with a given context size.
pub fn footprint(model: &ModelProfile, scheme: Scheme, context_tokens: usize) -> MemoryBreakdown {
    let params = model.params_b * 1e9;
    // Group-wise quantization stores per-group scales/zeros (~6% overhead
    // at group size 32, llama.cpp's q4/q8 layouts).
    let group_overhead = match scheme {
        Scheme::FP16 => 1.0,
        Scheme::INT8 => 1.06,
        Scheme::INT4 => 1.12,
    };
    let weights_gb = params * scheme.bytes_per_weight() * group_overhead / 1e9;
    let kv_cache_gb = model.kv_bytes_per_token() * context_tokens as f64 / 1e9;
    // Activations + runtime buffers: scales with hidden size, floor 0.25 GB.
    let runtime_gb = 0.25 + model.hidden as f64 * 4096.0 * 4.0 / 1e9;
    MemoryBreakdown {
        weights_gb,
        kv_cache_gb,
        runtime_gb,
    }
}

pub fn footprint_gb(model: &ModelProfile, scheme: Scheme) -> f64 {
    footprint(model, scheme, DEFAULT_CONTEXT_TOKENS).total_gb()
}

/// Does `scheme` fit under `limit_gb`? (a Table 5 cell)
pub fn fits(model: &ModelProfile, scheme: Scheme, limit_gb: f64) -> bool {
    footprint_gb(model, scheme) <= limit_gb
}

/// KV-cache token budget under `limit_gb`: the tokens' worth of fp16 KV
/// cache that fit after the weights and runtime buffers are resident.
/// Negative when the weights alone bust the budget — callers treat that
/// as deployment rejection.  This is the admission currency of the
/// serving simulator ([`crate::coordinator::traffic`]): each in-flight
/// request reserves `prompt + output` tokens of it.
pub fn kv_budget_tokens(model: &ModelProfile, scheme: Scheme, limit_gb: f64) -> f64 {
    let fp = footprint(model, scheme, 0);
    (limit_gb - fp.weights_gb - fp.runtime_gb) * 1e9 / model.kv_bytes_per_token()
}

/// The paper's Table 5 memory budgets.
pub const TABLE5_BUDGETS_GB: [f64; 4] = [4.0, 12.0, 20.0, 28.0];

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 5's exact ✓/✗ matrix for LLaMA2-13B.
    #[test]
    fn reproduces_table5_matrix() {
        let m = ModelProfile::llama2_13b();
        let expect = [
            (4.0, [false, false, false]),
            (12.0, [false, false, true]),
            (20.0, [false, true, true]),
            (28.0, [true, true, true]),
        ];
        for (budget, cells) in expect {
            let got = [
                fits(&m, Scheme::FP16, budget),
                fits(&m, Scheme::INT8, budget),
                fits(&m, Scheme::INT4, budget),
            ];
            assert_eq!(got, cells, "budget {budget} GB");
        }
    }

    /// The paper's worked example: 13B @ INT8 ≈ 13 GB weights.
    #[test]
    fn int8_13b_weighs_about_13gb() {
        let m = ModelProfile::llama2_13b();
        let b = footprint(&m, Scheme::INT8, DEFAULT_CONTEXT_TOKENS);
        assert!(
            (b.weights_gb - 13.0).abs() < 1.5,
            "weights {} GB",
            b.weights_gb
        );
        assert!(b.total_gb() > 12.0, "must reject a 12 GB budget");
    }

    #[test]
    fn footprint_monotone_in_bits() {
        for m in ModelProfile::figure5_models() {
            assert!(footprint_gb(&m, Scheme::INT4) < footprint_gb(&m, Scheme::INT8));
            assert!(footprint_gb(&m, Scheme::INT8) < footprint_gb(&m, Scheme::FP16));
        }
    }
}
