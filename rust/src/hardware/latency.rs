//! The kernel latency model — the Table 3 testbed substitution.
//!
//! Shape: `latency = base_us * (1 + κ * badness(exec))` where `badness ≥ 0`
//! sums per-knob suboptimality terms (occupancy, tile reuse vs shared-memory
//! capacity, unroll vs register pressure, memory hierarchy placement,
//! coalescing, loop order), and the pair (base_us, κ) is **self-calibrated**
//! per workload so that:
//!
//! * the llama.cpp default configuration reproduces the paper's measured
//!   *default* latency exactly, and
//! * a perfectly tuned configuration (badness → 0) reproduces the paper's
//!   *HAQA* latency exactly.
//!
//! A real tuner therefore lands somewhere in between, and the *shape* of
//! Table 3 (who wins, by what factor, which sizes are most tunable) is
//! preserved by construction while the search problem stays non-trivial
//! (10 interacting knobs, a narrow optimum, rollback-worthy cliffs).

use crate::util::rng::Rng;

use super::exec::{ExecConfig, MemHier};
use super::profile::{DeviceKind, DeviceProfile};
use super::workload::{calibrated, KernelKind, Workload};

/// Sum of per-knob suboptimality terms (0 = perfectly tuned).
pub fn badness(w: &Workload, p: &DeviceProfile, e: &ExecConfig) -> f64 {
    let mut b = 0.0;

    // --- launch geometry / occupancy ---------------------------------------
    let opt_block: f64 = match p.kind {
        DeviceKind::DesktopGpu => {
            if w.rows() >= 64 || w.kernel.is_matmul() {
                128.0
            } else {
                64.0
            }
        }
        DeviceKind::MobileGpu => 64.0,
        DeviceKind::Cpu => 16.0,
    };
    let blk = e.blockdim as f64;
    b += 0.35 * ((blk.log2() - opt_block.log2()).abs() / 3.0).powf(1.4);
    // Register pressure: too many threads * unroll spills.
    let regs_needed = e.blockdim as f64 * e.unroll as f64 * 32.0;
    if p.registers_per_sm > 0 && regs_needed > p.registers_per_sm as f64 {
        b += 0.35 * (regs_needed / p.registers_per_sm as f64 - 1.0).min(1.5);
    }

    // Grid utilization: enough blocks to cover the work and the SMs.
    let work_units = (w.rows() as f64 / 4.0).max(1.0) * if w.kernel.is_matmul() { 16.0 } else { 1.0 };
    let needed_blocks = work_units.max(p.sm_count as f64);
    let grid = e.griddim as f64;
    if grid < needed_blocks {
        b += 0.30 * ((needed_blocks / grid).log2() / 6.0).min(1.0);
    } else if grid > 4.0 * needed_blocks {
        b += 0.10 * ((grid / (4.0 * needed_blocks)).log2() / 4.0).min(1.0);
    }

    // --- tiling (data reuse vs shared-memory capacity) ----------------------
    if w.kernel.is_matmul() {
        let opt_tile: f64 = if p.kind == DeviceKind::MobileGpu { 32.0 } else { 64.0 };
        let t = e.tiling as f64;
        b += 0.40 * ((t.log2() - opt_tile.log2()).abs() / 3.0).powf(1.3);
        let tile_bytes = 2.0 * t * t * 4.0;
        if tile_bytes > p.shared_mem_kb as f64 * 1024.0 {
            b += 0.5; // shared-memory overflow cliff
        }
        b += e.loop_order.matmul_badness();
        // Memory hierarchy: the inner tile belongs in shared memory.
        b += match e.memory_hierarchy {
            MemHier::Shared => 0.0,
            MemHier::Local => 0.15,
            MemHier::Global => 0.35,
        };
        // Column-major weight access is uncoalesced unless pre-transposed.
        if !e.row_major && !e.transpose {
            b += 0.12;
        }
    } else {
        // Elementwise/rowwise kernels: modest tile sensitivity, global is
        // fine (a staging copy through shared memory just adds traffic).
        let opt_tile = 32.0_f64;
        b += 0.10 * ((e.tiling as f64).log2() - opt_tile.log2()).abs() / 4.0;
        b += match e.memory_hierarchy {
            MemHier::Global => 0.0,
            MemHier::Local => 0.05,
            MemHier::Shared => 0.08,
        };
        if !e.row_major {
            b += 0.25; // strided access on a bandwidth-bound kernel
        }
    }

    // --- unroll / ILP --------------------------------------------------------
    let opt_unroll = 4.0_f64;
    b += 0.20 * ((e.unroll as f64).log2() - opt_unroll.log2()).abs() / 2.0;

    // --- vector width --------------------------------------------------------
    b += 0.12 * (1.0 - (e.simd_width as f64 / 16.0)).max(0.0);

    // --- prefetch -------------------------------------------------------------
    let opt_pf = 8.0_f64;
    b += 0.06 * ((e.prefetch as f64 - opt_pf).abs() / opt_pf).min(1.0);

    b
}

/// Per-workload tunability: how much of the default->HAQA gap the knobs
/// explain.  κ is derived from the calibration table so that
/// `1 + κ * badness(default) = paper_default / paper_haqa`.
pub fn kappa(w: &Workload, p: &DeviceProfile) -> f64 {
    let (d, h) = calibrated(w);
    let ratio = (d / h).max(1.0);
    let b0 = badness(w, p, &ExecConfig::llamacpp_default()).max(1e-6);
    (ratio - 1.0) / b0
}

/// Simulated kernel latency in microseconds.
///
/// `noise_rng`: when provided, multiplies by ~N(1, 0.01²) measurement noise
/// (the paper averages 10 repetitions; benches do the same).
///
/// Hot loops should build a [`LatencyModel`] once instead: this free
/// function re-derives the calibration pair (and, inside [`kappa`], the
/// default-config badness) on every call.
pub fn kernel_latency_us(
    w: &Workload,
    p: &DeviceProfile,
    e: &ExecConfig,
    noise_rng: Option<&mut Rng>,
) -> f64 {
    let (_, haqa_us) = calibrated(w);
    let base = haqa_us * p.kernel_scale;
    let lat = base * (1.0 + kappa(w, p) * badness(w, p, e));
    match noise_rng {
        Some(rng) => lat * (1.0 + rng.normal() * 0.01),
        None => lat,
    }
}

/// Pre-calibrated latency model for one (workload, device) pair.
///
/// `calibrated()` and `kappa()` are loop-invariant per workload/device but
/// [`kernel_latency_us`] recomputed them on every call — ten times per
/// averaged measurement, once per repeat.  The model hoists them into
/// construction so batched measurement ([`crate::deploy::KernelTuner`])
/// and the kernel evaluator pay the setup exactly once per worker, and
/// each measurement is a single `badness` walk.
///
/// Bit-compatibility: `latency_us` performs the identical float operations
/// in the identical order as [`kernel_latency_us`], so cached evaluations
/// and fleet runs stay bit-for-bit reproducible (asserted in tests).
#[derive(Debug, Clone)]
pub struct LatencyModel {
    profile: DeviceProfile,
    workload: Workload,
    base_us: f64,
    kappa: f64,
}

impl LatencyModel {
    pub fn new(workload: Workload, profile: &DeviceProfile) -> LatencyModel {
        let (_, haqa_us) = calibrated(&workload);
        LatencyModel {
            base_us: haqa_us * profile.kernel_scale,
            kappa: kappa(&workload, profile),
            profile: profile.clone(),
            workload,
        }
    }

    pub fn workload(&self) -> Workload {
        self.workload
    }

    /// One simulated measurement (see [`kernel_latency_us`]).
    pub fn latency_us(&self, e: &ExecConfig, noise_rng: Option<&mut Rng>) -> f64 {
        let lat = self.base_us * (1.0 + self.kappa * badness(&self.workload, &self.profile, e));
        match noise_rng {
            Some(rng) => lat * (1.0 + rng.normal() * 0.01),
            None => lat,
        }
    }
}

/// Aggregate execution-config penalty for the end-to-end decode path
/// (Fig. 5's "Defaults" vs agent-optimized): matmul dominates inference
/// (~90% per the paper §4.3), the rest is elementwise.
pub fn e2e_config_penalty(p: &DeviceProfile, e: &ExecConfig) -> f64 {
    let mm = Workload::new(KernelKind::MatMul, 64);
    let sm = Workload::new(KernelKind::Softmax, 64);
    let pen_mm = 1.0 + kappa(&mm, p) * badness(&mm, p, e);
    let pen_el = 1.0 + kappa(&sm, p) * badness(&sm, p, e);
    0.9 * pen_mm + 0.1 * pen_el
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::workload::PAPER_TABLE3;

    #[test]
    fn default_config_reproduces_paper_defaults() {
        let p = DeviceProfile::a6000();
        let e = ExecConfig::llamacpp_default();
        for (k, b, d, _) in PAPER_TABLE3 {
            let w = Workload::new(*k, *b);
            let lat = kernel_latency_us(&w, &p, &e, None);
            assert!(
                (lat - d).abs() / d < 1e-6,
                "{}@{b}: {lat} vs paper {d}",
                k.label()
            );
        }
    }

    #[test]
    fn tuned_configs_approach_paper_haqa() {
        // A hand-tuned config close to the model's optimum should land
        // within ~15% of the paper's HAQA latency.
        let p = DeviceProfile::a6000();
        let tuned = ExecConfig {
            griddim: 256,
            blockdim: 128,
            tiling: 64,
            unroll: 4,
            simd_width: 16,
            row_major: true,
            transpose: false,
            prefetch: 8,
            memory_hierarchy: MemHier::Shared,
            loop_order: super::super::exec::LoopOrder::Mnk,
        };
        let w = Workload::new(KernelKind::MatMul, 64);
        let lat = kernel_latency_us(&w, &p, &tuned, None);
        let (_, h) = calibrated(&w);
        assert!(lat < h * 1.20, "tuned {lat} vs haqa {h}");
    }

    #[test]
    fn badness_nonnegative_and_latency_positive() {
        let p = DeviceProfile::a6000();
        let space = crate::search::spaces::kernel_exec();
        let mut rng = Rng::new(5);
        for _ in 0..300 {
            let cfg = space.sample(&mut rng);
            let e = ExecConfig::from_config(&cfg);
            for k in KernelKind::ALL {
                let w = Workload::new(k, 64);
                assert!(badness(&w, &p, &e) >= 0.0);
                assert!(kernel_latency_us(&w, &p, &e, None) > 0.0);
            }
        }
    }

    #[test]
    fn shared_memory_overflow_is_a_cliff() {
        let p = DeviceProfile::a6000();
        let w = Workload::new(KernelKind::MatMul, 64);
        let mut e = ExecConfig::llamacpp_default();
        e.memory_hierarchy = MemHier::Shared;
        e.tiling = 64;
        let ok = kernel_latency_us(&w, &p, &e, None);
        e.tiling = 256; // 2*256*256*4 = 512 KiB >> 100 KiB shared
        let bad = kernel_latency_us(&w, &p, &e, None);
        assert!(bad > ok * 1.2, "{bad} vs {ok}");
    }

    #[test]
    fn latency_model_is_bit_identical_to_free_function() {
        // The cached model must reproduce kernel_latency_us exactly — the
        // persistent cache and fleet determinism both depend on it.
        let space = crate::search::spaces::kernel_exec();
        let mut rng = Rng::new(17);
        for p in [DeviceProfile::a6000(), DeviceProfile::adreno740()] {
            for k in KernelKind::ALL {
                for b in [1usize, 64, 128] {
                    let w = Workload::new(k, b);
                    let model = LatencyModel::new(w, &p);
                    for _ in 0..20 {
                        let cfg = space.sample(&mut rng);
                        let e = ExecConfig::from_config(&cfg);
                        assert_eq!(
                            model.latency_us(&e, None).to_bits(),
                            kernel_latency_us(&w, &p, &e, None).to_bits(),
                            "{}@{b} on {}",
                            k.label(),
                            p.name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn mobile_kernels_slower_than_desktop() {
        let e = ExecConfig::llamacpp_default();
        let w = Workload::new(KernelKind::Softmax, 64);
        let d = kernel_latency_us(&w, &DeviceProfile::a6000(), &e, None);
        let m = kernel_latency_us(&w, &DeviceProfile::adreno740(), &e, None);
        assert!(m > 3.0 * d);
    }
}
