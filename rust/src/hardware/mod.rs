//! Hardware simulator — the testbed substitution (DESIGN.md §2).
//!
//! The paper measures on an NVIDIA A6000 and a OnePlus 11 (Snapdragon 8
//! Gen 2 / Adreno 740); neither is available here, so this module provides
//! an analytic device model encoding the same physical mechanisms the paper
//! names in §4.4: roofline compute-vs-memory bounds, launch-geometry
//! occupancy, register pressure, shared-memory capacity, coalescing, native
//! vs emulated low-precision paths (tensor-core INT4/INT8 MMA vs FP16
//! conversion + bit-unpacking).
//!
//! * [`profile`] — device profiles (A6000, Adreno 740, generic CPU).
//! * [`workload`] — the Table 3 kernel workloads + paper calibration table.
//! * [`exec`] — typed execution configuration (the tunable the agent moves).
//! * [`latency`] — the kernel latency model, self-calibrated so the paper's
//!   default config reproduces the paper's default latencies exactly and a
//!   perfect tuner recovers the paper's HAQA latencies.
//! * [`models`] — LLM descriptors (params/layers/dims) for Tables 4-5, Fig 5.
//! * [`memory`] — deployment memory-footprint model (Table 5).
//! * [`adaptive`] — the analytic §3.4 strategy selector (cross-checks the
//!   agent's bit-width decisions).

pub mod adaptive;
pub mod exec;
pub mod latency;
pub mod memory;
pub mod models;
pub mod profile;
pub mod workload;

pub use exec::ExecConfig;
pub use latency::{kernel_latency_us, LatencyModel};
pub use models::ModelProfile;
pub use profile::{preset, DeviceProfile, PRESET_NAMES};
pub use workload::{KernelKind, Workload};
