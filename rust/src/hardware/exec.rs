//! Typed execution configuration — the deployment tunable (paper §3.1's
//! kernel execution parameters + execution strategy).

use crate::search::{Config, Space};

#[derive(Debug, Clone, PartialEq)]
pub struct ExecConfig {
    pub griddim: u32,
    pub blockdim: u32,
    pub tiling: u32,
    pub unroll: u32,
    pub simd_width: u32,
    pub row_major: bool,
    pub transpose: bool,
    pub prefetch: u32,
    pub memory_hierarchy: MemHier,
    pub loop_order: LoopOrder,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemHier {
    Global,
    Shared,
    Local,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoopOrder {
    Mnk,
    Mkn,
    Nmk,
    Nkm,
    Kmn,
    Knm,
}

impl LoopOrder {
    fn parse(s: &str) -> LoopOrder {
        match s {
            "mkn" => LoopOrder::Mkn,
            "nmk" => LoopOrder::Nmk,
            "nkm" => LoopOrder::Nkm,
            "kmn" => LoopOrder::Kmn,
            "knm" => LoopOrder::Knm,
            _ => LoopOrder::Mnk,
        }
    }

    /// Relative badness for the matmul inner loop (k-innermost orders keep
    /// the accumulator in registers; k-outermost thrash the output tile).
    pub fn matmul_badness(&self) -> f64 {
        match self {
            LoopOrder::Mnk | LoopOrder::Nmk => 0.0,
            LoopOrder::Mkn | LoopOrder::Nkm => 0.08,
            LoopOrder::Kmn | LoopOrder::Knm => 0.15,
        }
    }
}

impl ExecConfig {
    /// llama.cpp's stock launch configuration — the "Default" column of
    /// Table 3 (and the default of `search::spaces::kernel_exec`).
    pub fn llamacpp_default() -> ExecConfig {
        ExecConfig {
            griddim: 32,
            blockdim: 64,
            tiling: 16,
            unroll: 2,
            simd_width: 4,
            row_major: true,
            transpose: false,
            prefetch: 0,
            memory_hierarchy: MemHier::Global,
            loop_order: LoopOrder::Mnk,
        }
    }

    /// Parse from a `kernel_exec` space configuration.
    pub fn from_config(cfg: &Config) -> ExecConfig {
        let geti = |k: &str, d: i64| cfg.get(k).map(|v| v.as_i64()).unwrap_or(d) as u32;
        let gets = |k: &str, d: &str| {
            cfg.get(k)
                .and_then(|v| v.as_str().map(|s| s.to_string()))
                .unwrap_or_else(|| d.to_string())
        };
        ExecConfig {
            griddim: geti("griddim_x", 32).max(1),
            blockdim: geti("blockdim_x", 64).max(1),
            tiling: geti("tiling_size", 16).max(1),
            unroll: geti("unroll", 2).max(1),
            simd_width: geti("simd_width", 4).max(1),
            row_major: gets("layout", "row_major") == "row_major",
            transpose: gets("transpose", "no") == "yes",
            prefetch: geti("prefetch", 0),
            memory_hierarchy: match gets("memory_hierarchy", "global").as_str() {
                "shared" => MemHier::Shared,
                "local" => MemHier::Local,
                _ => MemHier::Global,
            },
            loop_order: LoopOrder::parse(&gets("loop_order", "mnk")),
        }
    }

    /// Render back into a `kernel_exec` configuration (for prompts/logs).
    pub fn to_config(&self, space: &Space) -> Config {
        use crate::search::param::Value;
        let mut cfg = Config::new();
        cfg.insert("griddim_x".into(), Value::Int(self.griddim as i64));
        cfg.insert("blockdim_x".into(), Value::Int(self.blockdim as i64));
        cfg.insert("tiling_size".into(), Value::Int(self.tiling as i64));
        cfg.insert("unroll".into(), Value::Int(self.unroll as i64));
        cfg.insert("simd_width".into(), Value::Int(self.simd_width as i64));
        cfg.insert(
            "layout".into(),
            Value::Cat(if self.row_major { "row_major" } else { "col_major" }.into()),
        );
        cfg.insert(
            "transpose".into(),
            Value::Cat(if self.transpose { "yes" } else { "no" }.into()),
        );
        cfg.insert("prefetch".into(), Value::Int(self.prefetch as i64));
        cfg.insert(
            "memory_hierarchy".into(),
            Value::Cat(
                match self.memory_hierarchy {
                    MemHier::Global => "global",
                    MemHier::Shared => "shared",
                    MemHier::Local => "local",
                }
                .into(),
            ),
        );
        cfg.insert(
            "loop_order".into(),
            Value::Cat(
                match self.loop_order {
                    LoopOrder::Mnk => "mnk",
                    LoopOrder::Mkn => "mkn",
                    LoopOrder::Nmk => "nmk",
                    LoopOrder::Nkm => "nkm",
                    LoopOrder::Kmn => "kmn",
                    LoopOrder::Knm => "knm",
                }
                .into(),
            ),
        );
        space.repair(&cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::spaces;

    #[test]
    fn default_matches_space_default() {
        let space = spaces::kernel_exec();
        let from_space = ExecConfig::from_config(&space.default_config());
        assert_eq!(from_space, ExecConfig::llamacpp_default());
    }

    #[test]
    fn config_roundtrip() {
        let space = spaces::kernel_exec();
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..50 {
            let cfg = space.sample(&mut rng);
            let exec = ExecConfig::from_config(&cfg);
            let back = exec.to_config(&space);
            assert_eq!(ExecConfig::from_config(&back), exec);
        }
    }
}
