//! The analytic §3.4 adaptive-quantization strategy selector.
//!
//! Same decision procedure the agent's bit-width policy implements, exposed
//! as a plain function so (a) Table 5 can be generated without an agent in
//! the loop, and (b) tests can cross-check that the agent's hardware
//! analysis agrees with the analytic model (§4.4's "after extensive
//! validation, HAQA's recommendations proved accurate").

use crate::quant::Scheme;

use super::memory;
use super::models::ModelProfile;
use super::profile::DeviceProfile;

/// The three roofline components of [`token_time_ms`], in order
/// `(mem_ms, compute_ms, launch_ms)`: weight streaming, per-parameter
/// compute overhead (dequant/MMA issue), per-layer kernel launch.
///
/// Exposed separately because they scale differently with batch size —
/// one decode step of a continuous batch streams the weights **once**
/// but pays the compute term per sequence — which is what the serving
/// simulator ([`crate::coordinator::traffic`]) builds its batched decode
/// step from.
pub fn token_time_parts(model: &ModelProfile, scheme: Scheme, dev: &DeviceProfile) -> (f64, f64, f64) {
    let params = model.params_b * 1e9;
    let bytes = params * scheme.bytes_per_weight();
    let mem_ms = bytes / (dev.mem_bw_gbps * 1e9) * 1e3;
    let compute_ms = model.params_b * dev.ov_ps(scheme);
    let launch_ms = model.layers as f64 * dev.launch_overhead_ms;
    (mem_ms, compute_ms, launch_ms)
}

/// Decode-path token time (ms) for a model/scheme/device — the §4.4
/// roofline: memory streaming + per-parameter compute overhead + per-layer
/// launch overhead.  On devices without native INT4 the overhead term
/// dominates the bandwidth savings, which is exactly the counterintuitive
/// INT8-beats-INT4 result.
pub fn token_time_ms(model: &ModelProfile, scheme: Scheme, dev: &DeviceProfile) -> f64 {
    let (mem_ms, compute_ms, launch_ms) = token_time_parts(model, scheme, dev);
    mem_ms + compute_ms + launch_ms
}

pub fn tokens_per_sec(model: &ModelProfile, scheme: Scheme, dev: &DeviceProfile) -> f64 {
    1000.0 / token_time_ms(model, scheme, dev)
}

#[derive(Debug, Clone)]
pub struct StrategyChoice {
    pub scheme: Option<Scheme>,
    pub rationale: String,
    /// (scheme, fits, tokens/s) per candidate, fastest-first.
    pub candidates: Vec<(Scheme, bool, f64)>,
}

/// Pick the fastest quantization scheme that fits `limit_gb` on `dev`.
pub fn select(model: &ModelProfile, dev: &DeviceProfile, limit_gb: f64) -> StrategyChoice {
    let mut candidates: Vec<(Scheme, bool, f64)> = Scheme::ALL
        .iter()
        .map(|&s| {
            (
                s,
                memory::fits(model, s, limit_gb),
                tokens_per_sec(model, s, dev),
            )
        })
        .collect();
    candidates.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
    let pick = candidates.iter().find(|(_, fits, _)| *fits).map(|(s, _, _)| *s);
    let rationale = match pick {
        Some(Scheme::INT8) if !dev.int4_native => format!(
            "{} lacks native INT4: INT4 operands must be unpacked \
             (shift/AND/OR) and converted to FP16 before accumulation, so \
             INT4 falls off the accelerated path. INT8 hits the native \
             integer pipeline and fits the {limit_gb} GB budget.",
            dev.name
        ),
        Some(s) => format!(
            "{} supports {} on its fastest execution path (tensor-core MMA \
             with FP32 accumulation) and it fits the {limit_gb} GB budget.",
            dev.name,
            s.label()
        ),
        None => format!(
            "no quantization type fits {limit_gb} GB for {}; deployment \
             rejected.",
            model.name
        ),
    };
    StrategyChoice {
        scheme: pick,
        rationale,
        candidates,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// §4.4's headline: INT8 beats INT4 on the Adreno 740 for every
    /// Table 4 model, while INT4 wins on the A6000.
    #[test]
    fn mobile_int8_beats_int4_desktop_opposite() {
        let mob = DeviceProfile::adreno740();
        let gpu = DeviceProfile::a6000();
        for m in ModelProfile::table4_models() {
            assert!(
                tokens_per_sec(&m, Scheme::INT8, &mob)
                    > tokens_per_sec(&m, Scheme::INT4, &mob),
                "{}: INT4 should lose on mobile",
                m.name
            );
        }
        for m in ModelProfile::figure5_models() {
            assert!(
                tokens_per_sec(&m, Scheme::INT4, &gpu)
                    > tokens_per_sec(&m, Scheme::INT8, &gpu),
                "{}: INT4 should win on the A6000",
                m.name
            );
        }
    }

    /// Table 4 magnitudes: within 2x of the paper's mobile numbers and the
    /// right ordering (INT8 ≥ FP16 > INT4 in throughput-per-scheme shape).
    #[test]
    fn table4_magnitudes_plausible() {
        let mob = DeviceProfile::adreno740();
        let paper: &[(fn() -> ModelProfile, [f64; 3])] = &[
            (ModelProfile::openllama_3b, [5.11, 5.25, 4.95]),
            (ModelProfile::tinyllama_1_1b, [11.17, 11.23, 10.43]),
            (ModelProfile::gpt2_large, [13.41, 13.20, 12.29]),
        ];
        for (mk, rates) in paper {
            let m = mk();
            for (s, want) in Scheme::ALL.iter().zip(rates) {
                let got = tokens_per_sec(&m, *s, &mob);
                assert!(
                    got > want * 0.5 && got < want * 2.0,
                    "{} {}: {got:.2} vs paper {want}",
                    m.name,
                    s.label()
                );
            }
        }
    }

    #[test]
    fn selector_respects_memory_and_rejects() {
        let gpu = DeviceProfile::a6000();
        let m = ModelProfile::llama2_13b();
        assert_eq!(select(&m, &gpu, 12.0).scheme, Some(Scheme::INT4));
        assert_eq!(select(&m, &gpu, 20.0).scheme, Some(Scheme::INT4));
        assert_eq!(select(&m, &gpu, 4.0).scheme, None);
    }

    #[test]
    fn mobile_selector_explains_the_int4_trap() {
        let mob = DeviceProfile::adreno740();
        let m = ModelProfile::openllama_3b();
        let choice = select(&m, &mob, 10.0);
        assert_eq!(choice.scheme, Some(Scheme::INT8));
        assert!(choice.rationale.contains("unpack"), "{}", choice.rationale);
    }
}
