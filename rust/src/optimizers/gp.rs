//! Gaussian-process surrogate over the unit cube (squared-exponential
//! kernel, Cholesky-based exact inference) — the substrate for the
//! Bayesian-optimization baseline (Snoek et al., 2012).

use super::linalg::{self, Mat};

#[derive(Debug, Clone)]
pub struct GpParams {
    /// RBF length scale (shared across dims; inputs are unit-cube encoded).
    pub length_scale: f64,
    /// Signal variance.
    pub signal: f64,
    /// Observation noise variance.
    pub noise: f64,
}

impl Default for GpParams {
    fn default() -> Self {
        GpParams {
            length_scale: 0.3,
            signal: 1.0,
            noise: 1e-4,
        }
    }
}

pub struct Gp {
    params: GpParams,
    x: Vec<Vec<f64>>,
    /// Cholesky factor of K + noise I.
    l: Mat,
    /// alpha = K^{-1} (y - mean)
    alpha: Vec<f64>,
    y_mean: f64,
    y_std: f64,
}

fn rbf(p: &GpParams, a: &[f64], b: &[f64]) -> f64 {
    let d2: f64 = a
        .iter()
        .zip(b)
        .map(|(x, y)| {
            let d = x - y;
            d * d
        })
        .sum();
    p.signal * (-0.5 * d2 / (p.length_scale * p.length_scale)).exp()
}

impl Gp {
    /// Fit exact GP regression on (x, y); y is standardized internally.
    pub fn fit(params: GpParams, x: Vec<Vec<f64>>, y: &[f64]) -> Option<Gp> {
        let n = x.len();
        if n == 0 || y.len() != n {
            return None;
        }
        let y_mean = y.iter().sum::<f64>() / n as f64;
        let mut y_std = (y.iter().map(|v| (v - y_mean) * (v - y_mean)).sum::<f64>()
            / n as f64)
            .sqrt();
        if y_std < 1e-9 {
            y_std = 1.0;
        }
        let ys: Vec<f64> = y.iter().map(|v| (v - y_mean) / y_std).collect();
        let mut k = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                k[(i, j)] = rbf(&params, &x[i], &x[j]) + if i == j { params.noise } else { 0.0 };
            }
        }
        let l = linalg::cholesky(&k)?;
        let alpha = linalg::solve_upper_t(&l, &linalg::solve_lower(&l, &ys));
        Some(Gp {
            params,
            x,
            l,
            alpha,
            y_mean,
            y_std,
        })
    }

    /// Posterior mean and standard deviation at a query point.
    pub fn predict(&self, q: &[f64]) -> (f64, f64) {
        let n = self.x.len();
        let kstar: Vec<f64> = (0..n).map(|i| rbf(&self.params, &self.x[i], q)).collect();
        let mean_s = linalg::dot(&kstar, &self.alpha);
        let v = linalg::solve_lower(&self.l, &kstar);
        let var_s = (self.params.signal + self.params.noise - linalg::dot(&v, &v)).max(1e-12);
        (mean_s * self.y_std + self.y_mean, var_s.sqrt() * self.y_std)
    }

    /// Expected improvement (maximization) over incumbent `best_y`.
    pub fn expected_improvement(&self, q: &[f64], best_y: f64, xi: f64) -> f64 {
        let (mu, sigma) = self.predict(q);
        if sigma < 1e-12 {
            return 0.0;
        }
        let z = (mu - best_y - xi) / sigma;
        sigma * (z * phi_cdf(z) + phi_pdf(z))
    }
}

fn phi_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Standard normal CDF via erf approximation (Abramowitz & Stegun 7.1.26).
fn phi_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interpolates_training_points() {
        let x = vec![vec![0.0], vec![0.5], vec![1.0]];
        let y = [1.0, 2.0, 0.5];
        let gp = Gp::fit(GpParams::default(), x.clone(), &y).unwrap();
        for (xi, yi) in x.iter().zip(&y) {
            let (mu, sigma) = gp.predict(xi);
            assert!((mu - yi).abs() < 0.05, "mu {mu} vs {yi}");
            assert!(sigma < 0.2);
        }
    }

    #[test]
    fn uncertainty_grows_far_from_data() {
        let gp = Gp::fit(
            GpParams::default(),
            vec![vec![0.0, 0.0]],
            &[0.0],
        )
        .unwrap();
        let (_, s_near) = gp.predict(&[0.01, 0.0]);
        let (_, s_far) = gp.predict(&[1.0, 1.0]);
        assert!(s_far > s_near * 2.0, "{s_far} vs {s_near}");
    }

    #[test]
    fn ei_prefers_promising_regions() {
        // y rises towards x=1
        let x = vec![vec![0.0], vec![0.4], vec![0.8]];
        let y = [0.0, 0.4, 0.8];
        let gp = Gp::fit(GpParams::default(), x, &y).unwrap();
        let ei_hi = gp.expected_improvement(&[0.95], 0.8, 0.0);
        let ei_lo = gp.expected_improvement(&[0.05], 0.8, 0.0);
        assert!(ei_hi > ei_lo, "{ei_hi} vs {ei_lo}");
    }

    #[test]
    fn erf_sane() {
        assert!((erf(0.0)).abs() < 1e-6);
        assert!((erf(2.0) - 0.9953).abs() < 1e-3);
        assert!((phi_cdf(0.0) - 0.5).abs() < 1e-9);
    }
}
