//! HAQA as an [`Optimizer`]: the agent workflow adapted to the round-based
//! interface the Table 1/2 benches drive, so the agent competes against the
//! baselines under the identical 10-round budget.

use crate::agent::simulated::SimulatedLlm;
use crate::agent::{Agent, LlmBackend, TaskContext, TaskKind};
use crate::search::{Config, Space};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::{Observation, Optimizer, Proposal};

pub struct HaqaOptimizer {
    pub agent: Agent,
    pub kind: TaskKind,
    pub hardware: Option<Json>,
    pub objective: Json,
    pub budget: usize,
    /// Propagate backend errors instead of falling back to the default
    /// configuration.  The §3.3 never-stall fallback is right for live
    /// backends (a flaky HTTP endpoint must not kill a tuning run), but
    /// wrong for `replay:` — there a missing transcript means the run
    /// diverged from the recording and silently continuing with defaults
    /// would defeat the point of replay.
    pub strict_errors: bool,
    /// Index into `agent.cost.per_query` already surfaced by
    /// [`Optimizer::take_round_cost`].
    cost_seen: usize,
}

impl HaqaOptimizer {
    /// The default simulated-backend agent (deterministic).
    pub fn simulated() -> Self {
        HaqaOptimizer::with_seed(0x4a9a)
    }

    pub fn with_seed(seed: u64) -> Self {
        HaqaOptimizer::with_agent(Agent::blocking(SimulatedLlm::new(seed)))
    }

    /// Drive any pipeline backend (HTTP, record/replay, simulated-slow…).
    pub fn with_backend(backend: Box<dyn LlmBackend>) -> Self {
        HaqaOptimizer::with_agent(Agent::new(backend))
    }

    fn with_agent(agent: Agent) -> Self {
        HaqaOptimizer {
            agent,
            kind: TaskKind::Finetune,
            hardware: None,
            objective: Json::obj(),
            budget: 10,
            strict_errors: false,
            cost_seen: 0,
        }
    }

    pub fn for_task(mut self, kind: TaskKind) -> Self {
        self.kind = kind;
        self
    }

    pub fn with_hardware(mut self, hw: Json) -> Self {
        self.hardware = Some(hw);
        self
    }

    pub fn with_objective(mut self, obj: Json) -> Self {
        self.objective = obj;
        self
    }

    fn ctx<'a>(&self, space: &'a Space, history: &'a [Observation]) -> TaskContext<'a> {
        TaskContext {
            kind: self.kind,
            space,
            history,
            rounds_left: self.budget.saturating_sub(history.len()),
            hardware: self.hardware.clone(),
            objective: self.objective.clone(),
        }
    }
}

impl Optimizer for HaqaOptimizer {
    fn name(&self) -> &str {
        "haqa"
    }

    fn propose(&mut self, space: &Space, history: &[Observation], rng: &mut Rng) -> Config {
        match self.propose_submit(space, history, rng) {
            Proposal::Ready(cfg) => cfg,
            Proposal::Pending => self
                .propose_wait(space, history)
                .unwrap_or_else(|_| space.default_config()),
        }
    }

    fn propose_submit(
        &mut self,
        space: &Space,
        history: &[Observation],
        _rng: &mut Rng,
    ) -> Proposal {
        let ctx = self.ctx(space, history);
        match self.agent.submit_propose(&ctx) {
            Ok(()) => Proposal::Pending,
            Err(e) => {
                // The workflow must not stall (paper §3.3); fall back to the
                // defaults and surface the error in the task log.
                eprintln!("haqa agent error: {e:#}");
                Proposal::Ready(space.default_config())
            }
        }
    }

    fn propose_poll(
        &mut self,
        space: &Space,
        history: &[Observation],
    ) -> anyhow::Result<Option<Config>> {
        // Cheap-poll first: while the request is still in flight there is
        // no need to rebuild the task context (which clones the objective
        // and hardware JSON) — the fleet spins on this path.
        match self.agent.completion_ready() {
            Ok(false) => return Ok(None),
            Ok(true) => {}
            Err(e) if self.strict_errors => return Err(e),
            Err(e) => {
                eprintln!("haqa agent error: {e:#}");
                return Ok(Some(space.default_config()));
            }
        }
        let ctx = self.ctx(space, history);
        match self.agent.poll_propose(&ctx) {
            Ok(Some((cfg, _))) => Ok(Some(cfg)),
            Ok(None) => Ok(None),
            Err(e) if self.strict_errors => Err(e),
            Err(e) => {
                eprintln!("haqa agent error: {e:#}");
                Ok(Some(space.default_config()))
            }
        }
    }

    fn propose_wait(&mut self, space: &Space, history: &[Observation]) -> anyhow::Result<Config> {
        let ctx = self.ctx(space, history);
        match self.agent.wait_propose(&ctx) {
            Ok((cfg, _)) => Ok(cfg),
            Err(e) if self.strict_errors => Err(e),
            Err(e) => {
                eprintln!("haqa agent error: {e:#}");
                Ok(space.default_config())
            }
        }
    }

    /// The Appendix-C accounting the coordinator surfaces per track.
    fn cost_report(&self) -> Option<String> {
        if self.agent.cost.queries == 0 {
            None
        } else {
            Some(self.agent.cost.report())
        }
    }

    /// Aggregate the per-query cost lines accrued since the last call into
    /// one per-round JSON entry for the task log.
    fn take_round_cost(&mut self) -> Option<Json> {
        let qs = &self.agent.cost.per_query[self.cost_seen.min(self.agent.cost.per_query.len())..];
        if qs.is_empty() {
            return None;
        }
        let mut o = Json::obj();
        o.set("queries", Json::Num(qs.len() as f64));
        o.set("retries", Json::Num((qs.len() - 1) as f64));
        o.set(
            "prompt_tokens",
            Json::Num(qs.iter().map(|q| q.prompt_tokens).sum::<usize>() as f64),
        );
        o.set(
            "completion_tokens",
            Json::Num(qs.iter().map(|q| q.completion_tokens).sum::<usize>() as f64),
        );
        o.set(
            "api_seconds",
            Json::Num(qs.iter().map(|q| q.api_seconds).sum::<f64>()),
        );
        self.cost_seen = self.agent.cost.per_query.len();
        Some(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizers::best;
    use crate::search::spaces;

    /// HAQA should beat random search on a synthetic response surface that
    /// mimics QAT tuning (smooth, lr-dominant, with a divergence cliff).
    #[test]
    fn haqa_beats_random_on_qat_surface() {
        let space = spaces::resnet_qat();
        let score = |cfg: &Config| {
            let lr = cfg["learning_rate"].as_f64();
            let wd = cfg["weight_decay"].as_f64();
            let mom = cfg["momentum"].as_f64();
            if lr > 0.08 {
                return 0.1; // divergence cliff
            }
            let lr_term = -((lr.ln() - (0.02f64).ln()).powi(2)) / 3.0;
            let wd_term = -((wd.ln() - (1e-3f64).ln()).powi(2)) / 18.0;
            let mom_term = -((mom - 0.9) * (mom - 0.9)) * 2.0;
            0.9 + 0.08 * (lr_term + wd_term + mom_term)
        };
        let run = |opt: &mut dyn Optimizer, seed: u64| {
            let mut rng = Rng::new(seed);
            let mut hist = Vec::new();
            for _ in 0..10 {
                let c = opt.propose(&space, &hist, &mut rng);
                let mut o = Observation::new(c.clone(), score(&c));
                o.feedback = "{\"loss_slope\": -0.02}".into();
                hist.push(o);
            }
            best(&hist).unwrap().score
        };
        let mut wins = 0;
        for seed in 0..5 {
            let h = run(&mut HaqaOptimizer::with_seed(seed), seed);
            let r = run(&mut crate::optimizers::RandomSearch, seed);
            if h >= r {
                wins += 1;
            }
        }
        assert!(wins >= 3, "haqa won only {wins}/5 vs random");
    }

    #[test]
    fn exposes_cost_report() {
        let space = spaces::resnet_qat();
        let mut opt = HaqaOptimizer::simulated();
        let mut rng = Rng::new(0);
        let mut hist = Vec::new();
        for _ in 0..3 {
            let c = opt.propose(&space, &hist, &mut rng);
            hist.push(Observation::new(c, 0.5));
        }
        let report = opt.agent.cost.report();
        assert!(report.contains("tokens"), "{report}");
    }
}
