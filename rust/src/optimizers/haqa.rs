//! HAQA as an [`Optimizer`]: the agent workflow adapted to the round-based
//! interface the Table 1/2 benches drive, so the agent competes against the
//! baselines under the identical 10-round budget.

use crate::agent::simulated::SimulatedLlm;
use crate::agent::{Agent, TaskContext, TaskKind};
use crate::search::{Config, Space};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::{Observation, Optimizer};

pub struct HaqaOptimizer {
    pub agent: Agent,
    pub kind: TaskKind,
    pub hardware: Option<Json>,
    pub objective: Json,
    pub budget: usize,
}

impl HaqaOptimizer {
    /// The default simulated-backend agent (deterministic).
    pub fn simulated() -> Self {
        HaqaOptimizer::with_seed(0x4a9a)
    }

    pub fn with_seed(seed: u64) -> Self {
        let backend = SimulatedLlm::new(seed);
        HaqaOptimizer {
            agent: Agent::new(Box::new(backend)),
            kind: TaskKind::Finetune,
            hardware: None,
            objective: Json::obj(),
            budget: 10,
        }
    }

    pub fn for_task(mut self, kind: TaskKind) -> Self {
        self.kind = kind;
        self
    }

    pub fn with_hardware(mut self, hw: Json) -> Self {
        self.hardware = Some(hw);
        self
    }

    pub fn with_objective(mut self, obj: Json) -> Self {
        self.objective = obj;
        self
    }
}

impl Optimizer for HaqaOptimizer {
    fn name(&self) -> &str {
        "haqa"
    }

    fn propose(&mut self, space: &Space, history: &[Observation], _rng: &mut Rng) -> Config {
        let ctx = TaskContext {
            kind: self.kind,
            space,
            history,
            rounds_left: self.budget.saturating_sub(history.len()),
            hardware: self.hardware.clone(),
            objective: self.objective.clone(),
        };
        match self.agent.propose(&ctx) {
            Ok((cfg, _)) => cfg,
            Err(e) => {
                // The workflow must not stall (paper §3.3); fall back to the
                // defaults and surface the error in the task log.
                eprintln!("haqa agent error: {e:#}");
                space.default_config()
            }
        }
    }

    /// The Appendix-C accounting the coordinator surfaces per track.
    fn cost_report(&self) -> Option<String> {
        if self.agent.cost.queries == 0 {
            None
        } else {
            Some(self.agent.cost.report())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizers::best;
    use crate::search::spaces;

    /// HAQA should beat random search on a synthetic response surface that
    /// mimics QAT tuning (smooth, lr-dominant, with a divergence cliff).
    #[test]
    fn haqa_beats_random_on_qat_surface() {
        let space = spaces::resnet_qat();
        let score = |cfg: &Config| {
            let lr = cfg["learning_rate"].as_f64();
            let wd = cfg["weight_decay"].as_f64();
            let mom = cfg["momentum"].as_f64();
            if lr > 0.08 {
                return 0.1; // divergence cliff
            }
            let lr_term = -((lr.ln() - (0.02f64).ln()).powi(2)) / 3.0;
            let wd_term = -((wd.ln() - (1e-3f64).ln()).powi(2)) / 18.0;
            let mom_term = -((mom - 0.9) * (mom - 0.9)) * 2.0;
            0.9 + 0.08 * (lr_term + wd_term + mom_term)
        };
        let run = |opt: &mut dyn Optimizer, seed: u64| {
            let mut rng = Rng::new(seed);
            let mut hist = Vec::new();
            for _ in 0..10 {
                let c = opt.propose(&space, &hist, &mut rng);
                let mut o = Observation::new(c.clone(), score(&c));
                o.feedback = "{\"loss_slope\": -0.02}".into();
                hist.push(o);
            }
            best(&hist).unwrap().score
        };
        let mut wins = 0;
        for seed in 0..5 {
            let h = run(&mut HaqaOptimizer::with_seed(seed), seed);
            let r = run(&mut crate::optimizers::RandomSearch, seed);
            if h >= r {
                wins += 1;
            }
        }
        assert!(wins >= 3, "haqa won only {wins}/5 vs random");
    }

    #[test]
    fn exposes_cost_report() {
        let space = spaces::resnet_qat();
        let mut opt = HaqaOptimizer::simulated();
        let mut rng = Rng::new(0);
        let mut hist = Vec::new();
        for _ in 0..3 {
            let c = opt.propose(&space, &hist, &mut rng);
            hist.push(Observation::new(c, 0.5));
        }
        let report = opt.agent.cost.report();
        assert!(report.contains("tokens"), "{report}");
    }
}
