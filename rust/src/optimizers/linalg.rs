//! Dense linear algebra substrate for the GP surrogate (offline image:
//! no nalgebra/ndarray): row-major matrices, Cholesky factorization,
//! triangular solves.

#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Mat {
        let r = rows.len();
        let c = rows.first().map(|v| v.len()).unwrap_or(0);
        let mut m = Mat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            debug_assert_eq!(row.len(), c);
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Cholesky factorization A = L L^T for symmetric positive-definite A.
/// Adds escalating jitter to the diagonal if needed (standard GP practice);
/// returns None only if even the largest jitter fails.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    debug_assert_eq!(a.rows, a.cols);
    let n = a.rows;
    'jitter: for &jit in &[0.0, 1e-10, 1e-8, 1e-6, 1e-4] {
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a[(i, j)] + if i == j { jit } else { 0.0 };
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= 0.0 || !sum.is_finite() {
                        continue 'jitter;
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        return Some(l);
    }
    None
}

/// Solve L y = b (L lower-triangular).
pub fn solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l[(i, k)] * y[k];
        }
        y[i] = sum / l[(i, i)];
    }
    y
}

/// Solve L^T x = y (L lower-triangular).
pub fn solve_upper_t(l: &Mat, y: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in i + 1..n {
            sum -= l[(k, i)] * x[k];
        }
        x[i] = sum / l[(i, i)];
    }
    x
}

/// Solve A x = b via Cholesky (A SPD).
pub fn solve_spd(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    let l = cholesky(a)?;
    Some(solve_upper_t(&l, &solve_lower(&l, b)))
}

pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_recomposes() {
        // A = M M^T + n I is SPD
        let m = Mat::from_rows(&[
            vec![2.0, 1.0, 0.0],
            vec![1.0, 3.0, 0.5],
            vec![0.5, 0.2, 1.5],
        ]);
        let mut a = Mat::zeros(3, 3);
        for i in 0..3 {
            for j in 0..3 {
                a[(i, j)] = dot(m.row(i), m.row(j)) + if i == j { 3.0 } else { 0.0 };
            }
        }
        let l = cholesky(&a).unwrap();
        for i in 0..3 {
            for j in 0..3 {
                let mut v = 0.0;
                for k in 0..3 {
                    v += l[(i, k)] * l[(j, k)];
                }
                assert!((v - a[(i, j)]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn spd_solve_matches_direct() {
        let a = Mat::from_rows(&[vec![4.0, 1.0], vec![1.0, 3.0]]);
        let x = solve_spd(&a, &[1.0, 2.0]).unwrap();
        // verify A x = b
        assert!((4.0 * x[0] + x[1] - 1.0).abs() < 1e-10);
        assert!((x[0] + 3.0 * x[1] - 2.0).abs() < 1e-10);
    }

    #[test]
    fn jitter_rescues_semidefinite() {
        // Rank-deficient PSD matrix
        let a = Mat::from_rows(&[vec![1.0, 1.0], vec![1.0, 1.0]]);
        assert!(cholesky(&a).is_some());
    }

    #[test]
    fn triangular_solves() {
        let l = Mat::from_rows(&[vec![2.0, 0.0], vec![1.0, 3.0]]);
        let y = solve_lower(&l, &[4.0, 11.0]);
        assert!((y[0] - 2.0).abs() < 1e-12 && (y[1] - 3.0).abs() < 1e-12);
        let x = solve_upper_t(&l, &y);
        // L^T x = y  =>  [2 1; 0 3] x = [2, 3] => x1 = 1, x0 = 0.5
        assert!((x[1] - 1.0).abs() < 1e-12 && (x[0] - 0.5).abs() < 1e-12);
    }
}
