//! Local search (hill climbing) — the paper's "Local search" column.
//!
//! Starts at the default config, then perturbs the incumbent (best-so-far)
//! in the unit cube: a random subset of coordinates gets Gaussian noise
//! whose scale anneals with the round number.  Accept/reject is implicit
//! (we always move from the incumbent, so a bad step is abandoned).

use super::{best, Observation, Optimizer};
use crate::search::{Config, Space};
use crate::util::rng::Rng;

pub struct LocalSearch {
    /// Initial perturbation scale in unit-cube coordinates.
    pub sigma0: f64,
    /// Multiplicative decay per round.
    pub decay: f64,
}

impl LocalSearch {
    pub fn new() -> Self {
        LocalSearch {
            sigma0: 0.25,
            decay: 0.85,
        }
    }
}

impl Default for LocalSearch {
    fn default() -> Self {
        Self::new()
    }
}

impl Optimizer for LocalSearch {
    fn name(&self) -> &str {
        "local"
    }

    fn propose(&mut self, space: &Space, history: &[Observation], rng: &mut Rng) -> Config {
        let Some(incumbent) = best(history) else {
            return space.default_config();
        };
        let sigma = self.sigma0 * self.decay.powi(history.len() as i32 - 1);
        let mut u = space.encode(&incumbent.config);
        // Perturb 1..=ceil(d/3) random coordinates.
        let d = u.len();
        let k = 1 + rng.usize(d.div_ceil(3));
        for _ in 0..k {
            let i = rng.usize(d);
            u[i] = (u[i] + rng.normal() * sigma).clamp(0.0, 1.0);
        }
        space.decode(&u)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::spaces;

    #[test]
    fn proposals_stay_valid_and_near_incumbent() {
        let space = spaces::llama_qlora();
        let mut opt = LocalSearch::new();
        let mut rng = Rng::new(1);
        let mut hist = vec![Observation::new(space.default_config(), 0.6)];
        for round in 1..10 {
            let c = opt.propose(&space, &hist, &mut rng);
            assert!(space.is_valid(&c), "round {round}: {c:?}");
            hist.push(Observation::new(c, 0.1)); // worse: incumbent stays
        }
        // All proposals perturb the incumbent, not the last (bad) config.
        let inc = space.encode(&hist[0].config);
        let last = space.encode(&hist.last().unwrap().config);
        let dist: f64 = inc
            .iter()
            .zip(&last)
            .map(|(a, b)| (a - b).abs())
            .sum::<f64>();
        assert!(dist < 2.0, "drifted too far: {dist}");
    }

    /// On a smooth unimodal objective, hill climbing should improve over the
    /// default within a 10-round budget.
    #[test]
    fn improves_on_quadratic_objective() {
        let space = spaces::resnet_qat();
        let target = space.encode(&space.sample(&mut Rng::new(42)));
        let score = |cfg: &Config| {
            let u = space.encode(cfg);
            -u.iter()
                .zip(&target)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
        };
        let mut opt = LocalSearch::new();
        let mut rng = Rng::new(2);
        let mut hist: Vec<Observation> = Vec::new();
        for _ in 0..10 {
            let c = opt.propose(&space, &hist, &mut rng);
            let s = score(&c);
            hist.push(Observation::new(c, s));
        }
        let first = hist[0].score;
        let best_score = best(&hist).unwrap().score;
        assert!(best_score > first, "no improvement: {first} vs {best_score}");
    }
}
