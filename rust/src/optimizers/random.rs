//! Random search (Bergstra & Bengio, 2012) — the paper's "Random" column.
//!
//! Round 0 uses the default configuration (the paper's protocol recommends
//! defaults first for every method), then i.i.d. samples from the space.

use super::{Observation, Optimizer};
use crate::search::{Config, Space};
use crate::util::rng::Rng;

pub struct RandomSearch;

impl Optimizer for RandomSearch {
    fn name(&self) -> &str {
        "random"
    }

    fn propose(&mut self, space: &Space, history: &[Observation], rng: &mut Rng) -> Config {
        if history.is_empty() {
            space.default_config()
        } else {
            space.sample(rng)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::spaces;

    #[test]
    fn first_round_is_default_then_valid_samples() {
        let space = spaces::resnet_qat();
        let mut opt = RandomSearch;
        let mut rng = Rng::new(0);
        let mut hist = Vec::new();
        let c0 = opt.propose(&space, &hist, &mut rng);
        assert_eq!(c0, space.default_config());
        hist.push(Observation::new(c0, 0.5));
        for _ in 0..20 {
            let c = opt.propose(&space, &hist, &mut rng);
            assert!(space.is_valid(&c));
            hist.push(Observation::new(c, 0.1));
        }
    }
}
