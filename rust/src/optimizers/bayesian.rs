//! Bayesian optimization (GP + expected improvement) — the paper's
//! "Bayesian opt." column.
//!
//! Round 0: defaults.  Rounds 1-2: space-filling random exploration (a GP
//! on <3 points is not informative).  Then: fit the GP on the unit-cube
//! history and maximize EI over a random candidate set refined with local
//! perturbations of the incumbent.

use super::gp::{Gp, GpParams};
use super::{best, Observation, Optimizer};
use crate::search::{Config, Space};
use crate::util::rng::Rng;

pub struct BayesianOpt {
    pub candidates: usize,
    pub xi: f64,
}

impl BayesianOpt {
    pub fn new() -> Self {
        BayesianOpt {
            candidates: 512,
            xi: 0.01,
        }
    }
}

impl Default for BayesianOpt {
    fn default() -> Self {
        Self::new()
    }
}

impl Optimizer for BayesianOpt {
    fn name(&self) -> &str {
        "bayesian"
    }

    fn propose(&mut self, space: &Space, history: &[Observation], rng: &mut Rng) -> Config {
        if history.is_empty() {
            return space.default_config();
        }
        if history.len() < 3 {
            return space.sample(rng);
        }
        let x: Vec<Vec<f64>> = history.iter().map(|o| space.encode(&o.config)).collect();
        let y: Vec<f64> = history.iter().map(|o| o.score).collect();
        let Some(gp) = Gp::fit(GpParams::default(), x, &y) else {
            return space.sample(rng);
        };
        let best_y = best(history).map(|o| o.score).unwrap_or(0.0);
        let inc = space.encode(&best(history).unwrap().config);
        let d = inc.len();

        let mut best_u: Option<Vec<f64>> = None;
        let mut best_ei = f64::NEG_INFINITY;
        for c in 0..self.candidates {
            // Mix global random candidates with local perturbations of the
            // incumbent (classic EI-maximization heuristic).
            let u: Vec<f64> = if c % 3 == 0 {
                inc.iter()
                    .map(|v| (v + rng.normal() * 0.1).clamp(0.0, 1.0))
                    .collect()
            } else {
                (0..d).map(|_| rng.f64()).collect()
            };
            let ei = gp.expected_improvement(&u, best_y, self.xi);
            if ei > best_ei {
                best_ei = ei;
                best_u = Some(u);
            }
        }
        match best_u {
            Some(u) if best_ei > 0.0 => space.decode(&u),
            _ => space.sample(rng),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::spaces;

    /// BO should beat random search on a smooth objective with equal budget.
    #[test]
    fn outperforms_random_on_smooth_objective() {
        let space = spaces::resnet_qat();
        let target = space.encode(&space.sample(&mut Rng::new(11)));
        let score = |cfg: &Config| {
            let u = space.encode(cfg);
            -u.iter()
                .zip(&target)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
        };
        let run = |opt: &mut dyn Optimizer, seed: u64| -> f64 {
            let mut rng = Rng::new(seed);
            let mut hist = Vec::new();
            for _ in 0..12 {
                let c = opt.propose(&space, &hist, &mut rng);
                let s = score(&c);
                hist.push(Observation::new(c, s));
            }
            best(&hist).unwrap().score
        };
        let mut bo_wins = 0;
        for seed in 0..5 {
            let bo = run(&mut BayesianOpt::new(), seed);
            let rs = run(&mut super::super::RandomSearch, seed);
            if bo >= rs {
                bo_wins += 1;
            }
        }
        assert!(bo_wins >= 3, "BO won only {bo_wins}/5");
    }

    #[test]
    fn proposals_valid() {
        let space = spaces::llama_qlora();
        let mut opt = BayesianOpt::new();
        let mut rng = Rng::new(5);
        let mut hist = Vec::new();
        for i in 0..8 {
            let c = opt.propose(&space, &hist, &mut rng);
            assert!(space.is_valid(&c));
            hist.push(Observation::new(c, (i as f64 * 0.7).sin()));
        }
    }
}
