//! NSGA-II (Deb et al.) — the paper's "NSGA2" column.
//!
//! Full implementation: fast non-dominated sorting, crowding distance,
//! binary tournament selection, SBX-style blend crossover and polynomial
//! mutation in the unit cube.  Under the paper's 10-round budget it runs in
//! steady-state mode: a small initial population, then one offspring per
//! round bred from the current non-dominated set.
//!
//! Works single-objective (score only) or multi-objective (score + extras),
//! which is how the accuracy-vs-latency ablation bench uses it.

use super::{Observation, Optimizer};
use crate::search::{Config, Space};
use crate::util::rng::Rng;

pub struct Nsga2 {
    pub init_pop: usize,
    pub eta: f64,
    pub mutation_p: f64,
}

impl Nsga2 {
    pub fn new() -> Self {
        Nsga2 {
            init_pop: 4,
            eta: 10.0,
            mutation_p: 0.2,
        }
    }
}

impl Default for Nsga2 {
    fn default() -> Self {
        Self::new()
    }
}

/// Objective vector for an observation (all maximized).
fn objectives(o: &Observation) -> Vec<f64> {
    let mut v = vec![o.score];
    v.extend_from_slice(&o.extra);
    v
}

/// Does `a` Pareto-dominate `b`? (>= everywhere, > somewhere)
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if x < y {
            return false;
        }
        if x > y {
            strictly = true;
        }
    }
    strictly
}

/// Fast non-dominated sort: returns front index per item (0 = best front).
pub fn non_dominated_fronts(objs: &[Vec<f64>]) -> Vec<usize> {
    let n = objs.len();
    let mut dominated_by = vec![0usize; n];
    let mut dominates_list: Vec<Vec<usize>> = vec![Vec::new(); n];
    for i in 0..n {
        for j in 0..n {
            if i != j && dominates(&objs[i], &objs[j]) {
                dominates_list[i].push(j);
                dominated_by[j] += 1;
            }
        }
    }
    let mut front = vec![usize::MAX; n];
    let mut current: Vec<usize> = (0..n).filter(|&i| dominated_by[i] == 0).collect();
    let mut level = 0;
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            front[i] = level;
            for &j in &dominates_list[i] {
                dominated_by[j] -= 1;
                if dominated_by[j] == 0 {
                    next.push(j);
                }
            }
        }
        current = next;
        level += 1;
    }
    front
}

/// Crowding distance within one front (larger = more isolated = preferred).
pub fn crowding_distance(objs: &[Vec<f64>], members: &[usize]) -> Vec<f64> {
    let m = members.len();
    let mut dist = vec![0.0f64; m];
    if m == 0 {
        return dist;
    }
    let n_obj = objs[members[0]].len();
    for k in 0..n_obj {
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&a, &b| {
            objs[members[a]][k]
                .partial_cmp(&objs[members[b]][k])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let lo = objs[members[order[0]]][k];
        let hi = objs[members[order[m - 1]]][k];
        dist[order[0]] = f64::INFINITY;
        dist[order[m - 1]] = f64::INFINITY;
        if (hi - lo).abs() < 1e-15 {
            continue;
        }
        for w in 1..m - 1 {
            dist[order[w]] +=
                (objs[members[order[w + 1]]][k] - objs[members[order[w - 1]]][k]) / (hi - lo);
        }
    }
    dist
}

impl Nsga2 {
    /// Binary tournament by (front, crowding).
    fn select<'a>(
        &self,
        history: &'a [Observation],
        fronts: &[usize],
        crowd: &[f64],
        rng: &mut Rng,
    ) -> &'a Observation {
        let a = rng.usize(history.len());
        let b = rng.usize(history.len());
        let better = |i: usize, j: usize| {
            (fronts[i], std::cmp::Reverse(ordered(crowd[i])))
                < (fronts[j], std::cmp::Reverse(ordered(crowd[j])))
        };
        if better(a, b) {
            &history[a]
        } else {
            &history[b]
        }
    }
}

fn ordered(x: f64) -> u64 {
    // Total order for positive floats incl. inf.
    x.max(0.0).to_bits()
}

impl Optimizer for Nsga2 {
    fn name(&self) -> &str {
        "nsga2"
    }

    fn propose(&mut self, space: &Space, history: &[Observation], rng: &mut Rng) -> Config {
        if history.is_empty() {
            return space.default_config();
        }
        if history.len() < self.init_pop {
            return space.sample(rng);
        }
        let objs: Vec<Vec<f64>> = history.iter().map(objectives).collect();
        let fronts = non_dominated_fronts(&objs);
        // Per-item crowding within its own front.
        let mut crowd = vec![0.0f64; history.len()];
        let max_front = fronts.iter().copied().max().unwrap_or(0);
        for level in 0..=max_front {
            let members: Vec<usize> = (0..history.len())
                .filter(|&i| fronts[i] == level)
                .collect();
            let d = crowding_distance(&objs, &members);
            for (mi, &i) in members.iter().enumerate() {
                crowd[i] = d[mi];
            }
        }
        let p1 = self.select(history, &fronts, &crowd, rng);
        let p2 = self.select(history, &fronts, &crowd, rng);
        let u1 = space.encode(&p1.config);
        let u2 = space.encode(&p2.config);
        // Blend crossover + polynomial-ish mutation in the unit cube.
        let mut child = Vec::with_capacity(u1.len());
        for (a, b) in u1.iter().zip(&u2) {
            let w = rng.f64();
            let mut v = w * a + (1.0 - w) * b;
            if rng.bool(self.mutation_p) {
                let delta = rng.normal() / self.eta;
                v += delta;
            }
            child.push(v.clamp(0.0, 1.0));
        }
        space.decode(&child)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::spaces;

    #[test]
    fn domination_and_fronts() {
        let objs = vec![
            vec![1.0, 1.0], // dominated by 2
            vec![2.0, 0.5],
            vec![2.0, 2.0], // dominates 0
            vec![0.5, 3.0],
        ];
        assert!(dominates(&objs[2], &objs[0]));
        assert!(!dominates(&objs[1], &objs[3]));
        let fronts = non_dominated_fronts(&objs);
        assert_eq!(fronts[2], 0);
        assert_eq!(fronts[3], 0);
        assert!(fronts[0] > 0);
    }

    #[test]
    fn crowding_extremes_infinite() {
        let objs = vec![vec![0.0], vec![0.5], vec![1.0]];
        let d = crowding_distance(&objs, &[0, 1, 2]);
        assert!(d[0].is_infinite() && d[2].is_infinite());
        assert!(d[1].is_finite());
    }

    #[test]
    fn proposals_valid_over_budget() {
        let space = spaces::kernel_exec();
        let mut opt = Nsga2::new();
        let mut rng = Rng::new(7);
        let mut hist = Vec::new();
        for i in 0..12 {
            let c = opt.propose(&space, &hist, &mut rng);
            assert!(space.is_valid(&c), "{c:?}");
            let mut o = Observation::new(c, (i as f64).sin());
            o.extra = vec![-(i as f64)];
            hist.push(o);
        }
    }

    /// Multi-objective run keeps non-dominated diversity: the front of the
    /// final history should contain >1 distinct config.
    #[test]
    fn maintains_pareto_front() {
        let space = spaces::resnet_qat();
        let mut opt = Nsga2::new();
        let mut rng = Rng::new(8);
        let mut hist: Vec<Observation> = Vec::new();
        for _ in 0..20 {
            let c = opt.propose(&space, &hist, &mut rng);
            let u = space.encode(&c);
            // Conflicting objectives: f1 = u0, f2 = 1 - u0.
            let mut o = Observation::new(c, u[0]);
            o.extra = vec![1.0 - u[0]];
            hist.push(o);
        }
        let objs: Vec<Vec<f64>> = hist.iter().map(objectives).collect();
        let fronts = non_dominated_fronts(&objs);
        let front0 = fronts.iter().filter(|&&f| f == 0).count();
        assert!(front0 >= 2, "front collapsed: {front0}");
    }
}
