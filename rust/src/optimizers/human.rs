//! "Human" column: the average behaviour of experienced practitioners
//! (paper §4.2 cites PACT/DoReFa author-recommended settings).
//!
//! Modelled as a fixed playbook of expert moves: start from the published
//! defaults, then apply the classic manual-tuning sequence — halve/raise the
//! learning rate based on the loss trend, bump weight decay on overfit,
//! lower batch size for more update noise — one knob at a time, exactly the
//! "experts tweak one parameter at a time" behaviour Figure 1 describes.

use super::{best, Observation, Optimizer};
use crate::search::param::Value;
use crate::search::{Config, Space};
use crate::util::rng::Rng;

pub struct HumanPriors {
    step: usize,
}

impl HumanPriors {
    pub fn new() -> Self {
        HumanPriors { step: 0 }
    }

    /// One-knob expert move `i` applied to `cfg` (multiplicative nudges on
    /// the canonical knobs, skipped when the space lacks the knob).
    fn apply_move(&self, space: &Space, cfg: &mut Config, i: usize) {
        // (knob, factor) pairs in the order a practitioner tries them.
        const MOVES: &[(&str, f64)] = &[
            ("learning_rate", 3.0),
            ("learning_rate", 0.5),
            ("weight_decay", 3.0),
            ("batch_size", 0.5),
            ("momentum", 1.05),
            ("learning_rate", 0.25),
            ("lora_r", 2.0),
            ("max_steps", 1.5),
            ("weight_decay", 0.3),
            ("lora_dropout", 2.0),
            ("per_device_train_batch_size", 0.5),
            ("warmup_ratio", 1.5),
        ];
        let mut applied = 0;
        for (knob, factor) in MOVES {
            if space.get(knob).is_none() {
                continue;
            }
            if applied == i {
                let p = space.get(knob).unwrap();
                let v = cfg.get(*knob).cloned().unwrap_or_else(|| p.default.clone());
                let moved = match v {
                    Value::Float(x) => Value::Float(x * factor),
                    Value::Int(k) => Value::Int(((k as f64) * factor).round() as i64),
                    other => other,
                };
                cfg.insert(knob.to_string(), p.clamp(&moved));
                return;
            }
            applied += 1;
        }
    }
}

impl Default for HumanPriors {
    fn default() -> Self {
        Self::new()
    }
}

impl Optimizer for HumanPriors {
    fn name(&self) -> &str {
        "human"
    }

    fn propose(&mut self, space: &Space, history: &[Observation], _rng: &mut Rng) -> Config {
        if history.is_empty() {
            self.step = 0;
            return space.default_config();
        }
        // Tweak the best config seen so far with the next playbook move.
        let mut cfg = best(history)
            .map(|o| o.config.clone())
            .unwrap_or_else(|| space.default_config());
        self.apply_move(space, &mut cfg, self.step % 12);
        self.step += 1;
        space.repair(&cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::spaces;

    #[test]
    fn playbook_stays_valid() {
        for space in [spaces::resnet_qat(), spaces::llama_qlora()] {
            let mut opt = HumanPriors::new();
            let mut rng = Rng::new(0);
            let mut hist = Vec::new();
            for round in 0..10 {
                let c = opt.propose(&space, &hist, &mut rng);
                assert!(space.is_valid(&c), "{} round {round}: {c:?}", space.name);
                hist.push(Observation::new(c, 0.5 - round as f64 * 0.01));
            }
        }
    }

    #[test]
    fn first_move_changes_one_knob() {
        let space = spaces::resnet_qat();
        let mut opt = HumanPriors::new();
        let mut rng = Rng::new(0);
        let hist = vec![Observation::new(space.default_config(), 0.5)];
        let c = opt.propose(&space, &hist, &mut rng);
        let d = space.default_config();
        let changed: Vec<_> = c.iter().filter(|(k, v)| d.get(*k) != Some(v)).collect();
        assert_eq!(changed.len(), 1, "{changed:?}");
    }
}
