//! Hyperparameter optimizers: the paper's baselines + the HAQA agent.
//!
//! Table 1/2 columns map to: [`DefaultConfig`] ("Default"),
//! [`HumanPriors`] ("Human"), [`LocalSearch`] ("Local search"),
//! [`bayesian::BayesianOpt`] ("Bayesian opt."), [`RandomSearch`] ("Random
//! search"), [`nsga2::Nsga2`] ("NSGA2"), and [`haqa::HaqaOptimizer`]
//! ("HAQA", the agent).  All share the round-based [`Optimizer`] interface
//! the coordinator drives with a 10-round budget (paper §4.2).

pub mod bayesian;
pub mod gp;
pub mod haqa;
pub mod human;
pub mod linalg;
pub mod local;
pub mod nsga2;
pub mod random;

use crate::search::{Config, Space};
use crate::util::rng::Rng;

/// One completed evaluation.
#[derive(Debug, Clone)]
pub struct Observation {
    pub config: Config,
    /// Primary objective, **maximized** (accuracy; negative latency for
    /// deployment tuning).
    pub score: f64,
    /// Optional secondary objectives for multi-objective methods
    /// (also maximized).
    pub extra: Vec<f64>,
    /// Free-form evaluation feedback surfaced to the agent (loss curve,
    /// per-task accuracy, latency breakdown).
    pub feedback: String,
}

impl Observation {
    pub fn new(config: Config, score: f64) -> Self {
        Observation {
            config,
            score,
            extra: Vec::new(),
            feedback: String::new(),
        }
    }
}

/// Round-based ask interface; the coordinator evaluates and appends to
/// `history` between calls.
pub trait Optimizer {
    fn name(&self) -> &str;

    /// Propose the configuration for round `history.len()`.
    fn propose(&mut self, space: &Space, history: &[Observation], rng: &mut Rng) -> Config;
}

/// Best observation by score (ties -> earliest, i.e. fewest rounds).
pub fn best(history: &[Observation]) -> Option<&Observation> {
    history
        .iter()
        .max_by(|a, b| a.score.partial_cmp(&b.score).unwrap_or(std::cmp::Ordering::Equal))
}

pub use human::HumanPriors;
pub use local::LocalSearch;
pub use random::RandomSearch;

/// "Default" column: always the space's default configuration.
pub struct DefaultConfig;

impl Optimizer for DefaultConfig {
    fn name(&self) -> &str {
        "default"
    }

    fn propose(&mut self, space: &Space, _history: &[Observation], _rng: &mut Rng) -> Config {
        space.default_config()
    }
}

/// Build an optimizer by the names used in benches/CLI.
pub fn by_name(name: &str) -> anyhow::Result<Box<dyn Optimizer>> {
    Ok(match name {
        "default" => Box::new(DefaultConfig),
        "human" => Box::new(HumanPriors::new()),
        "local" => Box::new(LocalSearch::new()),
        "bayesian" => Box::new(bayesian::BayesianOpt::new()),
        "random" => Box::new(RandomSearch),
        "nsga2" => Box::new(nsga2::Nsga2::new()),
        "haqa" => Box::new(haqa::HaqaOptimizer::simulated()),
        other => anyhow::bail!("unknown optimizer '{other}'"),
    })
}

/// The Table 1/2 method roster, in the paper's column order.
pub const METHODS: &[&str] = &[
    "default", "human", "local", "bayesian", "random", "nsga2", "haqa",
];
