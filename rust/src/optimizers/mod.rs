//! Hyperparameter optimizers: the paper's baselines + the HAQA agent.
//!
//! Table 1/2 columns map to: [`DefaultConfig`] ("Default"),
//! [`HumanPriors`] ("Human"), [`LocalSearch`] ("Local search"),
//! [`bayesian::BayesianOpt`] ("Bayesian opt."), [`RandomSearch`] ("Random
//! search"), [`nsga2::Nsga2`] ("NSGA2"), and [`haqa::HaqaOptimizer`]
//! ("HAQA", the agent).  All share the round-based [`Optimizer`] interface
//! the coordinator drives with a 10-round budget (paper §4.2).

pub mod bayesian;
pub mod gp;
pub mod haqa;
pub mod human;
pub mod linalg;
pub mod local;
pub mod nsga2;
pub mod random;

use crate::search::{Config, Space};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One completed evaluation.
#[derive(Debug, Clone)]
pub struct Observation {
    pub config: Config,
    /// Primary objective, **maximized** (accuracy; negative latency for
    /// deployment tuning).
    pub score: f64,
    /// Optional secondary objectives for multi-objective methods
    /// (also maximized).
    pub extra: Vec<f64>,
    /// Free-form evaluation feedback surfaced to the agent (loss curve,
    /// per-task accuracy, latency breakdown).
    pub feedback: String,
}

impl Observation {
    pub fn new(config: Config, score: f64) -> Self {
        Observation {
            config,
            score,
            extra: Vec::new(),
            feedback: String::new(),
        }
    }
}

/// Outcome of [`Optimizer::propose_submit`]: synchronous optimizers answer
/// immediately; agent-backed ones enqueue a backend request and resolve it
/// through [`Optimizer::propose_poll`] / [`Optimizer::propose_wait`].
#[derive(Debug)]
pub enum Proposal {
    Ready(Config),
    Pending,
}

/// Round-based ask interface; the coordinator evaluates and appends to
/// `history` between calls.
///
/// The split `propose_submit` → `propose_poll`/`propose_wait` form is what
/// lets the fleet keep many scenarios' agent queries in flight while
/// workers evaluate other scenarios' configs: a round can yield between
/// "prompt built" and "completion consumed".  Synchronous optimizers get
/// the split form for free (submit computes immediately); `propose` stays
/// the one-call blocking composition and must produce identical results.
pub trait Optimizer {
    fn name(&self) -> &str;

    /// Propose the configuration for round `history.len()` (blocking).
    fn propose(&mut self, space: &Space, history: &[Observation], rng: &mut Rng) -> Config;

    /// Begin round `history.len()`'s proposal.  Agent-backed optimizers
    /// submit the prompt and return [`Proposal::Pending`]; the default
    /// computes synchronously.  `space` and `history` must be passed
    /// unchanged to the matching poll/wait.
    fn propose_submit(
        &mut self,
        space: &Space,
        history: &[Observation],
        rng: &mut Rng,
    ) -> Proposal {
        Proposal::Ready(self.propose(space, history, rng))
    }

    /// Non-blocking poll of a pending proposal (`Ok(None)` = still in
    /// flight).  Only valid after `propose_submit` returned `Pending`.
    fn propose_poll(
        &mut self,
        _space: &Space,
        _history: &[Observation],
    ) -> anyhow::Result<Option<Config>> {
        anyhow::bail!("optimizer '{}' has no pending proposal to poll", self.name())
    }

    /// Block until the pending proposal resolves.  Only valid after
    /// `propose_submit` returned `Pending`.
    fn propose_wait(&mut self, _space: &Space, _history: &[Observation]) -> anyhow::Result<Config> {
        anyhow::bail!("optimizer '{}' has no pending proposal to wait on", self.name())
    }

    /// The Appendix-C cost line for agent-backed optimizers; baselines cost
    /// nothing and return `None`.  The coordinator threads this into
    /// `TrackOutcome::cost_report`.
    fn cost_report(&self) -> Option<String> {
        None
    }

    /// Per-round agent accounting (queries/retries/tokens/latency) accrued
    /// since the last call — recorded into the task log so cost is
    /// auditable per request, not just as the final summary string.
    /// Baselines return `None`.
    fn take_round_cost(&mut self) -> Option<Json> {
        None
    }
}

impl<T: Optimizer + ?Sized> Optimizer for &mut T {
    fn name(&self) -> &str {
        (**self).name()
    }
    fn propose(&mut self, space: &Space, history: &[Observation], rng: &mut Rng) -> Config {
        (**self).propose(space, history, rng)
    }
    fn propose_submit(
        &mut self,
        space: &Space,
        history: &[Observation],
        rng: &mut Rng,
    ) -> Proposal {
        (**self).propose_submit(space, history, rng)
    }
    fn propose_poll(
        &mut self,
        space: &Space,
        history: &[Observation],
    ) -> anyhow::Result<Option<Config>> {
        (**self).propose_poll(space, history)
    }
    fn propose_wait(&mut self, space: &Space, history: &[Observation]) -> anyhow::Result<Config> {
        (**self).propose_wait(space, history)
    }
    fn cost_report(&self) -> Option<String> {
        (**self).cost_report()
    }
    fn take_round_cost(&mut self) -> Option<Json> {
        (**self).take_round_cost()
    }
}

impl<T: Optimizer + ?Sized> Optimizer for Box<T> {
    fn name(&self) -> &str {
        (**self).name()
    }
    fn propose(&mut self, space: &Space, history: &[Observation], rng: &mut Rng) -> Config {
        (**self).propose(space, history, rng)
    }
    fn propose_submit(
        &mut self,
        space: &Space,
        history: &[Observation],
        rng: &mut Rng,
    ) -> Proposal {
        (**self).propose_submit(space, history, rng)
    }
    fn propose_poll(
        &mut self,
        space: &Space,
        history: &[Observation],
    ) -> anyhow::Result<Option<Config>> {
        (**self).propose_poll(space, history)
    }
    fn propose_wait(&mut self, space: &Space, history: &[Observation]) -> anyhow::Result<Config> {
        (**self).propose_wait(space, history)
    }
    fn cost_report(&self) -> Option<String> {
        (**self).cost_report()
    }
    fn take_round_cost(&mut self) -> Option<Json> {
        (**self).take_round_cost()
    }
}

/// Best observation by score (ties -> earliest, i.e. fewest rounds).
/// A later observation replaces the incumbent only when strictly better,
/// which is what makes the tie contract hold (`max_by` would keep the
/// *last* maximum).  NaN scores never displace a real incumbent.
pub fn best(history: &[Observation]) -> Option<&Observation> {
    let mut it = history.iter();
    let mut incumbent = it.next()?;
    for o in it {
        if o.score > incumbent.score || incumbent.score.is_nan() {
            incumbent = o;
        }
    }
    Some(incumbent)
}

pub use human::HumanPriors;
pub use local::LocalSearch;
pub use random::RandomSearch;

/// "Default" column: always the space's default configuration.
pub struct DefaultConfig;

impl Optimizer for DefaultConfig {
    fn name(&self) -> &str {
        "default"
    }

    fn propose(&mut self, space: &Space, _history: &[Observation], _rng: &mut Rng) -> Config {
        space.default_config()
    }
}

/// Build an optimizer by the names used in benches/CLI.
pub fn by_name(name: &str) -> anyhow::Result<Box<dyn Optimizer>> {
    Ok(match name {
        "default" => Box::new(DefaultConfig),
        "human" => Box::new(HumanPriors::new()),
        "local" => Box::new(LocalSearch::new()),
        "bayesian" => Box::new(bayesian::BayesianOpt::new()),
        "random" => Box::new(RandomSearch),
        "nsga2" => Box::new(nsga2::Nsga2::new()),
        "haqa" => Box::new(haqa::HaqaOptimizer::simulated()),
        other => anyhow::bail!("unknown optimizer '{other}'"),
    })
}

/// The Table 1/2 method roster, in the paper's column order.
pub const METHODS: &[&str] = &[
    "default", "human", "local", "bayesian", "random", "nsga2", "haqa",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::spaces;

    fn obs(score: f64) -> Observation {
        Observation::new(spaces::bitwidth().default_config(), score)
    }

    #[test]
    fn best_breaks_ties_toward_earliest_round() {
        // Regression: `max_by` returns the *last* maximum on ties, which
        // contradicted the documented "ties -> earliest" contract.
        let hist = vec![obs(0.3), obs(0.9), obs(0.9), obs(0.5)];
        let b = best(&hist).unwrap();
        assert_eq!(b.score, 0.9);
        assert!(
            std::ptr::eq(b, &hist[1]),
            "tie must resolve to the earliest observation"
        );
    }

    #[test]
    fn best_handles_empty_and_nan() {
        assert!(best(&[]).is_none());
        let hist = vec![obs(f64::NAN), obs(0.2), obs(0.1)];
        assert_eq!(best(&hist).unwrap().score, 0.2);
        let hist = vec![obs(0.2), obs(f64::NAN)];
        assert_eq!(best(&hist).unwrap().score, 0.2);
    }

    #[test]
    fn baseline_optimizers_have_no_cost_report() {
        for name in METHODS.iter().filter(|m| **m != "haqa") {
            let opt = by_name(name).unwrap();
            assert!(opt.cost_report().is_none(), "{name}");
        }
    }
}
