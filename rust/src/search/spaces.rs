//! The paper's concrete search spaces (Appendix D / Appendix E), verbatim.
//!
//! The trainer maps budget-like parameters (epochs, max_steps) onto the
//! laptop-scale models with a fixed scale factor; the *space* the optimizers
//! and the agent see is the paper's.

use super::param::Param;
use super::space::Space;

/// ResNet-style QAT fine-tuning space (Appendix D, "ResNet-style models").
pub fn resnet_qat() -> Space {
    Space::new(
        "resnet_qat",
        vec![
            Param::log_float(
                "learning_rate", 1e-5, 0.2, 0.01,
                "The learning rate for the SGD optimizer",
            ),
            Param::log_int("batch_size", 32, 256, 128,
                           "The number of samples per batch"),
            Param::log_float("weight_decay", 1e-6, 0.1, 5e-4,
                             "The L2 regularization coefficient"),
            Param::float("momentum", 0.5, 0.99, 0.9,
                         "The momentum for the SGD optimizer"),
            Param::int("num_epochs", 8, 24, 12, "The number of training epochs"),
        ],
    )
}

/// LLaMA QLoRA fine-tuning space (Appendix E, Llama2-7b static prompt).
pub fn llama_qlora() -> Space {
    Space::new(
        "llama_qlora",
        vec![
            Param::log_float("learning_rate", 1e-5, 1e-3, 4e-4,
                             "Learning rate for the optimizer"),
            Param::int("per_device_train_batch_size", 4, 16, 8,
                       "Batch size for per-device training"),
            Param::int("gradient_accumulation_steps", 4, 32, 8,
                       "Number of steps for gradient accumulation"),
            Param::log_float("weight_decay", 0.001, 0.1, 0.01,
                             "L2 regularization coefficient"),
            Param::int("max_steps", 200, 1000, 400,
                       "Maximum number of steps for training"),
            Param::float("max_grad_norm", 0.1, 1.0, 0.3,
                         "Maximum norm for gradient clipping"),
            Param::int("lora_r", 8, 64, 16, "Rank parameter for LoRA"),
            Param::int("lora_alpha", 4, 32, 8, "Alpha parameter for LoRA"),
            Param::float("lora_dropout", 0.0, 0.3, 0.05,
                         "Dropout probability for LoRA"),
            Param::float("warmup_ratio", 0.0, 0.08, 0.03, "warmup_ratio"),
        ],
    )
}

/// Per-kernel execution configuration space (Appendix D, "End-to-end
/// deployment search" + the §3.1 kernel knobs: block size, tiling, unroll,
/// memory hierarchy, thread scheduling).
pub fn kernel_exec() -> Space {
    Space::new(
        "kernel_exec",
        vec![
            Param::log_int("griddim_x", 1, 256, 32,
                           "Grid dimension (thread blocks)"),
            Param::log_int("blockdim_x", 1, 256, 64,
                           "Threads per block (x)"),
            Param::log_int("tiling_size", 8, 256, 16,
                           "Tile edge for memory-access blocking"),
            Param::log_int("unroll", 1, 16, 2, "Loop unrolling factor"),
            Param::int("simd_width", 4, 16, 4, "Vector lanes per ALU op"),
            Param::cat("layout", &["row_major", "col_major"], "row_major",
                       "Memory layout for operand tensors"),
            Param::cat("transpose", &["no", "yes"], "no",
                       "Pre-transpose the weight operand"),
            Param::int("prefetch", 0, 16, 0, "Software prefetch distance"),
            Param::cat("memory_hierarchy", &["global", "shared", "local"],
                       "global", "Tensor placement for the inner tile"),
            Param::cat(
                "loop_order",
                &["mnk", "mkn", "nmk", "nkm", "kmn", "knm"],
                "mnk",
                "Loop-nest order for the kernel's 3 loops",
            ),
        ],
    )
}

/// Bit-width selection space (§3.4 adaptive quantization strategies).
pub fn bitwidth() -> Space {
    Space::new(
        "bitwidth",
        // "NONE" = reject deployment (no scheme satisfies the constraints —
        // the Table 5 "×" row at 4 GB).
        vec![Param::cat("quant", &["FP16", "INT8", "INT4", "NONE"], "INT8",
                        "Deployment quantization type (NONE = reject)")],
    )
}

/// Pallas tile-schedule space for the real-artifact tuning demo (the TPU
/// analogue; see DESIGN.md §Hardware-Adaptation).  Choices mirror the
/// AOT'd `micro_matmul_b64_*` tile variants.
pub fn pallas_tiles() -> Space {
    Space::new(
        "pallas_tiles",
        vec![Param::cat(
            "tile",
            &["t32", "t64", "t128", "t64w"],
            "t64",
            "qmatmul (bm, bn, bk) VMEM tile schedule",
        )],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn paper_spaces_sample_valid() {
        let mut rng = Rng::new(9);
        for space in [resnet_qat(), llama_qlora(), kernel_exec(), bitwidth()] {
            for _ in 0..100 {
                let cfg = space.sample(&mut rng);
                assert!(space.is_valid(&cfg), "{}: {cfg:?}", space.name);
            }
            assert!(space.is_valid(&space.default_config()));
        }
    }

    #[test]
    fn describe_mentions_every_param() {
        let s = llama_qlora();
        let d = s.describe();
        for p in &s.params {
            assert!(d.contains(&p.name));
        }
    }
}
