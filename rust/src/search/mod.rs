//! Typed hyperparameter search spaces (paper Appendix D).
//!
//! * [`param`] — parameter kinds: log/linear uniform floats, integers,
//!   categorical choices.
//! * [`space`] — named collections with sampling, validation, clamping and
//!   unit-cube encoding (used by the GP and NSGA-II).
//! * [`spaces`] — the paper's concrete search spaces, verbatim: ResNet QAT,
//!   LLaMA QLoRA, and the per-kernel deployment execution space.

pub mod param;
pub mod space;
pub mod spaces;

pub use param::{Param, ParamKind, Value};
pub use space::{Config, Space};
