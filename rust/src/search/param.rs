//! Parameter kinds and values.
//!
//! Mirrors the typing the paper's prompts use (Appendix E): `UniformFloat`
//! (optionally log-scale), `UniformInteger` (optionally log-scale) and
//! categorical choices (e.g. memory layout row/col-major).

use crate::util::json::Json;
use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Float(f64),
    Int(i64),
    Cat(String),
}

impl Value {
    pub fn as_f64(&self) -> f64 {
        match self {
            Value::Float(x) => *x,
            Value::Int(k) => *k as f64,
            Value::Cat(_) => f64::NAN,
        }
    }

    pub fn as_i64(&self) -> i64 {
        match self {
            Value::Float(x) => x.round() as i64,
            Value::Int(k) => *k,
            Value::Cat(_) => 0,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Cat(s) => Some(s),
            _ => None,
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            Value::Float(x) => Json::Num(*x),
            Value::Int(k) => Json::Num(*k as f64),
            Value::Cat(s) => Json::Str(s.clone()),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum ParamKind {
    /// Uniform float in [lo, hi]; `log` samples/encodes in log space.
    Float { lo: f64, hi: f64, log: bool },
    /// Uniform integer in [lo, hi] inclusive; `log` samples in log space.
    Int { lo: i64, hi: i64, log: bool },
    /// One of a fixed set of strings.
    Cat { choices: Vec<String> },
}

#[derive(Debug, Clone, PartialEq)]
pub struct Param {
    pub name: String,
    pub kind: ParamKind,
    pub default: Value,
    pub help: String,
}

impl Param {
    pub fn float(name: &str, lo: f64, hi: f64, default: f64, help: &str) -> Param {
        Param {
            name: name.into(),
            kind: ParamKind::Float { lo, hi, log: false },
            default: Value::Float(default),
            help: help.into(),
        }
    }

    pub fn log_float(name: &str, lo: f64, hi: f64, default: f64, help: &str) -> Param {
        Param {
            name: name.into(),
            kind: ParamKind::Float { lo, hi, log: true },
            default: Value::Float(default),
            help: help.into(),
        }
    }

    pub fn int(name: &str, lo: i64, hi: i64, default: i64, help: &str) -> Param {
        Param {
            name: name.into(),
            kind: ParamKind::Int { lo, hi, log: false },
            default: Value::Int(default),
            help: help.into(),
        }
    }

    pub fn log_int(name: &str, lo: i64, hi: i64, default: i64, help: &str) -> Param {
        Param {
            name: name.into(),
            kind: ParamKind::Int { lo, hi, log: true },
            default: Value::Int(default),
            help: help.into(),
        }
    }

    pub fn cat(name: &str, choices: &[&str], default: &str, help: &str) -> Param {
        Param {
            name: name.into(),
            kind: ParamKind::Cat {
                choices: choices.iter().map(|s| s.to_string()).collect(),
            },
            default: Value::Cat(default.into()),
            help: help.into(),
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> Value {
        match &self.kind {
            ParamKind::Float { lo, hi, log } => Value::Float(if *log {
                rng.log_uniform(*lo, *hi)
            } else {
                rng.uniform(*lo, *hi)
            }),
            ParamKind::Int { lo, hi, log } => Value::Int(if *log {
                let x = rng.log_uniform(*lo as f64, *hi as f64 + 1.0);
                (x.floor() as i64).clamp(*lo, *hi)
            } else {
                rng.int(*lo, *hi)
            }),
            ParamKind::Cat { choices } => Value::Cat(rng.choice(choices).clone()),
        }
    }

    /// Is `v` inside the declared range / choice set?
    pub fn contains(&self, v: &Value) -> bool {
        match (&self.kind, v) {
            (ParamKind::Float { lo, hi, .. }, Value::Float(x)) => {
                x.is_finite() && *x >= *lo && *x <= *hi
            }
            (ParamKind::Float { lo, hi, .. }, Value::Int(k)) => {
                (*k as f64) >= *lo && (*k as f64) <= *hi
            }
            (ParamKind::Int { lo, hi, .. }, Value::Int(k)) => k >= lo && k <= hi,
            (ParamKind::Int { lo, hi, .. }, Value::Float(x)) => {
                x.fract() == 0.0 && *x >= *lo as f64 && *x <= *hi as f64
            }
            (ParamKind::Cat { choices }, Value::Cat(s)) => choices.contains(s),
            _ => false,
        }
    }

    /// Clamp a raw value into range (used by optimizers after perturbation,
    /// never by the validator — the agent must stay in range on its own).
    pub fn clamp(&self, v: &Value) -> Value {
        match (&self.kind, v) {
            (ParamKind::Float { lo, hi, .. }, v) => {
                Value::Float(v.as_f64().clamp(*lo, *hi))
            }
            (ParamKind::Int { lo, hi, .. }, v) => Value::Int(v.as_i64().clamp(*lo, *hi)),
            (ParamKind::Cat { choices }, Value::Cat(s)) if choices.contains(s) => {
                Value::Cat(s.clone())
            }
            (ParamKind::Cat { choices }, _) => Value::Cat(choices[0].clone()),
        }
    }

    /// Encode to [0,1] (log-aware); categorical -> index fraction.
    pub fn encode(&self, v: &Value) -> f64 {
        match &self.kind {
            ParamKind::Float { lo, hi, log } => {
                let x = v.as_f64();
                if *log {
                    (x.ln() - lo.ln()) / (hi.ln() - lo.ln())
                } else {
                    (x - lo) / (hi - lo)
                }
            }
            ParamKind::Int { lo, hi, log } => {
                let x = v.as_i64() as f64;
                if *log {
                    (x.ln() - (*lo as f64).ln())
                        / ((*hi as f64).ln() - (*lo as f64).ln() + 1e-12)
                } else {
                    (x - *lo as f64) / ((*hi - *lo) as f64).max(1e-12)
                }
            }
            ParamKind::Cat { choices } => {
                let idx = v
                    .as_str()
                    .and_then(|s| choices.iter().position(|c| c == s))
                    .unwrap_or(0);
                if choices.len() <= 1 {
                    0.0
                } else {
                    idx as f64 / (choices.len() - 1) as f64
                }
            }
        }
    }

    /// Decode from [0,1] back into a valid value (inverse of `encode`).
    pub fn decode(&self, u: f64) -> Value {
        let u = u.clamp(0.0, 1.0);
        match &self.kind {
            ParamKind::Float { lo, hi, log } => Value::Float(
                if *log {
                    (lo.ln() + u * (hi.ln() - lo.ln())).exp()
                } else {
                    lo + u * (hi - lo)
                }
                // Guard float roundoff at the boundaries (exp(ln(lo)) < lo).
                .clamp(*lo, *hi),
            ),
            ParamKind::Int { lo, hi, log } => {
                let x = if *log {
                    ((*lo as f64).ln() + u * ((*hi as f64).ln() - (*lo as f64).ln())).exp()
                } else {
                    *lo as f64 + u * (*hi - *lo) as f64
                };
                Value::Int((x.round() as i64).clamp(*lo, *hi))
            }
            ParamKind::Cat { choices } => {
                let idx = ((u * (choices.len() - 1) as f64).round() as usize)
                    .min(choices.len() - 1);
                Value::Cat(choices[idx].clone())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_in_range() {
        let p = Param::log_float("lr", 1e-5, 0.2, 0.01, "");
        let mut rng = Rng::new(1);
        for _ in 0..500 {
            let v = p.sample(&mut rng);
            assert!(p.contains(&v), "{v:?}");
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let p = Param::log_float("lr", 1e-5, 0.2, 0.01, "");
        let v = Value::Float(3e-3);
        let u = p.encode(&v);
        let back = p.decode(u);
        assert!((back.as_f64() - 3e-3).abs() / 3e-3 < 1e-9);

        let q = Param::int("batch", 32, 256, 128, "");
        for k in [32i64, 100, 256] {
            let u = q.encode(&Value::Int(k));
            assert_eq!(q.decode(u).as_i64(), k);
        }
    }

    #[test]
    fn categorical_contains_and_clamp() {
        let p = Param::cat("layout", &["row", "col"], "row", "");
        assert!(p.contains(&Value::Cat("col".into())));
        assert!(!p.contains(&Value::Cat("diag".into())));
        assert_eq!(p.clamp(&Value::Cat("diag".into())), Value::Cat("row".into()));
    }

    #[test]
    fn int_accepts_integral_float() {
        let p = Param::int("n", 1, 10, 5, "");
        assert!(p.contains(&Value::Float(7.0)));
        assert!(!p.contains(&Value::Float(7.5)));
    }
}
