//! Named parameter collections: sampling, validation, encoding.

use std::collections::BTreeMap;

use super::param::{Param, Value};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// A concrete assignment of every parameter in a space.
pub type Config = BTreeMap<String, Value>;

#[derive(Debug, Clone, Default)]
pub struct Space {
    pub name: String,
    pub params: Vec<Param>,
}

/// A range/format violation found by [`Space::validate`] — these are exactly
/// the agent failure modes §3.2 of the paper lists (missing keys, values out
/// of the declared range, wrong types), surfaced so the coordinator can ask
/// the agent to retry.
#[derive(Debug, Clone, PartialEq)]
pub enum Violation {
    Missing(String),
    OutOfRange { name: String, got: String },
    UnknownKey(String),
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Violation::Missing(k) => write!(f, "missing hyperparameter '{k}'"),
            Violation::OutOfRange { name, got } => {
                write!(f, "'{name}' = {got} violates the declared range")
            }
            Violation::UnknownKey(k) => write!(f, "unknown hyperparameter '{k}'"),
        }
    }
}

impl Space {
    pub fn new(name: &str, params: Vec<Param>) -> Space {
        Space {
            name: name.into(),
            params,
        }
    }

    pub fn dim(&self) -> usize {
        self.params.len()
    }

    pub fn get(&self, name: &str) -> Option<&Param> {
        self.params.iter().find(|p| p.name == name)
    }

    pub fn default_config(&self) -> Config {
        self.params
            .iter()
            .map(|p| (p.name.clone(), p.default.clone()))
            .collect()
    }

    pub fn sample(&self, rng: &mut Rng) -> Config {
        self.params
            .iter()
            .map(|p| (p.name.clone(), p.sample(rng)))
            .collect()
    }

    /// All violations in `cfg` (empty == valid).
    pub fn validate(&self, cfg: &Config) -> Vec<Violation> {
        let mut v = Vec::new();
        for p in &self.params {
            match cfg.get(&p.name) {
                None => v.push(Violation::Missing(p.name.clone())),
                Some(val) if !p.contains(val) => v.push(Violation::OutOfRange {
                    name: p.name.clone(),
                    got: format!("{val:?}"),
                }),
                _ => {}
            }
        }
        for k in cfg.keys() {
            if self.get(k).is_none() {
                v.push(Violation::UnknownKey(k.clone()));
            }
        }
        v
    }

    pub fn is_valid(&self, cfg: &Config) -> bool {
        self.validate(cfg).is_empty()
    }

    /// Clamp every value into range, fill missing with defaults, drop unknowns.
    pub fn repair(&self, cfg: &Config) -> Config {
        self.params
            .iter()
            .map(|p| {
                let v = cfg
                    .get(&p.name)
                    .map(|v| p.clamp(v))
                    .unwrap_or_else(|| p.default.clone());
                (p.name.clone(), v)
            })
            .collect()
    }

    /// Encode a config to the unit cube (GP / NSGA-II representation).
    pub fn encode(&self, cfg: &Config) -> Vec<f64> {
        self.params
            .iter()
            .map(|p| {
                cfg.get(&p.name)
                    .map(|v| p.encode(v).clamp(0.0, 1.0))
                    .unwrap_or(0.5)
            })
            .collect()
    }

    /// Decode a unit-cube point back to a valid config.
    pub fn decode(&self, u: &[f64]) -> Config {
        self.params
            .iter()
            .enumerate()
            .map(|(i, p)| (p.name.clone(), p.decode(u.get(i).copied().unwrap_or(0.5))))
            .collect()
    }

    /// Parse a JSON object (e.g. an agent reply) into a Config.  Unknown
    /// keys are preserved as violations at validate-time, not dropped here.
    pub fn config_from_json(&self, j: &Json) -> Config {
        let mut cfg = Config::new();
        if let Some(obj) = j.as_obj() {
            for (k, v) in obj {
                let val = match v {
                    Json::Num(x) => {
                        // ints stay ints when the param says so
                        match self.get(k).map(|p| &p.kind) {
                            Some(super::param::ParamKind::Int { .. }) => {
                                Value::Int(x.round() as i64)
                            }
                            _ => Value::Float(*x),
                        }
                    }
                    Json::Str(s) => Value::Cat(s.clone()),
                    Json::Bool(b) => Value::Cat(b.to_string()),
                    _ => continue,
                };
                cfg.insert(k.clone(), val);
            }
        }
        cfg
    }

    pub fn config_to_json(&self, cfg: &Config) -> Json {
        // Emit in declared parameter order (prompt readability).
        let mut pairs = Vec::new();
        for p in &self.params {
            if let Some(v) = cfg.get(&p.name) {
                pairs.push((p.name.clone(), v.to_json()));
            }
        }
        Json::from_pairs(pairs)
    }

    /// Rebuild a Space from the JSON emitted by `agent::prompt::space_json`
    /// (the simulated backend reconstructs the space from CONTEXT_JSON, the
    /// same information a real LLM reads from the prose).
    pub fn from_json(name: &str, j: &Json) -> anyhow::Result<Space> {
        use super::param::{Param, ParamKind};
        let mut params = Vec::new();
        for item in j.as_arr().unwrap_or(&[]) {
            let pname = item.req_str("name")?;
            let kind = match item.req_str("type")? {
                "float" => ParamKind::Float {
                    lo: item.req_f64("lo")?,
                    hi: item.req_f64("hi")?,
                    log: item.get("log").and_then(|v| v.as_bool()).unwrap_or(false),
                },
                "int" => ParamKind::Int {
                    lo: item.req_f64("lo")? as i64,
                    hi: item.req_f64("hi")? as i64,
                    log: item.get("log").and_then(|v| v.as_bool()).unwrap_or(false),
                },
                "cat" => ParamKind::Cat {
                    choices: item
                        .req_arr("choices")?
                        .iter()
                        .filter_map(|c| c.as_str().map(|s| s.to_string()))
                        .collect(),
                },
                other => anyhow::bail!("unknown param type '{other}'"),
            };
            let default = match (&kind, item.req("default")?) {
                (ParamKind::Int { .. }, Json::Num(x)) => Value::Int(x.round() as i64),
                (_, Json::Num(x)) => Value::Float(*x),
                (_, Json::Str(s)) => Value::Cat(s.clone()),
                _ => anyhow::bail!("bad default for '{pname}'"),
            };
            params.push(Param {
                name: pname.to_string(),
                kind,
                default,
                help: String::new(),
            });
        }
        Ok(Space::new(name, params))
    }

    /// Human-readable search-space description for the static prompt
    /// (mirrors the paper's Appendix E formatting).
    pub fn describe(&self) -> String {
        let mut s = String::new();
        for p in &self.params {
            let (ty, range, log) = match &p.kind {
                super::param::ParamKind::Float { lo, hi, log } => (
                    "UniformFloat",
                    format!("[{lo}, {hi}]"),
                    *log,
                ),
                super::param::ParamKind::Int { lo, hi, log } => (
                    "UniformInteger",
                    format!("[{lo}, {hi}]"),
                    *log,
                ),
                super::param::ParamKind::Cat { choices } => (
                    "Categorical",
                    format!("{{{}}}", choices.join(", ")),
                    false,
                ),
            };
            s.push_str(&format!(
                "'{}': {}. Type: {}, Range: {}, Default: {:?}{}\n",
                p.name,
                p.help,
                ty,
                range,
                p.default,
                if log { ", Log scale" } else { "" }
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::param::Param;

    fn space() -> Space {
        Space::new(
            "t",
            vec![
                Param::log_float("lr", 1e-5, 0.2, 0.01, "learning rate"),
                Param::int("batch_size", 32, 256, 128, "batch"),
                Param::cat("layout", &["row", "col"], "row", "layout"),
            ],
        )
    }

    #[test]
    fn sample_validates() {
        let s = space();
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            assert!(s.is_valid(&s.sample(&mut rng)));
        }
    }

    #[test]
    fn validate_reports_all_failure_modes() {
        let s = space();
        let mut cfg = s.default_config();
        cfg.insert("lr".into(), Value::Float(5.0)); // out of range
        cfg.remove("batch_size"); // missing
        cfg.insert("bogus".into(), Value::Int(1)); // unknown
        let v = s.validate(&cfg);
        assert_eq!(v.len(), 3, "{v:?}");
    }

    #[test]
    fn repair_produces_valid() {
        let s = space();
        let mut cfg = Config::new();
        cfg.insert("lr".into(), Value::Float(99.0));
        cfg.insert("layout".into(), Value::Cat("diag".into()));
        let r = s.repair(&cfg);
        assert!(s.is_valid(&r), "{r:?}");
    }

    #[test]
    fn encode_decode_roundtrip() {
        let s = space();
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let cfg = s.sample(&mut rng);
            let u = s.encode(&cfg);
            let back = s.decode(&u);
            assert!(s.is_valid(&back));
            // floats should round-trip tightly
            let lr0 = cfg["lr"].as_f64();
            let lr1 = back["lr"].as_f64();
            assert!((lr0.ln() - lr1.ln()).abs() < 1e-9);
        }
    }

    #[test]
    fn json_roundtrip() {
        let s = space();
        let cfg = s.default_config();
        let j = s.config_to_json(&cfg);
        let back = s.config_from_json(&j);
        assert_eq!(cfg, back);
    }
}
