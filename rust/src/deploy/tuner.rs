//! Per-kernel execution-config tuning (paper Table 3 track).
//!
//! Two evaluation paths:
//! * [`KernelTuner`] — the simulated A6000/Adreno path: any `kernel_exec`
//!   configuration is scored by the hardware latency model (10 averaged
//!   noisy measurements, like the paper's protocol);
//! * [`PallasTuner`] — the real-artifact path: the qmatmul tile-schedule
//!   variants AOT'd by `aot.py` are executed on the PJRT CPU client and
//!   timed for real (the TPU-analogue demo of the same loop; DESIGN.md
//!   §Hardware-Adaptation).

use std::collections::HashMap;

use anyhow::Result;

use crate::hardware::{DeviceProfile, ExecConfig, LatencyModel, Workload};
use crate::optimizers::{Observation, Optimizer};
use crate::runtime::{ArtifactSet, Tensor};
use crate::search::{Config, Space};
use crate::util::rng::Rng;

/// Averaged measurement count (paper §4.1: "each experiment is repeated 10
/// times and the average result is taken").
pub const REPEATS: usize = 10;

pub struct KernelTuner<'a> {
    pub profile: &'a DeviceProfile,
    pub workload: Workload,
    pub noise_seed: u64,
}

impl<'a> KernelTuner<'a> {
    /// The pre-calibrated latency model for this tuner's (workload,
    /// device).  Build it once and thread it through the free
    /// [`measure_with`] to amortize the calibration setup across
    /// measurements (that is what [`KernelEvaluator`] does).
    ///
    /// [`KernelEvaluator`]: crate::coordinator::evaluator::KernelEvaluator
    pub fn model(&self) -> LatencyModel {
        LatencyModel::new(self.workload, self.profile)
    }

    /// Mean simulated latency (µs) of an execution config.
    pub fn measure(&self, cfg: &Config) -> f64 {
        measure_with(&self.model(), self.noise_seed, cfg)
    }

    /// Measure a slice of configs against one model build — the batched
    /// path.
    pub fn measure_batch(&self, cfgs: &[Config]) -> Vec<f64> {
        let model = self.model();
        cfgs.iter().map(|c| measure_with(&model, self.noise_seed, c)).collect()
    }

    /// Drive an optimizer for `rounds`; score = −latency (maximized).
    pub fn tune(
        &self,
        opt: &mut dyn Optimizer,
        space: &Space,
        rounds: usize,
        rng: &mut Rng,
    ) -> Vec<Observation> {
        let mut history: Vec<Observation> = Vec::with_capacity(rounds);
        for _ in 0..rounds {
            let cfg = opt.propose(space, &history, rng);
            let lat = self.measure(&cfg);
            let mut obs = Observation::new(cfg, -lat);
            obs.feedback = format!("{{\"latency_us\": {lat:.3}}}");
            history.push(obs);
        }
        history
    }

    /// Best (config, latency µs) of a tuning trace.
    pub fn best(history: &[Observation]) -> (Config, f64) {
        let best = crate::optimizers::best(history).expect("non-empty history");
        (best.config.clone(), -best.score)
    }
}

/// One averaged measurement against a pre-built latency model: the paper's
/// 10-repeat protocol with the deterministic per-config noise stream
/// (seeded by the blockdim so distinct launch geometries see distinct
/// noise, exactly as the original per-call path did).
pub fn measure_with(model: &LatencyModel, noise_seed: u64, cfg: &Config) -> f64 {
    let exec = ExecConfig::from_config(cfg);
    let mut rng = Rng::new(noise_seed).split(exec.blockdim as u64);
    let mut acc = 0.0;
    for _ in 0..REPEATS {
        acc += model.latency_us(&exec, Some(&mut rng));
    }
    acc / REPEATS as f64
}

/// Real-latency tuning over the AOT'd Pallas tile variants.
pub struct PallasTuner<'a> {
    pub set: &'a ArtifactSet,
}

#[derive(Debug, Clone)]
pub struct PallasMeasurement {
    pub variant: String,
    pub tile: Vec<i64>,
    pub median_us: f64,
}

impl<'a> PallasTuner<'a> {
    /// Measure every `micro_matmul_b64_*` tile variant on the PJRT CPU
    /// client; returns measurements sorted fastest-first.
    pub fn measure_variants(&self, iters: usize) -> Result<Vec<PallasMeasurement>> {
        let mut out = Vec::new();
        let mut rng = Rng::new(0xbe);
        for art in self.set.family("micro") {
            if !art.name.starts_with("micro_matmul_b64_") {
                continue;
            }
            let exec = self.set.executor(&art.name)?;
            let mut named: HashMap<&str, Tensor> = HashMap::new();
            for spec in &art.inputs {
                let mut t = Tensor::zeros(&spec.shape);
                rng.fill_uniform(&mut t.data);
                named.insert(spec.name.as_str(), t);
            }
            let args = exec.build_args(&[], &[], &named)?;
            // Warmup + timed runs.
            exec.run_raw(&args)?;
            let mut samples = Vec::with_capacity(iters);
            for _ in 0..iters {
                let t0 = std::time::Instant::now();
                exec.run_raw(&args)?;
                samples.push(t0.elapsed().as_secs_f64() * 1e6);
            }
            let tile = art
                .meta
                .get("tile")
                .and_then(|t| t.as_arr())
                .map(|a| a.iter().map(|v| v.as_i64().unwrap_or(0)).collect())
                .unwrap_or_default();
            out.push(PallasMeasurement {
                variant: art.name.clone(),
                tile,
                median_us: crate::util::stats::median(&samples),
            });
        }
        out.sort_by(|a, b| a.median_us.partial_cmp(&b.median_us).unwrap());
        Ok(out)
    }
}
