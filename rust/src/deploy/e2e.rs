//! End-to-end deployment throughput (paper Table 4 / Figure 5).
//!
//! Combines the §3.4 roofline token-time model with the kernel-level
//! execution-config penalty (matmul-dominated, per §4.3's "90% of inference
//! runtime"): `tokens/s = 1000 / (token_time_ms * config_penalty)`.

use crate::hardware::latency::e2e_config_penalty;
use crate::hardware::{adaptive, DeviceProfile, ExecConfig, ModelProfile};
use crate::quant::Scheme;

/// Simulated decode throughput for a model/scheme/device/exec-config.
pub fn tokens_per_sec(
    model: &ModelProfile,
    scheme: Scheme,
    dev: &DeviceProfile,
    exec: &ExecConfig,
) -> f64 {
    let base_ms = adaptive::token_time_ms(model, scheme, dev);
    1000.0 / (base_ms * e2e_config_penalty(dev, exec))
}

/// Figure 5 pair: (llama.cpp default, agent-tuned) throughput.
pub fn default_vs_tuned(
    model: &ModelProfile,
    scheme: Scheme,
    dev: &DeviceProfile,
    tuned: &ExecConfig,
) -> (f64, f64) {
    (
        tokens_per_sec(model, scheme, dev, &ExecConfig::llamacpp_default()),
        tokens_per_sec(model, scheme, dev, tuned),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::exec::MemHier;

    fn tuned() -> ExecConfig {
        ExecConfig {
            griddim: 256,
            blockdim: 128,
            tiling: 64,
            unroll: 4,
            simd_width: 16,
            row_major: true,
            transpose: false,
            prefetch: 8,
            memory_hierarchy: MemHier::Shared,
            loop_order: crate::hardware::exec::LoopOrder::Mnk,
        }
    }

    /// Figure 5's headline: agent-optimized 1.2-1.5x over defaults on the
    /// A6000, INT4 > INT8 > FP16 ordering.
    #[test]
    fn figure5_shape() {
        let dev = DeviceProfile::a6000();
        for m in ModelProfile::figure5_models() {
            let (d, t) = default_vs_tuned(&m, Scheme::INT4, &dev, &tuned());
            let speedup = t / d;
            assert!(
                (1.1..=1.8).contains(&speedup),
                "{}: speedup {speedup:.2}",
                m.name
            );
            let fp16 = tokens_per_sec(&m, Scheme::FP16, &dev, &tuned());
            let int8 = tokens_per_sec(&m, Scheme::INT8, &dev, &tuned());
            let int4 = tokens_per_sec(&m, Scheme::INT4, &dev, &tuned());
            assert!(int4 > int8 && int8 > fp16, "{}: {fp16} {int8} {int4}", m.name);
        }
    }

    /// Table 4's shape on mobile: INT8 >= FP16 > INT4.
    #[test]
    fn table4_shape() {
        let dev = DeviceProfile::adreno740();
        for m in ModelProfile::table4_models() {
            let fp16 = tokens_per_sec(&m, Scheme::FP16, &dev, &tuned());
            let int8 = tokens_per_sec(&m, Scheme::INT8, &dev, &tuned());
            let int4 = tokens_per_sec(&m, Scheme::INT4, &dev, &tuned());
            assert!(int8 > int4, "{}: int8 {int8} int4 {int4}", m.name);
            assert!(fp16 > int4, "{}: fp16 {fp16} int4 {int4}", m.name);
        }
    }

    /// Bigger models decode slower under every scheme.
    #[test]
    fn throughput_monotone_in_model_size() {
        let dev = DeviceProfile::a6000();
        let small = tokens_per_sec(
            &ModelProfile::llama32_3b(),
            Scheme::INT8,
            &dev,
            &ExecConfig::llamacpp_default(),
        );
        let big = tokens_per_sec(
            &ModelProfile::llama2_13b(),
            Scheme::INT8,
            &dev,
            &ExecConfig::llamacpp_default(),
        );
        assert!(small > big);
    }
}
