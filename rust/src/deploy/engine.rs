//! Token-generation engine — the llama.cpp analogue (DESIGN.md §2).
//!
//! Serves the tiny LM end-to-end on the PJRT CPU client: the decode-step
//! artifact (whose forward pass is built *entirely* from the Pallas
//! kernels) is executed once per generated token over a sliding context
//! window.  Latency is measured for real; the qmatmul tile schedule is
//! selectable per the AOT'd variants, which is the deployment tunable.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::{ArtifactSet, Tensor};
use crate::trainer::data::{SEQ, VOCAB};
use crate::trainer::lm::R_MAX;

pub struct TokenEngine<'a> {
    set: &'a ArtifactSet,
    /// Decode artifact name (`lm_decode_default` or a tile variant).
    pub artifact: String,
    /// frozen inputs: base ++ lora in manifest order.
    frozen: Vec<Tensor>,
    pub bits: f32,
    rank_mask: Tensor,
    lora_scale: f32,
}

#[derive(Debug, Clone)]
pub struct GenerationStats {
    pub tokens: Vec<usize>,
    pub per_token_us: Vec<f64>,
}

impl GenerationStats {
    pub fn tokens_per_sec(&self) -> f64 {
        let total_s: f64 = self.per_token_us.iter().sum::<f64>() / 1e6;
        self.tokens.len() as f64 / total_s.max(1e-12)
    }

    pub fn median_token_us(&self) -> f64 {
        crate::util::stats::median(&self.per_token_us)
    }
}

impl<'a> TokenEngine<'a> {
    pub fn new(
        set: &'a ArtifactSet,
        artifact: &str,
        base: &[Tensor],
        lora: &[Tensor],
        bits: f32,
        lora_r: usize,
        lora_alpha: f64,
    ) -> Result<TokenEngine<'a>> {
        let mut frozen = Vec::with_capacity(base.len() + lora.len());
        frozen.extend_from_slice(base);
        frozen.extend_from_slice(lora);
        let mut rank_mask = Tensor::zeros(&[R_MAX]);
        for i in 0..lora_r.min(R_MAX) {
            rank_mask.data[i] = 1.0;
        }
        Ok(TokenEngine {
            set,
            artifact: artifact.to_string(),
            frozen,
            bits,
            rank_mask,
            lora_scale: (lora_alpha / lora_r.max(1) as f64) as f32,
        })
    }

    /// Greedy-decode `n_tokens` continuations of `prompt` (token ids),
    /// timing each decode step.
    ///
    /// The decode loop is allocation-free per token: the `[1, SEQ, VOCAB]`
    /// one-hot buffer, the rank mask and the scalar inputs are built once
    /// and the buffer is updated incrementally — clear the SEQ slots that
    /// are set, slide the window, set the SEQ new slots — instead of
    /// reallocating and re-zeroing SEQ×VOCAB floats every step.
    pub fn generate(&self, prompt: &[usize], n_tokens: usize) -> Result<GenerationStats> {
        let exec = self.set.executor(&self.artifact)?;
        let mut window: Vec<usize> = vec![0; SEQ];
        let start = SEQ.saturating_sub(prompt.len());
        for (i, &t) in prompt.iter().rev().take(SEQ).rev().enumerate() {
            window[start + i] = t % VOCAB;
        }
        let mut x = Tensor::zeros(&[1, SEQ, VOCAB]);
        for (t, &id) in window.iter().enumerate() {
            x.data[t * VOCAB + id] = 1.0;
        }
        let mut named: HashMap<&str, Tensor> = HashMap::new();
        named.insert("tokens", x);
        named.insert("rank_mask", self.rank_mask.clone());
        named.insert("bits", Tensor::scalar(self.bits));
        named.insert("lora_scale", Tensor::scalar(self.lora_scale));
        let mut tokens = Vec::with_capacity(n_tokens);
        let mut per_token_us = Vec::with_capacity(n_tokens);
        for _ in 0..n_tokens {
            let t0 = Instant::now();
            let (_, out) = exec.step(Vec::new(), &self.frozen, &named)?;
            per_token_us.push(t0.elapsed().as_secs_f64() * 1e6);
            let logits = &out[0]; // (V,)
            let next = logits.argmax_last()[0];
            tokens.push(next);
            let x = named.get_mut("tokens").expect("tokens buffer");
            for (t, &id) in window.iter().enumerate() {
                x.data[t * VOCAB + id] = 0.0;
            }
            window.rotate_left(1);
            window[SEQ - 1] = next;
            for (t, &id) in window.iter().enumerate() {
                x.data[t * VOCAB + id] = 1.0;
            }
        }
        Ok(GenerationStats {
            tokens,
            per_token_us,
        })
    }
}
