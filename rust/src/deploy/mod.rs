//! Deployment side: kernel tuning, the token-generation engine (llama.cpp
//! analogue over PJRT), and end-to-end throughput aggregation.

pub mod engine;
pub mod e2e;
pub mod tuner;

pub use engine::TokenEngine;
pub use tuner::KernelTuner;
