//! Zero-dependency substrates.
//!
//! The build image is offline (only the `xla` crate closure is vendored),
//! so the pieces a framework would normally pull from crates.io are
//! implemented here: a JSON parser/writer, a seeded RNG family, descriptive
//! statistics, a CLI argument parser, a markdown/CSV table renderer, a
//! micro-benchmark harness (criterion stand-in) and a miniature
//! property-testing library used by the test suite.

pub mod bench;
pub mod cli;
pub mod hash;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;

/// Format a float with engineering-friendly precision (tables/logs).
pub fn fmt_sig(x: f64, digits: usize) -> String {
    if x == 0.0 || !x.is_finite() {
        return format!("{x}");
    }
    let mag = x.abs().log10().floor() as i32;
    let dec = (digits as i32 - 1 - mag).max(0) as usize;
    format!("{:.*}", dec, x)
}
