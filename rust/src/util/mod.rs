//! Zero-dependency substrates.
//!
//! The build image is offline (only the `xla` crate closure is vendored),
//! so the pieces a framework would normally pull from crates.io are
//! implemented here: a JSON parser/writer, a seeded RNG family, descriptive
//! statistics, a CLI argument parser, a markdown/CSV table renderer, a
//! micro-benchmark harness (criterion stand-in) and a miniature
//! property-testing library used by the test suite.

pub mod bench;
pub mod cli;
pub mod hash;
pub mod json;
pub mod jsonl;
pub mod knob;
pub mod proptest;
pub mod retry;
pub mod rng;
pub mod stats;
pub mod table;

/// Poison-tolerant mutex lock, shared by every module that holds state
/// behind a `Mutex` (agent backends, caches, fleet slots): a worker that
/// panicked mid-operation cannot corrupt single-statement updates, so the
/// guard is recovered instead of propagating poison.
pub fn lock<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Render a caught panic payload for error reporting (fleet worker
/// isolation, backend dispatcher threads).
pub fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".into()
    }
}

/// Format a float with engineering-friendly precision (tables/logs).
pub fn fmt_sig(x: f64, digits: usize) -> String {
    if x == 0.0 || !x.is_finite() {
        return format!("{x}");
    }
    let mag = x.abs().log10().floor() as i32;
    let dec = (digits as i32 - 1 - mag).max(0) as usize;
    format!("{:.*}", dec, x)
}
