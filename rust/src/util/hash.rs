//! Deterministic content hashing for cache keys.
//!
//! The evaluation cache is content-addressed: the key is a hash of the
//! canonical-JSON rendering of (track, scenario knobs, configuration), so
//! the same evaluation requested from any round, method sweep, bench table
//! or worker thread maps to the same entry.  Two independent FNV-1a lanes
//! are combined into a 128-bit digest — pure Rust, no crates, stable across
//! platforms and runs (never hash pointer or iteration-order dependent
//! data; canonicalize first).

/// FNV-1a over `bytes` from an explicit basis (64-bit lane).
pub fn fnv1a64(bytes: &[u8], basis: u64) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = basis;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// The standard FNV-1a 64 offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// 128-bit content digest: two decorrelated FNV-1a lanes plus a
/// length-mixed term so prefixes of each other cannot collide trivially.
pub fn content_hash_128(bytes: &[u8]) -> u128 {
    let lo = fnv1a64(bytes, FNV_OFFSET);
    let hi = fnv1a64(bytes, FNV_OFFSET ^ 0x9e37_79b9_7f4a_7c15)
        .wrapping_add((bytes.len() as u64).wrapping_mul(0xbf58_476d_1ce4_e5b9));
    ((hi as u128) << 64) | lo as u128
}

/// Hex rendering of a 128-bit digest (log/debug output and the persistent
/// cache-journal key field).
pub fn hex128(h: u128) -> String {
    format!("{h:032x}")
}

/// Inverse of [`hex128`]: parse a lowercase/uppercase hex digest of at most
/// 32 digits.  Returns `None` for empty, overlong or non-hex input — the
/// cache-journal loader treats that as a corrupt record.
pub fn parse_hex128(s: &str) -> Option<u128> {
    if s.is_empty() || s.len() > 32 {
        return None;
    }
    u128::from_str_radix(s, 16).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_across_calls() {
        let a = content_hash_128(b"track\n{\"a\":1}\n{\"lr\":0.01}");
        let b = content_hash_128(b"track\n{\"a\":1}\n{\"lr\":0.01}");
        assert_eq!(a, b);
    }

    #[test]
    fn sensitive_to_any_byte() {
        let base = content_hash_128(b"kernel\n{\"batch\":64}");
        assert_ne!(base, content_hash_128(b"kernel\n{\"batch\":65}"));
        assert_ne!(base, content_hash_128(b"kernel\n{\"batch\":64} "));
        assert_ne!(base, content_hash_128(b""));
    }

    #[test]
    fn lanes_decorrelated() {
        // lo and hi lanes must not be equal for ordinary inputs.
        let h = content_hash_128(b"haqa");
        assert_ne!((h >> 64) as u64, h as u64);
        assert_eq!(hex128(h).len(), 32);
    }

    #[test]
    fn hex128_round_trips() {
        for h in [0u128, 1, u128::MAX, content_hash_128(b"haqa")] {
            assert_eq!(parse_hex128(&hex128(h)), Some(h));
        }
        assert_eq!(parse_hex128("2a"), Some(0x2a), "short forms accepted");
        assert_eq!(parse_hex128(""), None);
        assert_eq!(parse_hex128("zz"), None);
        assert_eq!(parse_hex128(&"f".repeat(33)), None, "overlong rejected");
    }
}
