//! Unified CLI/env knob resolution.
//!
//! Every numeric tuning knob in the CLI follows one contract, stated in
//! docs/ARCHITECTURE.md and previously re-implemented five times across
//! the coordinator (`workers_from_env`, `cap_from_env`, `batch_from_env`,
//! the retries and queue-cap parsers):
//!
//! * **CLI wins over env.**  An explicit flag value is taken verbatim —
//!   the environment is only consulted when the flag is absent.
//! * **Garbage is a hard error, never a silent default.**  An env value
//!   that does not parse fails the run with
//!   `"{ENV} must be {noun}, got '{value}'"` — the seed behavior of
//!   falling back to the default turned typos into mis-sized fleets.
//! * **Zero is a hard error where zero cannot mean anything.**  Knobs
//!   whose zero value could only be a typo (batch size, cache capacity,
//!   queue cap) reject it with a knob-specific message pointing at the
//!   way to actually turn the feature off.
//!
//! [`Knob`] carries the env-var name, the noun used in the error message,
//! and the parser; call sites keep their own defaults and clamps, which
//! differ per knob.  The public `*_from_env` functions on
//! [`FleetRunner`](crate::coordinator::FleetRunner),
//! [`EvalCache`](crate::coordinator::EvalCache) and the serve CLI are thin
//! delegations onto this module, so their pinned messages — asserted by
//! tests — come from exactly one format string.

use anyhow::{anyhow, Result};

/// One CLI/env knob: where it reads from and how a raw string becomes a
/// value.  See the module docs for the resolution contract.
pub struct Knob<T> {
    /// Environment variable consulted when the CLI flag is absent.
    env: &'static str,
    /// How the error message names the expected value ("a positive
    /// integer", "a non-negative integer", …).
    noun: &'static str,
    /// Raw string → value; `None` means unparseable (a hard error).
    parse: fn(&str) -> Option<T>,
}

impl<T> Knob<T> {
    /// A knob reading `env` with `parse`, erroring as
    /// `"{env} must be {noun}, got '…'"` on garbage.
    pub fn new(env: &'static str, noun: &'static str, parse: fn(&str) -> Option<T>) -> Knob<T> {
        Knob { env, noun, parse }
    }

    /// Resolve: the CLI value verbatim when present, else the env var
    /// (garbage is a hard error), else `None` — the caller supplies the
    /// default and any clamping.
    pub fn get(&self, cli: Option<T>) -> Result<Option<T>> {
        if let Some(n) = cli {
            return Ok(Some(n));
        }
        match std::env::var(self.env) {
            Ok(v) => match (self.parse)(&v) {
                Some(n) => Ok(Some(n)),
                None => Err(anyhow!("{} must be {}, got '{v}'", self.env, self.noun)),
            },
            Err(_) => Ok(None),
        }
    }
}

/// The whitespace-tolerant integer parser every counter knob shares.
fn parse_usize(s: &str) -> Option<usize> {
    s.trim().parse().ok()
}

impl Knob<usize> {
    /// An integer-valued knob (the common case): trims whitespace, parses
    /// as `usize`, hard-errors on anything else.
    pub fn counter(env: &'static str, noun: &'static str) -> Knob<usize> {
        Knob::new(env, noun, parse_usize)
    }

    /// [`Knob::get`] for knobs where 0 — from either source — is always a
    /// typo: rejects `Some(0)` with the knob-specific `zero_msg` (which
    /// should name how the feature is actually turned off).
    pub fn require_nonzero(&self, cli: Option<usize>, zero_msg: &str) -> Result<Option<usize>> {
        match self.get(cli)? {
            Some(0) => Err(anyhow!("{zero_msg}")),
            other => Ok(other),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolution_order_and_messages_are_pinned() {
        // One test so the env mutation is serialized (house pattern for
        // every *_from_env test in the tree).  A dedicated variable keeps
        // it from racing the real knobs' tests.
        let knob = Knob::counter("HAQA_KNOB_SELFTEST", "a positive integer");

        // CLI wins without consulting the env at all.
        std::env::set_var("HAQA_KNOB_SELFTEST", "garbage");
        let cli = knob.get(Some(7));
        assert_eq!(cli.unwrap(), Some(7), "CLI value taken verbatim");

        // Garbage env is a hard error with the pinned message shape.
        let err = knob.get(None);
        let msg = format!("{:#}", err.expect_err("typo must not be swallowed"));
        assert_eq!(
            msg, "HAQA_KNOB_SELFTEST must be a positive integer, got 'garbage'",
            "the one shared format string"
        );

        // Whitespace-padded integers parse; absence resolves to None.
        std::env::set_var("HAQA_KNOB_SELFTEST", " 42 ");
        assert_eq!(knob.get(None).unwrap(), Some(42));
        std::env::remove_var("HAQA_KNOB_SELFTEST");
        assert_eq!(knob.get(None).unwrap(), None, "caller owns the default");

        // Zero-rejecting knobs surface the caller's message for both
        // sources; nonzero and absent pass through.
        assert_eq!(knob.require_nonzero(Some(3), "no zeros").unwrap(), Some(3));
        assert_eq!(knob.require_nonzero(None, "no zeros").unwrap(), None);
        let err = knob.require_nonzero(Some(0), "no zeros please");
        let msg = format!("{:#}", err.expect_err("zero is a typo"));
        assert_eq!(msg, "no zeros please");
        std::env::set_var("HAQA_KNOB_SELFTEST", "0");
        let err = knob.require_nonzero(None, "no zeros please");
        std::env::remove_var("HAQA_KNOB_SELFTEST");
        assert!(err.is_err(), "env zero is the same typo");
    }
}
