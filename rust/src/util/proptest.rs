//! Miniature property-testing library (proptest stand-in, offline image).
//!
//! `check(seed, cases, gen, prop)` runs `prop` over `cases` generated
//! inputs; on failure it performs a bounded greedy shrink using the
//! generator's `shrink` and panics with the minimal counterexample.  Used
//! throughout `tests/` for coordinator/optimizer/simulator invariants.

use super::rng::Rng;

/// A value generator with an optional shrinker.
pub trait Gen {
    type Value: std::fmt::Debug + Clone;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate "smaller" values; default: no shrinking.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run a property over `cases` random inputs.
pub fn check<G: Gen, P: Fn(&G::Value) -> Result<(), String>>(
    seed: u64,
    cases: usize,
    gen: &G,
    prop: P,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if let Err(msg) = prop(&v) {
            // Greedy bounded shrink.
            let mut best = v.clone();
            let mut best_msg = msg;
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in gen.shrink(&best) {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {seed}):\n  input: {best:?}\n  error: {best_msg}"
            );
        }
    }
}

/// Uniform f64 in [lo, hi].
pub struct F64Range(pub f64, pub f64);

impl Gen for F64Range {
    type Value = f64;
    fn generate(&self, rng: &mut Rng) -> f64 {
        rng.uniform(self.0, self.1)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mut out = Vec::new();
        if (*v - self.0).abs() > 1e-12 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2.0);
        }
        out
    }
}

/// Uniform integer in [lo, hi].
pub struct I64Range(pub i64, pub i64);

impl Gen for I64Range {
    type Value = i64;
    fn generate(&self, rng: &mut Rng) -> i64 {
        rng.int(self.0, self.1)
    }
    fn shrink(&self, v: &i64) -> Vec<i64> {
        let mut out = Vec::new();
        if *v != self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
        }
        out
    }
}

/// Vector of values from an element generator with length in [min_len, max_len].
pub struct VecGen<G> {
    pub elem: G,
    pub min_len: usize,
    pub max_len: usize,
}

impl<G: Gen> Gen for VecGen<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let len = self.min_len + rng.usize(self.max_len - self.min_len + 1);
        (0..len).map(|_| self.elem.generate(rng)).collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            out.push(v[..v.len() / 2.max(self.min_len)].to_vec());
            let mut shorter = v.clone();
            shorter.pop();
            out.push(shorter);
        }
        out
    }
}

/// Pair of independent generators.
pub struct PairGen<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairGen<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(1, 200, &F64Range(0.0, 1.0), |x| {
            if (0.0..=1.0).contains(x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(1, 50, &I64Range(0, 100), |x| {
            if *x < 95 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }

    #[test]
    fn vec_gen_respects_bounds() {
        let g = VecGen {
            elem: I64Range(0, 5),
            min_len: 2,
            max_len: 6,
        };
        check(2, 100, &g, |v| {
            if (2..=6).contains(&v.len()) {
                Ok(())
            } else {
                Err(format!("len {}", v.len()))
            }
        });
    }
}
