//! Append-only JSONL journal scanning — the one implementation of the
//! hygiene rules documented in `docs/CACHE.md`, shared by the evaluation
//! cache (load + compact) and the agent transcript journal:
//!
//! * one record per `\n`-terminated line;
//! * blank/whitespace-only lines are ignored (append-only tail healing
//!   writes them);
//! * corrupt lines — bad UTF-8, unparseable JSON, or records the caller's
//!   visitor rejects — are *skipped and counted*, never fatal;
//! * a newline-less tail is a torn final write from a crashed writer: it
//!   is skipped, counted, and reported so the caller can heal it by
//!   **appending** a newline (never by truncating — a concurrent writer
//!   may be mid-append).

use super::json::{self, Json};

/// What a scan observed besides the records it delivered.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct JsonlScan {
    /// Corrupt/truncated records skipped (including a torn tail).
    pub skipped: usize,
    /// The bytes end mid-record (no terminating newline).
    pub torn_tail: bool,
}

/// Walk every record, calling `visit(&json, raw_line)` for each line that
/// parses as JSON.  The visitor returns whether the record was valid for
/// its schema; `false` counts the line as skipped.
pub fn scan(bytes: &[u8], mut visit: impl FnMut(&Json, &str) -> bool) -> JsonlScan {
    let mut out = JsonlScan::default();
    let mut pos = 0usize;
    while pos < bytes.len() {
        let Some(off) = bytes[pos..].iter().position(|&b| b == b'\n') else {
            out.torn_tail = true;
            out.skipped += 1;
            break;
        };
        let end = pos + off;
        let line = &bytes[pos..end];
        if !line.iter().all(|b| b.is_ascii_whitespace()) {
            let ok = std::str::from_utf8(line)
                .ok()
                .and_then(|l| json::parse(l).ok().map(|j| (j, l)))
                .map(|(j, l)| visit(&j, l))
                .unwrap_or(false);
            if !ok {
                out.skipped += 1;
            }
        }
        pos = end + 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scans_skipping_blank_corrupt_and_torn_lines() {
        let bytes = b"{\"a\":1}\n\n   \nnot json\n{\"a\":2}\n{\"a\":3";
        let mut seen = Vec::new();
        let s = scan(bytes, |j, raw| {
            seen.push((j.req_f64("a").unwrap(), raw.to_string()));
            true
        });
        assert_eq!(seen.len(), 2, "{seen:?}");
        assert_eq!(seen[0].1, "{\"a\":1}");
        assert_eq!(s.skipped, 2, "corrupt line + torn tail");
        assert!(s.torn_tail);
    }

    #[test]
    fn visitor_rejection_counts_as_skipped() {
        let bytes = b"{\"a\":1}\n{\"b\":1}\n";
        let s = scan(bytes, |j, _| j.get("a").is_some());
        assert_eq!(s.skipped, 1);
        assert!(!s.torn_tail);
    }
}
