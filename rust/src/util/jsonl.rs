//! Append-only JSONL journal scanning — the one implementation of the
//! hygiene rules documented in `docs/CACHE.md`, shared by the evaluation
//! cache (load + compact) and the agent transcript journal:
//!
//! * one record per `\n`-terminated line;
//! * blank/whitespace-only lines are ignored (append-only tail healing
//!   writes them);
//! * corrupt lines — bad UTF-8, unparseable JSON, or records the caller's
//!   visitor rejects — are *skipped and counted*, never fatal;
//! * a newline-less tail is a torn final write from a crashed writer: it
//!   is skipped, counted, and reported so the caller can heal it by
//!   **appending** a newline (never by truncating — a concurrent writer
//!   may be mid-append).

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;

use super::json::{self, Json};

/// Open `path` for appending, healing a torn tail first: if the file
/// exists, is non-empty, and does not end in a newline (a crashed writer's
/// torn final record), a single `\n` is **appended** before returning —
/// never a truncation, because a concurrent writer sharing the journal may
/// be mid-append; if the torn view was just an in-flight append, the extra
/// newline lands as a blank line, which [`scan`] ignores.  This is the one
/// implementation of the append-open half of the hygiene rules, shared by
/// the eval-cache journal, the agent transcript journal and the device
/// measurement transcripts.
pub fn open_append_healed(path: &Path) -> std::io::Result<File> {
    let torn_tail = match OpenOptions::new().read(true).open(path) {
        Ok(mut f) => {
            let len = f.seek(SeekFrom::End(0))?;
            if len == 0 {
                false
            } else {
                f.seek(SeekFrom::End(-1))?;
                let mut last = [0u8; 1];
                f.read_exact(&mut last)?;
                last[0] != b'\n'
            }
        }
        Err(_) => false, // no file yet: nothing to heal
    };
    let mut file = OpenOptions::new().create(true).append(true).open(path)?;
    if torn_tail {
        file.write_all(b"\n")?;
    }
    Ok(file)
}

/// What a scan observed besides the records it delivered.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct JsonlScan {
    /// Corrupt/truncated records skipped (including a torn tail).
    pub skipped: usize,
    /// The bytes end mid-record (no terminating newline).
    pub torn_tail: bool,
}

/// Walk every record, calling `visit(&json, raw_line)` for each line that
/// parses as JSON.  The visitor returns whether the record was valid for
/// its schema; `false` counts the line as skipped.
pub fn scan(bytes: &[u8], mut visit: impl FnMut(&Json, &str) -> bool) -> JsonlScan {
    let mut out = JsonlScan::default();
    let mut pos = 0usize;
    while pos < bytes.len() {
        let Some(off) = bytes[pos..].iter().position(|&b| b == b'\n') else {
            out.torn_tail = true;
            out.skipped += 1;
            break;
        };
        let end = pos + off;
        let line = &bytes[pos..end];
        if !line.iter().all(|b| b.is_ascii_whitespace()) {
            let ok = std::str::from_utf8(line)
                .ok()
                .and_then(|l| json::parse(l).ok().map(|j| (j, l)))
                .map(|(j, l)| visit(&j, l))
                .unwrap_or(false);
            if !ok {
                out.skipped += 1;
            }
        }
        pos = end + 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scans_skipping_blank_corrupt_and_torn_lines() {
        let bytes = b"{\"a\":1}\n\n   \nnot json\n{\"a\":2}\n{\"a\":3";
        let mut seen = Vec::new();
        let s = scan(bytes, |j, raw| {
            seen.push((j.req_f64("a").unwrap(), raw.to_string()));
            true
        });
        assert_eq!(seen.len(), 2, "{seen:?}");
        assert_eq!(seen[0].1, "{\"a\":1}");
        assert_eq!(s.skipped, 2, "corrupt line + torn tail");
        assert!(s.torn_tail);
    }

    #[test]
    fn visitor_rejection_counts_as_skipped() {
        let bytes = b"{\"a\":1}\n{\"b\":1}\n";
        let s = scan(bytes, |j, _| j.get("a").is_some());
        assert_eq!(s.skipped, 1);
        assert!(!s.torn_tail);
    }

    #[test]
    fn open_append_healed_terminates_torn_tails_only() {
        let dir = std::env::temp_dir().join(format!("haqa_jsonl_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("j.jsonl");
        // Missing file: created empty, nothing appended.
        drop(open_append_healed(&path).unwrap());
        assert_eq!(std::fs::read(&path).unwrap(), b"");
        // Clean tail: untouched.
        std::fs::write(&path, b"{\"a\":1}\n").unwrap();
        drop(open_append_healed(&path).unwrap());
        assert_eq!(std::fs::read(&path).unwrap(), b"{\"a\":1}\n");
        // Torn tail: newline appended, never truncated.
        std::fs::write(&path, b"{\"a\":1}\n{\"torn").unwrap();
        drop(open_append_healed(&path).unwrap());
        assert_eq!(std::fs::read(&path).unwrap(), b"{\"a\":1}\n{\"torn\n");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
