//! Append-only JSONL journal scanning — the one implementation of the
//! hygiene rules documented in `docs/CACHE.md`, shared by the evaluation
//! cache (load + compact) and the agent transcript journal:
//!
//! * one record per `\n`-terminated line;
//! * blank/whitespace-only lines are ignored (append-only tail healing
//!   writes them);
//! * corrupt lines — bad UTF-8, unparseable JSON, or records the caller's
//!   visitor rejects — are *skipped and counted*, never fatal;
//! * a newline-less tail is a torn final write from a crashed writer: it
//!   is skipped, counted, and reported so the caller can heal it by
//!   **appending** a newline (never by truncating — a concurrent writer
//!   may be mid-append).

use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, Read, Seek, SeekFrom, Write};
use std::path::Path;

use super::json::{self, Json};

/// Open `path` for appending, healing a torn tail first: if the file
/// exists, is non-empty, and does not end in a newline (a crashed writer's
/// torn final record), a single `\n` is **appended** before returning —
/// never a truncation, because a concurrent writer sharing the journal may
/// be mid-append; if the torn view was just an in-flight append, the extra
/// newline lands as a blank line, which [`scan`] ignores.  This is the one
/// implementation of the append-open half of the hygiene rules, shared by
/// the eval-cache journal, the agent transcript journal and the device
/// measurement transcripts.
pub fn open_append_healed(path: &Path) -> std::io::Result<File> {
    let torn_tail = match OpenOptions::new().read(true).open(path) {
        Ok(mut f) => {
            let len = f.seek(SeekFrom::End(0))?;
            if len == 0 {
                false
            } else {
                f.seek(SeekFrom::End(-1))?;
                let mut last = [0u8; 1];
                f.read_exact(&mut last)?;
                last[0] != b'\n'
            }
        }
        Err(_) => false, // no file yet: nothing to heal
    };
    let mut file = OpenOptions::new().create(true).append(true).open(path)?;
    if torn_tail {
        file.write_all(b"\n")?;
    }
    Ok(file)
}

/// What a scan observed besides the records it delivered.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct JsonlScan {
    /// Corrupt/truncated records skipped (including a torn tail).
    pub skipped: usize,
    /// The bytes end mid-record (no terminating newline).
    pub torn_tail: bool,
}

/// Walk every record, calling `visit(&json, raw_line)` for each line that
/// parses as JSON.  The visitor returns whether the record was valid for
/// its schema; `false` counts the line as skipped.
pub fn scan(bytes: &[u8], mut visit: impl FnMut(&Json, &str) -> bool) -> JsonlScan {
    let mut out = JsonlScan::default();
    let mut pos = 0usize;
    while pos < bytes.len() {
        let Some(off) = bytes[pos..].iter().position(|&b| b == b'\n') else {
            out.torn_tail = true;
            out.skipped += 1;
            break;
        };
        let end = pos + off;
        let line = &bytes[pos..end];
        if !line.iter().all(|b| b.is_ascii_whitespace()) {
            let ok = std::str::from_utf8(line)
                .ok()
                .and_then(|l| json::parse(l).ok().map(|j| (j, l)))
                .map(|(j, l)| visit(&j, l))
                .unwrap_or(false);
            if !ok {
                out.skipped += 1;
            }
        }
        pos = end + 1;
    }
    out
}

/// Stream [`scan`] over a file without materializing it: records are read
/// one `read_until(b'\n')` line at a time through a `BufReader`, so loading
/// a multi-gigabyte journal costs one line of memory, not the whole file.
/// Skip/torn-tail semantics are identical to [`scan`] on the same bytes —
/// in particular a bad-UTF-8 line is *skipped*, never an I/O error, which
/// is why this reads raw bytes instead of `read_line` into a `String`.
pub fn scan_file(
    path: &Path,
    mut visit: impl FnMut(&Json, &str) -> bool,
) -> std::io::Result<JsonlScan> {
    let mut reader = BufReader::new(File::open(path)?);
    let mut out = JsonlScan::default();
    let mut buf: Vec<u8> = Vec::new();
    loop {
        buf.clear();
        if reader.read_until(b'\n', &mut buf)? == 0 {
            break;
        }
        let Some((&b'\n', line)) = buf.split_last() else {
            out.torn_tail = true;
            out.skipped += 1;
            break;
        };
        if line.iter().all(|b| b.is_ascii_whitespace()) {
            continue;
        }
        let ok = std::str::from_utf8(line)
            .ok()
            .and_then(|l| json::parse(l).ok().map(|j| (j, l)))
            .map(|(j, l)| visit(&j, l))
            .unwrap_or(false);
        if !ok {
            out.skipped += 1;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scans_skipping_blank_corrupt_and_torn_lines() {
        let bytes = b"{\"a\":1}\n\n   \nnot json\n{\"a\":2}\n{\"a\":3";
        let mut seen = Vec::new();
        let s = scan(bytes, |j, raw| {
            seen.push((j.req_f64("a").unwrap(), raw.to_string()));
            true
        });
        assert_eq!(seen.len(), 2, "{seen:?}");
        assert_eq!(seen[0].1, "{\"a\":1}");
        assert_eq!(s.skipped, 2, "corrupt line + torn tail");
        assert!(s.torn_tail);
    }

    #[test]
    fn visitor_rejection_counts_as_skipped() {
        let bytes = b"{\"a\":1}\n{\"b\":1}\n";
        let s = scan(bytes, |j, _| j.get("a").is_some());
        assert_eq!(s.skipped, 1);
        assert!(!s.torn_tail);
    }

    #[test]
    fn scan_file_matches_in_memory_scan() {
        // Blank lines, corruption, bad UTF-8 and a torn tail: the
        // streaming scanner must agree with `scan` on all of them.
        let mut bytes = b"{\"a\":1}\n\n   \nnot json\n".to_vec();
        bytes.extend_from_slice(&[0xFF, 0xFE, b'\n']); // invalid UTF-8 line
        bytes.extend_from_slice(b"{\"a\":2}\n{\"a\":3");
        let dir = std::env::temp_dir().join(format!("haqa_scanfile_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("j.jsonl");
        std::fs::write(&path, &bytes).unwrap();

        let mut mem = Vec::new();
        let s_mem = scan(&bytes, |j, _| {
            mem.push(j.req_f64("a").unwrap());
            true
        });
        let mut streamed = Vec::new();
        let s_file = scan_file(&path, |j, raw| {
            assert!(json::parse(raw).is_ok(), "raw line is handed through");
            streamed.push(j.req_f64("a").unwrap());
            true
        })
        .unwrap();
        assert_eq!(mem, streamed);
        assert_eq!(s_mem, s_file);
        assert!(s_file.torn_tail);
        assert_eq!(s_file.skipped, 3, "corrupt + bad-utf8 + torn tail");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_append_healed_terminates_torn_tails_only() {
        let dir = std::env::temp_dir().join(format!("haqa_jsonl_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("j.jsonl");
        // Missing file: created empty, nothing appended.
        drop(open_append_healed(&path).unwrap());
        assert_eq!(std::fs::read(&path).unwrap(), b"");
        // Clean tail: untouched.
        std::fs::write(&path, b"{\"a\":1}\n").unwrap();
        drop(open_append_healed(&path).unwrap());
        assert_eq!(std::fs::read(&path).unwrap(), b"{\"a\":1}\n");
        // Torn tail: newline appended, never truncated.
        std::fs::write(&path, b"{\"a\":1}\n{\"torn").unwrap();
        drop(open_append_healed(&path).unwrap());
        assert_eq!(std::fs::read(&path).unwrap(), b"{\"a\":1}\n{\"torn\n");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
