//! Deterministic RNG family (SplitMix64 core, PCG-style helpers).
//!
//! Every stochastic component in the repo (datasets, initializers, optimizer
//! sampling, simulated measurement noise) draws from seeded `Rng` instances,
//! so all tables/figures regenerate bit-identically.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zeros fixed point.
        Rng {
            state: seed ^ 0x9e37_79b9_7f4a_7c15,
        }
    }

    /// Derive an independent stream (hash-split), for per-component seeding.
    pub fn split(&self, tag: u64) -> Rng {
        let mut r = Rng::new(self.state.wrapping_add(tag.wrapping_mul(0xbf58_476d_1ce4_e5b9)));
        r.next_u64();
        r
    }

    pub fn next_u64(&mut self) -> u64 {
        // SplitMix64
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Log-uniform in [lo, hi) (lo > 0).
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        (self.uniform(lo.ln(), hi.ln())).exp()
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        let span = (hi - lo + 1) as u64;
        lo + (self.next_u64() % span) as i64
    }

    pub fn usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal (Box-Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize(items.len())]
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.usize(i + 1);
            items.swap(i, j);
        }
    }

    /// Fill a buffer with N(0, scale^2) f32 values.
    pub fn fill_normal(&mut self, buf: &mut [f32], scale: f32) {
        for v in buf.iter_mut() {
            *v = self.normal_f32() * scale;
        }
    }

    /// Fill a buffer with U[0,1) f32 values.
    pub fn fill_uniform(&mut self, buf: &mut [f32]) {
        for v in buf.iter_mut() {
            *v = self.f32();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let r = Rng::new(7);
        let mut a = r.split(1);
        let mut b = r.split(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.uniform(2.0, 5.0);
            assert!((2.0..5.0).contains(&x));
            let k = r.int(-3, 9);
            assert!((-3..=9).contains(&k));
        }
    }

    #[test]
    fn log_uniform_bounds() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            let x = r.log_uniform(1e-5, 0.2);
            assert!((1e-5..0.2001).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 20000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
