//! Descriptive statistics for benches and table generation.

#[derive(Debug, Clone, Copy, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1); 0 for n < 2.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (s[hi] - s[lo]) * (rank - lo as f64)
    }
}

/// Median absolute deviation (robust spread for latency benches).
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let m = median(xs);
    let dev: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&dev)
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    Summary {
        n: xs.len(),
        mean: mean(xs),
        std: std(xs),
        min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
        max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        median: median(xs),
    }
}

/// Best-so-far transform for convergence curves (maximization).
pub fn running_max(xs: &[f64]) -> Vec<f64> {
    let mut best = f64::NEG_INFINITY;
    xs.iter()
        .map(|&x| {
            best = best.max(x);
            best
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std(&xs) - 1.2909944487).abs() < 1e-6);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn percentile_interp() {
        let xs = [10.0, 20.0, 30.0, 40.0, 50.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 50.0);
        assert_eq!(percentile(&xs, 25.0), 20.0);
    }

    #[test]
    fn running_max_monotone() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0];
        assert_eq!(running_max(&xs), vec![3.0, 3.0, 4.0, 4.0, 5.0]);
    }

    #[test]
    fn mad_robust() {
        let xs = [1.0, 1.0, 1.0, 100.0];
        assert_eq!(mad(&xs), 0.0);
    }
}
