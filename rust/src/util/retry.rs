//! Bounded exponential-backoff retry — the one skeleton behind every
//! transport retry loop in the tree.
//!
//! Before this module the device-measurement client
//! (`coordinator::device`) and the HTTP agent backend (`agent::http`)
//! each hand-rolled the same loop: attempt, sleep `base * 2^(n-1)` capped
//! at a transport-specific ceiling, try again up to a bounded retry
//! count, and surface the last error with an `after N attempt(s)`
//! context.  Each call site keeps its own constants (the device client
//! retries connects with 100 ms base / 2 s cap; the HTTP client retries
//! connects, timeouts, 429 and 5xx with 250 ms base / 4 s cap) — only the
//! skeleton is shared, so the two policies can never drift apart
//! structurally while staying independently tuned.
//!
//! The scenario-level retry policy (`haqa fleet --retries`, see
//! [`crate::coordinator::fleet`]) reuses the same [`Backoff::delay_before`]
//! schedule for its between-attempt sleeps.

use std::time::Duration;

use anyhow::Result;

/// What one attempt of a retried operation produced.
pub enum Attempt<T> {
    /// The operation succeeded; stop retrying.
    Done(T),
    /// A transient failure — retry (with backoff) if the budget allows.
    Retry(anyhow::Error),
    /// A permanent failure — stop immediately, never burn retries on it.
    Fatal(anyhow::Error),
}

/// A bounded exponential-backoff policy: `retries` retries after the
/// first attempt, sleeping `base * 2^(n-1)` before retry `n`, capped at
/// `cap`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Backoff {
    /// Retries after the first attempt (0 = single attempt, no retry).
    pub retries: usize,
    /// First backoff delay; doubles per retry.
    pub base: Duration,
    /// Ceiling no backoff delay exceeds.
    pub cap: Duration,
}

impl Backoff {
    /// Build a policy (`const` so call sites can keep theirs in a const).
    pub const fn new(retries: usize, base: Duration, cap: Duration) -> Backoff {
        Backoff { retries, base, cap }
    }

    /// Total attempts this policy allows (`retries + 1`).
    pub fn attempts(&self) -> usize {
        self.retries + 1
    }

    /// The sleep before attempt `attempt` (0-based): `None` before the
    /// first attempt, else `base * 2^(attempt-1)` capped at `cap`.  The
    /// shift is saturated so absurd attempt counts cannot overflow.
    pub fn delay_before(&self, attempt: usize) -> Option<Duration> {
        if attempt == 0 {
            return None;
        }
        let exp = self.base.saturating_mul(1u32 << (attempt - 1).min(16));
        Some(exp.min(self.cap))
    }

    /// Drive `op` under this policy: sleep per [`Backoff::delay_before`],
    /// call `op(attempt)`, and keep going while it answers
    /// [`Attempt::Retry`] and the budget lasts.  [`Attempt::Fatal`] stops
    /// immediately.  Every error exit carries an `after N attempt(s)`
    /// context where `N` counts the attempts actually made — so a fatal
    /// first-attempt failure reads `after 1 attempt(s)`, and an exhausted
    /// retry budget reads `after retries+1 attempt(s)` exactly as the two
    /// pre-existing hand-rolled loops reported it.
    pub fn run<T>(&self, mut op: impl FnMut(usize) -> Attempt<T>) -> Result<T> {
        let mut last_err: Option<anyhow::Error> = None;
        let mut made = 0usize;
        for attempt in 0..=self.retries {
            if let Some(d) = self.delay_before(attempt) {
                std::thread::sleep(d);
            }
            made = attempt + 1;
            match op(attempt) {
                Attempt::Done(v) => return Ok(v),
                Attempt::Retry(e) => last_err = Some(e),
                Attempt::Fatal(e) => {
                    last_err = Some(e);
                    break;
                }
            }
        }
        Err(last_err
            .unwrap_or_else(|| anyhow::anyhow!("unreachable: no attempt ran"))
            .context(format!("after {made} attempt(s)")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FAST: Backoff = Backoff::new(3, Duration::from_millis(1), Duration::from_millis(4));

    #[test]
    fn delay_schedule_doubles_and_caps() {
        let b = Backoff::new(5, Duration::from_millis(100), Duration::from_secs(2));
        assert_eq!(b.delay_before(0), None, "no sleep before the first try");
        assert_eq!(b.delay_before(1), Some(Duration::from_millis(100)));
        assert_eq!(b.delay_before(2), Some(Duration::from_millis(200)));
        assert_eq!(b.delay_before(3), Some(Duration::from_millis(400)));
        // … doubling forever would overflow; the cap bounds it.
        assert_eq!(b.delay_before(5), Some(Duration::from_millis(1600)));
        assert_eq!(b.delay_before(6), Some(Duration::from_secs(2)));
        assert_eq!(b.delay_before(500), Some(Duration::from_secs(2)), "shift saturates");
        assert_eq!(b.attempts(), 6);
    }

    #[test]
    fn schedule_matches_the_historical_device_and_http_loops() {
        // The two call sites this module deduplicates kept these exact
        // constants; their per-retry sleeps must be reproduced bit-for-bit.
        let device = Backoff::new(2, Duration::from_millis(100), Duration::from_secs(2));
        assert_eq!(device.delay_before(1), Some(Duration::from_millis(100)));
        assert_eq!(device.delay_before(2), Some(Duration::from_millis(200)));
        let http = Backoff::new(3, Duration::from_millis(250), Duration::from_secs(4));
        assert_eq!(http.delay_before(1), Some(Duration::from_millis(250)));
        assert_eq!(http.delay_before(2), Some(Duration::from_millis(500)));
        assert_eq!(http.delay_before(3), Some(Duration::from_millis(1000)));
    }

    #[test]
    fn succeeds_after_transient_failures() {
        let mut calls = 0;
        let v = FAST
            .run(|attempt| {
                calls += 1;
                if attempt < 2 {
                    Attempt::Retry(anyhow::anyhow!("transient #{attempt}"))
                } else {
                    Attempt::Done(attempt)
                }
            })
            .unwrap();
        assert_eq!(v, 2);
        assert_eq!(calls, 3);
    }

    #[test]
    fn exhaustion_reports_total_attempts() {
        let mut calls = 0;
        let err = FAST
            .run::<()>(|_| {
                calls += 1;
                Attempt::Retry(anyhow::anyhow!("still down"))
            })
            .unwrap_err();
        assert_eq!(calls, 4, "retries + 1 attempts");
        let msg = format!("{err:#}");
        assert!(msg.contains("after 4 attempt(s)"), "{msg}");
        assert!(msg.contains("still down"), "{msg}");
    }

    #[test]
    fn fatal_stops_immediately_and_counts_honestly() {
        let mut calls = 0;
        let err = FAST
            .run::<()>(|_| {
                calls += 1;
                Attempt::Fatal(anyhow::anyhow!("bad request"))
            })
            .unwrap_err();
        assert_eq!(calls, 1, "fatal errors never burn retries");
        let msg = format!("{err:#}");
        assert!(msg.contains("after 1 attempt(s)"), "{msg}");
    }

    #[test]
    fn zero_retry_policy_is_a_single_attempt() {
        let b = Backoff::new(0, Duration::from_millis(1), Duration::from_millis(1));
        let mut calls = 0;
        let err = b
            .run::<()>(|_| {
                calls += 1;
                Attempt::Retry(anyhow::anyhow!("down"))
            })
            .unwrap_err();
        assert_eq!(calls, 1);
        assert!(format!("{err:#}").contains("after 1 attempt(s)"));
    }
}
