//! Minimal JSON parser + writer (serde stand-in, offline image).
//!
//! Supports the full JSON grammar; objects preserve insertion order (the
//! agent emits configs whose key order mirrors the prompt's search-space
//! order, which keeps transcripts reproducible).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors ---------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn from_pairs(pairs: Vec<(String, Json)>) -> Json {
        Json::Obj(pairs)
    }

    /// Shorthand string constructor (`Json::str("x")` instead of
    /// `Json::Str("x".to_string())`) — the cache journal and report
    /// writers build many small objects.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---- accessors -------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn set(&mut self, key: &str, value: Json) {
        if let Json::Obj(kv) = self {
            if let Some(slot) = kv.iter_mut().find(|(k, _)| k == key) {
                slot.1 = value;
            } else {
                kv.push((key.to_string(), value));
            }
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x.round() as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Required-field helpers (errors instead of panics).
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("json key '{key}' is not a string"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("json key '{key}' is not a number"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("json key '{key}' is not an array"))
    }

    /// Convert an object to a map for order-independent comparisons.
    pub fn to_map(&self) -> BTreeMap<String, Json> {
        match self {
            Json::Obj(kv) => kv.iter().cloned().collect(),
            _ => BTreeMap::new(),
        }
    }

    // ---- serialization ---------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !kv.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Canonical JSON for content-addressed hashing (the deterministic
/// cache-key spec): object keys sorted lexicographically, no whitespace,
/// minimal number representation.  Array order is preserved (it is
/// semantic).  Two `Json` values that differ only in object key order
/// canonicalize identically.
pub fn canonical(v: &Json) -> String {
    let mut s = String::new();
    write_canonical(v, &mut s);
    s
}

fn write_canonical(v: &Json, out: &mut String) {
    match v {
        Json::Obj(kv) => {
            let mut idx: Vec<usize> = (0..kv.len()).collect();
            idx.sort_by(|&a, &b| kv[a].0.cmp(&kv[b].0));
            out.push('{');
            for (n, &i) in idx.iter().enumerate() {
                if n > 0 {
                    out.push(',');
                }
                write_escaped(out, &kv[i].0);
                out.push(':');
                write_canonical(&kv[i].1, out);
            }
            out.push('}');
        }
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_canonical(x, out);
            }
            out.push(']');
        }
        other => other.write(out, None, 0),
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- parsing ---------------------------------------------------------------

pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

/// Extract the first JSON object embedded in free text (the agent's replies
/// wrap configurations in prose, exactly like the paper's GPT-4 transcripts).
pub fn extract_object(text: &str) -> Option<Json> {
    let bytes = text.as_bytes();
    let mut start = 0;
    while let Some(off) = text[start..].find('{') {
        let begin = start + off;
        // Find the matching close brace, respecting strings.
        let mut depth = 0usize;
        let mut in_str = false;
        let mut esc = false;
        for (j, &c) in bytes[begin..].iter().enumerate() {
            if in_str {
                if esc {
                    esc = false;
                } else if c == b'\\' {
                    esc = true;
                } else if c == b'"' {
                    in_str = false;
                }
                continue;
            }
            match c {
                b'"' => in_str = true,
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        let cand = &text[begin..=begin + j];
                        if let Ok(v) = parse(cand) {
                            return Some(v);
                        }
                        break;
                    }
                }
                _ => {}
            }
        }
        start = begin + 1;
    }
    None
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.i,
        }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let text = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            kv.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\n"], "c": {"d": -2.5e3}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.req_f64("a").unwrap(), 1.0);
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z": 1, "a": 2}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.clone()).collect();
        assert_eq!(keys, vec!["z", "a"]);
    }

    #[test]
    fn extract_from_prose() {
        let text = "Thought: lr too high.\nHere is the config: \
                    {\"learning_rate\": 0.004, \"batch_size\": 170} — done.";
        let v = extract_object(text).unwrap();
        assert_eq!(v.req_f64("learning_rate").unwrap(), 0.004);
    }

    #[test]
    fn extract_skips_invalid_prefix() {
        let text = "weird {not json} but {\"k\": [1,2]} ok";
        let v = extract_object(text).unwrap();
        assert_eq!(v.req_arr("k").unwrap().len(), 2);
    }

    #[test]
    fn canonical_is_key_order_independent() {
        let a = parse(r#"{"b": 1, "a": {"z": [1, 2], "y": 0.5}}"#).unwrap();
        let b = parse(r#"{ "a": {"y": 0.5, "z": [1,2]}, "b": 1 }"#).unwrap();
        assert_eq!(canonical(&a), canonical(&b));
        assert_eq!(canonical(&a), r#"{"a":{"y":0.5,"z":[1,2]},"b":1}"#);
        // Array order is semantic and must NOT be normalized away.
        let c = parse(r#"{"a": {"z": [2, 1], "y": 0.5}, "b": 1}"#).unwrap();
        assert_ne!(canonical(&a), canonical(&c));
    }

    #[test]
    fn shorthand_constructors() {
        let mut o = Json::obj();
        o.set("name", Json::str("x"));
        o.set("owned", Json::str(String::from("y")));
        assert_eq!(o.to_string(), r#"{"name":"x","owned":"y"}"#);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{\"a\": }").is_err());
        assert!(parse("[1,,2]").is_err());
        assert!(parse("").is_err());
    }
}
