//! Micro-benchmark harness (criterion stand-in, offline image).
//!
//! All `benches/*.rs` binaries are `harness = false` and use this module:
//! warmup, timed iterations, robust summary (median / MAD / mean ± std),
//! and a uniform one-line report so `cargo bench` output is diffable.

use std::time::Instant;

use super::stats;

#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            iters: 10,
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples_us: Vec<f64>,
}

impl BenchResult {
    pub fn median_us(&self) -> f64 {
        stats::median(&self.samples_us)
    }

    pub fn mad_us(&self) -> f64 {
        stats::mad(&self.samples_us)
    }

    pub fn mean_us(&self) -> f64 {
        stats::mean(&self.samples_us)
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} median {:>10.2} µs  mad {:>8.2} µs  mean {:>10.2} µs  (n={})",
            self.name,
            self.median_us(),
            self.mad_us(),
            self.mean_us(),
            self.samples_us.len(),
        )
    }
}

/// Time `f` (already including any per-iteration setup) `cfg.iters` times
/// after warmup; returns per-iteration wall time in microseconds.
pub fn bench<F: FnMut()>(name: &str, cfg: BenchConfig, mut f: F) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    BenchResult {
        name: name.to_string(),
        samples_us: samples,
    }
}

/// Time a batch of `n` inner repetitions per sample (for sub-microsecond
/// bodies); reports per-repetition time.
pub fn bench_batched<F: FnMut()>(
    name: &str,
    cfg: BenchConfig,
    inner: usize,
    mut f: F,
) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters {
        let t0 = Instant::now();
        for _ in 0..inner {
            f();
        }
        samples.push(t0.elapsed().as_secs_f64() * 1e6 / inner as f64);
    }
    BenchResult {
        name: name.to_string(),
        samples_us: samples,
    }
}

/// Prevent the optimizer from discarding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Bench-binary flag lookup, tolerant of cargo-bench's extra args
/// (`--bench`, filters): `--quick` or env `HAQA_QUICK=1`.
pub fn flag(name: &str) -> bool {
    let want = format!("--{name}");
    std::env::args().any(|a| a == want)
        || std::env::var(format!("HAQA_{}", name.to_uppercase()))
            .map(|v| v == "1" || v == "true")
            .unwrap_or(false)
}

/// Bench-binary `--key=value` / env `HAQA_KEY` lookup.
pub fn opt(name: &str) -> Option<String> {
    let pref = format!("--{name}=");
    std::env::args()
        .find_map(|a| a.strip_prefix(&pref).map(|s| s.to_string()))
        .or_else(|| std::env::var(format!("HAQA_{}", name.to_uppercase())).ok())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_requested_samples() {
        let r = bench(
            "noop",
            BenchConfig {
                warmup_iters: 1,
                iters: 5,
            },
            || {
                black_box(1 + 1);
            },
        );
        assert_eq!(r.samples_us.len(), 5);
        assert!(r.median_us() >= 0.0);
    }
}
