//! Minimal CLI argument parser (clap stand-in, offline image).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments,
//! with declared options for `--help` generation.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    specs: Vec<OptSpec>,
    prog: String,
    about: String,
}

impl Args {
    pub fn new(prog: &str, about: &str) -> Self {
        Args {
            prog: prog.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec {
            name,
            help,
            takes_value: true,
            default: None,
        });
        self
    }

    pub fn opt_default(
        mut self,
        name: &'static str,
        default: &'static str,
        help: &'static str,
    ) -> Self {
        self.specs.push(OptSpec {
            name,
            help,
            takes_value: true,
            default: Some(default),
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.prog, self.about);
        for spec in &self.specs {
            let val = if spec.takes_value { " <value>" } else { "" };
            let def = spec
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{}{:<24} {}{}\n", spec.name, val, spec.help, def));
        }
        s
    }

    /// Parse an iterator of argument strings (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(mut self, argv: I) -> anyhow::Result<Self> {
        for spec in &self.specs {
            if let Some(d) = spec.default {
                self.flags.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                print!("{}", self.usage());
                std::process::exit(0);
            }
            if let Some(body) = arg.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{key}\n{}", self.usage()))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow::anyhow!("--{key} needs a value"))?,
                    };
                    self.flags.insert(key, val);
                } else {
                    self.flags.insert(key, "true".to_string());
                }
            } else {
                self.positional.push(arg);
            }
        }
        Ok(self)
    }

    pub fn parse_env(self) -> anyhow::Result<Self> {
        self.parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.get(name) == Some("true")
    }

    pub fn get_f64(&self, name: &str) -> anyhow::Result<Option<f64>> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => Ok(Some(s.parse::<f64>().map_err(|_| {
                anyhow::anyhow!("--{name} expects a number, got '{s}'")
            })?)),
        }
    }

    pub fn get_usize(&self, name: &str) -> anyhow::Result<Option<usize>> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => Ok(Some(s.parse::<usize>().map_err(|_| {
                anyhow::anyhow!("--{name} expects an integer, got '{s}'")
            })?)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = Args::new("t", "")
            .opt("seed", "")
            .opt_default("rounds", "10", "")
            .flag("quick", "")
            .parse(argv(&["run", "--seed=42", "--quick", "--rounds", "5"]))
            .unwrap();
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("seed"), Some("42"));
        assert_eq!(a.get_usize("rounds").unwrap(), Some(5));
        assert!(a.get_bool("quick"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::new("t", "")
            .opt_default("rounds", "10", "")
            .parse(argv(&[]))
            .unwrap();
        assert_eq!(a.get_usize("rounds").unwrap(), Some(10));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(Args::new("t", "").parse(argv(&["--nope"])).is_err());
    }
}
