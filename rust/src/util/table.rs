//! Markdown / CSV table renderer for the paper-table regenerators.
//!
//! Every bench prints its table with this module so the output is directly
//! comparable to the paper's tables and easy to paste into EXPERIMENTS.md.

use std::fmt::Write as _;

#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = width[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "\n### {}\n", self.title);
        }
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let body: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = width[i]))
                .collect();
            format!("| {} |", body.join(" | "))
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &width));
        let sep: Vec<String> = width.iter().map(|w| "-".repeat(*w)).collect();
        let _ = writeln!(out, "{}", fmt_row(&sep, &width));
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &width));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Print to stdout and save CSV under `results/` (created on demand).
    pub fn emit(&self, csv_name: &str) {
        print!("{}", self.to_markdown());
        let dir = std::path::Path::new("results");
        if std::fs::create_dir_all(dir).is_ok() {
            let path = dir.join(csv_name);
            if let Err(e) = std::fs::write(&path, self.to_csv()) {
                eprintln!("warn: could not write {}: {e}", path.display());
            } else {
                println!("[saved {}]", path.display());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(vec!["xxx".into(), "1".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a   | bbbb |"));
        assert!(md.contains("| xxx | 1    |"));
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("", &["a"]);
        t.row(vec!["x,y".into()]);
        assert!(t.to_csv().contains("\"x,y\""));
    }
}
