//! Transcript journaling: record a live backend session to disk, replay it
//! offline and bit-identically.
//!
//! [`RecordingBackend`] wraps any [`LlmBackend`] and appends one JSON line
//! per completed request to a `transcripts.jsonl` journal;
//! [`ReplayBackend`] loads that journal and serves the recorded
//! completions without touching the network.  This is how HTTP agent runs
//! become reproducible in CI: record once against the live endpoint,
//! commit (or artifact) the journal, replay everywhere else.
//!
//! Records are keyed by the 128-bit content hash of the canonical-JSON
//! rendering of the request transcript — the same hashing discipline as
//! the evaluation cache (`docs/CACHE.md`) — so replay matches requests by
//! *content*, not by call order, and repeated identical prompts are served
//! FIFO.  The journal shares the cache's append-only hygiene: one
//! `write_all` per record, corrupt or torn lines skipped with a warning,
//! and a torn tail healed by appending a newline (never by truncating).

use std::collections::{HashMap, VecDeque};
use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use crate::util::hash;
use crate::util::json::{self, Json};
use crate::util::{jsonl, lock};

use super::backend::{AgentRequest, Completion, LlmBackend, Message, RequestId, SyncMailbox};
use super::batch::BatchLlm;

/// Journal file name when a directory is given instead of a file path.
pub const TRANSCRIPT_FILE: &str = "transcripts.jsonl";

/// Content key of a request transcript: canonical JSON of the messages.
pub fn transcript_key(messages: &[Message]) -> u128 {
    let arr = Json::Arr(
        messages
            .iter()
            .map(|m| {
                let mut o = Json::obj();
                o.set("role", Json::str(m.role.as_str()));
                o.set("content", Json::str(m.content.clone()));
                o
            })
            .collect(),
    );
    hash::content_hash_128(json::canonical(&arr).as_bytes())
}

fn journal_path(path: &Path) -> PathBuf {
    if path.extension().is_some() {
        path.to_path_buf()
    } else {
        path.join(TRANSCRIPT_FILE)
    }
}

fn encode_record(key: u128, model: &str, c: &Completion) -> String {
    let mut o = Json::obj();
    o.set("key", Json::str(hash::hex128(key)));
    o.set("model", Json::str(model));
    o.set("completion", Json::str(c.text.clone()));
    o.set("prompt_tokens", Json::Num(c.prompt_tokens as f64));
    o.set("completion_tokens", Json::Num(c.completion_tokens as f64));
    // Authoritative f64 bit pattern (hex) so replayed cost accounting is
    // bit-identical; the plain number is informational.
    o.set("api_seconds", Json::Num(c.api_seconds));
    o.set("api_s_bits", Json::str(format!("{:016x}", c.api_seconds.to_bits())));
    let mut line = o.to_string();
    line.push('\n');
    line
}

/// A batch boundary record: which transcript keys one provider round-trip
/// served, in request order.  Written by [`BatchRecorder`] after the
/// batch's item records; enforced by [`BatchReplay`]; ignored (not even
/// counted as corrupt) by the unbatched [`ReplayBackend`].
fn encode_batch_record(keys: &[u128]) -> String {
    let mut o = Json::obj();
    o.set(
        "batch",
        Json::Arr(keys.iter().map(|k| Json::str(hash::hex128(*k))).collect()),
    );
    let mut line = o.to_string();
    line.push('\n');
    line
}

fn decode_record(j: &Json) -> Option<(u128, Completion)> {
    let key = hash::parse_hex128(j.get("key")?.as_str()?)?;
    let text = j.get("completion")?.as_str()?.to_string();
    let prompt_tokens = j.get("prompt_tokens")?.as_f64()? as usize;
    let completion_tokens = j.get("completion_tokens")?.as_f64()? as usize;
    let api_seconds = j
        .get("api_s_bits")
        .and_then(|v| v.as_str())
        .and_then(|s| u64::from_str_radix(s, 16).ok())
        .map(f64::from_bits)
        .or_else(|| j.get("api_seconds").and_then(|v| v.as_f64()))?;
    Some((
        key,
        Completion {
            text,
            prompt_tokens,
            completion_tokens,
            api_seconds,
        },
    ))
}

// ---------------------------------------------------------------------------
// RecordingBackend
// ---------------------------------------------------------------------------

struct Recorder {
    file: File,
    /// Inner request id → transcript content key, pending journaling.
    keys: HashMap<u64, u128>,
}

/// Journals every completed request of the wrapped backend.
pub struct RecordingBackend {
    inner: Box<dyn LlmBackend>,
    rec: Mutex<Recorder>,
    path: PathBuf,
}

impl RecordingBackend {
    /// Wrap `inner`, appending records to `path` (a `.jsonl` file, or a
    /// directory that gets a `transcripts.jsonl`).
    pub fn create(path: impl AsRef<Path>, inner: Box<dyn LlmBackend>) -> Result<RecordingBackend> {
        let path = journal_path(path.as_ref());
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        // Torn tails are healed by appending (never truncating) — the
        // shared journal hygiene implementation.
        let file = jsonl::open_append_healed(&path)?;
        Ok(RecordingBackend {
            inner,
            rec: Mutex::new(Recorder {
                file,
                keys: HashMap::new(),
            }),
            path,
        })
    }

    pub fn journal_path(&self) -> &Path {
        &self.path
    }

    fn journal(&self, id: RequestId, c: &Completion) {
        let mut g = lock(&self.rec);
        if let Some(key) = g.keys.remove(&id.0) {
            let line = encode_record(key, self.inner.model_name(), c);
            // One write per record; a failed append only loses the journal
            // line, never the live completion.
            let _ = g
                .file
                .write_all(line.as_bytes())
                .and_then(|()| g.file.flush());
        }
    }
}

impl LlmBackend for RecordingBackend {
    fn model_name(&self) -> &str {
        self.inner.model_name()
    }

    fn submit(&self, req: AgentRequest) -> Result<RequestId> {
        let key = transcript_key(&req.messages);
        let id = self.inner.submit(req)?;
        lock(&self.rec).keys.insert(id.0, key);
        Ok(id)
    }

    fn try_recv(&self, id: RequestId) -> Result<Option<Completion>> {
        let out = self.inner.try_recv(id)?;
        if let Some(c) = &out {
            self.journal(id, c);
        }
        Ok(out)
    }

    fn recv(&self, id: RequestId) -> Result<Completion> {
        let c = self.inner.recv(id)?;
        self.journal(id, &c);
        Ok(c)
    }
}

// ---------------------------------------------------------------------------
// ReplayBackend
// ---------------------------------------------------------------------------

struct ReplayState {
    /// FIFO of recorded completions per transcript key.
    records: HashMap<u128, VecDeque<Completion>>,
    mail: SyncMailbox,
}

/// Serves recorded completions by transcript content — fully offline.
pub struct ReplayBackend {
    model: String,
    state: Mutex<ReplayState>,
    path: PathBuf,
}

/// Everything one pass over a transcript journal yields: the per-key FIFO
/// of completions, the batch boundaries (if the session was recorded
/// through [`BatchRecorder`]), and the recorded model label.
struct JournalData {
    model: String,
    records: HashMap<u128, VecDeque<Completion>>,
    batches: VecDeque<Vec<u128>>,
    loaded: usize,
}

fn load_journal(path: &Path) -> Result<JournalData> {
    let bytes =
        std::fs::read(path).with_context(|| format!("transcript journal {}", path.display()))?;
    let mut data = JournalData {
        model: String::from("replay"),
        records: HashMap::new(),
        batches: VecDeque::new(),
        loaded: 0,
    };
    let scan = jsonl::scan(&bytes, |j, _| {
        if let Some(arr) = j.get("batch").and_then(|v| v.as_arr()) {
            let mut keys = Vec::with_capacity(arr.len());
            for k in arr {
                match k.as_str().and_then(hash::parse_hex128) {
                    Some(h) => keys.push(h),
                    None => return false,
                }
            }
            data.batches.push_back(keys);
            return true;
        }
        if let Some(m) = j.get("model").and_then(|v| v.as_str()) {
            data.model = format!("replay:{m}");
        }
        match decode_record(j) {
            Some((key, c)) => {
                data.records.entry(key).or_default().push_back(c);
                data.loaded += 1;
                true
            }
            None => false,
        }
    });
    if scan.skipped > 0 {
        eprintln!(
            "transcript replay: skipped {} corrupt/truncated record(s) in {}",
            scan.skipped,
            path.display()
        );
    }
    Ok(data)
}

impl ReplayBackend {
    pub fn open(path: impl AsRef<Path>) -> Result<ReplayBackend> {
        let path = journal_path(path.as_ref());
        let data = load_journal(&path)?;
        if data.loaded == 0 {
            return Err(anyhow!("no transcript records in {}", path.display()));
        }
        Ok(ReplayBackend {
            model: data.model,
            state: Mutex::new(ReplayState {
                records: data.records,
                mail: SyncMailbox::default(),
            }),
            path,
        })
    }

    /// Recorded completions not yet served (for end-of-run coverage checks).
    pub fn remaining(&self) -> usize {
        lock(&self.state).records.values().map(|q| q.len()).sum()
    }
}

impl LlmBackend for ReplayBackend {
    fn model_name(&self) -> &str {
        &self.model
    }

    fn submit(&self, req: AgentRequest) -> Result<RequestId> {
        let key = transcript_key(&req.messages);
        let mut g = lock(&self.state);
        let result = g
            .records
            .get_mut(&key)
            .and_then(|q| q.pop_front())
            .ok_or_else(|| {
                anyhow!(
                    "no recorded completion for transcript {} in {} — the \
                     replayed run diverged from the recording",
                    hash::hex128(key),
                    self.path.display()
                )
            });
        Ok(g.mail.push(result))
    }

    fn try_recv(&self, id: RequestId) -> Result<Option<Completion>> {
        lock(&self.state).mail.take(id, &self.model).map(Some)
    }

    fn recv(&self, id: RequestId) -> Result<Completion> {
        lock(&self.state).mail.take(id, &self.model)
    }
}

// ---------------------------------------------------------------------------
// BatchRecorder / BatchReplay: the batched pipeline's journal adapters
// ---------------------------------------------------------------------------

/// Journals every completed request of a wrapped [`BatchLlm`] provider —
/// the batch-mode counterpart of [`RecordingBackend`] — plus one *batch
/// boundary* record per provider round-trip (`{"batch": [key, …]}`), so a
/// replay reproduces not just each completion but the batching itself.
/// Item records use the exact [`RecordingBackend`] format, so a journal
/// recorded batched also replays through the unbatched [`ReplayBackend`]
/// (which skips the boundary lines).
pub struct BatchRecorder {
    inner: Box<dyn BatchLlm>,
    file: File,
    path: PathBuf,
}

impl BatchRecorder {
    /// Wrap `inner`, appending records to `path` (a `.jsonl` file, or a
    /// directory that gets a `transcripts.jsonl`).
    pub fn create(path: impl AsRef<Path>, inner: Box<dyn BatchLlm>) -> Result<BatchRecorder> {
        let path = journal_path(path.as_ref());
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let file = jsonl::open_append_healed(&path)?;
        Ok(BatchRecorder { inner, file, path })
    }

    /// Where the journal is being written.
    pub fn journal_path(&self) -> &Path {
        &self.path
    }
}

impl BatchLlm for BatchRecorder {
    fn model_name(&self) -> &str {
        self.inner.model_name()
    }

    fn complete_batch(&mut self, reqs: &[AgentRequest]) -> Vec<Result<Completion>> {
        let keys: Vec<u128> = reqs.iter().map(|r| transcript_key(&r.messages)).collect();
        let out = self.inner.complete_batch(reqs);
        let mut buf = String::new();
        for (key, r) in keys.iter().zip(&out) {
            if let Ok(c) = r {
                buf.push_str(&encode_record(*key, self.inner.model_name(), c));
            }
        }
        // The boundary carries every key — failed items included — because
        // it records the batch *composition* the provider was asked for.
        buf.push_str(&encode_batch_record(&keys));
        // One write for the whole batch (items + boundary); a failed
        // append only loses journal lines, never the live completions.
        let _ = self
            .file
            .write_all(buf.as_bytes())
            .and_then(|()| self.file.flush());
        out
    }
}

/// Serves a recorded journal as a [`BatchLlm`]: items match by transcript
/// content (FIFO per key, like [`ReplayBackend`]) and, when the journal
/// carries batch boundary records, every `complete_batch` call must
/// reproduce the recorded batch composition exactly — a divergence fails
/// the whole batch loudly instead of silently re-batching.  Journals
/// recorded *unbatched* (no boundary records) replay without composition
/// enforcement.
pub struct BatchReplay {
    model: String,
    records: HashMap<u128, VecDeque<Completion>>,
    batches: VecDeque<Vec<u128>>,
    enforce: bool,
    path: PathBuf,
}

impl BatchReplay {
    /// Load `path` (same journal format as [`ReplayBackend::open`]).
    pub fn open(path: impl AsRef<Path>) -> Result<BatchReplay> {
        let path = journal_path(path.as_ref());
        let data = load_journal(&path)?;
        if data.loaded == 0 {
            return Err(anyhow!("no transcript records in {}", path.display()));
        }
        Ok(BatchReplay {
            model: data.model,
            records: data.records,
            enforce: !data.batches.is_empty(),
            batches: data.batches,
            path,
        })
    }

    /// Recorded completions not yet served.
    pub fn remaining(&self) -> usize {
        self.records.values().map(|q| q.len()).sum()
    }
}

impl BatchLlm for BatchReplay {
    fn model_name(&self) -> &str {
        &self.model
    }

    fn complete_batch(&mut self, reqs: &[AgentRequest]) -> Vec<Result<Completion>> {
        let keys: Vec<u128> = reqs.iter().map(|r| transcript_key(&r.messages)).collect();
        if self.enforce {
            let expected = self.batches.pop_front();
            if expected.as_deref() != Some(&keys[..]) {
                let what = match expected {
                    Some(e) => format!(
                        "the recording's next batch has {} request(s) with \
                         different content",
                        e.len()
                    ),
                    None => "the recording has no further provider batches".to_string(),
                };
                return keys
                    .iter()
                    .map(|_| {
                        Err(anyhow!(
                            "provider batch composition diverged from the \
                             recording in {}: {what}",
                            self.path.display()
                        ))
                    })
                    .collect();
            }
        }
        keys.iter()
            .map(|k| {
                self.records
                    .get_mut(k)
                    .and_then(|q| q.pop_front())
                    .ok_or_else(|| {
                        anyhow!(
                            "no recorded completion for transcript {} in {} — \
                             the replayed run diverged from the recording",
                            hash::hex128(*k),
                            self.path.display()
                        )
                    })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::backend::Pipelined;
    use crate::agent::simulated::SimulatedLlm;
    use crate::agent::prompt::dynamic_prompt;
    use crate::agent::{TaskContext, TaskKind};
    use crate::search::spaces;
    use crate::util::json::Json;

    fn tmp(tag: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!(
            "haqa_transcript_{tag}_{}.jsonl",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&p);
        p
    }

    fn prompt_messages(seed_round: usize) -> Vec<Message> {
        let space = spaces::resnet_qat();
        let ctx = TaskContext {
            kind: TaskKind::Finetune,
            space: &space,
            history: &[],
            rounds_left: 3 + seed_round,
            hardware: None,
            objective: Json::obj(),
        };
        vec![Message::user(dynamic_prompt(&ctx, &[]))]
    }

    #[test]
    fn record_then_replay_is_bit_identical() {
        let path = tmp("roundtrip");
        let live = RecordingBackend::create(
            &path,
            Box::new(Pipelined::new(SimulatedLlm::new(5).with_failure_rate(0.0))),
        )
        .unwrap();
        let m1 = prompt_messages(0);
        let m2 = prompt_messages(1);
        let c1 = live.complete(&m1).unwrap();
        let c2 = live.complete(&m2).unwrap();

        let replay = ReplayBackend::open(&path).unwrap();
        let r2 = replay.complete(&m2).unwrap();
        let r1 = replay.complete(&m1).unwrap();
        assert_eq!(r1.text, c1.text);
        assert_eq!(r2.text, c2.text, "replay matches by content, not order");
        assert_eq!(r1.prompt_tokens, c1.prompt_tokens);
        assert_eq!(
            r1.api_seconds.to_bits(),
            c1.api_seconds.to_bits(),
            "accounting replays bit-exactly"
        );
        assert_eq!(replay.remaining(), 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn replay_rejects_unrecorded_transcripts() {
        let path = tmp("miss");
        let live = RecordingBackend::create(
            &path,
            Box::new(Pipelined::new(SimulatedLlm::new(5).with_failure_rate(0.0))),
        )
        .unwrap();
        live.complete(&prompt_messages(0)).unwrap();
        let replay = ReplayBackend::open(&path).unwrap();
        let err = replay.complete(&prompt_messages(7)).unwrap_err();
        assert!(format!("{err:#}").contains("no recorded completion"), "{err:#}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_lines_are_skipped_and_torn_tail_healed() {
        let path = tmp("corrupt");
        {
            let live = RecordingBackend::create(
                &path,
                Box::new(Pipelined::new(SimulatedLlm::new(5).with_failure_rate(0.0))),
            )
            .unwrap();
            live.complete(&prompt_messages(0)).unwrap();
        }
        // A crashed writer's torn, newline-less tail.
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"{\"key\":\"00ff\",\"completion");
        std::fs::write(&path, &bytes).unwrap();
        // Re-opening for recording heals the tail by appending a newline…
        {
            let live = RecordingBackend::create(
                &path,
                Box::new(Pipelined::new(SimulatedLlm::new(6).with_failure_rate(0.0))),
            )
            .unwrap();
            live.complete(&prompt_messages(1)).unwrap();
        }
        // …so both intact records load and the torn one is skipped.
        let replay = ReplayBackend::open(&path).unwrap();
        assert_eq!(replay.remaining(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn empty_journal_is_an_error() {
        let path = tmp("empty");
        std::fs::write(&path, "").unwrap();
        assert!(ReplayBackend::open(&path).is_err());
        assert!(BatchReplay::open(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn batched_record_then_batch_replay_is_bit_identical() {
        let path = tmp("batch_roundtrip");
        let reqs = vec![
            AgentRequest::new(prompt_messages(0)),
            AgentRequest::new(prompt_messages(1)),
        ];
        let live = {
            let mut rec =
                BatchRecorder::create(&path, Box::new(SimulatedLlm::stateless(5))).unwrap();
            rec.complete_batch(&reqs)
        };
        let mut replay = BatchReplay::open(&path).unwrap();
        let again = replay.complete_batch(&reqs);
        assert_eq!(again.len(), live.len());
        for (a, b) in live.iter().zip(&again) {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            assert_eq!(a.text, b.text);
            assert_eq!(a.prompt_tokens, b.prompt_tokens);
            assert_eq!(
                a.api_seconds.to_bits(),
                b.api_seconds.to_bits(),
                "accounting replays bit-exactly"
            );
        }
        assert_eq!(replay.remaining(), 0);
        // The recording holds exactly one provider batch: asking for a
        // second diverges, failing every item loudly.
        let exhausted = replay.complete_batch(&reqs);
        assert!(exhausted.iter().all(|r| r.is_err()));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn batch_composition_divergence_fails_the_whole_batch() {
        let path = tmp("batch_diverge");
        let reqs = vec![
            AgentRequest::new(prompt_messages(0)),
            AgentRequest::new(prompt_messages(1)),
        ];
        {
            let mut rec =
                BatchRecorder::create(&path, Box::new(SimulatedLlm::stateless(5))).unwrap();
            rec.complete_batch(&reqs);
        }
        // Same contents, different composition (the batch split in two):
        // replay must fail rather than silently re-batch.
        let mut replay = BatchReplay::open(&path).unwrap();
        let out = replay.complete_batch(&reqs[..1]);
        assert_eq!(out.len(), 1);
        let err = out[0].as_ref().unwrap_err();
        assert!(format!("{err:#}").contains("diverged"), "{err:#}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn unbatched_replay_serves_a_batched_recording_and_skips_boundaries() {
        let path = tmp("batch_compat");
        let m1 = prompt_messages(0);
        {
            let mut rec =
                BatchRecorder::create(&path, Box::new(SimulatedLlm::stateless(5))).unwrap();
            let live = rec.complete_batch(&[AgentRequest::new(m1.clone())]);
            assert!(live[0].is_ok());
        }
        let replay = ReplayBackend::open(&path).unwrap();
        assert_eq!(replay.remaining(), 1, "the boundary line is not an item");
        let c = replay.complete(&m1).unwrap();
        assert!(!c.text.is_empty());
        let _ = std::fs::remove_file(&path);
    }
}
