//! Static / Dynamic prompt construction (paper §3.1, Figures 2 and 3).
//!
//! The *static prompt* carries the unchanging task description: hardware
//! platform specification (Fig. 2a), deployment objective (2b), fine-tuning
//! objective (2c), the search space, and the ReAct instruction block.  The
//! *dynamic prompt* carries per-round state: rounds left, current config,
//! evaluation feedback, and the conversation history window (2d).
//!
//! A machine-readable `CONTEXT_JSON:` line is embedded alongside the prose —
//! the paper's prompts already embed JSON blocks (configs, kernel specs);
//! centralizing one canonical block is what makes the workflow
//! backend-agnostic (the simulated policy parses it; a real LLM reads the
//! surrounding prose too).

use crate::optimizers::Observation;
use crate::util::json::Json;

use super::{TaskContext, TaskKind};

/// The ReAct instruction block (paper §3.2, highlighted purple in Fig. 2).
pub const REACT_BLOCK: &str = "\
Before making a decision, always generate a reasoning step (Thought) to \
analyze the current context, considering previous results and constraints. \
Then, take an appropriate action (Action) based on your reasoning. After \
the action, observe (Observation) the outcomes we feed back to you and \
adjust your approach accordingly. Identify missing information, potential \
errors, and formulate a strategy before taking any action. Each trial's \
configuration and results should be taken into account for a comprehensive \
analysis of the optimization process. Please review the history and \
consider your next steps before proceeding.";

pub const SYSTEM_PROMPT: &str = "\
You are an expert assistant specialized in optimizing hyperparameters for \
both fine-tuning and deployment of quantized neural networks. Your goal is \
to help improve the accuracy and inference speed of the network by \
providing optimized hyperparameter configurations.";

/// Build the static prompt for a task (sent once, reused every round).
pub fn static_prompt(ctx: &TaskContext) -> String {
    let mut s = String::new();
    match ctx.kind {
        TaskKind::Finetune => {
            s.push_str(
                "You are helping optimize the hyperparameters of quantized \
                 model fine-tuning.\n",
            );
        }
        TaskKind::KernelTuning => {
            s.push_str(
                "You are helping optimize the execution configuration of the \
                 model's computational kernels for deployment. Optimize the \
                 kernel execution parameters (computation block size for \
                 parallelization, tiling size for memory access, loop \
                 unrolling) and the execution strategy (memory hierarchy \
                 placement, thread scheduling). The deployment latency \
                 results will be fed back to you.\n",
            );
        }
        TaskKind::Bitwidth => {
            s.push_str(
                "Please choose an appropriate quantization bit width that \
                 satisfies the memory limitations and achieves better \
                 performance on this hardware.\n",
            );
        }
    }
    if let Some(hw) = &ctx.hardware {
        s.push_str("\nHere are more details about the hardware: ");
        s.push_str(&hw.to_string());
        s.push('\n');
    }
    s.push_str("\nObjective details: ");
    s.push_str(&ctx.objective.to_string());
    s.push_str("\n\nHere is the hyperparameter search space:\n");
    s.push_str(&ctx.space.describe());
    s.push_str(
        "\nYou will get the evaluation result after each trial. The goal is \
         to find the configuration that maximizes the objective within a \
         given budget. If the result does not change, explore different \
         parts of the search space. You provide one set of configurations \
         at a time; when the results are given, you return an optimized \
         configuration. **Make sure that all hyperparameters remain within \
         the defined range**. It is recommended to use the default \
         parameters for the first round. Please provide the configuration \
         in **JSON format**.\n\n",
    );
    s.push_str(REACT_BLOCK);
    s
}

/// Serialize one history entry the way the paper's transcripts do.
fn history_entry(round: usize, obs: &Observation) -> Json {
    let mut o = Json::obj();
    o.set("round", Json::Num(round as f64));
    o.set(
        "config",
        Json::from_pairs(
            obs.config
                .iter()
                .map(|(k, v)| (k.clone(), v.to_json()))
                .collect(),
        ),
    );
    o.set("score", Json::Num(obs.score));
    if !obs.feedback.is_empty() {
        o.set("feedback", Json::Str(obs.feedback.clone()));
    }
    o
}

/// Build the dynamic prompt for the current round (paper Fig. 2d): budget
/// note, latest config + result, history window, and the canonical
/// CONTEXT_JSON block.
pub fn dynamic_prompt(ctx: &TaskContext, history_window: &[(usize, &Observation)]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "Note that there are {} rounds left, please try to make effective \
         attempts. Finish tasks with interleaving Thought, Action, \
         Observation steps.\n",
        ctx.rounds_left
    ));
    if let Some((round, last)) = history_window.last() {
        s.push_str(&format!(
            "\nThe current configuration (round {round}) is: {}\n",
            Json::from_pairs(
                last.config
                    .iter()
                    .map(|(k, v)| (k.clone(), v.to_json()))
                    .collect()
            )
            .to_string()
        ));
        s.push_str(&format!(
            "The result based on this configuration: score = {:.6}.",
            last.score
        ));
        if !last.feedback.is_empty() {
            s.push_str(&format!(" Evaluation feedback: {}", last.feedback));
        }
        s.push('\n');
    } else {
        s.push_str(
            "\nThis is the first round. It is recommended to use the default \
             parameters.\n",
        );
    }
    let hist = Json::Arr(
        history_window
            .iter()
            .map(|(round, obs)| history_entry(*round, obs))
            .collect(),
    );
    s.push_str(&format!("\nHistory: {}\n", hist.to_string()));

    // Canonical machine-readable context (see module docs).
    let mut ctx_json = Json::obj();
    ctx_json.set("task", Json::Str(ctx.kind.as_str().to_string()));
    ctx_json.set("rounds_left", Json::Num(ctx.rounds_left as f64));
    ctx_json.set("space", space_json(ctx.space));
    ctx_json.set("history", hist);
    if let Some(hw) = &ctx.hardware {
        ctx_json.set("hardware", hw.clone());
    }
    ctx_json.set("objective", ctx.objective.clone());
    s.push_str(&format!("\nCONTEXT_JSON: {}\n", ctx_json.to_string()));
    s.push_str(
        "\nPlease check the history and think about your next plan before \
         action, then provide the next configuration in JSON format.",
    );
    s
}

/// The search space as JSON (used in CONTEXT_JSON).
pub fn space_json(space: &crate::search::Space) -> Json {
    use crate::search::param::ParamKind;
    let mut arr = Vec::new();
    for p in &space.params {
        let mut o = Json::obj();
        o.set("name", Json::Str(p.name.clone()));
        match &p.kind {
            ParamKind::Float { lo, hi, log } => {
                o.set("type", Json::Str("float".into()));
                o.set("lo", Json::Num(*lo));
                o.set("hi", Json::Num(*hi));
                o.set("log", Json::Bool(*log));
            }
            ParamKind::Int { lo, hi, log } => {
                o.set("type", Json::Str("int".into()));
                o.set("lo", Json::Num(*lo as f64));
                o.set("hi", Json::Num(*hi as f64));
                o.set("log", Json::Bool(*log));
            }
            ParamKind::Cat { choices } => {
                o.set("type", Json::Str("cat".into()));
                o.set(
                    "choices",
                    Json::Arr(choices.iter().map(|c| Json::Str(c.clone())).collect()),
                );
            }
        }
        o.set("default", p.default.to_json());
        arr.push(o);
    }
    Json::Arr(arr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::spaces;

    fn ctx<'a>(space: &'a crate::search::Space, hist: &'a [Observation]) -> TaskContext<'a> {
        TaskContext {
            kind: TaskKind::Finetune,
            space,
            history: hist,
            rounds_left: 7,
            hardware: None,
            objective: Json::obj(),
        }
    }

    #[test]
    fn static_prompt_contains_space_and_react() {
        let space = spaces::resnet_qat();
        let c = ctx(&space, &[]);
        let s = static_prompt(&c);
        assert!(s.contains("learning_rate"));
        assert!(s.contains("Thought"));
        assert!(s.contains("JSON format"));
    }

    #[test]
    fn dynamic_prompt_embeds_context_json() {
        let space = spaces::resnet_qat();
        let hist = vec![Observation::new(space.default_config(), 0.89)];
        let window: Vec<(usize, &Observation)> =
            hist.iter().enumerate().collect();
        let c = ctx(&space, &hist);
        let s = dynamic_prompt(&c, &window);
        assert!(s.contains("7 rounds left"));
        let json_line = s
            .lines()
            .find(|l| l.starts_with("CONTEXT_JSON: "))
            .expect("context json line");
        let v = crate::util::json::parse(
            json_line.trim_start_matches("CONTEXT_JSON: "),
        )
        .unwrap();
        assert_eq!(v.req_str("task").unwrap(), "finetune");
        assert_eq!(v.req_arr("history").unwrap().len(), 1);
    }
}
