//! Provider-side request batching: many in-flight proposals coalesce into
//! one provider round-trip (OpenAI batch-API style).
//!
//! PR 3 made the agent stack a request pipeline and let the fleet keep
//! many scenarios' queries in flight; this module closes the last
//! unexploited layer of that pipeline.  [`BatchLlm`] is the provider-side
//! contract — complete *many* transcripts in **one** request —
//! and [`BatchingBackend`] is the [`LlmBackend`] adapter over it:
//! `submit` buffers requests up to a size cap, a cap-fill or an explicit
//! [`BatchingBackend::flush`] executes the whole buffer as a single
//! provider call, and completions fan back out by [`RequestId`].
//!
//! ```text
//!   session A ── submit ──┐
//!   session B ── submit ──┤   BatchingBackend        provider
//!   session C ── submit ──┼──▶ [A B C …] buffer ──▶ complete_batch(…)
//!   session D ── submit ──┘        │ flush()            │ 1 round-trip
//!   try_recv(id) ◀── fan-out by RequestId ◀─────────────┘
//! ```
//!
//! [`AgentPool`] is the fleet-level registry that makes cross-scenario
//! coalescing possible: one shared `BatchingBackend` per backend *spec*
//! (`simulated`, `replay:…`, `http://…`, …), handed to every scenario as a
//! [`SharedBackend`] handle.  A shared provider must answer a given
//! transcript identically for every scenario, so pooled simulated policies
//! are **content-seeded** ([`super::simulated::SimulatedLlm::stateless`]):
//! the completion is a pure function of the transcript, exactly like a
//! temperature-0 endpoint — which is also what makes batched runs
//! bit-identical to unbatched ones and lets `record:`/`replay:` journals
//! match by content.
//!
//! Flush semantics: a batch executes when (a) the buffer reaches the size
//! cap (inside the `submit` that filled it), (b) a blocking
//! [`LlmBackend::recv`] lands on a still-buffered request (the serial
//! path's implicit flush point), or (c) the driver calls `flush`
//! explicitly — the fleet does so at the end of each submit sweep, once
//! every live session is parked on an in-flight request, so batches
//! actually fill instead of degenerating to size 1.  Execution is
//! synchronous on the flushing thread and the inner provider is locked for
//! the whole batch, so with one worker the batch composition — and
//! therefore a recorded journal's batch boundaries — is deterministic.

use std::collections::{HashMap, HashSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::util::{lock, panic_message};

use super::backend::{AgentRequest, Completion, LlmBackend, RequestId};

/// A provider that completes many transcripts in one round-trip.
///
/// The contract: `complete_batch` must return exactly `reqs.len()`
/// results, **in request order**; a per-item failure is an `Err` in that
/// item's slot and must not poison the other items (partial failure).  A
/// whole-batch transport failure is every slot `Err`.
pub trait BatchLlm: Send {
    /// Human-readable provider identifier (logged in task logs).
    fn model_name(&self) -> &str;

    /// Complete `reqs` in one provider request.
    fn complete_batch(&mut self, reqs: &[AgentRequest]) -> Vec<Result<Completion>>;
}

impl BatchLlm for Box<dyn BatchLlm> {
    fn model_name(&self) -> &str {
        (**self).model_name()
    }

    fn complete_batch(&mut self, reqs: &[AgentRequest]) -> Vec<Result<Completion>> {
        (**self).complete_batch(reqs)
    }
}

/// Lifetime counters of one [`BatchingBackend`] (or an [`AgentPool`]
/// aggregate): how many requests were submitted, how many provider
/// round-trips served them, and the largest single batch.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BatchStats {
    /// Requests submitted (each occupies one slot in some batch).
    pub submitted: usize,
    /// Provider round-trips (`complete_batch` calls) that served them.
    pub provider_requests: usize,
    /// Largest batch executed.
    pub max_batch: usize,
}

struct BatchState {
    next_id: u64,
    /// Submitted but not yet executed, in submission order.
    queue: Vec<(u64, AgentRequest)>,
    done: HashMap<u64, Result<Completion>>,
    delivered: HashSet<u64>,
    stats: BatchStats,
}

/// The batching [`LlmBackend`] adapter over any [`BatchLlm`] provider —
/// see the module docs for buffer/flush semantics and the determinism
/// argument.
pub struct BatchingBackend<B> {
    model: String,
    cap: usize,
    inner: Mutex<B>,
    state: Mutex<BatchState>,
}

impl<B: BatchLlm> BatchingBackend<B> {
    /// Buffer up to `cap` requests per provider call (`cap` is clamped to
    /// ≥ 1; a cap of 1 executes every request at submit — the *unbatched*
    /// control the bench compares against).
    pub fn new(inner: B, cap: usize) -> BatchingBackend<B> {
        let cap = cap.max(1);
        BatchingBackend {
            model: format!("batch{}:{}", cap, inner.model_name()),
            cap,
            inner: Mutex::new(inner),
            state: Mutex::new(BatchState {
                next_id: 0,
                queue: Vec::new(),
                done: HashMap::new(),
                delivered: HashSet::new(),
                stats: BatchStats::default(),
            }),
        }
    }

    /// The buffer's size cap.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Lifetime request/round-trip counters.
    pub fn stats(&self) -> BatchStats {
        lock(&self.state).stats
    }

    /// Execute everything buffered — in provider requests of at most
    /// `cap` items each — and fan the completions out to their
    /// [`RequestId`]s.  Returns how many requests were flushed (0 when
    /// the buffer was empty).
    pub fn flush(&self) -> usize {
        let mut flushed = 0;
        loop {
            // Drain up to one cap's worth, then release the state lock
            // before touching the provider: other threads keep submitting
            // (and polling ids that are mid-flush simply see "still in
            // flight") while this chunk runs.  Draining by chunk — rather
            // than taking the whole queue — keeps every provider call
            // within the advertised cap even when a racing submit slips
            // an item in between the cap-fill check and this drain.
            let batch: Vec<(u64, AgentRequest)> = {
                let mut g = lock(&self.state);
                if g.queue.is_empty() {
                    break;
                }
                let take = g.queue.len().min(self.cap);
                g.queue.drain(..take).collect()
            };
            let (ids, reqs): (Vec<u64>, Vec<AgentRequest>) = batch.into_iter().unzip();
            // A panicking provider must still complete every id it was
            // handed: otherwise the other sessions batched into this
            // flush poll `try_recv` forever (and a panic at the fleet's
            // flush point would escape the per-scenario isolation and
            // abort the whole batch).  Same containment discipline as the
            // Dispatcher's work threads.
            let results =
                catch_unwind(AssertUnwindSafe(|| lock(&self.inner).complete_batch(&reqs)))
                    .unwrap_or_else(|p| {
                        let msg = panic_message(&p);
                        reqs.iter()
                            .map(|_| Err(anyhow!("batch provider panicked: {msg}")))
                            .collect()
                    });
            let n = ids.len();
            flushed += n;
            let mut g = lock(&self.state);
            g.stats.provider_requests += 1;
            g.stats.max_batch = g.stats.max_batch.max(n);
            let mut it = results.into_iter();
            for id in ids {
                // The BatchLlm contract is one result per request; a
                // short reply becomes per-item errors, never a hung
                // receiver.
                let r = it.next().unwrap_or_else(|| {
                    Err(anyhow!("batch provider returned too few completions"))
                });
                g.done.insert(id, r);
            }
        }
        flushed
    }
}

impl<B: BatchLlm> LlmBackend for BatchingBackend<B> {
    fn model_name(&self) -> &str {
        &self.model
    }

    fn submit(&self, req: AgentRequest) -> Result<RequestId> {
        let (id, full) = {
            let mut g = lock(&self.state);
            let id = g.next_id;
            g.next_id += 1;
            g.stats.submitted += 1;
            g.queue.push((id, req));
            (id, g.queue.len() >= self.cap)
        };
        if full {
            self.flush();
        }
        Ok(RequestId(id))
    }

    fn try_recv(&self, id: RequestId) -> Result<Option<Completion>> {
        let mut g = lock(&self.state);
        if id.0 >= g.next_id {
            return Err(anyhow!("request {} was never submitted", id.0));
        }
        if g.delivered.contains(&id.0) {
            return Err(anyhow!("request {} was already received", id.0));
        }
        match g.done.remove(&id.0) {
            Some(r) => {
                g.delivered.insert(id.0);
                r.map(Some)
            }
            // Still buffered, or mid-flush on another thread.
            None => Ok(None),
        }
    }

    fn recv(&self, id: RequestId) -> Result<Completion> {
        loop {
            if let Some(c) = self.try_recv(id)? {
                return Ok(c);
            }
            // Not done: if the request still sits in the buffer this is the
            // blocking path's flush point (a size-1-or-more batch executes
            // now); if not, another thread's flush is mid-execution — back
            // off briefly and re-poll.
            let queued = lock(&self.state).queue.iter().any(|(q, _)| *q == id.0);
            if queued {
                self.flush();
            } else {
                std::thread::sleep(Duration::from_micros(200));
            }
        }
    }
}

/// The seed every pooled provider is built from.  Scenario seeds are
/// deliberately *not* used: a shared provider must answer a given
/// transcript identically for every scenario, so the pooled simulated
/// policy derives its randomness from this fleet-level constant plus the
/// transcript content (see [`super::simulated::SimulatedLlm::stateless`]).
pub const POOL_SEED: u64 = 0x4a9a;

type PoolSlot = Arc<BatchingBackend<Box<dyn BatchLlm>>>;

/// Fleet-level registry of shared batching backends: one
/// [`BatchingBackend`] per backend spec, so in-flight proposals from many
/// scenarios coalesce into the same provider batches.  Built by the fleet
/// when `--batch`/`HAQA_BATCH` is set and handed to every scenario's agent
/// as a [`SharedBackend`] handle.
pub struct AgentPool {
    batch: usize,
    backends: Mutex<HashMap<String, PoolSlot>>,
}

impl AgentPool {
    /// A pool whose backends buffer up to `batch` requests per provider
    /// call (clamped to ≥ 1).
    pub fn new(batch: usize) -> AgentPool {
        AgentPool {
            batch: batch.max(1),
            backends: Mutex::new(HashMap::new()),
        }
    }

    /// The per-provider-call size cap.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Get-or-create the shared backend for `spec` (see
    /// [`super::batch_llm_from_spec`] for the accepted specs).
    pub fn backend(&self, spec: &str) -> Result<SharedBackend> {
        // Normalized key: `""` and `"simulated"` are the same provider, so
        // scenarios spelling the default differently must still coalesce
        // into one shared backend.
        let trimmed = spec.trim();
        let key = if trimmed.is_empty() {
            "simulated".to_string()
        } else {
            trimmed.to_string()
        };
        let mut g = lock(&self.backends);
        if let Some(b) = g.get(&key) {
            return Ok(SharedBackend(Arc::clone(b)));
        }
        let inner = super::batch_llm_from_spec(&key, POOL_SEED)?;
        let slot: PoolSlot = Arc::new(BatchingBackend::new(inner, self.batch));
        g.insert(key, Arc::clone(&slot));
        Ok(SharedBackend(slot))
    }

    /// Flush every backend's buffer (the fleet's end-of-sweep flush
    /// point); returns the total number of requests flushed.
    pub fn flush(&self) -> usize {
        let slots: Vec<PoolSlot> = lock(&self.backends).values().cloned().collect();
        slots.iter().map(|b| b.flush()).sum()
    }

    /// Aggregate counters across every backend in the pool.
    pub fn stats(&self) -> BatchStats {
        let mut out = BatchStats::default();
        for b in lock(&self.backends).values() {
            let s = b.stats();
            out.submitted += s.submitted;
            out.provider_requests += s.provider_requests;
            out.max_batch = out.max_batch.max(s.max_batch);
        }
        out
    }
}

/// A cloneable handle to one of an [`AgentPool`]'s shared backends; this
/// is what a pooled scenario's `Agent` owns in place of a private backend.
pub struct SharedBackend(PoolSlot);

impl LlmBackend for SharedBackend {
    fn model_name(&self) -> &str {
        self.0.model_name()
    }

    fn submit(&self, req: AgentRequest) -> Result<RequestId> {
        self.0.submit(req)
    }

    fn try_recv(&self, id: RequestId) -> Result<Option<Completion>> {
        self.0.try_recv(id)
    }

    fn recv(&self, id: RequestId) -> Result<Completion> {
        self.0.recv(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::backend::Message;

    /// Scripted provider: echoes each item tagged with the round-trip
    /// index, and fails items whose last user message contains "poison".
    struct Scripted {
        calls: usize,
    }

    impl Scripted {
        fn new() -> Scripted {
            Scripted { calls: 0 }
        }
    }

    impl BatchLlm for Scripted {
        fn model_name(&self) -> &str {
            "scripted"
        }

        fn complete_batch(&mut self, reqs: &[AgentRequest]) -> Vec<Result<Completion>> {
            self.calls += 1;
            reqs.iter()
                .map(|r| {
                    let text = r.messages.last().map(|m| m.content.clone()).unwrap_or_default();
                    if text.contains("poison") {
                        Err(anyhow!("provider rejected item: {text}"))
                    } else {
                        Ok(Completion {
                            text: format!("call{}:{}", self.calls, text),
                            prompt_tokens: 3,
                            completion_tokens: 2,
                            api_seconds: 0.1,
                        })
                    }
                })
                .collect()
        }
    }

    fn req(text: &str) -> AgentRequest {
        AgentRequest::new(vec![Message::user(text)])
    }

    #[test]
    fn cap_fill_executes_one_provider_request_and_fans_out() {
        let b = BatchingBackend::new(Scripted::new(), 2);
        let a = b.submit(req("a")).unwrap();
        assert!(b.try_recv(a).unwrap().is_none(), "buffered, not in flight");
        let c = b.submit(req("b")).unwrap();
        let ca = b.try_recv(a).unwrap().expect("flushed at cap fill");
        let cb = b.try_recv(c).unwrap().expect("same batch");
        assert_eq!(ca.text, "call1:a");
        assert_eq!(cb.text, "call1:b");
        let st = b.stats();
        assert_eq!(st.provider_requests, 1, "two requests, one round-trip");
        assert_eq!(st.submitted, 2);
        assert_eq!(st.max_batch, 2);
        assert!(b.try_recv(a).is_err(), "a completion is handed out once");
    }

    #[test]
    fn explicit_flush_drains_a_partial_fill() {
        let b = BatchingBackend::new(Scripted::new(), 8);
        let a = b.submit(req("x")).unwrap();
        let c = b.submit(req("y")).unwrap();
        assert!(b.try_recv(a).unwrap().is_none());
        assert_eq!(b.flush(), 2, "partial buffer flushes on demand");
        assert_eq!(b.flush(), 0, "empty buffer is a no-op");
        assert_eq!(b.try_recv(a).unwrap().unwrap().text, "call1:x");
        assert_eq!(b.try_recv(c).unwrap().unwrap().text, "call1:y");
        assert_eq!(b.stats().provider_requests, 1);
    }

    #[test]
    fn batch_of_one_completes_at_submit() {
        let b = BatchingBackend::new(Scripted::new(), 1);
        let a = b.submit(req("solo")).unwrap();
        let c = b.try_recv(a).unwrap().expect("cap 1 flushes inside submit");
        assert_eq!(c.text, "call1:solo");
        assert_eq!(b.stats().provider_requests, 1);
        assert_eq!(b.stats().max_batch, 1);
    }

    #[test]
    fn one_poisoned_item_fails_alone_and_the_rest_complete() {
        let b = BatchingBackend::new(Scripted::new(), 3);
        let a = b.submit(req("ok1")).unwrap();
        let p = b.submit(req("poison pill")).unwrap();
        let c = b.submit(req("ok2")).unwrap();
        assert_eq!(b.try_recv(a).unwrap().unwrap().text, "call1:ok1");
        let err = b.try_recv(p).unwrap_err();
        assert!(format!("{err:#}").contains("poison"), "{err:#}");
        assert_eq!(b.try_recv(c).unwrap().unwrap().text, "call1:ok2");
        assert_eq!(b.stats().provider_requests, 1, "partial failure, one trip");
    }

    #[test]
    fn recv_flushes_a_buffered_request_instead_of_hanging() {
        let b = BatchingBackend::new(Scripted::new(), 16);
        let a = b.submit(req("blocked")).unwrap();
        let c = b.recv(a).unwrap();
        assert_eq!(c.text, "call1:blocked");
        assert_eq!(b.stats().max_batch, 1, "blocking receive is a flush point");
        let err = b.recv(a).unwrap_err();
        assert!(format!("{err:#}").contains("already received"), "{err:#}");
    }

    struct Panicky;

    impl BatchLlm for Panicky {
        fn model_name(&self) -> &str {
            "panicky"
        }

        fn complete_batch(&mut self, _reqs: &[AgentRequest]) -> Vec<Result<Completion>> {
            panic!("provider exploded mid-batch")
        }
    }

    #[test]
    fn provider_panic_completes_every_batched_id_with_an_error() {
        let b = BatchingBackend::new(Panicky, 2);
        let a = b.submit(req("a")).unwrap();
        // The cap-fill flush panics inside the provider; both ids must
        // still resolve (to errors), never hang their sessions.
        let c = b.submit(req("b")).unwrap();
        let ea = b.try_recv(a).unwrap_err();
        assert!(format!("{ea:#}").contains("panicked"), "{ea:#}");
        assert!(b.try_recv(c).is_err());
        assert_eq!(b.stats().provider_requests, 1);
    }

    #[test]
    fn unknown_ids_are_rejected() {
        let b = BatchingBackend::new(Scripted::new(), 2);
        assert!(b.try_recv(RequestId(9)).is_err());
        assert!(b.recv(RequestId(9)).is_err());
    }

    #[test]
    fn pool_shares_one_backend_per_spec_and_aggregates_stats() {
        let pool = AgentPool::new(4);
        let h1 = pool.backend("simulated").unwrap();
        let h2 = pool.backend(" simulated ").unwrap();
        let h3 = pool.backend("").unwrap();
        // Three handles (default spec spelled three ways), one buffer: all
        // submissions land in the same batch.  (Real prompts carry a
        // CONTEXT_JSON block; these don't, so the simulated policy fails
        // them — the sharing is what's under test.)
        let a = h1.submit(req("from h1")).unwrap();
        let c = h2.submit(req("from h2")).unwrap();
        let d = h3.submit(req("from h3")).unwrap();
        assert_eq!(pool.flush(), 3, "one shared buffer behind every handle");
        assert!(h1.try_recv(a).is_err(), "no CONTEXT_JSON: per-item error");
        assert!(h2.try_recv(c).is_err());
        assert!(h3.try_recv(d).is_err());
        let st = pool.stats();
        assert_eq!(st.submitted, 3);
        assert_eq!(st.provider_requests, 1);
        assert_eq!(st.max_batch, 3);
        assert!(pool.backend("telepathy").is_err(), "bad specs still fail");
    }
}
