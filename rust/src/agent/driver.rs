//! The agent driver: prompt assembly → backend request → validation → retry.
//!
//! This is the inner loop of Figure 3, restructured as a resumable state
//! machine over the request pipeline: [`Agent::submit_propose`] builds the
//! static+dynamic prompt and enqueues it on the backend; a later
//! [`Agent::poll_propose`] (non-blocking) or [`Agent::wait_propose`]
//! (blocking) consumes the completion, parses and validates it, and on a
//! §3.2 failure appends the corrective message and re-submits (bounded
//! retries — each retry is itself an in-flight request the fleet can
//! overlap).  The final fallback repairs the last reply into range so the
//! workflow never stalls.  [`Agent::propose`] is the one-call blocking
//! composition of the two halves, bit-identical to the pre-pipeline loop.

use anyhow::{anyhow, Result};

use crate::search::Config;

use super::backend::{
    AgentRequest, BlockingLlm, Completion, LlmBackend, Message, Pipelined, RequestId,
};
use super::history::HistoryManager;
use super::prompt::{dynamic_prompt, static_prompt, SYSTEM_PROMPT};
use super::react::{parse_completion, AgentReply};
use super::tokens::CostTracker;
use super::validator;
use super::TaskContext;

/// An in-flight proposal: the transcript sent, which retry attempt it is,
/// and the backend request to poll.  The conversation state lives here (not
/// in the backend), so the agent can be driven from any thread that holds
/// it between "prompt built" and "completion consumed".
#[derive(Debug)]
pub struct PendingPropose {
    messages: Vec<Message>,
    attempt: usize,
    id: RequestId,
    /// A completion fetched by [`Agent::completion_ready`] but not yet
    /// consumed by the validation step.
    arrived: Option<Completion>,
}

pub struct Agent {
    backend: Box<dyn LlmBackend>,
    pub history_mgr: HistoryManager,
    pub cost: CostTracker,
    pub max_retries: usize,
    /// Transcript of (thought, config) per round for the task log (§3.3).
    pub log: Vec<AgentReply>,
    /// The proposal currently in flight, if any.
    pending: Option<PendingPropose>,
    /// Static-prompt memo — the paper's point of the static/dynamic split
    /// is that the static half never changes within a task, so it is built
    /// once per (task, space) and reused every round (§Perf L3).
    static_memo: Option<(String, String)>,
}

impl Agent {
    pub fn new(backend: Box<dyn LlmBackend>) -> Agent {
        Agent {
            backend,
            history_mgr: HistoryManager::default(),
            cost: CostTracker::default(),
            max_retries: 3,
            log: Vec::new(),
            pending: None,
            static_memo: None,
        }
    }

    /// Convenience: drive a synchronous backend through the provided
    /// [`Pipelined`] adapter (the pre-pipeline construction shape).
    pub fn blocking<B: BlockingLlm + 'static>(backend: B) -> Agent {
        Agent::new(Box::new(Pipelined::new(backend)))
    }

    pub fn model_name(&self) -> &str {
        self.backend.model_name()
    }

    /// Is a proposal currently awaiting its completion?
    pub fn in_flight(&self) -> bool {
        self.pending.is_some()
    }

    fn build_messages(&mut self, ctx: &TaskContext) -> Vec<Message> {
        let window = self.history_mgr.window(ctx.history);
        let memo_key = format!("{}/{}", ctx.kind.as_str(), ctx.space.name);
        let static_text = match &self.static_memo {
            Some((k, text)) if *k == memo_key => text.clone(),
            _ => {
                let text = static_prompt(ctx);
                self.static_memo = Some((memo_key, text.clone()));
                text
            }
        };
        vec![
            Message::system(SYSTEM_PROMPT),
            Message::user(static_text),
            Message::user(dynamic_prompt(ctx, &window)),
        ]
    }

    /// Build this round's prompt and enqueue it on the backend.  The
    /// completion is consumed by [`Agent::poll_propose`] /
    /// [`Agent::wait_propose`] with the same `ctx`.
    pub fn submit_propose(&mut self, ctx: &TaskContext) -> Result<()> {
        if self.pending.is_some() {
            return Err(anyhow!("a proposal is already in flight"));
        }
        let messages = self.build_messages(ctx);
        let id = self.backend.submit(AgentRequest::new(messages.clone()))?;
        self.pending = Some(PendingPropose {
            messages,
            attempt: 0,
            id,
            arrived: None,
        });
        Ok(())
    }

    /// Non-blocking check whether the in-flight request's completion has
    /// arrived, without consuming the proposal — the cheap poll the fleet
    /// spins on while a session is parked (no prompt/context work happens
    /// until this returns `true`).  A backend error consumes the proposal
    /// (same as [`Agent::poll_propose`]).
    pub fn completion_ready(&mut self) -> Result<bool> {
        let (id, has_arrived) = match &self.pending {
            Some(p) => (p.id, p.arrived.is_some()),
            None => return Err(anyhow!("no proposal in flight — call submit_propose first")),
        };
        if has_arrived {
            return Ok(true);
        }
        match self.backend.try_recv(id) {
            Ok(Some(c)) => {
                if let Some(p) = self.pending.as_mut() {
                    p.arrived = Some(c);
                }
                Ok(true)
            }
            Ok(None) => Ok(false),
            Err(e) => {
                self.pending = None;
                Err(e)
            }
        }
    }

    /// Non-blocking: consume the in-flight completion if it has arrived.
    /// `Ok(None)` means it is still in flight — possibly because a §3.2
    /// validation failure was answered with a corrective re-submission.
    pub fn poll_propose(&mut self, ctx: &TaskContext) -> Result<Option<(Config, AgentReply)>> {
        self.step_propose(ctx, false)
    }

    /// Blocking: wait until the in-flight proposal resolves (including any
    /// retries) and return the validated configuration.
    pub fn wait_propose(&mut self, ctx: &TaskContext) -> Result<(Config, AgentReply)> {
        loop {
            if let Some(done) = self.step_propose(ctx, true)? {
                return Ok(done);
            }
        }
    }

    /// One round, blocking: submit + wait.  Bit-identical to the
    /// pre-pipeline `propose` loop.
    pub fn propose(&mut self, ctx: &TaskContext) -> Result<(Config, AgentReply)> {
        if self.pending.is_none() {
            self.submit_propose(ctx)?;
        }
        self.wait_propose(ctx)
    }

    /// Advance the proposal state machine by at most one completion.
    fn step_propose(
        &mut self,
        ctx: &TaskContext,
        block: bool,
    ) -> Result<Option<(Config, AgentReply)>> {
        let mut p = self
            .pending
            .take()
            .ok_or_else(|| anyhow!("no proposal in flight — call submit_propose first"))?;
        let completion = if let Some(c) = p.arrived.take() {
            c
        } else if block {
            self.backend.recv(p.id)?
        } else {
            match self.backend.try_recv(p.id)? {
                Some(c) => c,
                None => {
                    self.pending = Some(p);
                    return Ok(None);
                }
            }
        };
        self.cost.record_completion(&completion);
        let reply = parse_completion(&completion);
        match validator::check(ctx.space, &reply) {
            Ok(cfg) => {
                self.log.push(reply.clone());
                Ok(Some((cfg, reply)))
            }
            Err(err) if p.attempt < self.max_retries => {
                self.cost.record_retry();
                p.messages.push(Message::assistant(completion.text));
                p.messages
                    .push(Message::user(validator::retry_message(&err, ctx.space)));
                p.attempt += 1;
                p.id = self.backend.submit(AgentRequest::new(p.messages.clone()))?;
                self.pending = Some(p);
                Ok(None)
            }
            Err(_) => {
                // Fallback: repair whatever the agent last said (never
                // stall the workflow — §3.3's robustness requirement).
                let cfg = reply
                    .config
                    .as_ref()
                    .map(|j| ctx.space.repair(&ctx.space.config_from_json(j)))
                    .unwrap_or_else(|| ctx.space.default_config());
                self.log.push(reply.clone());
                Ok(Some((cfg, reply)))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::simulated::SimulatedLlm;
    use crate::agent::{TaskContext, TaskKind};
    use crate::optimizers::Observation;
    use crate::search::spaces;
    use crate::util::json::Json;

    #[test]
    fn retry_loop_recovers_from_injected_failures() {
        let space = spaces::resnet_qat();
        // 100% failure rate on first attempts; retries always valid.
        let backend = SimulatedLlm::new(1).with_failure_rate(1.0);
        let mut agent = Agent::blocking(backend);
        let history = vec![Observation::new(space.default_config(), 0.8)];
        let ctx = TaskContext {
            kind: TaskKind::Finetune,
            space: &space,
            history: &history,
            rounds_left: 4,
            hardware: None,
            objective: Json::obj(),
        };
        let (cfg, _) = agent.propose(&ctx).unwrap();
        assert!(space.is_valid(&cfg));
        assert!(agent.cost.retries >= 1, "no retry recorded");
        assert!(agent.cost.queries >= 2);
    }

    #[test]
    fn cost_accumulates_across_rounds() {
        let space = spaces::resnet_qat();
        let backend = SimulatedLlm::new(2).with_failure_rate(0.0);
        let mut agent = Agent::blocking(backend);
        let mut history = Vec::new();
        for round in 0..5 {
            let ctx = TaskContext {
                kind: TaskKind::Finetune,
                space: &space,
                history: &history,
                rounds_left: 5 - round,
                hardware: None,
                objective: Json::obj(),
            };
            let (cfg, _) = agent.propose(&ctx).unwrap();
            history.push(Observation::new(cfg, 0.5 + round as f64 * 0.01));
        }
        assert_eq!(agent.cost.queries, 5);
        assert!(agent.cost.total_tokens() > 1000);
        assert!(agent.cost.cost_usd() > 0.0);
        assert_eq!(agent.log.len(), 5);
        assert_eq!(agent.cost.per_query.len(), 5, "one cost line per query");
        assert!(agent.cost.per_query.iter().all(|q| q.prompt_tokens > 0));
    }

    #[test]
    fn split_submit_poll_matches_blocking_propose() {
        let space = spaces::resnet_qat();
        let history = vec![Observation::new(space.default_config(), 0.8)];
        let run = |split: bool| {
            let mut agent = Agent::blocking(SimulatedLlm::new(9).with_failure_rate(0.5));
            let ctx = TaskContext {
                kind: TaskKind::Finetune,
                space: &space,
                history: &history,
                rounds_left: 4,
                hardware: None,
                objective: Json::obj(),
            };
            let (cfg, reply) = if split {
                agent.submit_propose(&ctx).unwrap();
                loop {
                    if let Some(done) = agent.poll_propose(&ctx).unwrap() {
                        break done;
                    }
                }
            } else {
                agent.propose(&ctx).unwrap()
            };
            (space.config_to_json(&cfg).to_string(), reply.raw, agent.cost.queries)
        };
        assert_eq!(run(true), run(false), "split path must be bit-identical");
    }

    #[test]
    fn double_submit_is_rejected() {
        let space = spaces::resnet_qat();
        let mut agent = Agent::blocking(SimulatedLlm::new(3).with_failure_rate(0.0));
        let ctx = TaskContext {
            kind: TaskKind::Finetune,
            space: &space,
            history: &[],
            rounds_left: 1,
            hardware: None,
            objective: Json::obj(),
        };
        agent.submit_propose(&ctx).unwrap();
        assert!(agent.in_flight());
        assert!(agent.submit_propose(&ctx).is_err());
        agent.wait_propose(&ctx).unwrap();
        assert!(!agent.in_flight());
    }
}
