//! The agent driver: prompt assembly → backend call → validation → retry.
//!
//! This is the inner loop of Figure 3: each round, the static prompt and
//! the (history-managed) dynamic prompt are sent to the backend; the reply
//! is parsed and validated; on a §3.2 failure the corrective message is
//! appended and the backend re-queried (bounded retries); the final fallback
//! repairs the last reply into range so the workflow never stalls.

use anyhow::Result;

use crate::search::Config;

use super::backend::{LlmBackend, Message};
use super::history::HistoryManager;
use super::prompt::{dynamic_prompt, static_prompt, SYSTEM_PROMPT};
use super::react::{parse_reply, AgentReply};
use super::tokens::CostTracker;
use super::validator;
use super::TaskContext;

pub struct Agent {
    backend: Box<dyn LlmBackend>,
    pub history_mgr: HistoryManager,
    pub cost: CostTracker,
    pub max_retries: usize,
    /// Transcript of (thought, config) per round for the task log (§3.3).
    pub log: Vec<AgentReply>,
    /// Static-prompt memo — the paper's point of the static/dynamic split
    /// is that the static half never changes within a task, so it is built
    /// once per (task, space) and reused every round (§Perf L3).
    static_memo: Option<(String, String)>,
}

impl Agent {
    pub fn new(backend: Box<dyn LlmBackend>) -> Agent {
        Agent {
            backend,
            history_mgr: HistoryManager::default(),
            cost: CostTracker::default(),
            max_retries: 3,
            log: Vec::new(),
            static_memo: None,
        }
    }

    pub fn model_name(&self) -> &str {
        self.backend.model_name()
    }

    /// One round: returns the validated configuration and the reply.
    pub fn propose(&mut self, ctx: &TaskContext) -> Result<(Config, AgentReply)> {
        let window = self.history_mgr.window(ctx.history);
        let memo_key = format!("{}/{}", ctx.kind.as_str(), ctx.space.name);
        let static_text = match &self.static_memo {
            Some((k, text)) if *k == memo_key => text.clone(),
            _ => {
                let text = static_prompt(ctx);
                self.static_memo = Some((memo_key, text.clone()));
                text
            }
        };
        let mut messages = vec![
            Message::system(SYSTEM_PROMPT),
            Message::user(static_text),
            Message::user(dynamic_prompt(ctx, &window)),
        ];
        let mut last_reply: Option<AgentReply> = None;
        for attempt in 0..=self.max_retries {
            let completion = self.backend.complete(&messages)?;
            self.cost.record(&messages, &completion);
            let reply = parse_reply(&completion);
            match validator::check(ctx.space, &reply) {
                Ok(cfg) => {
                    self.log.push(reply.clone());
                    return Ok((cfg, reply));
                }
                Err(err) => {
                    last_reply = Some(reply);
                    if attempt < self.max_retries {
                        self.cost.record_retry();
                        messages.push(Message::assistant(completion));
                        messages.push(Message::user(validator::retry_message(
                            &err, ctx.space,
                        )));
                    }
                }
            }
        }
        // Fallback: repair whatever the agent last said (never stall the
        // workflow — §3.3's robustness requirement).
        let reply = last_reply.unwrap_or_else(|| parse_reply(""));
        let cfg = reply
            .config
            .as_ref()
            .map(|j| ctx.space.repair(&ctx.space.config_from_json(j)))
            .unwrap_or_else(|| ctx.space.default_config());
        self.log.push(reply.clone());
        Ok((cfg, reply))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::simulated::SimulatedLlm;
    use crate::agent::{TaskContext, TaskKind};
    use crate::optimizers::Observation;
    use crate::search::spaces;
    use crate::util::json::Json;

    #[test]
    fn retry_loop_recovers_from_injected_failures() {
        let space = spaces::resnet_qat();
        // 100% failure rate on first attempts; retries always valid.
        let backend = SimulatedLlm::new(1).with_failure_rate(1.0);
        let mut agent = Agent::new(Box::new(backend));
        let history = vec![Observation::new(space.default_config(), 0.8)];
        let ctx = TaskContext {
            kind: TaskKind::Finetune,
            space: &space,
            history: &history,
            rounds_left: 4,
            hardware: None,
            objective: Json::obj(),
        };
        let (cfg, _) = agent.propose(&ctx).unwrap();
        assert!(space.is_valid(&cfg));
        assert!(agent.cost.retries >= 1, "no retry recorded");
        assert!(agent.cost.queries >= 2);
    }

    #[test]
    fn cost_accumulates_across_rounds() {
        let space = spaces::resnet_qat();
        let backend = SimulatedLlm::new(2).with_failure_rate(0.0);
        let mut agent = Agent::new(Box::new(backend));
        let mut history = Vec::new();
        for round in 0..5 {
            let ctx = TaskContext {
                kind: TaskKind::Finetune,
                space: &space,
                history: &history,
                rounds_left: 5 - round,
                hardware: None,
                objective: Json::obj(),
            };
            let (cfg, _) = agent.propose(&ctx).unwrap();
            history.push(Observation::new(cfg, 0.5 + round as f64 * 0.01));
        }
        assert_eq!(agent.cost.queries, 5);
        assert!(agent.cost.total_tokens() > 1000);
        assert!(agent.cost.cost_usd() > 0.0);
        assert_eq!(agent.log.len(), 5);
    }
}
