//! The LLM-agent workflow (paper §3) — HAQA's core contribution.
//!
//! * [`backend`] — the request-oriented `LlmBackend` pipeline
//!   (`submit`/`try_recv`/`recv`) plus the [`backend::BlockingLlm`] trait
//!   and [`backend::Pipelined`] adapter for synchronous backends.  The
//!   paper uses GPT-4-0613; this repo ships [`simulated::SimulatedLlm`],
//!   a deterministic rule-based ReAct policy implementing the tuning
//!   heuristics visible in the paper's Appendix E transcripts (substitution
//!   table in DESIGN.md §2).
//! * [`batch`] — provider-side request batching: the [`batch::BatchLlm`]
//!   trait, the [`batch::BatchingBackend`] buffering adapter, and the
//!   fleet-level [`batch::AgentPool`] that coalesces many scenarios'
//!   in-flight proposals into one provider request (`--batch` /
//!   `HAQA_BATCH`).
//! * `http` — the real OpenAI-style HTTP backend (module and link exist
//!   only under the `http-agent` feature).
//! * [`transcript`] — record/replay journaling so live sessions replay
//!   offline and bit-identically (see `docs/AGENT.md`).
//! * [`prompt`] — static/dynamic prompt construction (§3.1, Fig. 2/3).
//! * [`history`] — conversation-history length management (§3.3).
//! * [`react`] — ReAct reply structure: Thought / Action / config JSON (§3.2).
//! * [`validator`] — format/range violation detection + retry loop (§3.2's
//!   three observed failure modes).
//! * [`tokens`] — token & cost accounting (Appendix C).

pub mod backend;
pub mod batch;
pub mod driver;
pub mod history;
#[cfg(feature = "http-agent")]
pub mod http;
pub mod prompt;
pub mod react;
pub mod simulated;
pub mod tokens;
pub mod transcript;
pub mod validator;

use anyhow::Result;

use crate::optimizers::Observation;
use crate::search::Space;
use crate::util::json::Json;

pub use backend::{
    AgentRequest, BlockingLlm, Completion, LlmBackend, Message, Pipelined, RequestId, Role, SlowLlm,
};
pub use batch::{AgentPool, BatchLlm, BatchStats, BatchingBackend, SharedBackend};
pub use driver::Agent;
pub use react::AgentReply;
pub use transcript::{BatchRecorder, BatchReplay, RecordingBackend, ReplayBackend};

/// Build a backend from a scenario's `backend` spec string:
///
/// * `"simulated"` (or empty) — the deterministic ReAct policy, seeded;
/// * `"simulated-slow:<ms>"` — the same policy behind `<ms>` of simulated
///   API latency, served asynchronously (the bench overlap stand-in);
/// * `"record:<path>"` — simulated policy journaled to `<path>`;
///   `"record:<path>=<inner-spec>"` journals any other backend (e.g.
///   `record:run.jsonl=http://10.0.0.5:8000` records a live endpoint for
///   later replay);
/// * `"replay:<path>"` — serve a recorded transcript journal, offline;
/// * `"chaos:<plan>=<inner-spec>"` — deterministic fault injection
///   ([`crate::coordinator::chaos`]) ahead of the inner backend's calls
///   (outermost wrapper only);
/// * `"http://host[:port][/path]"` — the real HTTP backend (needs the
///   `http-agent` feature).
///
/// The seed only feeds the simulated policy; recorded/replayed/HTTP
/// backends ignore it.
pub fn backend_from_spec(spec: &str, seed: u64) -> Result<Box<dyn LlmBackend>> {
    let spec = spec.trim();
    if spec.is_empty() || spec == "simulated" {
        return Ok(Box::new(Pipelined::new(simulated::SimulatedLlm::new(seed))));
    }
    if let Some(rest) = spec.strip_prefix("chaos:") {
        let (plan, inner_spec) = crate::coordinator::chaos::split_chaos_spec(rest)
            .map_err(|e| anyhow::anyhow!("in backend spec '{spec}': {e:#}"))?;
        anyhow::ensure!(
            !inner_spec.starts_with("chaos:"),
            "backend spec '{spec}' nests chaos wrappers — chaos takes a plain inner spec"
        );
        let inner = backend_from_spec(inner_spec, seed)?;
        return Ok(Box::new(crate::coordinator::chaos::ChaosBackend::new(
            plan, inner,
        )?));
    }
    if let Some(ms) = spec.strip_prefix("simulated-slow:") {
        let ms: u64 = ms.trim().parse().map_err(|_| {
            anyhow::anyhow!("bad latency '{ms}' in backend spec '{spec}' (expected milliseconds)")
        })?;
        return Ok(Box::new(SlowLlm::new(
            simulated::SimulatedLlm::new(seed),
            std::time::Duration::from_millis(ms),
        )));
    }
    if let Some(rest) = spec.strip_prefix("record:") {
        // Composable: `record:<path>` journals the simulated policy;
        // `record:<path>=<inner-spec>` wraps any other backend, so a live
        // HTTP session can be recorded for offline `replay:<path>`.
        let (path, inner_spec) = match rest.split_once('=') {
            Some((p, i)) => (p, i),
            None => (rest, "simulated"),
        };
        let inner = backend_from_spec(inner_spec, seed)?;
        return Ok(Box::new(RecordingBackend::create(path, inner)?));
    }
    if let Some(path) = spec.strip_prefix("replay:") {
        return Ok(Box::new(ReplayBackend::open(path)?));
    }
    if spec.starts_with("http://") || spec.starts_with("https://") {
        #[cfg(feature = "http-agent")]
        {
            return Ok(Box::new(http::HttpLlmBackend::from_url(spec)?));
        }
        #[cfg(not(feature = "http-agent"))]
        anyhow::bail!(
            "backend '{spec}' needs the `http-agent` feature \
             (build with --features http-agent)"
        );
    }
    anyhow::bail!(
        "unknown backend spec '{spec}' (expected simulated | simulated-slow:<ms> | \
         record:<path> | replay:<path> | chaos:<plan>=<spec> | http://…)"
    )
}

/// True when `spec` is a `replay:` backend, looking through an outer
/// `chaos:<plan>=` wrapper — replayed runs enforce strict agent errors
/// (a divergence from the recording must fail loudly) whether or not
/// faults are being injected around them.
pub fn is_replay_spec(spec: &str) -> bool {
    let s = spec.trim();
    let s = match s.strip_prefix("chaos:").and_then(|r| r.split_once('=')) {
        Some((_, inner)) => inner.trim(),
        None => s,
    };
    s.starts_with("replay:")
}

/// Build the *batch-capable* provider tree for a backend spec — the
/// `--batch` / `HAQA_BATCH` fleet mode's counterpart of
/// [`backend_from_spec`].  Same spec grammar, but every layer implements
/// [`BatchLlm`] so a [`batch::BatchingBackend`] on top can coalesce many
/// scenarios' requests into one provider call:
///
/// * `"simulated"` (or empty) — the **content-seeded** policy
///   ([`simulated::SimulatedLlm::stateless`]): a shared provider must
///   answer a given transcript identically for every scenario;
/// * `"simulated-slow:<ms>"` — the same policy behind `<ms>` of simulated
///   latency, paid **once per batch** rather than once per request;
/// * `"record:<path>[=<inner-spec>]"` — journal items *and batch
///   boundaries* through [`transcript::BatchRecorder`];
/// * `"replay:<path>"` — serve a recorded journal, enforcing the recorded
///   batch composition ([`transcript::BatchReplay`]);
/// * `"chaos:<plan>=<inner-spec>"` — deterministic fault injection per
///   provider batch ([`crate::coordinator::chaos`]), outermost only;
/// * `"http://…"` — one chat-JSON request per batch (`http-agent`
///   feature).
pub fn batch_llm_from_spec(spec: &str, seed: u64) -> Result<Box<dyn BatchLlm>> {
    let spec = spec.trim();
    if spec.is_empty() || spec == "simulated" {
        return Ok(Box::new(simulated::SimulatedLlm::stateless(seed)));
    }
    if let Some(rest) = spec.strip_prefix("chaos:") {
        let (plan, inner_spec) = crate::coordinator::chaos::split_chaos_spec(rest)
            .map_err(|e| anyhow::anyhow!("in backend spec '{spec}': {e:#}"))?;
        anyhow::ensure!(
            !inner_spec.starts_with("chaos:"),
            "backend spec '{spec}' nests chaos wrappers — chaos takes a plain inner spec"
        );
        let inner = batch_llm_from_spec(inner_spec, seed)?;
        return Ok(Box::new(crate::coordinator::chaos::ChaosBatchLlm::new(
            plan, inner,
        )?));
    }
    if let Some(ms) = spec.strip_prefix("simulated-slow:") {
        let ms: u64 = ms.trim().parse().map_err(|_| {
            anyhow::anyhow!("bad latency '{ms}' in backend spec '{spec}' (expected milliseconds)")
        })?;
        return Ok(Box::new(SlowLlm::new(
            simulated::SimulatedLlm::stateless(seed),
            std::time::Duration::from_millis(ms),
        )));
    }
    if let Some(rest) = spec.strip_prefix("record:") {
        let (path, inner_spec) = match rest.split_once('=') {
            Some((p, i)) => (p, i),
            None => (rest, "simulated"),
        };
        let inner = batch_llm_from_spec(inner_spec, seed)?;
        return Ok(Box::new(BatchRecorder::create(path, inner)?));
    }
    if let Some(path) = spec.strip_prefix("replay:") {
        return Ok(Box::new(BatchReplay::open(path)?));
    }
    if spec.starts_with("http://") || spec.starts_with("https://") {
        #[cfg(feature = "http-agent")]
        {
            return Ok(Box::new(http::HttpLlmBackend::from_url(spec)?));
        }
        #[cfg(not(feature = "http-agent"))]
        anyhow::bail!(
            "backend '{spec}' needs the `http-agent` feature \
             (build with --features http-agent)"
        );
    }
    anyhow::bail!(
        "unknown backend spec '{spec}' (expected simulated | simulated-slow:<ms> | \
         record:<path> | replay:<path> | chaos:<plan>=<spec> | http://…)"
    )
}

/// What the agent is optimizing this round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// Quantization fine-tuning hyperparameters (Table 1/2 track).
    Finetune,
    /// Per-kernel execution configuration (Table 3 track).
    KernelTuning,
    /// Deployment bit-width selection under constraints (Table 5 / §4.4).
    Bitwidth,
}

impl TaskKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            TaskKind::Finetune => "finetune",
            TaskKind::KernelTuning => "kernel_tuning",
            TaskKind::Bitwidth => "bitwidth",
        }
    }
}

/// Everything the prompt builder needs for one round.
pub struct TaskContext<'a> {
    pub kind: TaskKind,
    pub space: &'a Space,
    pub history: &'a [Observation],
    pub rounds_left: usize,
    /// Hardware platform description (Fig. 2a) — the §3.4 adaptive-strategy
    /// input.  JSON mirrors the paper's spec blocks.
    pub hardware: Option<Json>,
    /// Task-specific detail (model name, quantization bits, memory limit…).
    pub objective: Json,
}
