//! The LLM-agent workflow (paper §3) — HAQA's core contribution.
//!
//! * [`backend`] — the `LlmBackend` trait: messages in, completion out.
//!   The paper uses GPT-4-0613; this repo ships [`simulated::SimulatedLlm`],
//!   a deterministic rule-based ReAct policy implementing the tuning
//!   heuristics visible in the paper's Appendix E transcripts (substitution
//!   table in DESIGN.md §2).  A real HTTP backend can be slotted in without
//!   touching the workflow.
//! * [`prompt`] — static/dynamic prompt construction (§3.1, Fig. 2/3).
//! * [`history`] — conversation-history length management (§3.3).
//! * [`react`] — ReAct reply structure: Thought / Action / config JSON (§3.2).
//! * [`validator`] — format/range violation detection + retry loop (§3.2's
//!   three observed failure modes).
//! * [`tokens`] — token & cost accounting (Appendix C).

pub mod backend;
pub mod driver;
pub mod history;
pub mod prompt;
pub mod react;
pub mod simulated;
pub mod tokens;
pub mod validator;

use crate::optimizers::Observation;
use crate::search::Space;
use crate::util::json::Json;

pub use backend::{LlmBackend, Message, Role};
pub use driver::Agent;
pub use react::AgentReply;

/// What the agent is optimizing this round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// Quantization fine-tuning hyperparameters (Table 1/2 track).
    Finetune,
    /// Per-kernel execution configuration (Table 3 track).
    KernelTuning,
    /// Deployment bit-width selection under constraints (Table 5 / §4.4).
    Bitwidth,
}

impl TaskKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            TaskKind::Finetune => "finetune",
            TaskKind::KernelTuning => "kernel_tuning",
            TaskKind::Bitwidth => "bitwidth",
        }
    }
}

/// Everything the prompt builder needs for one round.
pub struct TaskContext<'a> {
    pub kind: TaskKind,
    pub space: &'a Space,
    pub history: &'a [Observation],
    pub rounds_left: usize,
    /// Hardware platform description (Fig. 2a) — the §3.4 adaptive-strategy
    /// input.  JSON mirrors the paper's spec blocks.
    pub hardware: Option<Json>,
    /// Task-specific detail (model name, quantization bits, memory limit…).
    pub objective: Json,
}
