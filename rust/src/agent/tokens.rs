//! Token & cost accounting (paper Appendix C).
//!
//! The paper reports ~150K tokens ≈ $5 and 2.34 s average round-trip per
//! query for end-to-end optimization of 2-3 models on GPT-4's list pricing.
//! We count estimated tokens per call (a ~4-chars/token word-piece
//! estimator, the standard rule of thumb for English+JSON) and price them
//! at GPT-4-0613 rates so every bench can print its Appendix-C line.

use super::backend::{Completion, Message};

/// GPT-4-0613 list pricing (USD per 1K tokens), as of the paper's writing.
pub const PROMPT_PRICE_PER_1K: f64 = 0.03;
pub const COMPLETION_PRICE_PER_1K: f64 = 0.06;

/// Paper-reported mean API round-trip (seconds), used by the simulated
/// backend's latency accounting (we do NOT sleep; we account).
pub const SIMULATED_ROUNDTRIP_S: f64 = 2.34;

/// Word-piece token estimate: ceil(chars / 4), plus a small per-message
/// framing overhead (role tags), matching OpenAI's accounting shape.
pub fn estimate_tokens(text: &str) -> usize {
    text.chars().count().div_ceil(4)
}

pub fn estimate_prompt_tokens(messages: &[Message]) -> usize {
    messages
        .iter()
        .map(|m| estimate_tokens(&m.content) + 4)
        .sum()
}

/// Per-request accounting line: what one backend query billed.  The
/// workflow aggregates these into per-round cost entries in the task log,
/// so agent latency/cost is auditable request by request (not just as the
/// final summary string).
#[derive(Debug, Clone)]
pub struct QueryCost {
    pub prompt_tokens: usize,
    pub completion_tokens: usize,
    /// Measured (real backends) or accounted (simulated) latency, seconds.
    pub api_seconds: f64,
}

#[derive(Debug, Clone, Default)]
pub struct CostTracker {
    pub queries: usize,
    pub prompt_tokens: usize,
    pub completion_tokens: usize,
    pub retries: usize,
    /// Accounted (not slept) API latency, seconds.
    pub api_seconds: f64,
    /// One entry per backend query, in completion-consumption order.
    pub per_query: Vec<QueryCost>,
}

impl CostTracker {
    /// Record a pipeline completion with its per-request accounting.
    pub fn record_completion(&mut self, c: &Completion) {
        self.queries += 1;
        self.prompt_tokens += c.prompt_tokens;
        self.completion_tokens += c.completion_tokens;
        self.api_seconds += c.api_seconds;
        self.per_query.push(QueryCost {
            prompt_tokens: c.prompt_tokens,
            completion_tokens: c.completion_tokens,
            api_seconds: c.api_seconds,
        });
    }

    pub fn record_retry(&mut self) {
        self.retries += 1;
    }

    pub fn total_tokens(&self) -> usize {
        self.prompt_tokens + self.completion_tokens
    }

    pub fn cost_usd(&self) -> f64 {
        self.prompt_tokens as f64 / 1000.0 * PROMPT_PRICE_PER_1K
            + self.completion_tokens as f64 / 1000.0 * COMPLETION_PRICE_PER_1K
    }

    /// The Appendix-C style one-liner.
    pub fn report(&self) -> String {
        format!(
            "agent cost: {} queries ({} retries), {} tokens ({} prompt + {} completion), \
             ≈ ${:.2} @ GPT-4 list pricing, {:.1} s accounted API latency \
             ({:.2} s/query)",
            self.queries,
            self.retries,
            self.total_tokens(),
            self.prompt_tokens,
            self.completion_tokens,
            self.cost_usd(),
            self.api_seconds,
            if self.queries > 0 {
                self.api_seconds / self.queries as f64
            } else {
                0.0
            },
        )
    }

    pub fn merge(&mut self, other: &CostTracker) {
        self.queries += other.queries;
        self.prompt_tokens += other.prompt_tokens;
        self.completion_tokens += other.completion_tokens;
        self.retries += other.retries;
        self.api_seconds += other.api_seconds;
        self.per_query.extend(other.per_query.iter().cloned());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_estimate_scales_with_length() {
        assert_eq!(estimate_tokens(""), 0);
        assert_eq!(estimate_tokens("abcd"), 1);
        assert_eq!(estimate_tokens("abcde"), 2);
    }

    /// Build a completion the way the `Pipelined` adapter does: estimated
    /// tokens, accounted round-trip latency.
    fn estimated(messages: &[Message], text: &str) -> Completion {
        Completion {
            prompt_tokens: estimate_prompt_tokens(messages),
            completion_tokens: estimate_tokens(text),
            api_seconds: SIMULATED_ROUNDTRIP_S,
            text: text.to_string(),
        }
    }

    #[test]
    fn cost_math() {
        let mut t = CostTracker::default();
        t.record_completion(&estimated(&[Message::user("x".repeat(4000))], &"y".repeat(2000)));
        assert_eq!(t.queries, 1);
        assert!(t.prompt_tokens >= 1000);
        // 1000 prompt tokens * 0.03/1k + 500 completion * 0.06/1k ≈ 0.06
        let c = t.cost_usd();
        assert!(c > 0.05 && c < 0.08, "{c}");
        assert_eq!(t.per_query.len(), 1);
        assert_eq!(t.per_query[0].api_seconds, SIMULATED_ROUNDTRIP_S);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = CostTracker::default();
        let mut b = CostTracker::default();
        a.record_completion(&estimated(&[Message::user("hello world")], "ok"));
        b.record_completion(&estimated(&[Message::user("hi")], "fine"));
        b.record_retry();
        a.merge(&b);
        assert_eq!(a.queries, 2);
        assert_eq!(a.retries, 1);
        assert_eq!(a.per_query.len(), 2);
    }
}
