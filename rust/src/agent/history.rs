//! Conversation-history length management (paper §3.3).
//!
//! The paper observed that unmanaged history exceeds the agent's context
//! window and interrupts the workflow; HAQA therefore keeps a budgeted
//! window.  Policy: always keep the *first* round (the anchor showing the
//! default-config result — the paper's transcripts reference it) plus the
//! most recent rounds that fit the token budget.

use crate::optimizers::Observation;

use super::tokens::estimate_tokens;

#[derive(Debug, Clone)]
pub struct HistoryManager {
    /// Token budget for the serialized history window.
    pub max_tokens: usize,
    /// Hard cap on entries regardless of tokens (user-controllable length,
    /// §3.3 "allows users to control the length of the optimization
    /// history").
    pub max_entries: usize,
}

impl Default for HistoryManager {
    fn default() -> Self {
        HistoryManager {
            max_tokens: 3000,
            max_entries: 16,
        }
    }
}

impl HistoryManager {
    /// Select the `(round_index, observation)` window to include.
    pub fn window<'a>(&self, history: &'a [Observation]) -> Vec<(usize, &'a Observation)> {
        if history.is_empty() {
            return Vec::new();
        }
        let cost = |o: &Observation| {
            estimate_tokens(&format!("{:?}", o.config)) + estimate_tokens(&o.feedback) + 16
        };
        let mut selected: Vec<usize> = Vec::new();
        let mut budget = self.max_tokens as i64;
        let last = history.len() - 1;

        // The latest round is the current feedback: always kept, whatever
        // the budget.  The anchor (round 0) is next in priority.
        selected.push(last);
        budget -= cost(&history[last]) as i64;
        if last != 0 {
            budget -= cost(&history[0]) as i64;
            if budget >= 0 || self.max_entries >= 2 {
                selected.push(0);
            }
        }

        // Then most recent first, then re-sort ascending.
        for i in (1..last).rev() {
            if selected.len() >= self.max_entries {
                break;
            }
            let c = cost(&history[i]) as i64;
            if budget - c < 0 {
                break;
            }
            budget -= c;
            selected.push(i);
        }
        selected.sort_unstable();
        selected.dedup();
        selected.into_iter().map(|i| (i, &history[i])).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::spaces;

    fn obs(feedback_len: usize) -> Observation {
        let space = spaces::resnet_qat();
        let mut o = Observation::new(space.default_config(), 0.5);
        o.feedback = "x".repeat(feedback_len);
        o
    }

    #[test]
    fn keeps_everything_when_small() {
        let h: Vec<Observation> = (0..5).map(|_| obs(10)).collect();
        let m = HistoryManager::default();
        assert_eq!(m.window(&h).len(), 5);
    }

    #[test]
    fn truncates_but_keeps_anchor_and_recent() {
        let h: Vec<Observation> = (0..50).map(|_| obs(400)).collect();
        let m = HistoryManager {
            max_tokens: 1200,
            max_entries: 16,
        };
        let w = m.window(&h);
        assert!(w.len() < 50);
        assert_eq!(w[0].0, 0, "anchor round dropped");
        assert_eq!(w.last().unwrap().0, 49, "most recent round dropped");
        // Window indices strictly increasing.
        assert!(w.windows(2).all(|p| p[0].0 < p[1].0));
    }

    #[test]
    fn entry_cap_respected() {
        let h: Vec<Observation> = (0..40).map(|_| obs(5)).collect();
        let m = HistoryManager {
            max_tokens: 100_000,
            max_entries: 8,
        };
        assert!(m.window(&h).len() <= 8);
    }
}
