//! `HttpLlmBackend` — an OpenAI-style chat-completions client over a plain
//! `std::net::TcpStream` (feature `http-agent`, default off; no new deps).
//!
//! This is the seam the paper's GPT-4-0613 driver lands on: requests are
//! the standard `{"model": …, "messages": [{"role", "content"}…]}` JSON,
//! replies are parsed from `choices[0].message.content`, and the server's
//! `usage` block feeds the per-request cost accounting (Appendix C) —
//! falling back to the local token estimator when the server omits it.
//!
//! Transport policy:
//! * plain HTTP only (`http://host[:port][/path]`) — TLS is expected to be
//!   terminated by a local proxy/sidecar; `https://` is rejected eagerly;
//! * per-attempt connect/read/write **timeouts**;
//! * **bounded exponential-backoff retry** on connect errors, timeouts,
//!   HTTP 429 and 5xx (client errors other than 429 are fatal);
//! * each request runs on a [`Dispatcher`] thread, so submissions never
//!   block and the fleet overlaps in-flight queries across scenarios.
//!
//! Wrap it in [`super::transcript::RecordingBackend`] to journal the
//! session for offline, bit-identical replay in CI.

use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::util::json::{self, Json};
use crate::util::retry::{Attempt, Backoff};

use super::backend::{AgentRequest, Completion, Dispatcher, LlmBackend, Message, RequestId};
use super::batch::BatchLlm;
use super::tokens::{estimate_prompt_tokens, estimate_tokens};

#[derive(Debug, Clone)]
pub struct HttpConfig {
    pub host: String,
    pub port: u16,
    /// Request path, e.g. `/v1/chat/completions`.
    pub path: String,
    /// Model name sent in the request body (`HAQA_LLM_MODEL` overrides).
    pub model: String,
    /// Bearer token (`HAQA_API_KEY`), if the endpoint needs one.
    pub api_key: Option<String>,
    /// Per-attempt connect/read/write timeout.
    pub timeout: Duration,
    /// Retries after the first attempt (connect errors, timeouts, 429, 5xx).
    pub max_retries: usize,
    /// First backoff delay; doubles per retry, capped at [`BACKOFF_CAP`].
    pub backoff_base: Duration,
}

/// Exponential backoff is bounded: base * 2^n, never beyond this.
pub const BACKOFF_CAP: Duration = Duration::from_secs(4);

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            host: "127.0.0.1".into(),
            port: 80,
            path: "/v1/chat/completions".into(),
            model: std::env::var("HAQA_LLM_MODEL").unwrap_or_else(|_| "gpt-4-0613".into()),
            api_key: std::env::var("HAQA_API_KEY").ok(),
            timeout: Duration::from_secs(60),
            max_retries: 3,
            backoff_base: Duration::from_millis(250),
        }
    }
}

pub struct HttpLlmBackend {
    cfg: Arc<HttpConfig>,
    label: String,
    dispatcher: Dispatcher,
}

impl HttpLlmBackend {
    pub fn new(cfg: HttpConfig) -> HttpLlmBackend {
        HttpLlmBackend {
            label: format!("{}@{}:{}", cfg.model, cfg.host, cfg.port),
            cfg: Arc::new(cfg),
            dispatcher: Dispatcher::new(),
        }
    }

    /// Parse `http://host[:port][/path]`; `https://` is rejected (terminate
    /// TLS in a local proxy).
    pub fn from_url(url: &str) -> Result<HttpLlmBackend> {
        if url.starts_with("https://") {
            bail!(
                "https endpoints are not supported by the std-TCP backend — \
                 terminate TLS in a local proxy and point HAQA at http://"
            );
        }
        let rest = url
            .strip_prefix("http://")
            .ok_or_else(|| anyhow!("LLM endpoint must start with http://, got '{url}'"))?;
        let (authority, path) = match rest.find('/') {
            Some(i) => (&rest[..i], &rest[i..]),
            None => (rest, ""),
        };
        let (host, port) = match authority.rsplit_once(':') {
            Some((h, p)) => (
                h.to_string(),
                p.parse::<u16>()
                    .map_err(|_| anyhow!("bad port in LLM endpoint '{url}'"))?,
            ),
            None => (authority.to_string(), 80),
        };
        if host.is_empty() {
            bail!("empty host in LLM endpoint '{url}'");
        }
        let defaults = HttpConfig::default();
        Ok(HttpLlmBackend::new(HttpConfig {
            host,
            port,
            path: if path.is_empty() {
                defaults.path.clone()
            } else {
                path.to_string()
            },
            ..defaults
        }))
    }
}

impl LlmBackend for HttpLlmBackend {
    fn model_name(&self) -> &str {
        &self.label
    }

    fn submit(&self, req: AgentRequest) -> Result<RequestId> {
        let cfg = Arc::clone(&self.cfg);
        Ok(self.dispatcher.submit(move || request_with_retry(&cfg, &req.messages)))
    }

    fn try_recv(&self, id: RequestId) -> Result<Option<Completion>> {
        self.dispatcher.try_recv(id)
    }

    fn recv(&self, id: RequestId) -> Result<Completion> {
        self.dispatcher.recv(id)
    }
}

impl BatchLlm for HttpLlmBackend {
    fn model_name(&self) -> &str {
        &self.label
    }

    /// Pack every transcript into **one** chat-JSON request —
    /// `{"model": …, "batch": [{"messages": […]}, …]}` — answered by a
    /// `{"results": […]}` array, one entry per item in request order: a
    /// standard completion object (its `usage` block feeds that item's
    /// cost accounting) or an `{"error": …}` object, which becomes that
    /// item's error while the rest of the batch still completes.  The
    /// single-request retry policy is preserved whole-batch: bounded
    /// exponential backoff on connect errors, timeouts, 429 and 5xx;
    /// other 4xx (and malformed reply bodies) are fatal.
    fn complete_batch(&mut self, reqs: &[AgentRequest]) -> Vec<Result<Completion>> {
        batch_request_with_retry(&self.cfg, reqs)
    }
}

fn request_body(model: &str, messages: &[Message]) -> String {
    let mut body = Json::obj();
    body.set("model", Json::str(model));
    body.set("messages", messages_json(messages));
    body.to_string()
}

/// Should this failure be retried (with backoff)?
fn retryable(status: Option<u16>) -> bool {
    match status {
        None => true, // connect/write/read failure or timeout
        Some(429) => true,
        Some(s) => (500..600).contains(&s),
    }
}

/// The one retry skeleton both the single-request and batch paths share
/// ([`crate::util::retry::Backoff`] with this transport's base/cap):
/// bounded exponential backoff on connect errors, timeouts, 429 and 5xx;
/// other 4xx are fatal; a 2xx whose body `parse` rejects is a broken
/// server, not a transient, so it never burns retries.
fn send_with_retry<T>(
    cfg: &HttpConfig,
    body: &str,
    parse: impl Fn(&str, f64) -> Result<T>,
) -> Result<T> {
    Backoff::new(cfg.max_retries, cfg.backoff_base, BACKOFF_CAP).run(|_| {
        let t0 = std::time::Instant::now();
        match request_once(cfg, body) {
            Ok((status, resp_body)) if (200..300).contains(&status) => {
                match parse(&resp_body, t0.elapsed().as_secs_f64()) {
                    Ok(v) => Attempt::Done(v),
                    Err(e) => Attempt::Fatal(e),
                }
            }
            Ok((status, resp_body)) => {
                let snip: String = resp_body.chars().take(200).collect();
                let err = anyhow!(
                    "HTTP {status} from {}:{}{}: {snip}",
                    cfg.host,
                    cfg.port,
                    cfg.path
                );
                if retryable(Some(status)) {
                    Attempt::Retry(err)
                } else {
                    Attempt::Fatal(err)
                }
            }
            Err(e) => Attempt::Retry(e),
        }
    })
}

fn request_with_retry(cfg: &HttpConfig, messages: &[Message]) -> Result<Completion> {
    let body = request_body(&cfg.model, messages);
    send_with_retry(cfg, &body, |resp, wall| {
        parse_completion_json(resp, messages, wall)
    })
}

fn messages_json(messages: &[Message]) -> Json {
    Json::Arr(
        messages
            .iter()
            .map(|m| {
                let mut o = Json::obj();
                o.set("role", Json::str(m.role.as_str()));
                o.set("content", Json::str(m.content.clone()));
                o
            })
            .collect(),
    )
}

fn batch_request_body(model: &str, reqs: &[AgentRequest]) -> String {
    let mut body = Json::obj();
    body.set("model", Json::str(model));
    body.set(
        "batch",
        Json::Arr(
            reqs.iter()
                .map(|r| {
                    let mut o = Json::obj();
                    o.set("messages", messages_json(&r.messages));
                    o
                })
                .collect(),
        ),
    );
    body.to_string()
}

/// Split a `{"results": […]}` reply back out into per-item completions.
/// The results array must be exactly `reqs.len()` long; a short or
/// malformed reply is a whole-batch error (the caller fails every slot).
fn parse_batch_results(
    body: &str,
    reqs: &[AgentRequest],
    wall_s: f64,
) -> Result<Vec<Result<Completion>>> {
    let j = json::parse(body).map_err(|e| anyhow!("bad batch-completion JSON: {e}"))?;
    let results = j
        .get("results")
        .and_then(|r| r.as_arr())
        .ok_or_else(|| anyhow!("no results array in batch completion"))?;
    if results.len() != reqs.len() {
        bail!(
            "batch completion has {} result(s) for {} request(s)",
            results.len(),
            reqs.len()
        );
    }
    Ok(results
        .iter()
        .zip(reqs)
        .map(|(item, req)| {
            if let Some(err) = item.get("error") {
                let msg = err
                    .get("message")
                    .and_then(|m| m.as_str())
                    .unwrap_or("unspecified provider error");
                return Err(anyhow!("provider rejected batch item: {msg}"));
            }
            completion_from_json(item, &req.messages, wall_s)
        })
        .collect())
}

fn batch_request_with_retry(cfg: &HttpConfig, reqs: &[AgentRequest]) -> Vec<Result<Completion>> {
    let body = batch_request_body(&cfg.model, reqs);
    match send_with_retry(cfg, &body, |resp, wall| parse_batch_results(resp, reqs, wall)) {
        Ok(per_item) => per_item,
        // Whole-batch failure: every item gets the transport error, so
        // partial batches never half-complete silently.
        Err(e) => {
            let msg = format!("{e:#}");
            reqs.iter()
                .map(|_| Err(anyhow!("batched request failed: {msg}")))
                .collect()
        }
    }
}

/// One HTTP/1.1 POST round-trip.  Returns (status, body).
fn request_once(cfg: &HttpConfig, body: &str) -> Result<(u16, String)> {
    let addr = (cfg.host.as_str(), cfg.port)
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| anyhow!("cannot resolve {}:{}", cfg.host, cfg.port))?;
    let mut stream = TcpStream::connect_timeout(&addr, cfg.timeout)?;
    stream.set_read_timeout(Some(cfg.timeout))?;
    stream.set_write_timeout(Some(cfg.timeout))?;

    let auth = cfg
        .api_key
        .as_deref()
        .map(|k| format!("Authorization: Bearer {k}\r\n"))
        .unwrap_or_default();
    let request = format!(
        "POST {} HTTP/1.1\r\nHost: {}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n{auth}Connection: close\r\n\r\n{body}",
        cfg.path,
        cfg.host,
        body.len(),
    );
    stream.write_all(request.as_bytes())?;

    // `Connection: close` lets us read to EOF; the per-socket timeout
    // still bounds a stalled server.
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw)?;
    parse_http_response(&raw)
}

fn parse_http_response(raw: &[u8]) -> Result<(u16, String)> {
    let head_end = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .ok_or_else(|| anyhow!("malformed HTTP response: no header terminator"))?;
    let head = std::str::from_utf8(&raw[..head_end])?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or_default();
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("malformed HTTP status line '{status_line}'"))?;
    let chunked = lines.clone().any(|l| {
        let l = l.to_ascii_lowercase();
        l.starts_with("transfer-encoding:") && l.contains("chunked")
    });
    let content_length: Option<usize> = lines
        .filter_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.eq_ignore_ascii_case("content-length")
                .then(|| v.trim().parse().ok())?
        })
        .next();

    let payload = &raw[head_end + 4..];
    let body_bytes = if chunked {
        decode_chunked(payload)?
    } else if let Some(n) = content_length {
        if payload.len() < n {
            bail!("truncated HTTP body: {} of {} bytes", payload.len(), n);
        }
        payload[..n].to_vec()
    } else {
        payload.to_vec() // Connection: close — body runs to EOF
    };
    Ok((status, String::from_utf8(body_bytes)?))
}

fn decode_chunked(mut rest: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    loop {
        let line_end = rest
            .windows(2)
            .position(|w| w == b"\r\n")
            .ok_or_else(|| anyhow!("malformed chunked body"))?;
        // A chunk-size line may carry extensions (`1a;name=value`, RFC 9112
        // §7.1.1): everything after the first `;` is ignored.
        let size_field = std::str::from_utf8(&rest[..line_end])?
            .split(';')
            .next()
            .unwrap_or("")
            .trim();
        let size = usize::from_str_radix(size_field, 16)
            .map_err(|_| anyhow!("malformed chunk size"))?;
        rest = &rest[line_end + 2..];
        if size == 0 {
            return Ok(out);
        }
        if rest.len() < size + 2 {
            bail!("truncated chunk: {} of {size} bytes", rest.len());
        }
        out.extend_from_slice(&rest[..size]);
        rest = &rest[size + 2..];
    }
}

fn parse_completion_json(body: &str, messages: &[Message], wall_s: f64) -> Result<Completion> {
    let j = json::parse(body).map_err(|e| anyhow!("bad completion JSON: {e}"))?;
    completion_from_json(&j, messages, wall_s)
}

/// Extract one completion object (`choices[0].message.content` + `usage`)
/// — shared by the single-request and batch reply paths.
fn completion_from_json(j: &Json, messages: &[Message], wall_s: f64) -> Result<Completion> {
    let text = j
        .get("choices")
        .and_then(|c| c.as_arr())
        .and_then(|a| a.first())
        .and_then(|c| c.get("message"))
        .and_then(|m| m.get("content"))
        .and_then(|t| t.as_str())
        .ok_or_else(|| anyhow!("no choices[0].message.content in completion"))?
        .to_string();
    let usage = j.get("usage");
    let prompt_tokens = usage
        .and_then(|u| u.get("prompt_tokens"))
        .and_then(|v| v.as_f64())
        .map(|v| v as usize)
        .unwrap_or_else(|| estimate_prompt_tokens(messages));
    let completion_tokens = usage
        .and_then(|u| u.get("completion_tokens"))
        .and_then(|v| v.as_f64())
        .map(|v| v as usize)
        .unwrap_or_else(|| estimate_tokens(&text));
    Ok(Completion {
        text,
        prompt_tokens,
        completion_tokens,
        api_seconds: wall_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufRead;
    use std::net::TcpListener;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Minimal in-process chat-completions stub.  Each accepted connection
    /// is answered per `script[i]` (i = connection index): `Ok(text)` →
    /// 200 with a usage block; `Err(status)` → that status; a negative
    /// status → accept, read, never respond (forces the client timeout).
    fn stub_server(script: Vec<Result<&'static str, i32>>) -> (u16, Arc<AtomicUsize>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let port = listener.local_addr().unwrap().port();
        let hits = Arc::new(AtomicUsize::new(0));
        let seen = Arc::clone(&hits);
        std::thread::spawn(move || {
            for action in script {
                let Ok((mut sock, _)) = listener.accept() else {
                    return;
                };
                seen.fetch_add(1, Ordering::SeqCst);
                // Read the request head + declared body.
                let mut reader = std::io::BufReader::new(sock.try_clone().unwrap());
                let mut content_length = 0usize;
                loop {
                    let mut line = String::new();
                    if reader.read_line(&mut line).is_err() || line == "\r\n" || line.is_empty() {
                        break;
                    }
                    if let Some((k, v)) = line.split_once(':') {
                        if k.eq_ignore_ascii_case("content-length") {
                            content_length = v.trim().parse().unwrap_or(0);
                        }
                    }
                }
                let mut body = vec![0u8; content_length];
                let _ = std::io::Read::read_exact(&mut reader, &mut body);
                match action {
                    Ok(text) => {
                        let mut msg = Json::obj();
                        msg.set("content", Json::str(text));
                        let mut choice = Json::obj();
                        choice.set("message", msg);
                        let mut usage = Json::obj();
                        usage.set("prompt_tokens", Json::Num(11.0));
                        usage.set("completion_tokens", Json::Num(7.0));
                        let mut resp = Json::obj();
                        resp.set("choices", Json::Arr(vec![choice]));
                        resp.set("usage", usage);
                        let body = resp.to_string();
                        let _ = sock.write_all(
                            format!(
                                "HTTP/1.1 200 OK\r\nContent-Length: {}\r\n\
                                 Connection: close\r\n\r\n{body}",
                                body.len()
                            )
                            .as_bytes(),
                        );
                    }
                    Err(status) if status > 0 => {
                        let _ = sock.write_all(
                            format!(
                                "HTTP/1.1 {status} X\r\nContent-Length: 5\r\n\
                                 Connection: close\r\n\r\noops!"
                            )
                            .as_bytes(),
                        );
                    }
                    Err(_) => {
                        // Stall: hold the socket open past the client
                        // timeout, then drop it.
                        std::thread::sleep(Duration::from_millis(300));
                    }
                }
            }
        });
        (port, hits)
    }

    fn client(port: u16, max_retries: usize) -> HttpLlmBackend {
        HttpLlmBackend::new(HttpConfig {
            host: "127.0.0.1".into(),
            port,
            timeout: Duration::from_millis(100),
            max_retries,
            backoff_base: Duration::from_millis(5),
            api_key: Some("test-key".into()),
            model: "test-model".into(),
            ..HttpConfig::default()
        })
    }

    fn ask(b: &HttpLlmBackend) -> Result<Completion> {
        b.complete(&[Message::user("propose a config")])
    }

    #[test]
    fn parses_completion_and_usage() {
        let (port, hits) = stub_server(vec![Ok("Thought: ok\n{\"lr\": 0.01}")]);
        let c = ask(&client(port, 0)).unwrap();
        assert_eq!(c.text, "Thought: ok\n{\"lr\": 0.01}");
        assert_eq!(c.prompt_tokens, 11, "server usage is authoritative");
        assert_eq!(c.completion_tokens, 7);
        assert!(c.api_seconds > 0.0);
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn retries_5xx_with_backoff_then_succeeds() {
        let (port, hits) = stub_server(vec![Err(500), Err(503), Ok("recovered")]);
        let c = ask(&client(port, 3)).unwrap();
        assert_eq!(c.text, "recovered");
        assert_eq!(hits.load(Ordering::SeqCst), 3, "two failures then success");
    }

    #[test]
    fn client_errors_are_fatal_not_retried() {
        let (port, hits) = stub_server(vec![Err(401), Ok("never served")]);
        let err = ask(&client(port, 3)).unwrap_err();
        assert!(format!("{err:#}").contains("401"), "{err:#}");
        assert_eq!(hits.load(Ordering::SeqCst), 1, "4xx must not retry");
    }

    #[test]
    fn timeout_is_retried_then_surfaced() {
        let (port, hits) = stub_server(vec![Err(-1), Err(-1)]);
        let err = ask(&client(port, 1)).unwrap_err();
        assert!(format!("{err:#}").contains("2 attempt"), "{err:#}");
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn url_parsing_and_https_rejection() {
        let b = HttpLlmBackend::from_url("http://example.com:8080/v2/chat").unwrap();
        assert_eq!(b.cfg.host, "example.com");
        assert_eq!(b.cfg.port, 8080);
        assert_eq!(b.cfg.path, "/v2/chat");
        let b = HttpLlmBackend::from_url("http://example.com").unwrap();
        assert_eq!(b.cfg.port, 80);
        assert_eq!(b.cfg.path, "/v1/chat/completions");
        assert!(HttpLlmBackend::from_url("https://example.com").is_err());
        assert!(HttpLlmBackend::from_url("ftp://example.com").is_err());
    }

    /// Batch-protocol stub: each accepted connection parses the batch
    /// request body and answers per `script[i]`: `Ok(items)` → 200 with a
    /// results array (each item `Ok(text)` → a completion object with a
    /// per-item usage block, `Err(msg)` → an error object); `Err(status)`
    /// → that HTTP status for the whole request.  A request whose batch
    /// length does not match the scripted items gets a 400.
    type BatchScript = Vec<Result<Vec<Result<&'static str, &'static str>>, i32>>;

    fn batch_stub(script: BatchScript) -> (u16, Arc<AtomicUsize>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let port = listener.local_addr().unwrap().port();
        let hits = Arc::new(AtomicUsize::new(0));
        let seen = Arc::clone(&hits);
        std::thread::spawn(move || {
            for action in script {
                let Ok((mut sock, _)) = listener.accept() else {
                    return;
                };
                seen.fetch_add(1, Ordering::SeqCst);
                let mut reader = std::io::BufReader::new(sock.try_clone().unwrap());
                let mut content_length = 0usize;
                loop {
                    let mut line = String::new();
                    if reader.read_line(&mut line).is_err() || line == "\r\n" || line.is_empty() {
                        break;
                    }
                    if let Some((k, v)) = line.split_once(':') {
                        if k.eq_ignore_ascii_case("content-length") {
                            content_length = v.trim().parse().unwrap_or(0);
                        }
                    }
                }
                let mut body = vec![0u8; content_length];
                let _ = std::io::Read::read_exact(&mut reader, &mut body);
                let respond = |sock: &mut std::net::TcpStream, status: u16, body: &str| {
                    let _ = sock.write_all(
                        format!(
                            "HTTP/1.1 {status} X\r\nContent-Length: {}\r\n\
                             Connection: close\r\n\r\n{body}",
                            body.len()
                        )
                        .as_bytes(),
                    );
                };
                match action {
                    Ok(items) => {
                        let n = std::str::from_utf8(&body)
                            .ok()
                            .and_then(|s| json::parse(s).ok())
                            .and_then(|j| j.get("batch").and_then(|b| b.as_arr()).map(|a| a.len()))
                            .unwrap_or(0);
                        if n != items.len() {
                            respond(&mut sock, 400, "batch length mismatch");
                            continue;
                        }
                        let mut results = Vec::new();
                        for (i, item) in items.into_iter().enumerate() {
                            match item {
                                Ok(text) => {
                                    let mut msg = Json::obj();
                                    msg.set("content", Json::str(text));
                                    let mut choice = Json::obj();
                                    choice.set("message", msg);
                                    let mut usage = Json::obj();
                                    usage.set("prompt_tokens", Json::Num(11.0 + i as f64));
                                    usage.set("completion_tokens", Json::Num(7.0 + i as f64));
                                    let mut r = Json::obj();
                                    r.set("choices", Json::Arr(vec![choice]));
                                    r.set("usage", usage);
                                    results.push(r);
                                }
                                Err(m) => {
                                    let mut e = Json::obj();
                                    e.set("message", Json::str(m));
                                    let mut r = Json::obj();
                                    r.set("error", e);
                                    results.push(r);
                                }
                            }
                        }
                        let mut resp = Json::obj();
                        resp.set("results", Json::Arr(results));
                        respond(&mut sock, 200, &resp.to_string());
                    }
                    Err(status) => respond(&mut sock, status as u16, "oops!"),
                }
            }
        });
        (port, hits)
    }

    fn batch_reqs(n: usize) -> Vec<AgentRequest> {
        (0..n)
            .map(|i| AgentRequest::new(vec![Message::user(format!("prompt {i}"))]))
            .collect()
    }

    #[test]
    fn batch_round_trip_splits_usage_per_item() {
        let (port, hits) = batch_stub(vec![Ok(vec![Ok("alpha"), Ok("beta")])]);
        let mut b = client(port, 0);
        let out = b.complete_batch(&batch_reqs(2));
        assert_eq!(out.len(), 2);
        let (a, c) = (out[0].as_ref().unwrap(), out[1].as_ref().unwrap());
        assert_eq!(a.text, "alpha");
        assert_eq!(c.text, "beta");
        assert_eq!(a.prompt_tokens, 11, "per-item usage split back out");
        assert_eq!(c.prompt_tokens, 12);
        assert_eq!(c.completion_tokens, 8);
        assert_eq!(hits.load(Ordering::SeqCst), 1, "one provider round-trip");
    }

    #[test]
    fn one_rejected_batch_item_fails_alone() {
        let (port, hits) = batch_stub(vec![Ok(vec![Ok("good"), Err("content filter")])]);
        let out = client(port, 0).complete_batch(&batch_reqs(2));
        assert_eq!(out[0].as_ref().unwrap().text, "good");
        let err = out[1].as_ref().unwrap_err();
        assert!(format!("{err:#}").contains("content filter"), "{err:#}");
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn batch_5xx_retries_the_whole_batch_then_succeeds() {
        let (port, hits) = batch_stub(vec![Err(503), Ok(vec![Ok("recovered")])]);
        let out = client(port, 2).complete_batch(&batch_reqs(1));
        assert_eq!(out[0].as_ref().unwrap().text, "recovered");
        assert_eq!(hits.load(Ordering::SeqCst), 2, "one failure then success");
    }

    #[test]
    fn batch_4xx_fails_every_slot_without_retry() {
        let (port, hits) = batch_stub(vec![Err(401), Ok(vec![Ok("never served")])]);
        let out = client(port, 3).complete_batch(&batch_reqs(2));
        assert!(out.iter().all(|r| r.is_err()));
        let err = out[0].as_ref().unwrap_err();
        assert!(format!("{err:#}").contains("401"), "{err:#}");
        assert_eq!(hits.load(Ordering::SeqCst), 1, "4xx must not retry");
    }

    #[test]
    fn chunked_bodies_decode() {
        // First chunk carries a chunk extension (RFC 9112 §7.1.1).
        let raw = b"HTTP/1.1 200 OK\r\nTransfer-Encoding: chunked\r\n\r\n\
                    5;ext=1\r\nhello\r\n6\r\n world\r\n0\r\n\r\n";
        let (status, body) = parse_http_response(raw).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "hello world");
    }
}
