//! ReAct reply structure (paper §3.2).
//!
//! The agent's completions interleave free-text reasoning with a JSON
//! configuration, exactly like the paper's Appendix E transcripts.  This
//! module extracts the structured parts: the Thought text, the Action
//! (proposed config JSON) and any declared code change flag.

use crate::util::json::{self, Json};

use super::backend::Completion;

#[derive(Debug, Clone)]
pub struct AgentReply {
    /// Free-text reasoning (the `Thought:` section, or the whole prose).
    pub thought: String,
    /// The proposed configuration object, if one was found.
    pub config: Option<Json>,
    /// The raw completion (for task logs).
    pub raw: String,
    /// Tokens billed for the request that produced this reply (0 when the
    /// reply was parsed from bare text rather than a pipeline completion).
    pub prompt_tokens: usize,
    pub completion_tokens: usize,
}

/// Parse a pipeline [`Completion`] into a structured reply, carrying the
/// per-request token accounting along for the task log.
pub fn parse_completion(c: &Completion) -> AgentReply {
    AgentReply {
        prompt_tokens: c.prompt_tokens,
        completion_tokens: c.completion_tokens,
        ..parse_reply(&c.text)
    }
}

/// Parse a completion's text into a structured reply.
pub fn parse_reply(raw: &str) -> AgentReply {
    let thought = raw
        .split("Thought:")
        .nth(1)
        .map(|rest| {
            rest.split("Action:")
                .next()
                .unwrap_or(rest)
                .trim()
                .to_string()
        })
        .unwrap_or_else(|| {
            // No explicit tag: treat leading prose (up to the JSON) as thought.
            raw.split('{').next().unwrap_or("").trim().to_string()
        });
    AgentReply {
        thought,
        config: json::extract_object(raw),
        raw: raw.to_string(),
        prompt_tokens: 0,
        completion_tokens: 0,
    }
}

/// Render a reply in the canonical ReAct form (used by the simulated
/// backend so its transcripts read like the paper's).
pub fn render_reply(thought: &str, config: &Json) -> String {
    format!(
        "Thought: {thought}\nAction: propose the next configuration.\n\
         The suggested new CONFIG is as follows: {}",
        config.to_string()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_tagged_reply() {
        let raw = "Thought: lr seems high; halving.\nAction: update config.\n\
                   {\"learning_rate\": 0.005, \"batch_size\": 128}";
        let r = parse_reply(raw);
        assert!(r.thought.contains("halving"));
        let cfg = r.config.unwrap();
        assert_eq!(cfg.req_f64("learning_rate").unwrap(), 0.005);
    }

    #[test]
    fn parses_untagged_prose_reply() {
        let raw = "From the training loss the model is improving. The \
                   suggested new CONFIG is as follows: {\"momentum\": 0.88}";
        let r = parse_reply(raw);
        assert!(r.thought.contains("improving"));
        assert_eq!(r.config.unwrap().req_f64("momentum").unwrap(), 0.88);
    }

    #[test]
    fn missing_json_yields_none() {
        let r = parse_reply("I cannot decide yet.");
        assert!(r.config.is_none());
    }

    #[test]
    fn render_then_parse_roundtrips() {
        let mut cfg = Json::obj();
        cfg.set("learning_rate", Json::Num(0.004));
        let raw = render_reply("continue the trend", &cfg);
        let r = parse_reply(&raw);
        assert_eq!(r.config.unwrap().req_f64("learning_rate").unwrap(), 0.004);
        assert!(r.thought.contains("continue"));
    }
}
