//! `SimulatedLlm` — the deterministic GPT-4 stand-in (DESIGN.md §2).
//!
//! Implements [`BlockingLlm`] (lifted into the request pipeline by
//! [`super::backend::Pipelined`]) with a rule-based ReAct policy that encodes the
//! tuning heuristics visible in the paper's Appendix E transcripts:
//!
//! * **fine-tuning**: first round defaults; continue a move that improved;
//!   roll back + redirect after a regression ("roll back the previous more
//!   aggressive optimization"); one-knob playbook moves on plateau; special
//!   handling for divergence (learning rate down) and low-bit instability.
//! * **kernel tuning**: hardware-informed initial launch geometry, then
//!   coordinate descent with rollback, reasoning about occupancy / register
//!   pressure / coalescing exactly like the appendix deployment transcript.
//! * **bit-width selection**: §3.4/§4.4 hardware analysis — feasibility from
//!   the memory model, preference order from native instruction support
//!   (tensor-core GPUs prefer INT4; mobile GPUs without native INT4 prefer
//!   INT8 despite the smaller bit-width "looking" faster).
//!
//! It also injects the paper's §3.2 failure modes at a configurable rate
//! (malformed replies, out-of-range values) so the validator/retry machinery
//! is exercised on every long run.
//!
//! The policy reads the canonical `CONTEXT_JSON:` block from the latest user
//! message — the same information a human/GPT-4 reads from the surrounding
//! prose — and returns a paper-style completion (Thought + JSON config).

use anyhow::{anyhow, Result};

use crate::search::param::{ParamKind, Value};
use crate::search::{Config, Space};
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

use super::backend::{AgentRequest, BlockingLlm, Message, Role};
use super::batch::BatchLlm;
use super::react::render_reply;
use super::tokens::{estimate_prompt_tokens, estimate_tokens, SIMULATED_ROUNDTRIP_S};
use super::transcript::transcript_key;
use super::Completion;

pub struct SimulatedLlm {
    rng: Rng,
    seed: u64,
    /// Content-seeded mode: each completion draws from an RNG derived from
    /// `(seed, transcript content)` instead of the instance's running
    /// stream, making the reply a pure function of the transcript — like a
    /// temperature-0 endpoint.  This is what lets one instance be shared
    /// (and batch-served) across scenarios without call order mattering.
    stateless: bool,
    /// Probability of emitting a §3.2 failure-mode reply (retries always
    /// produce a valid one, as GPT-4 does after correction).
    pub failure_rate: f64,
}

impl SimulatedLlm {
    pub fn new(seed: u64) -> Self {
        SimulatedLlm {
            rng: Rng::new(seed),
            seed,
            stateless: false,
            failure_rate: 0.05,
        }
    }

    /// The content-seeded policy (see the `stateless` field): same
    /// transcript ⇒ same completion, regardless of call order or sharing.
    /// This is the variant [`crate::agent::batch::AgentPool`] builds for
    /// the batched fleet.
    pub fn stateless(seed: u64) -> Self {
        let mut s = SimulatedLlm::new(seed);
        s.stateless = true;
        s
    }

    pub fn with_failure_rate(mut self, p: f64) -> Self {
        self.failure_rate = p;
        self
    }
}

impl BlockingLlm for SimulatedLlm {
    fn model_name(&self) -> &str {
        "simulated-react-policy"
    }

    fn complete(&mut self, messages: &[Message]) -> Result<String> {
        if self.stateless {
            let key = transcript_key(messages);
            let mut rng = Rng::new(self.seed ^ (key as u64) ^ ((key >> 64) as u64));
            complete_impl(messages, &mut rng, self.failure_rate)
        } else {
            complete_impl(messages, &mut self.rng, self.failure_rate)
        }
    }
}

impl BatchLlm for SimulatedLlm {
    fn model_name(&self) -> &str {
        "simulated-react-policy"
    }

    /// The native batch path: items complete in request order against the
    /// same policy the unbatched pipeline runs, so the offline default
    /// exercises exactly the code a provider-side batch would.
    fn complete_batch(&mut self, reqs: &[AgentRequest]) -> Vec<Result<Completion>> {
        reqs.iter()
            .map(|r| {
                BlockingLlm::complete(self, &r.messages).map(|text| Completion {
                    prompt_tokens: estimate_prompt_tokens(&r.messages),
                    completion_tokens: estimate_tokens(&text),
                    api_seconds: SIMULATED_ROUNDTRIP_S,
                    text,
                })
            })
            .collect()
    }
}

/// One policy step: parse the transcript's `CONTEXT_JSON` block, run the
/// task's rule-based policy, maybe inject a §3.2 failure mode.  Takes the
/// RNG explicitly so the stateful (instance stream) and stateless
/// (content-derived) modes share every other line of code.
fn complete_impl(messages: &[Message], rng: &mut Rng, failure_rate: f64) -> Result<String> {
    let ctx = extract_context(messages)
        .ok_or_else(|| anyhow!("no CONTEXT_JSON block in transcript"))?;
    let is_retry = messages
        .last()
        .map(|m| m.role == Role::User && m.content.contains("previous response was invalid"))
        .unwrap_or(false);

    let space = Space::from_json("ctx", ctx.req("space")?)?;
    let history = parse_history(&ctx, &space);
    let task = ctx.req_str("task")?.to_string();

    let (thought, cfg) = match task.as_str() {
        "kernel_tuning" => kernel_policy(&ctx, &space, &history, rng),
        "bitwidth" => bitwidth_policy(&ctx, &space),
        _ => finetune_policy(&ctx, &space, &history, rng),
    };
    let cfg = space.repair(&cfg);

    // §3.2 failure injection (never on a retry).
    if !is_retry && rng.bool(failure_rate) {
        return Ok(faulty_reply(rng, &space, &cfg, &thought));
    }
    Ok(render_reply(&thought, &space.config_to_json(&cfg)))
}

/// Emit one of the paper's three observed failure modes.
fn faulty_reply(rng: &mut Rng, space: &Space, cfg: &Config, thought: &str) -> String {
    match rng.usize(3) {
        0 => {
            // Mode 1: response without the required JSON format.
            format!(
                "Thought: {thought}\nI believe the next configuration \
                 should decrease the learning rate slightly and increase \
                 regularization, as discussed above."
            )
        }
        1 => {
            // Mode 2: a constraint violation (first numeric param 10x
            // over its upper bound).
            let mut bad = cfg.clone();
            if let Some(p) = space.params.iter().find(|p| {
                matches!(p.kind, ParamKind::Float { .. } | ParamKind::Int { .. })
            }) {
                let v = match &p.kind {
                    ParamKind::Float { hi, .. } => Value::Float(hi * 10.0),
                    ParamKind::Int { hi, .. } => Value::Int(hi * 10),
                    _ => unreachable!(),
                };
                bad.insert(p.name.clone(), v);
            }
            render_reply(thought, &space.config_to_json(&bad))
        }
        _ => {
            // Mode 3: irrelevant content around a broken JSON object.
            format!(
                "Thought: {thought}\nAs an aside, transformers were \
                 introduced in 2017 and attention scales quadratically. \
                 {{\"learning_rate\": oops}}"
            )
        }
    }
}

#[cfg(test)]
mod stateless_tests {
    use super::*;
    use crate::agent::prompt::dynamic_prompt;
    use crate::agent::{TaskContext, TaskKind};
    use crate::search::spaces;
    use crate::util::json::Json;

    fn kernel_prompt(batch: usize) -> Vec<Message> {
        let space = spaces::kernel_exec();
        let mut obj = Json::obj();
        obj.set("kernel", Json::str(format!("matmul:{batch}")));
        let ctx = TaskContext {
            kind: TaskKind::KernelTuning,
            space: &space,
            history: &[],
            rounds_left: 5,
            hardware: None,
            objective: obj,
        };
        vec![Message::user(dynamic_prompt(&ctx, &[]))]
    }

    /// The shared-provider contract: a content-seeded policy answers a
    /// given transcript identically whatever the call order, and two
    /// instances with the same seed agree — so pooled scenarios can share
    /// one instance and batches can execute in any composition.
    #[test]
    fn stateless_completions_are_order_invariant() {
        let (a, b) = (kernel_prompt(64), kernel_prompt(128));
        let mut fwd = SimulatedLlm::stateless(9);
        let fa = fwd.complete(&a).unwrap();
        let fb = fwd.complete(&b).unwrap();
        let mut rev = SimulatedLlm::stateless(9);
        let rb = rev.complete(&b).unwrap();
        let ra = rev.complete(&a).unwrap();
        assert_eq!(fa, ra, "call order must not change a completion");
        assert_eq!(fb, rb);
        // The stateful policy keeps its running stream (unchanged default).
        let mut stateful = SimulatedLlm::new(9);
        let sa1 = stateful.complete(&a).unwrap();
        let mut stateful2 = SimulatedLlm::new(9);
        assert_eq!(sa1, stateful2.complete(&a).unwrap());
    }

    /// The native batch path returns one completion per request, in
    /// order, matching the one-at-a-time path bit for bit.
    #[test]
    fn native_batch_matches_sequential_completion() {
        let reqs = vec![
            AgentRequest::new(kernel_prompt(64)),
            AgentRequest::new(kernel_prompt(128)),
        ];
        let batched = SimulatedLlm::stateless(4).complete_batch(&reqs);
        assert_eq!(batched.len(), 2);
        let mut seq = SimulatedLlm::stateless(4);
        for (r, b) in reqs.iter().zip(&batched) {
            let b = b.as_ref().expect("valid prompt completes");
            assert_eq!(b.text, seq.complete(&r.messages).unwrap());
            assert!(b.prompt_tokens > 0 && b.completion_tokens > 0);
        }
    }
}

// ---------------------------------------------------------------------------
// context parsing
// ---------------------------------------------------------------------------

fn extract_context(messages: &[Message]) -> Option<Json> {
    for m in messages.iter().rev() {
        if m.role != Role::User {
            continue;
        }
        for line in m.content.lines().rev() {
            if let Some(rest) = line.strip_prefix("CONTEXT_JSON: ") {
                if let Ok(v) = json::parse(rest) {
                    return Some(v);
                }
            }
        }
    }
    None
}

struct Hist {
    config: Config,
    score: f64,
    feedback: Json,
}

fn parse_history(ctx: &Json, space: &Space) -> Vec<Hist> {
    let mut out = Vec::new();
    if let Some(arr) = ctx.get("history").and_then(|h| h.as_arr()) {
        for item in arr {
            let config = item
                .get("config")
                .map(|c| space.config_from_json(c))
                .unwrap_or_default();
            let score = item.get("score").and_then(|s| s.as_f64()).unwrap_or(0.0);
            let feedback = item
                .get("feedback")
                .and_then(|f| f.as_str())
                .and_then(|s| json::parse(s).ok())
                .unwrap_or(Json::obj());
            out.push(Hist {
                config,
                score,
                feedback,
            });
        }
    }
    out
}

fn best_idx(history: &[Hist]) -> usize {
    let mut bi = 0;
    for (i, h) in history.iter().enumerate() {
        if h.score > history[bi].score {
            bi = i;
        }
    }
    bi
}

// ---------------------------------------------------------------------------
// fine-tuning policy
// ---------------------------------------------------------------------------

fn finetune_policy(
    ctx: &Json,
    space: &Space,
    history: &[Hist],
    rng: &mut Rng,
) -> (String, Config) {
    if history.is_empty() {
        return (
            "First round: it is recommended to use the default parameters \
             for training, establishing a calibrated baseline."
                .into(),
            space.default_config(),
        );
    }
    let last = history.len() - 1;
    let bi = best_idx(history);
    let best = &history[bi];
    let diverged = best.score - history[last].score > 0.25 * best.score.abs().max(0.05)
        || history[last]
            .feedback
            .get("diverged")
            .and_then(|v| v.as_bool())
            .unwrap_or(false);

    // Low-bit context: be conservative with lr, generous with budget.
    let low_bit = ctx
        .get("objective")
        .and_then(|o| o.get("bits"))
        .and_then(|b| b.as_f64())
        .map(|b| b <= 2.5)
        .unwrap_or(false);

    let mut cfg = best.config.clone();

    if diverged {
        scale(space, &mut cfg, "learning_rate", 0.35);
        scale(space, &mut cfg, "max_grad_norm", 0.7);
        return (
            "The last configuration regressed sharply — the loss list \
             suggests the model is skipping over minima. Rolling back to \
             the best configuration and reducing the learning rate and \
             gradient-clipping norm for fine-grained optimization."
                .into(),
            cfg,
        );
    }

    let improved_last = last == bi && history.len() >= 2;
    if improved_last {
        // Continue the successful direction with momentum (0.7 step).
        let prev_best = best_idx(&history[..last]);
        let u_prev = space.encode(&history[prev_best].config);
        let u_last = space.encode(&history[last].config);
        let u_next: Vec<f64> = u_prev
            .iter()
            .zip(&u_last)
            .map(|(p, l)| (l + 0.7 * (l - p)).clamp(0.0, 1.0))
            .collect();
        return (
            "The last change improved the validation result. The loss \
             trend is healthy, so I continue in the same direction with a \
             slightly smaller step to avoid overshooting."
                .into(),
            space.decode(&u_next),
        );
    }

    // Plateau / mild regression: one-knob playbook from the best config,
    // informed by the loss-curve feedback.
    let slope = history[last]
        .feedback
        .get("loss_slope")
        .and_then(|v| v.as_f64())
        .unwrap_or(-0.01);
    let round = history.len();
    if round % 4 == 0 {
        // Periodic exploration within a trust region of the incumbent
        // ("if the loss remains unchanged, explore different parts of the
        // search space" — the static prompt's own instruction).
        let mut u = space.encode(&cfg);
        for _ in 0..2 {
            let i = rng.usize(u.len());
            u[i] = (u[i] + rng.normal() * 0.2).clamp(0.0, 1.0);
        }
        return (
            "Results have plateaued around the incumbent. Exploring a \
             nearby region of the search space to find new features that \
             help accuracy."
                .into(),
            space.decode(&u),
        );
    }
    let thought;
    let low_score = best.score < 0.45; // far from a trained model's accuracy
    if slope > -8e-3 || low_score {
        // Loss flat (or accuracy still near chance): training is not making
        // real progress — raise the learning rate / training budget.
        if low_bit {
            scale(space, &mut cfg, "num_epochs", 1.4);
            scale(space, &mut cfg, "max_steps", 1.4);
            scale(space, &mut cfg, "learning_rate", 1.5);
            thought = "Loss has flattened under aggressive quantization; \
                       the straight-through gradients are small, so low-bit \
                       training needs a longer schedule and a *larger* \
                       learning rate to make progress — extending the \
                       budget and raising lr."
                .to_string();
        } else {
            scale(space, &mut cfg, "learning_rate", 2.2);
            scale(space, &mut cfg, "num_epochs", 1.3);
            scale(space, &mut cfg, "max_steps", 1.3);
            scale(space, &mut cfg, "lora_r", 1.5);
            scale(space, &mut cfg, "lora_alpha", 1.5);
            thought = "The training loss has flattened early and accuracy \
                       is far below what this model should reach — it is \
                       under-fitting. Increasing the learning rate, the \
                       training budget and the adapter capacity \
                       (lora_r/alpha) to add expressiveness."
                .to_string();
        }
    } else {
        // Loss still falling but validation flat: regularize.
        match round % 3 {
            0 => {
                scale(space, &mut cfg, "weight_decay", 2.5);
                scale(space, &mut cfg, "lora_dropout", 1.5);
                thought = "Training loss decreases while validation is \
                           flat — likely mild overfitting. Increasing \
                           weight decay (and adapter dropout) to control \
                           generalization error."
                    .to_string();
            }
            1 => {
                scale(space, &mut cfg, "learning_rate", 0.6);
                scale(space, &mut cfg, "batch_size", 0.75);
                scale(space, &mut cfg, "per_device_train_batch_size", 0.75);
                thought = "Now is a good time for finer-grained \
                           optimization: lower the learning rate and \
                           shrink the batch for more frequent parameter \
                           updates."
                    .to_string();
            }
            _ => {
                nudge_float(space, &mut cfg, "momentum", -0.04);
                scale(space, &mut cfg, "warmup_ratio", 1.5);
                thought = "Momentum can make the optimizer miss the \
                           minimum; reducing it slightly (and lengthening \
                           warmup) for a more careful descent."
                    .to_string();
            }
        }
    }
    (thought, cfg)
}

fn scale(space: &Space, cfg: &mut Config, name: &str, factor: f64) {
    if let Some(p) = space.get(name) {
        let v = cfg.get(name).cloned().unwrap_or_else(|| p.default.clone());
        let moved = match v {
            Value::Float(x) => Value::Float(x * factor),
            Value::Int(k) => Value::Int(((k as f64) * factor).round() as i64),
            other => other,
        };
        cfg.insert(name.to_string(), p.clamp(&moved));
    }
}

fn nudge_float(space: &Space, cfg: &mut Config, name: &str, delta: f64) {
    if let Some(p) = space.get(name) {
        let v = cfg.get(name).map(|v| v.as_f64()).unwrap_or(p.default.as_f64());
        cfg.insert(name.to_string(), p.clamp(&Value::Float(v + delta)));
    }
}

// ---------------------------------------------------------------------------
// kernel-tuning policy (deployment)
// ---------------------------------------------------------------------------

/// Coordinate-descent order with the appendix transcript's reasoning.
const KERNEL_KNOBS: &[&str] = &[
    "blockdim_x",
    "tiling_size",
    "unroll",
    "griddim_x",
    "memory_hierarchy",
    "simd_width",
    "prefetch",
    "layout",
    "loop_order",
];

fn kernel_policy(
    ctx: &Json,
    space: &Space,
    history: &[Hist],
    rng: &mut Rng,
) -> (String, Config) {
    let hw = ctx.get("hardware").cloned().unwrap_or(Json::obj());
    let is_matmul = ctx
        .get("objective")
        .and_then(|o| o.get("kernel"))
        .and_then(|k| k.as_str())
        .map(|k| k.contains("matmul"))
        .unwrap_or(false);
    let tensor_cores = hw
        .get("tensor_cores")
        .and_then(|v| v.as_bool())
        .unwrap_or(false);

    if history.is_empty() {
        // Hardware-informed starting point.
        let mut cfg = space.default_config();
        set_int(space, &mut cfg, "blockdim_x", if tensor_cores { 128 } else { 64 });
        set_int(space, &mut cfg, "griddim_x", 64);
        set_int(space, &mut cfg, "tiling_size", if is_matmul { 32 } else { 16 });
        set_int(space, &mut cfg, "unroll", 4);
        if is_matmul {
            set_cat(space, &mut cfg, "memory_hierarchy", "shared");
        }
        return (
            "Analyzing the hardware: given the SM count and shared-memory \
             size, a 128-thread block with a 32-wide tile in shared memory \
             should give good occupancy for this kernel; starting there."
                .into(),
            cfg,
        );
    }

    let last = history.len() - 1;
    let bi = best_idx(history);
    let improved_last = last == bi;
    let mut cfg = history[bi].config.clone();
    let knob = KERNEL_KNOBS[(history.len() - 1) % KERNEL_KNOBS.len()];

    if improved_last && history.len() >= 2 {
        // Push the knob that just worked, further in the same direction.
        let prev = &history[last - 1].config;
        for name in KERNEL_KNOBS {
            let (Some(a), Some(b)) = (prev.get(*name), cfg.get(*name)) else {
                continue;
            };
            if a != b {
                let dir = if b.as_f64() > a.as_f64() { 2.0 } else { 0.5 };
                scale(space, &mut cfg, name, dir);
                return (
                    format!(
                        "The last optimization significantly improved \
                         latency. Pushing {name} further in the same \
                         direction to exploit remaining headroom while \
                         watching for register pressure."
                    ),
                    cfg,
                );
            }
        }
    }

    // Rollback + next knob (the appendix's regression reasoning).
    let (thought, dir): (String, f64) = match knob {
        "blockdim_x" => (
            "The previous change regressed, likely from register pressure \
             and shared-memory contention. Rolling back to the best \
             configuration and rebalancing threads per block."
                .into(),
            if rng.bool(0.5) { 2.0 } else { 0.5 },
        ),
        "tiling_size" => (
            "Adjusting the tile size to improve data reuse in the memory \
             hierarchy without overflowing shared memory."
                .into(),
            if improved_last { 2.0 } else { 0.5 },
        ),
        "unroll" => (
            "Unrolling balances instruction-level parallelism against \
             register spills; moving the unroll factor one notch."
                .into(),
            if rng.bool(0.5) { 2.0 } else { 0.5 },
        ),
        "griddim_x" => (
            "Ensuring more SMs are occupied by adjusting the grid \
             dimension for balanced workload distribution."
                .into(),
            2.0,
        ),
        _ => (
            format!(
                "Switching the execution strategy knob '{knob}' to test an \
                 alternative memory/scheduling arrangement."
            ),
            1.0,
        ),
    };
    match knob {
        "memory_hierarchy" => cycle_cat(space, &mut cfg, knob),
        "layout" => cycle_cat(space, &mut cfg, knob),
        "loop_order" => cycle_cat(space, &mut cfg, knob),
        "simd_width" => scale(space, &mut cfg, knob, 2.0),
        "prefetch" => nudge_int(space, &mut cfg, knob, 4),
        _ => scale(space, &mut cfg, knob, dir),
    }
    (thought, cfg)
}

fn set_int(space: &Space, cfg: &mut Config, name: &str, v: i64) {
    if let Some(p) = space.get(name) {
        cfg.insert(name.to_string(), p.clamp(&Value::Int(v)));
    }
}

fn nudge_int(space: &Space, cfg: &mut Config, name: &str, d: i64) {
    if let Some(p) = space.get(name) {
        let v = cfg.get(name).map(|v| v.as_i64()).unwrap_or(0);
        cfg.insert(name.to_string(), p.clamp(&Value::Int(v + d)));
    }
}

fn set_cat(space: &Space, cfg: &mut Config, name: &str, v: &str) {
    if let Some(p) = space.get(name) {
        cfg.insert(name.to_string(), p.clamp(&Value::Cat(v.into())));
    }
}

fn cycle_cat(space: &Space, cfg: &mut Config, name: &str) {
    if let Some(p) = space.get(name) {
        if let ParamKind::Cat { choices } = &p.kind {
            let cur = cfg
                .get(name)
                .and_then(|v| v.as_str().map(|s| s.to_string()))
                .unwrap_or_else(|| choices[0].clone());
            let idx = choices.iter().position(|c| *c == cur).unwrap_or(0);
            let next = choices[(idx + 1) % choices.len()].clone();
            cfg.insert(name.to_string(), Value::Cat(next));
        }
    }
}

// ---------------------------------------------------------------------------
// bit-width policy (§3.4 adaptive quantization strategies)
// ---------------------------------------------------------------------------

fn bitwidth_policy(ctx: &Json, space: &Space) -> (String, Config) {
    let hw = ctx.get("hardware").cloned().unwrap_or(Json::obj());
    let obj = ctx.get("objective").cloned().unwrap_or(Json::obj());
    let limit_gb = obj
        .get("memory_limit_gb")
        .and_then(|v| v.as_f64())
        .unwrap_or(f64::INFINITY);
    let mem = obj.get("mem_gb").cloned().unwrap_or(Json::obj());
    let tensor_cores = hw.get("tensor_cores").and_then(|v| v.as_bool()).unwrap_or(false);
    let int4_native = hw.get("int4_native").and_then(|v| v.as_bool()).unwrap_or(false);
    let int8_native = hw.get("int8_native").and_then(|v| v.as_bool()).unwrap_or(true);

    // Preference order from the hardware analysis (paper §4.4):
    // tensor-core GPUs execute INT4 MMA with FP32 accumulate at the highest
    // throughput; platforms without native INT4 pay FP16-conversion and
    // bit-unpacking overhead, so INT8 wins there.
    let order: Vec<&str> = if tensor_cores && int4_native {
        vec!["INT4", "INT8", "FP16"]
    } else if int8_native {
        vec!["INT8", "FP16", "INT4"]
    } else {
        vec!["FP16", "INT8", "INT4"]
    };
    for q in &order {
        let fits = mem
            .get(q)
            .and_then(|v| v.as_f64())
            .map(|gb| gb <= limit_gb)
            .unwrap_or(false);
        if fits {
            let mut cfg = Config::new();
            cfg.insert("quant".to_string(), Value::Cat(q.to_string()));
            let thought = if *q == "INT8" && !int4_native {
                "This GPU does not natively support INT4: INT4 elements \
                 must be converted to FP16 with extra bitwise unpacking \
                 (shift/AND/OR) before accumulation, negating the expected \
                 benefit. INT8 hits the accelerated path, so despite the \
                 smaller bit-width looking faster on paper, INT8 is the \
                 right choice here — it also fits the memory limit."
                    .to_string()
            } else {
                format!(
                    "{q} fits within the {limit_gb} GB budget and maps onto \
                     this platform's fastest supported execution path \
                     (tensor-core MMA with FP32 accumulation), so I select \
                     {q}."
                )
            };
            return (thought, space.repair(&cfg));
        }
    }
    // Nothing fits: reject (the coordinator reports infeasibility, Table 5's
    // "x" cells).
    let mut cfg = Config::new();
    cfg.insert("quant".to_string(), Value::Cat("NONE".to_string()));
    (
        "No quantization type satisfies the memory limit on this device; \
         the deployment must be rejected."
            .into(),
        cfg,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::prompt::dynamic_prompt;
    use crate::agent::react::parse_reply;
    use crate::agent::{TaskContext, TaskKind};
    use crate::optimizers::Observation;
    use crate::search::spaces;

    fn run_round(
        kind: TaskKind,
        space: &Space,
        history: &[Observation],
        hardware: Option<Json>,
        objective: Json,
    ) -> String {
        let ctx = TaskContext {
            kind,
            space,
            history,
            rounds_left: 5,
            hardware,
            objective,
        };
        let window: Vec<(usize, &Observation)> = history.iter().enumerate().collect();
        let prompt = dynamic_prompt(&ctx, &window);
        let mut llm = SimulatedLlm::new(3).with_failure_rate(0.0);
        llm.complete(&[Message::user(prompt)]).unwrap()
    }

    #[test]
    fn first_round_proposes_defaults() {
        let space = spaces::resnet_qat();
        let raw = run_round(TaskKind::Finetune, &space, &[], None, Json::obj());
        let cfg = space.config_from_json(&parse_reply(&raw).config.unwrap());
        assert_eq!(space.repair(&cfg), space.default_config());
    }

    #[test]
    fn divergence_triggers_lr_cut() {
        let space = spaces::resnet_qat();
        let mut h = vec![Observation::new(space.default_config(), 0.80)];
        let mut bad = space.default_config();
        bad.insert("learning_rate".into(), Value::Float(0.15));
        let mut o = Observation::new(bad, 0.10);
        o.feedback = "{\"diverged\": true}".into();
        h.push(o);
        let raw = run_round(TaskKind::Finetune, &space, &h, None, Json::obj());
        assert!(raw.contains("Rolling back"), "{raw}");
        let cfg = space.config_from_json(&parse_reply(&raw).config.unwrap());
        let lr = cfg["learning_rate"].as_f64();
        assert!(lr < 0.01, "lr {lr} not reduced from best 0.01");
    }

    #[test]
    fn mobile_hardware_prefers_int8() {
        let space = spaces::bitwidth();
        let mut hw = Json::obj();
        hw.set("tensor_cores", Json::Bool(false));
        hw.set("int4_native", Json::Bool(false));
        hw.set("int8_native", Json::Bool(true));
        let mut obj = Json::obj();
        obj.set("memory_limit_gb", Json::Num(10.0));
        let mut mem = Json::obj();
        mem.set("FP16", Json::Num(6.0));
        mem.set("INT8", Json::Num(3.0));
        mem.set("INT4", Json::Num(1.5));
        obj.set("mem_gb", mem);
        let raw = run_round(TaskKind::Bitwidth, &space, &[], Some(hw), obj);
        assert!(raw.contains("INT8"), "{raw}");
        let cfg = space.config_from_json(&parse_reply(&raw).config.unwrap());
        assert_eq!(cfg["quant"].as_str(), Some("INT8"));
    }

    #[test]
    fn a6000_prefers_int4_when_it_fits() {
        let space = spaces::bitwidth();
        let mut hw = Json::obj();
        hw.set("tensor_cores", Json::Bool(true));
        hw.set("int4_native", Json::Bool(true));
        hw.set("int8_native", Json::Bool(true));
        let mut obj = Json::obj();
        obj.set("memory_limit_gb", Json::Num(10.0));
        let mut mem = Json::obj();
        mem.set("FP16", Json::Num(26.0));
        mem.set("INT8", Json::Num(13.0));
        mem.set("INT4", Json::Num(6.5));
        obj.set("mem_gb", mem);
        let raw = run_round(TaskKind::Bitwidth, &space, &[], Some(hw), obj);
        let cfg = space.config_from_json(&parse_reply(&raw).config.unwrap());
        assert_eq!(cfg["quant"].as_str(), Some("INT4"));
    }

    #[test]
    fn failure_injection_produces_invalid_replies_sometimes() {
        let space = spaces::resnet_qat();
        let history = vec![Observation::new(space.default_config(), 0.8)];
        let ctx = TaskContext {
            kind: TaskKind::Finetune,
            space: &space,
            history: &history,
            rounds_left: 5,
            hardware: None,
            objective: Json::obj(),
        };
        let window: Vec<(usize, &Observation)> = history.iter().enumerate().collect();
        let prompt = dynamic_prompt(&ctx, &window);
        let mut llm = SimulatedLlm::new(7).with_failure_rate(1.0);
        let raw = llm.complete(&[Message::user(prompt)]).unwrap();
        let reply = parse_reply(&raw);
        let invalid = match &reply.config {
            None => true,
            Some(j) => !space.is_valid(&space.config_from_json(j)),
        };
        assert!(invalid, "expected an injected failure: {raw}");
    }
}
