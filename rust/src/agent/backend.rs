//! The LLM backend interface: a request/response pipeline.
//!
//! The workflow is backend-agnostic: the paper runs GPT-4-0613 over HTTP;
//! this repo runs [`super::simulated::SimulatedLlm`] so results are
//! deterministic and offline.  Anything that maps a chat transcript to a
//! completion can drive HAQA.
//!
//! Since the fleet overlaps many scenarios' agent queries, the backend is
//! **request-oriented**: [`LlmBackend::submit`] enqueues a transcript and
//! returns a [`RequestId`]; [`LlmBackend::try_recv`] polls it without
//! blocking and [`LlmBackend::recv`] waits for it.  Synchronous backends
//! (the simulated policy, a recorded-transcript replay) implement the
//! plain [`BlockingLlm`] trait instead and are lifted into the pipeline by
//! the provided [`Pipelined`] adapter, which completes requests at submit
//! time — so every pre-pipeline call site keeps working and stays
//! bit-identical.  Genuinely asynchronous backends (HTTP, the
//! latency-simulating [`SlowLlm`]) run each request on a [`Dispatcher`]
//! thread and overlap with whatever the fleet evaluates meanwhile.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::util::lock;

use super::tokens::{estimate_prompt_tokens, estimate_tokens, SIMULATED_ROUNDTRIP_S};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    System,
    User,
    Assistant,
}

impl Role {
    pub fn as_str(&self) -> &'static str {
        match self {
            Role::System => "system",
            Role::User => "user",
            Role::Assistant => "assistant",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Message {
    pub role: Role,
    pub content: String,
}

impl Message {
    pub fn system(content: impl Into<String>) -> Message {
        Message {
            role: Role::System,
            content: content.into(),
        }
    }

    pub fn user(content: impl Into<String>) -> Message {
        Message {
            role: Role::User,
            content: content.into(),
        }
    }

    pub fn assistant(content: impl Into<String>) -> Message {
        Message {
            role: Role::Assistant,
            content: content.into(),
        }
    }
}

/// One chat-completion request: the full transcript to complete.
#[derive(Debug, Clone)]
pub struct AgentRequest {
    pub messages: Vec<Message>,
}

impl AgentRequest {
    pub fn new(messages: Vec<Message>) -> AgentRequest {
        AgentRequest { messages }
    }
}

/// Handle for an in-flight request (backend-local, monotonically issued).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RequestId(pub u64);

/// A finished completion with its per-request accounting (Appendix C).
#[derive(Debug, Clone)]
pub struct Completion {
    /// The assistant's reply text.
    pub text: String,
    /// Prompt tokens billed for this request (estimated, or the server's
    /// `usage.prompt_tokens` for HTTP backends).
    pub prompt_tokens: usize,
    /// Completion tokens billed for this request.
    pub completion_tokens: usize,
    /// Round-trip latency in seconds: measured for real backends,
    /// *accounted* ([`SIMULATED_ROUNDTRIP_S`]) for simulated ones.
    pub api_seconds: f64,
}

/// A request-oriented chat-completion backend.
///
/// Submission and receipt are decoupled so the fleet can keep many
/// scenarios' queries in flight at once.  Implementations share state
/// behind `&self` (interior mutability); each agent conversation keeps at
/// most one request in flight, but distinct agents may share one backend.
pub trait LlmBackend: Send {
    /// Human-readable model identifier (logged in task logs / cost report).
    fn model_name(&self) -> &str;

    /// Enqueue a transcript for completion.
    fn submit(&self, req: AgentRequest) -> Result<RequestId>;

    /// Non-blocking poll: `Ok(None)` while the request is still in flight.
    /// A completion is handed out exactly once.
    fn try_recv(&self, id: RequestId) -> Result<Option<Completion>>;

    /// Blocking receive.
    fn recv(&self, id: RequestId) -> Result<Completion>;

    /// Provided blocking adapter: submit + recv in one call.
    fn complete(&self, messages: &[Message]) -> Result<Completion> {
        let id = self.submit(AgentRequest::new(messages.to_vec()))?;
        self.recv(id)
    }
}

/// A synchronous chat backend: the pre-pipeline `LlmBackend` shape.
///
/// Implementors (the simulated ReAct policy, transcript replay) are lifted
/// into the request pipeline with [`Pipelined`], or given artificial
/// latency with [`SlowLlm`].
pub trait BlockingLlm: Send {
    fn model_name(&self) -> &str;

    /// Produce the assistant completion for a transcript.
    fn complete(&mut self, messages: &[Message]) -> Result<String>;
}

// ---------------------------------------------------------------------------
// SyncMailbox: the hand-out-once store for complete-at-submit backends
// ---------------------------------------------------------------------------

/// Completion store for synchronous pipeline backends ([`Pipelined`],
/// [`super::transcript::ReplayBackend`]): results exist the moment they
/// are submitted, ids are monotonic, and each completion is handed out
/// exactly once — a second receive (or an id never issued) is an error,
/// since a synchronous backend is never "still in flight".
#[derive(Default)]
pub struct SyncMailbox {
    next_id: u64,
    done: HashMap<u64, Result<Completion>>,
}

impl SyncMailbox {
    pub fn push(&mut self, result: Result<Completion>) -> RequestId {
        let id = self.next_id;
        self.next_id += 1;
        self.done.insert(id, result);
        RequestId(id)
    }

    pub fn take(&mut self, id: RequestId, label: &str) -> Result<Completion> {
        match self.done.remove(&id.0) {
            Some(r) => r,
            None => Err(anyhow!(
                "unknown or already-received request {} on '{label}'",
                id.0
            )),
        }
    }
}

// ---------------------------------------------------------------------------
// Pipelined: the blocking adapter
// ---------------------------------------------------------------------------

struct PipeInner<B> {
    backend: B,
    mail: SyncMailbox,
}

/// Lifts a [`BlockingLlm`] into the request pipeline by completing each
/// request synchronously at submit time.  `try_recv` therefore always
/// succeeds on the first poll — the behavior (and, for deterministic
/// backends, the output) is bit-identical to calling the blocking backend
/// directly, which is what keeps the serial and pipelined fleet paths
/// interchangeable.
pub struct Pipelined<B> {
    model: String,
    inner: Mutex<PipeInner<B>>,
}

impl<B: BlockingLlm> Pipelined<B> {
    pub fn new(backend: B) -> Pipelined<B> {
        Pipelined {
            model: backend.model_name().to_string(),
            inner: Mutex::new(PipeInner {
                backend,
                mail: SyncMailbox::default(),
            }),
        }
    }
}

impl<B: BlockingLlm> LlmBackend for Pipelined<B> {
    fn model_name(&self) -> &str {
        &self.model
    }

    fn submit(&self, req: AgentRequest) -> Result<RequestId> {
        let mut g = lock(&self.inner);
        let result = g.backend.complete(&req.messages).map(|text| Completion {
            prompt_tokens: estimate_prompt_tokens(&req.messages),
            completion_tokens: estimate_tokens(&text),
            api_seconds: SIMULATED_ROUNDTRIP_S,
            text,
        });
        Ok(g.mail.push(result))
    }

    fn try_recv(&self, id: RequestId) -> Result<Option<Completion>> {
        lock(&self.inner).mail.take(id, &self.model).map(Some)
    }

    fn recv(&self, id: RequestId) -> Result<Completion> {
        lock(&self.inner).mail.take(id, &self.model)
    }
}

// ---------------------------------------------------------------------------
// Dispatcher: one-thread-per-request async executor
// ---------------------------------------------------------------------------

struct DispatchState {
    next_id: u64,
    done: HashMap<u64, Result<Completion>>,
    /// Ids whose completion was already handed out — polling one again is
    /// a caller bug and must error (the `Pipelined` contract), not park
    /// forever on the condvar.
    delivered: HashSet<u64>,
}

/// Shared completion mailbox for asynchronous backends: `submit` runs the
/// work closure on a detached thread; `recv` blocks on a condvar.  The
/// in-flight count is bounded externally (`HAQA_INFLIGHT` caps how many
/// scenarios have a query outstanding), so a thread per request stays
/// cheap.
#[derive(Clone)]
pub struct Dispatcher {
    state: Arc<(Mutex<DispatchState>, Condvar)>,
}

impl Default for Dispatcher {
    fn default() -> Self {
        Dispatcher::new()
    }
}

impl Dispatcher {
    pub fn new() -> Dispatcher {
        Dispatcher {
            state: Arc::new((
                Mutex::new(DispatchState {
                    next_id: 0,
                    done: HashMap::new(),
                    delivered: HashSet::new(),
                }),
                Condvar::new(),
            )),
        }
    }

    pub fn submit<F>(&self, work: F) -> RequestId
    where
        F: FnOnce() -> Result<Completion> + Send + 'static,
    {
        let id = {
            let mut g = lock(&self.state.0);
            let id = g.next_id;
            g.next_id += 1;
            id
        };
        let state = Arc::clone(&self.state);
        std::thread::spawn(move || {
            // A panicking work closure must still deliver *something*:
            // otherwise a blocking `recv` parks on the condvar forever and
            // a pipelined fleet polls `Ok(None)` until the end of time.
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(work))
                .unwrap_or_else(|p| {
                    Err(anyhow!(
                        "backend request panicked: {}",
                        crate::util::panic_message(&p)
                    ))
                });
            let (m, cv) = &*state;
            lock(m).done.insert(id, out);
            cv.notify_all();
        });
        RequestId(id)
    }

    pub fn try_recv(&self, id: RequestId) -> Result<Option<Completion>> {
        let mut g = lock(&self.state.0);
        if id.0 >= g.next_id {
            return Err(anyhow!("request {} was never submitted", id.0));
        }
        if g.delivered.contains(&id.0) {
            return Err(anyhow!("request {} was already received", id.0));
        }
        match g.done.remove(&id.0) {
            Some(r) => {
                g.delivered.insert(id.0);
                r.map(Some)
            }
            None => Ok(None),
        }
    }

    pub fn recv(&self, id: RequestId) -> Result<Completion> {
        let (m, cv) = &*self.state;
        let mut g = lock(m);
        if id.0 >= g.next_id {
            return Err(anyhow!("request {} was never submitted", id.0));
        }
        if g.delivered.contains(&id.0) {
            return Err(anyhow!("request {} was already received", id.0));
        }
        loop {
            if let Some(r) = g.done.remove(&id.0) {
                g.delivered.insert(id.0);
                return r;
            }
            g = cv.wait(g).unwrap_or_else(|p| p.into_inner());
        }
    }
}

// ---------------------------------------------------------------------------
// SlowLlm: simulated API latency over a blocking backend
// ---------------------------------------------------------------------------

/// Wraps a [`BlockingLlm`] with artificial per-request latency, served
/// asynchronously.  This is the `haqa bench` agent-overlap stand-in for a
/// real HTTP round-trip: the completion *text* is exactly what the inner
/// backend produces (so results stay bit-identical to the un-slowed run),
/// but the reply arrives `latency` later on a dispatcher thread, giving
/// the fleet something real to overlap.
pub struct SlowLlm<B> {
    model: String,
    inner: Mutex<B>,
    latency: Duration,
    dispatcher: Dispatcher,
}

impl<B: BlockingLlm + 'static> SlowLlm<B> {
    pub fn new(backend: B, latency: Duration) -> SlowLlm<B> {
        SlowLlm {
            model: format!("{}+{}ms", backend.model_name(), latency.as_millis()),
            inner: Mutex::new(backend),
            latency,
            dispatcher: Dispatcher::new(),
        }
    }
}

impl<B: BlockingLlm + 'static> super::batch::BatchLlm for SlowLlm<B> {
    fn model_name(&self) -> &str {
        &self.model
    }

    /// One amortized round-trip for the whole batch: every item completes
    /// against the inner backend in request order (so deterministic
    /// backends stay deterministic), then the single simulated API latency
    /// is paid once — which is exactly the economics provider-side
    /// batching buys over per-request calls.
    fn complete_batch(&mut self, reqs: &[AgentRequest]) -> Vec<Result<Completion>> {
        let t0 = std::time::Instant::now();
        let texts: Vec<Result<String>> = {
            let mut g = lock(&self.inner);
            reqs.iter().map(|r| g.complete(&r.messages)).collect()
        };
        std::thread::sleep(self.latency);
        let wall = t0.elapsed().as_secs_f64();
        reqs.iter()
            .zip(texts)
            .map(|(r, text)| {
                text.map(|text| Completion {
                    prompt_tokens: estimate_prompt_tokens(&r.messages),
                    completion_tokens: estimate_tokens(&text),
                    api_seconds: wall,
                    text,
                })
            })
            .collect()
    }
}

impl<B: BlockingLlm + 'static> LlmBackend for SlowLlm<B> {
    fn model_name(&self) -> &str {
        &self.model
    }

    fn submit(&self, req: AgentRequest) -> Result<RequestId> {
        // Compute on the submitting thread so the inner backend sees
        // requests strictly in submission order (its RNG stream stays
        // deterministic however delivery threads are scheduled); only the
        // *delivery* is delayed asynchronously.
        let t0 = std::time::Instant::now();
        let text = lock(&self.inner).complete(&req.messages)?;
        let latency = self.latency;
        Ok(self.dispatcher.submit(move || {
            std::thread::sleep(latency);
            Ok(Completion {
                prompt_tokens: estimate_prompt_tokens(&req.messages),
                completion_tokens: estimate_tokens(&text),
                api_seconds: t0.elapsed().as_secs_f64(),
                text,
            })
        }))
    }

    fn try_recv(&self, id: RequestId) -> Result<Option<Completion>> {
        self.dispatcher.try_recv(id)
    }

    fn recv(&self, id: RequestId) -> Result<Completion> {
        self.dispatcher.recv(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Echoes the last user message, counting calls.
    struct Echo {
        calls: usize,
    }

    impl BlockingLlm for Echo {
        fn model_name(&self) -> &str {
            "echo"
        }
        fn complete(&mut self, messages: &[Message]) -> Result<String> {
            self.calls += 1;
            Ok(format!(
                "echo#{}: {}",
                self.calls,
                messages.last().map(|m| m.content.as_str()).unwrap_or("")
            ))
        }
    }

    #[test]
    fn pipelined_completes_at_submit_and_hands_out_once() {
        let b = Pipelined::new(Echo { calls: 0 });
        let id = b.submit(AgentRequest::new(vec![Message::user("hi")])).unwrap();
        let c = b.try_recv(id).unwrap().expect("ready at first poll");
        assert_eq!(c.text, "echo#1: hi");
        assert!(c.prompt_tokens > 0 && c.completion_tokens > 0);
        assert!(b.try_recv(id).is_err(), "a completion is handed out once");
    }

    #[test]
    fn pipelined_blocking_adapter_round_trips() {
        let b = Pipelined::new(Echo { calls: 0 });
        let c = b.complete(&[Message::user("one")]).unwrap();
        assert_eq!(c.text, "echo#1: one");
        let c = b.complete(&[Message::user("two")]).unwrap();
        assert_eq!(c.text, "echo#2: two");
        assert_eq!(c.api_seconds, SIMULATED_ROUNDTRIP_S);
    }

    #[test]
    fn slow_backend_overlaps_and_preserves_text() {
        let b = SlowLlm::new(Echo { calls: 0 }, Duration::from_millis(30));
        let t0 = std::time::Instant::now();
        let a = b.submit(AgentRequest::new(vec![Message::user("a")])).unwrap();
        let c = b.submit(AgentRequest::new(vec![Message::user("b")])).unwrap();
        // Both requests are in flight concurrently: total wall well under
        // two sequential latencies.
        let ca = b.recv(a).unwrap();
        let cb = b.recv(c).unwrap();
        let wall = t0.elapsed();
        assert_eq!(ca.text, "echo#1: a");
        assert_eq!(cb.text, "echo#2: b");
        assert!(
            wall < Duration::from_millis(55),
            "requests did not overlap: {wall:?}"
        );
        assert!(ca.api_seconds >= 0.03);
    }

    #[test]
    fn dispatcher_rejects_unknown_ids() {
        let d = Dispatcher::new();
        assert!(d.try_recv(RequestId(5)).is_err());
        assert!(d.recv(RequestId(5)).is_err());
    }

    #[test]
    fn dispatcher_surfaces_a_panicking_work_closure_as_an_error() {
        let d = Dispatcher::new();
        let id = d.submit(|| panic!("boom in the request path"));
        let err = d.recv(id).unwrap_err();
        assert!(format!("{err:#}").contains("boom"), "{err:#}");
    }

    #[test]
    fn dispatcher_errors_on_double_receive_instead_of_hanging() {
        let d = Dispatcher::new();
        let id = d.submit(|| {
            Ok(Completion {
                text: "x".into(),
                prompt_tokens: 1,
                completion_tokens: 1,
                api_seconds: 0.0,
            })
        });
        d.recv(id).unwrap();
        // A second receive of the same id is a caller bug: it must error
        // like `Pipelined` does, never park on the condvar forever.
        let err = d.recv(id).unwrap_err();
        assert!(format!("{err:#}").contains("already received"), "{err:#}");
        assert!(d.try_recv(id).is_err());
    }
}
