//! The LLM backend interface.
//!
//! The workflow is backend-agnostic: the paper runs GPT-4-0613 over HTTP;
//! this repo runs [`super::simulated::SimulatedLlm`] so results are
//! deterministic and offline.  Anything that maps a chat transcript to a
//! completion can drive HAQA.

use anyhow::Result;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    System,
    User,
    Assistant,
}

impl Role {
    pub fn as_str(&self) -> &'static str {
        match self {
            Role::System => "system",
            Role::User => "user",
            Role::Assistant => "assistant",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Message {
    pub role: Role,
    pub content: String,
}

impl Message {
    pub fn system(content: impl Into<String>) -> Message {
        Message {
            role: Role::System,
            content: content.into(),
        }
    }

    pub fn user(content: impl Into<String>) -> Message {
        Message {
            role: Role::User,
            content: content.into(),
        }
    }

    pub fn assistant(content: impl Into<String>) -> Message {
        Message {
            role: Role::Assistant,
            content: content.into(),
        }
    }
}

/// A chat-completion backend.
pub trait LlmBackend {
    /// Human-readable model identifier (logged in task logs / cost report).
    fn model_name(&self) -> &str;

    /// Produce the assistant completion for a transcript.
    fn complete(&mut self, messages: &[Message]) -> Result<String>;
}
