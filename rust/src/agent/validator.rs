//! Response validation + retry-message construction (paper §3.2).
//!
//! The paper lists three failure modes observed in agent replies:
//!   1. responses that do not adhere to the required format,
//!   2. configurations violating predefined constraints,
//!   3. irrelevant information unrelated to the task.
//! The validator detects (1) and (2) — (3) is harmless once (1)/(2) pass,
//! because only the extracted JSON drives the workflow — and produces the
//! corrective user message for the retry loop.

use crate::search::{space::Violation, Config, Space};

use super::react::AgentReply;

#[derive(Debug, Clone)]
pub enum ValidationError {
    /// No JSON configuration could be extracted (failure mode 1).
    NoConfig,
    /// The config violates the declared space (failure mode 2).
    Violations(Vec<Violation>),
}

impl std::fmt::Display for ValidationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ValidationError::NoConfig => {
                write!(f, "the reply did not contain a JSON configuration")
            }
            ValidationError::Violations(v) => {
                let msgs: Vec<String> = v.iter().map(|x| x.to_string()).collect();
                write!(f, "{}", msgs.join("; "))
            }
        }
    }
}

/// Check a reply against a space; returns the parsed config when valid.
pub fn check(space: &Space, reply: &AgentReply) -> Result<Config, ValidationError> {
    let Some(cfg_json) = &reply.config else {
        return Err(ValidationError::NoConfig);
    };
    let cfg = space.config_from_json(cfg_json);
    let violations = space.validate(&cfg);
    // Unknown keys alone are tolerated (the paper's agent sometimes echoes
    // extra fields like "code_changed"); range/missing errors are not.
    let hard: Vec<Violation> = violations
        .into_iter()
        .filter(|v| !matches!(v, Violation::UnknownKey(_)))
        .collect();
    if hard.is_empty() {
        // Strip unknown keys for the returned config.
        let clean: Config = cfg
            .into_iter()
            .filter(|(k, _)| space.get(k).is_some())
            .collect();
        Ok(clean)
    } else {
        Err(ValidationError::Violations(hard))
    }
}

/// The corrective message sent back to the agent on validation failure.
pub fn retry_message(err: &ValidationError, space: &Space) -> String {
    format!(
        "Your previous response was invalid: {err}. Please provide exactly \
         one configuration in JSON format with every hyperparameter inside \
         its declared range. The search space is:\n{}",
        space.describe()
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::react::parse_reply;
    use crate::search::spaces;

    #[test]
    fn accepts_valid_config_with_extra_keys() {
        let space = spaces::resnet_qat();
        let reply = parse_reply(
            "{\"learning_rate\": 0.004, \"batch_size\": 170, \"weight_decay\": \
             0.0009, \"momentum\": 0.9, \"num_epochs\": 12, \"code_changed\": \
             \"false\"}",
        );
        let cfg = check(&space, &reply).unwrap();
        assert_eq!(cfg.len(), 5);
    }

    #[test]
    fn rejects_out_of_range() {
        let space = spaces::resnet_qat();
        let reply = parse_reply("{\"learning_rate\": 5.0, \"batch_size\": 128, \
             \"weight_decay\": 0.0005, \"momentum\": 0.9, \"num_epochs\": 12}");
        match check(&space, &reply) {
            Err(ValidationError::Violations(v)) => assert_eq!(v.len(), 1),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_missing_keys_and_no_json() {
        let space = spaces::resnet_qat();
        assert!(matches!(
            check(&space, &parse_reply("{\"learning_rate\": 0.01}")),
            Err(ValidationError::Violations(_))
        ));
        assert!(matches!(
            check(&space, &parse_reply("thinking...")),
            Err(ValidationError::NoConfig)
        ));
    }

    #[test]
    fn retry_message_names_the_problem() {
        let space = spaces::resnet_qat();
        let err = check(&space, &parse_reply("no json here")).unwrap_err();
        let msg = retry_message(&err, &space);
        assert!(msg.contains("JSON"));
        assert!(msg.contains("learning_rate"));
    }
}
