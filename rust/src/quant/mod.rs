//! Quantization schemes and Rust-side reference implementations.
//!
//! * [`Scheme`] — deployment bit-widths (FP16/INT8/INT4, Table 4/5, Fig. 5)
//!   and QAT precisions (w8a8/w4a4/w2a2, Table 1).
//! * [`dorefa`] — DoReFa fake-quantization in Rust, the oracle used by the
//!   property tests to cross-check the simulator's quantization assumptions
//!   and by the deploy engine to quantize host-side weights.

pub mod dorefa;

/// Deployment quantization type (paper Tables 3-5, Figure 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    FP16,
    INT8,
    INT4,
}

impl Scheme {
    pub const ALL: [Scheme; 3] = [Scheme::FP16, Scheme::INT8, Scheme::INT4];

    pub fn label(&self) -> &'static str {
        match self {
            Scheme::FP16 => "FP16",
            Scheme::INT8 => "INT8",
            Scheme::INT4 => "INT4",
        }
    }

    pub fn parse(s: &str) -> Option<Scheme> {
        match s.to_ascii_uppercase().as_str() {
            "FP16" => Some(Scheme::FP16),
            "INT8" => Some(Scheme::INT8),
            "INT4" => Some(Scheme::INT4),
            _ => None,
        }
    }

    pub fn bits(&self) -> u32 {
        match self {
            Scheme::FP16 => 16,
            Scheme::INT8 => 8,
            Scheme::INT4 => 4,
        }
    }

    pub fn bytes_per_weight(&self) -> f64 {
        self.bits() as f64 / 8.0
    }

    /// The runtime `bits` scalar fed to the DoReFa artifacts ("FP16" is
    /// modelled as 16-level-exponent quantization, effectively lossless for
    /// these models).
    pub fn dorefa_bits(&self) -> f32 {
        self.bits() as f32
    }
}

/// QAT precision pair (Table 1 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QatPrecision {
    pub wbits: u32,
    pub abits: u32,
}

impl QatPrecision {
    pub const W8A8: QatPrecision = QatPrecision { wbits: 8, abits: 8 };
    pub const W4A4: QatPrecision = QatPrecision { wbits: 4, abits: 4 };
    pub const W2A2: QatPrecision = QatPrecision { wbits: 2, abits: 2 };
    pub const TABLE1: [QatPrecision; 3] =
        [QatPrecision::W8A8, QatPrecision::W4A4, QatPrecision::W2A2];

    pub fn label(&self) -> String {
        format!("w{}a{}", self.wbits, self.abits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_roundtrip_and_sizes() {
        for s in Scheme::ALL {
            assert_eq!(Scheme::parse(s.label()), Some(s));
        }
        assert_eq!(Scheme::FP16.bytes_per_weight(), 2.0);
        assert_eq!(Scheme::INT4.bytes_per_weight(), 0.5);
    }

    #[test]
    fn qat_labels() {
        assert_eq!(QatPrecision::W2A2.label(), "w2a2");
    }
}
