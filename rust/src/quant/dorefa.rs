//! DoReFa fake-quantization in Rust — mirror of the L1 Pallas kernel
//! (`python/compile/kernels/dorefa.py`) and its jnp oracle.
//!
//! Used by property tests (quantization invariants that must agree with the
//! artifacts' behaviour) and by the deploy engine to pre-quantize host-side
//! weights when emulating a given bit-width.

/// Uniform quantization of values in [0,1] to `levels` steps:
/// `round(x * L) / L` with round-half-to-even (matching jnp.round / HLO
/// round_nearest_even, which the artifacts use).
pub fn quantize_levels(x: f32, levels: f32) -> f32 {
    round_half_even(x * levels) / levels
}

fn round_half_even(x: f32) -> f32 {
    let r = x.round(); // half away from zero
    if (x - x.trunc()).abs() == 0.5 {
        // tie: pick the even neighbour
        let below = x.floor();
        let above = x.ceil();
        if (below as i64) % 2 == 0 {
            below
        } else {
            above
        }
    } else {
        r
    }
}

/// DoReFa weight quantization over a slice (per-tensor max-normalized tanh).
pub fn weight_quant(w: &[f32], kbits: f32) -> Vec<f32> {
    let levels = (2.0f32).powf(kbits) - 1.0;
    let t: Vec<f32> = w.iter().map(|x| x.tanh()).collect();
    let maxabs = t.iter().fold(0.0f32, |m, x| m.max(x.abs())) * 2.0 + 1e-8;
    t.iter()
        .map(|x| 2.0 * quantize_levels(x / maxabs + 0.5, levels) - 1.0)
        .collect()
}

/// DoReFa activation quantization: quantize_k(clip(a, 0, 1)).
pub fn act_quant(a: &[f32], kbits: f32) -> Vec<f32> {
    let levels = (2.0f32).powf(kbits) - 1.0;
    a.iter()
        .map(|x| quantize_levels(x.clamp(0.0, 1.0), levels))
        .collect()
}

/// Number of distinct representable weight values at k bits.
pub fn weight_levels(kbits: u32) -> usize {
    (1usize << kbits).max(2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, F64Range, PairGen, VecGen};

    #[test]
    fn weight_quant_bounded_and_leveled() {
        let w: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) / 8.0).collect();
        for k in [2.0, 4.0, 8.0] {
            let q = weight_quant(&w, k);
            assert!(q.iter().all(|x| (-1.0..=1.0).contains(x)));
            let mut distinct: Vec<i64> =
                q.iter().map(|x| (x * 1e5).round() as i64).collect();
            distinct.sort_unstable();
            distinct.dedup();
            assert!(distinct.len() <= weight_levels(k as u32), "k={k}");
        }
    }

    #[test]
    fn act_quant_idempotent_property() {
        let gen = PairGen(
            VecGen {
                elem: F64Range(-2.0, 2.0),
                min_len: 1,
                max_len: 64,
            },
            F64Range(2.0, 8.0),
        );
        check(11, 100, &gen, |(v, k)| {
            let a: Vec<f32> = v.iter().map(|x| *x as f32).collect();
            let k = k.round() as u32 as f32;
            let q1 = act_quant(&a, k);
            let q2 = act_quant(&q1, k);
            for (x, y) in q1.iter().zip(&q2) {
                if (x - y).abs() > 1e-6 {
                    return Err(format!("not idempotent: {x} vs {y}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn monotone_in_bits_error() {
        // More bits => smaller quantization error on average.
        let a: Vec<f32> = (0..256).map(|i| i as f32 / 255.0).collect();
        let err = |k: f32| -> f32 {
            act_quant(&a, k)
                .iter()
                .zip(&a)
                .map(|(q, x)| (q - x).abs())
                .sum::<f32>()
        };
        assert!(err(2.0) > err(4.0));
        assert!(err(4.0) > err(8.0));
    }
}
