//! # HAQA-RS
//!
//! Reproduction of *"From Bits to Chips: An LLM-based Hardware-Aware
//! Quantization Agent for Streamlined Deployment of LLMs"* as a three-layer
//! Rust + JAX + Pallas system.
//!
//! * **Layer 1/2** (build time, `python/`): Pallas kernels + JAX train/eval/
//!   decode graphs, AOT-lowered to HLO text in `artifacts/`.
//! * **Layer 3** (this crate): the paper's contribution — the agentic
//!   quantization + deployment workflow — plus every substrate it needs
//!   (optimizers, hardware simulator, PJRT runtime, trainer, deploy engine).
//!
//! Python never runs on the request path: after `make artifacts`, the `haqa`
//! binary is self-contained.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! | module | role |
//! |---|---|
//! | [`util`] | zero-dep substrates: RNG, JSON, CLI, stats, tables, bench, property testing |
//! | [`search`] | typed hyperparameter spaces (paper Appendix D) |
//! | [`optimizers`] | Random / Local / Bayesian(GP) / NSGA-II / Human / HAQA |
//! | [`agent`] | LLM-agent workflow: prompts, ReAct, history, validation, cost |
//! | [`hardware`] | device profiles, latency & memory models, adaptive strategy |
//! | [`quant`] | quantization schemes + Rust-side DoReFa/QLoRA oracles |
//! | [`runtime`] | PJRT client (behind the `pjrt` feature), artifact registry, executable cache, pure-Rust literal fallback |
//! | [`trainer`] | synthetic datasets + QAT/QLoRA training loops |
//! | [`deploy`] | kernel tuner, token-generation engine, e2e throughput |
//! | [`coordinator`] | the HAQA iteration loop (paper Fig. 3) behind one seam: |
//! | [`coordinator::evaluator`] | the `Evaluator` trait + fine-tune / kernel / bit-width backends |
//! | [`coordinator::device`] | device-backend evaluators: JSONL/TCP measurement protocol + stub server |
//! | [`coordinator::cache`] | content-addressed evaluation cache (canonical-JSON keys) |
//! | [`coordinator::fleet`] | parallel scenario-fleet runner, bit-identical to serial |
//! | [`report`] | table/figure emitters for every paper table & figure |
//!
//! Feature `pjrt` (default off) gates the `xla` dependency: the default
//! build is fully offline — coordinator, optimizers, agent, simulator,
//! cache and fleet all run — and only AOT-graph execution needs the
//! feature plus the real xla_extension binding.

pub mod agent;
pub mod coordinator;
pub mod deploy;
pub mod hardware;
pub mod optimizers;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod search;
pub mod trainer;
pub mod util;
