//! `haqa` — the CLI launcher for the HAQA-RS reproduction.
//!
//! ```text
//! haqa smoke [filter]          compile+execute artifacts end-to-end
//! haqa artifacts               list the artifact registry
//! haqa tune   [--flags]        fine-tuning HPO (Table 1/2 single cell)
//! haqa kernel [--flags]        kernel exec-config tuning (Table 3 cell)
//! haqa bitwidth [--flags]      bit-width selection (Table 5 / §4.4)
//! haqa generate [--flags]      serve token generation (llama.cpp analogue)
//! haqa run <scenario.json>     run a scenario file (incl. the joint loop)
//! haqa fleet <scenarios.json>  run a scenario batch across a worker pool
//!                              (--inflight N overlaps agent queries,
//!                               --batch N coalesces them into provider
//!                               batches, --backend/--evaluator SPEC
//!                               override the scenarios' specs — incl.
//!                               chaos:<plan>=… fault injection —
//!                               --retries N restarts transient failures,
//!                               --resume DIR journals + resumes runs,
//!                               --cache-cap N bounds the memory cache
//!                               tier, --cache-addr HOST:PORT shares a
//!                               `haqa cache serve` endpoint; first
//!                               SIGINT drains gracefully)
//! haqa scenarios gen           expand a matrix spec into a scenario batch
//!                              (deterministic; feeds `haqa fleet`)
//! haqa bench [--quick]         fleet/cache throughput harness → BENCH_2.json
//!                              + agent-overlap phase → BENCH_3.json
//!                              + provider-batching phase → BENCH_5.json
//!                              + 10k-scenario scale phase → BENCH_6.json
//!                              + chaos fault-overhead phase → BENCH_7.json
//!                              + distributed remote-cache phase → BENCH_8.json
//!                              + traffic-shaped serving phase → BENCH_10.json
//! haqa serve [--addr]          resident fleet daemon: warm cache/agent pool
//!                              across submissions, bounded admission queue,
//!                              per-client scoped journals, graceful drain
//! haqa submit <batch.json>     submit a batch to `haqa serve`, stream the
//!                              per-scenario results, exit with its status
//! haqa cache serve             serve a shared warm-cache tier over JSONL/TCP
//! haqa cache compact           rewrite the eval-cache journal, live entries only
//! haqa device serve            serve the JSONL device-measurement protocol
//! haqa device ping             hello round-trip against a device server
//! ```

use anyhow::Result;
use haqa::coordinator::cache_server;
use haqa::coordinator::{CacheServer, EvalCache, FleetRunner, RemoteCacheTier, Scenario, Workflow};
use haqa::coordinator::scenario::{parse_precision, Track};
use haqa::optimizers::best;
use haqa::runtime::{ArtifactSet, InputRole, Tensor};
use haqa::trainer::lm::LmBase;
use haqa::util::cli::Args;
use haqa::util::rng::Rng;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => ("help", Vec::new()),
    };
    match cmd {
        "smoke" => smoke(rest.first().map(|s| s.as_str())),
        "artifacts" => list_artifacts(),
        "tune" => tune(rest),
        "kernel" => kernel(rest),
        "bitwidth" => bitwidth(rest),
        "generate" => generate(rest),
        "run" => run_scenario(rest),
        "fleet" => fleet(rest),
        "serve" => serve_cmd(rest),
        "submit" => submit_cmd(rest),
        "scenarios" => scenarios_cmd(rest),
        "bench" => bench_fleet(rest),
        "cache" => cache_cmd(rest),
        "device" => device_cmd(rest),
        "perf" => perf(),
        "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand '{other}' (try `haqa help`)"),
    }
}

const HELP: &str = "\
haqa — hardware-aware quantization agent (paper reproduction)

  haqa smoke [filter]       compile+execute artifacts (substring filter)
  haqa artifacts            list the artifact registry
  haqa tune                 fine-tuning HPO (haqa vs baselines); --help
  haqa kernel               kernel execution-config tuning; --help
  haqa bitwidth             adaptive bit-width selection; --help
  haqa generate             token-generation engine on PJRT; --help
  haqa run <scenario.json>  run a scenario file (finetune/kernel/bitwidth/joint)
  haqa fleet <batch.json>   run a scenario batch on a worker pool w/ eval cache
                            (--inflight N overlaps in-flight agent queries,
                            --batch N coalesces them into provider batches,
                            --retries N restarts transient/panicked failures,
                            --resume DIR journals outcomes + skips completed,
                            --backend/--evaluator SPEC override scenario specs
                            incl. chaos:<plan>=… deterministic fault injection,
                            --cache-cap N bounds the memory cache tier,
                            --cache-addr HOST:PORT shares a cache server; accepts
                            a {\"matrix\": …} generator spec directly; the first
                            SIGINT drains in-flight work, a second force-kills)
  haqa scenarios gen        expand a scenario-matrix spec deterministically
                            (--spec/--count/--seed/--out); axes include a
                            `traffic` list of serving profiles; feeds `haqa
                            fleet`
  haqa bench                cold/warm serial/fleet throughput harness plus the
                            agent-overlap, provider-batching, 10k-scenario
                            scale, chaos fault-overhead, distributed
                            remote-cache and traffic-shaped serving phases;
                            --help
  haqa serve                resident fleet daemon on HOST:PORT (default
                            127.0.0.1:7436): submit/status/results/cancel/drain
                            over JSONL/TCP, warm eval cache + agent pool across
                            submissions, --queue-cap bounds admission, SIGINT
                            or the drain verb finishes in-flight work
  haqa submit <batch.json>  submit a batch to a running `haqa serve`, stream
                            per-scenario results (bit-identical to `haqa
                            fleet`), exit with the fleet's status
  haqa cache serve          serve a shared warm-cache tier over JSONL/TCP
                            (target of `haqa fleet --cache-addr HOST:PORT`)
  haqa cache compact        rewrite the eval-cache journal keeping live entries
  haqa device serve         serve the device-measurement protocol (simulator-
                            backed stub; target of remote:// evaluator specs)
  haqa device ping          hello round-trip against a device server

Benches regenerating every paper table/figure: `cargo bench` (see DESIGN.md).
";

fn tune(rest: Vec<String>) -> Result<()> {
    let a = Args::new("haqa tune", "fine-tuning hyperparameter optimization")
        .opt_default("track", "lm", "cnn | lm")
        .opt_default("model", "cnn_s", "cnn_s|cnn_m|cnn_l (cnn track)")
        .opt_default("precision", "w4a4", "w8a8|w4a4|w2a2 (cnn track)")
        .opt_default("bits", "8", "LM base bit-width: 4|8|16")
        .opt_default("optimizer", "haqa", "default|human|local|bayesian|random|nsga2|haqa")
        .opt_default("budget", "10", "tuning rounds")
        .opt_default("seed", "0", "rng seed")
        .opt_default("steps-per-epoch", "3", "CNN steps per search-space epoch")
        .opt_default("step-scale", "0.25", "LM fraction of the paper's max_steps")
        .parse(rest)?;
    let mut sc = Scenario {
        name: format!("tune_{}", a.get("optimizer").unwrap()),
        track: if a.get("track") == Some("cnn") {
            Track::FinetuneCnn
        } else {
            Track::FinetuneLm
        },
        model: a.get("model").unwrap().to_string(),
        precision: parse_precision(a.get("precision").unwrap())?,
        bits: a.get_f64("bits")?.unwrap_or(8.0) as f32,
        optimizer: a.get("optimizer").unwrap().to_string(),
        budget: a.get_usize("budget")?.unwrap_or(10),
        seed: a.get_f64("seed")?.unwrap_or(0.0) as u64,
        steps_per_epoch: a.get_usize("steps-per-epoch")?.unwrap_or(3),
        step_scale: a.get_f64("step-scale")?.unwrap_or(0.25),
        ..Scenario::default()
    };
    if sc.track == Track::FinetuneLm {
        sc.model = "tiny-lm".into();
    }
    let set = ArtifactSet::load_default()?;
    let wf = Workflow::new(&set);
    let out = wf.run_finetune(&sc)?;
    for (i, o) in out.history.iter().enumerate() {
        println!("round {i:2}  score {:.4}  {}", o.score, o.feedback);
    }
    println!(
        "best score {:.4} (round {})",
        out.best_score,
        out.history
            .iter()
            .position(|o| o.score == out.best_score)
            .unwrap_or(0)
    );
    if let Some(cost) = &out.cost_report {
        println!("{cost}");
    }
    if let Some(p) = out.log_path {
        println!("task log: {}", p.display());
    }
    Ok(())
}

fn kernel(rest: Vec<String>) -> Result<()> {
    let a = Args::new("haqa kernel", "kernel execution-config tuning")
        .opt_default("kernel", "matmul:64", "kernel:batch, e.g. softmax:128")
        .opt_default("device", "a6000", "hardware profile preset (a6000|adreno740|cpu|a100|orin)")
        .opt_default("optimizer", "haqa", "optimizer name")
        .opt_default("budget", "10", "tuning rounds")
        .opt_default("seed", "0", "rng seed")
        .opt_default(
            "evaluator",
            "simulated",
            "simulated | device:<profile> | remote://host:port (see docs/EVALUATORS.md)",
        )
        .parse(rest)?;
    let sc = Scenario {
        name: format!("kernel_{}", a.get("kernel").unwrap().replace(':', "_")),
        track: Track::Kernel,
        kernel: a.get("kernel").unwrap().to_string(),
        device: a.get("device").unwrap().to_string(),
        optimizer: a.get("optimizer").unwrap().to_string(),
        budget: a.get_usize("budget")?.unwrap_or(10),
        seed: a.get_f64("seed")?.unwrap_or(0.0) as u64,
        evaluator: a.get("evaluator").unwrap().to_string(),
        ..Scenario::default()
    };
    // Kernel tuning needs no artifacts: it runs on the analytic simulator,
    // in-process or behind the device-measurement protocol.
    let wf = Workflow::simulated();
    let out = wf.run_kernel(&sc)?;
    for (i, o) in out.history.iter().enumerate() {
        println!("round {i:2}  latency {:9.3} µs", -o.score);
    }
    let b = best(&out.history).unwrap();
    println!("best latency {:.3} µs", -b.score);
    if let Some(cost) = &out.cost_report {
        println!("{cost}");
    }
    Ok(())
}

fn bitwidth(rest: Vec<String>) -> Result<()> {
    let a = Args::new("haqa bitwidth", "adaptive quantization bit-width selection")
        .opt_default("model", "llama2-13b", "deployment model")
        .opt_default("device", "a6000", "a6000 | adreno740")
        .opt_default("memory-gb", "10", "memory limit")
        .opt(
            "traffic",
            "score under a named traffic profile (chat-burst | batch-offline | \
             mobile-single-user) instead of lone-request token time",
        )
        .opt_default("seed", "0", "rng seed (shapes the traffic arrival stream)")
        .parse(rest)?;
    let traffic = a.get("traffic").unwrap_or("").to_string();
    let sc = Scenario {
        name: "bitwidth".into(),
        track: Track::Bitwidth,
        model: a.get("model").unwrap().to_string(),
        device: a.get("device").unwrap().to_string(),
        memory_limit_gb: a.get_f64("memory-gb")?.unwrap_or(10.0),
        seed: a.get_f64("seed")?.unwrap_or(0.0) as u64,
        traffic: traffic.clone(),
        ..Scenario::default()
    };
    // Bit-width selection runs on the analytic models — no artifacts needed.
    let wf = Workflow::simulated();
    let out = wf.run_bitwidth(&sc)?;
    let o = &out.history[0];
    if traffic.is_empty() {
        println!(
            "agent choice: {:?}  (simulated {:.2} tokens/s)",
            o.config.get("quant"),
            o.score
        );
    } else {
        println!(
            "agent choice: {:?}  (simulated p99 {:.1} ms under '{traffic}')",
            o.config.get("quant"),
            -o.score
        );
    }
    println!("feedback: {}", o.feedback);
    Ok(())
}

fn generate(rest: Vec<String>) -> Result<()> {
    let a = Args::new("haqa generate", "token generation on the PJRT engine")
        .opt_default("tokens", "32", "tokens to generate")
        .opt_default("bits", "8", "base bit-width 4|8|16")
        .opt_default("tile", "default", "qmatmul tile variant: default|mm16x16x16|mm32x32x32|mm64x64x64")
        .opt_default("seed", "0", "rng seed")
        .parse(rest)?;
    let set = ArtifactSet::load_default()?;
    let base = LmBase::new(&set, a.get_f64("seed")?.unwrap_or(0.0) as u64)?;
    let art = set.get("lm_train_b8")?;
    let mut rng = Rng::new(1);
    let lora: Vec<Tensor> = art
        .inputs_with_role(InputRole::State)
        .iter()
        .take(8)
        .map(|s| s.init_tensor(&mut rng))
        .collect();
    let engine = haqa::deploy::TokenEngine::new(
        &set,
        &format!("lm_decode_{}", a.get("tile").unwrap()),
        &base.tensors,
        &lora,
        a.get_f64("bits")?.unwrap_or(8.0) as f32,
        16,
        8.0,
    )?;
    let n = a.get_usize("tokens")?.unwrap_or(32);
    let stats = engine.generate(&[1, 2, 3, 4], n)?;
    println!("generated {} tokens: {:?}", stats.tokens.len(), &stats.tokens);
    println!(
        "throughput {:.1} tokens/s, median step {:.0} µs",
        stats.tokens_per_sec(),
        stats.median_token_us()
    );
    Ok(())
}

fn run_scenario(rest: Vec<String>) -> Result<()> {
    let path = rest
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: haqa run <scenario.json>"))?;
    let sc = Scenario::load(path)?;
    // Load the artifact registry only for tracks that train on PJRT.
    let set = if sc.needs_artifacts() {
        Some(ArtifactSet::load_default()?)
    } else {
        None
    };
    let wf = match &set {
        Some(s) => Workflow::new(s),
        None => Workflow::simulated(),
    };
    if sc.track == Track::Joint {
        let (ft, kt, bw) = wf.run_joint(&sc)?;
        println!("finetune best score: {:.4}", ft.best_score);
        println!("kernel best latency: {:.3} µs", -kt.best_score);
        println!("bitwidth choice score: {:.2} tokens/s", bw.best_score);
    } else {
        let out = wf.run(&sc)?;
        println!("best score: {:.4}", out.best_score);
    }
    Ok(())
}

/// Run a scenario batch across a scoped-thread worker pool with the shared
/// content-addressed evaluation cache (`haqa fleet <batch.json>`).
fn fleet(rest: Vec<String>) -> Result<()> {
    let a = Args::new("haqa fleet", "run a scenario batch across a worker pool")
        .opt("workers", "worker threads (default: env HAQA_WORKERS or 4)")
        .opt("inflight", "agent queries kept in flight per worker (default: env HAQA_INFLIGHT or 1)")
        .opt("batch", "coalesce up to N in-flight proposals into one provider request (default: env HAQA_BATCH or off)")
        .opt("backend", "override every scenario's agent backend spec (e.g. replay:<journal> for the CI drift gate, chaos:<plan>=simulated for fault injection)")
        .opt("evaluator", "override every scenario's evaluator spec (e.g. chaos:<plan>=simulated for the CI chaos gate)")
        .opt("retries", "restarts granted to transient/panicked scenario failures (default: env HAQA_RETRIES or 0)")
        .opt("resume", "journal completed scenarios to DIR/fleet_state.jsonl and skip the ones already recorded there (crash-safe; same flag for the first run and every resume)")
        .opt("cache-dir", "persist the eval-cache journal here (shared across runs and processes)")
        .opt("cache-addr", "share evaluations through a `haqa cache serve` endpoint at HOST:PORT (default: env HAQA_CACHE_ADDR or off; mutually exclusive with --cache-dir)")
        .opt("cache-cap", "bound the in-memory cache tier to N entries, LRU-evicted (default: env HAQA_CACHE_CAP or unbounded; never changes scores)")
        .flag("no-cache", "disable the content-addressed evaluation cache")
        .flag("quiet", "skip per-scenario task-log writes (10k-scale runs)")
        .flag("check-serial", "re-run serially and verify bit-identical scores")
        .parse(rest)?;
    let path = a
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: haqa fleet <scenarios.json> [--workers N] [--inflight N]"))?;
    let mut scenarios = Scenario::load_many(path)?;
    anyhow::ensure!(!scenarios.is_empty(), "no scenarios in {path}");
    if let Some(spec) = a.get("backend") {
        // The nightly replay-drift job records/replays a whole committed
        // batch without editing the scenario file.
        for sc in &mut scenarios {
            sc.backend = spec.to_string();
        }
    }
    if let Some(spec) = a.get("evaluator") {
        // Same idea on the evaluation seam: the CI chaos gate wraps a whole
        // committed batch in `chaos:<plan>=simulated` without editing it.
        for sc in &mut scenarios {
            sc.evaluator = spec.to_string();
        }
    }
    let workers = FleetRunner::workers_from_env(a.get_usize("workers")?)?;
    let inflight = FleetRunner::inflight_from_env(a.get_usize("inflight")?)?;
    let batch = FleetRunner::batch_from_env(a.get_usize("batch")?)?;
    let retries = FleetRunner::retries_from_env(a.get_usize("retries")?)?;
    let mut runner = FleetRunner::new(workers)
        .with_inflight(inflight)
        .with_retries(retries)
        .with_sigint_drain();
    if let Some(b) = batch {
        runner = runner.with_batch(b);
    }
    if let Some(dir) = a.get("resume") {
        runner = runner.with_state_dir(std::path::Path::new(dir))?;
    }
    let cap = EvalCache::cap_from_env(a.get_usize("cache-cap")?)?;
    let cache_addr = cache_server::addr_from_env(a.get("cache-addr"))?;
    match (a.get("cache-dir"), cache_addr, cap) {
        (Some(_), Some(_), _) => anyhow::bail!(
            "--cache-dir and --cache-addr/HAQA_CACHE_ADDR are mutually exclusive: \
             the journal lives on the server (start it with `haqa cache serve --cache-dir …`)"
        ),
        (Some(dir), None, cap) => runner = runner.with_cache(EvalCache::with_dir_capped(dir, cap)?),
        (None, Some(addr), cap) => {
            runner = runner.with_cache(EvalCache::with_remote(RemoteCacheTier::new(&addr)?, cap))
        }
        (None, None, Some(c)) => runner = runner.with_cache(EvalCache::bounded(c)),
        (None, None, None) => {}
    }
    if a.get_bool("no-cache") {
        runner = runner.without_cache();
    }
    if a.get_bool("quiet") {
        runner = runner.quiet();
    }
    let t0 = std::time::Instant::now();
    let report = runner.run(&scenarios);
    for (sc, out) in scenarios.iter().zip(&report.outcomes) {
        match out {
            // --quiet keeps the output readable at 10k scale: errors and
            // the aggregate lines below still print.
            Ok(o) if !a.get_bool("quiet") => println!(
                "{:<24} {:?}: best {:.4}  ({} rounds, {} cache hits)",
                sc.name,
                sc.track,
                o.best_score,
                o.history.len(),
                o.cache_hits
            ),
            Ok(_) => {}
            Err(e) => println!("{:<24} {:?}: error: {e:#}", sc.name, sc.track),
        }
    }
    println!(
        "fleet: {} scenarios ({} families) on {} workers (inflight {}) in {:.2}s",
        scenarios.len(),
        report.families,
        workers,
        inflight,
        t0.elapsed().as_secs_f64()
    );
    if let Some(st) = report.cache {
        let cap_cell = st
            .capacity
            .map(|c| format!("cap {c}"))
            .unwrap_or_else(|| "unbounded".into());
        println!(
            "evaluation cache: {} hits / {} misses ({} entries, peak {}, {} evicted, {})",
            st.hits, st.misses, st.entries, st.peak_entries, st.evictions, cap_cell
        );
        if st.journal_records > 0 {
            println!(
                "journal: {} record(s) in {} group-committed write(s)",
                st.journal_records, st.journal_writes
            );
        }
        if st.remote_hits + st.remote_misses > 0 {
            // The CI remote-cache gate greps this line: the second fleet
            // against a warm server must report remote hits > 0.
            println!(
                "remote cache: {} hits / {} misses in {} round-trip(s)",
                st.remote_hits, st.remote_misses, st.remote_round_trips
            );
        }
    }
    if report.resumed > 0 {
        println!(
            "resumed: {} scenario(s) from the fleet-state journal",
            report.resumed
        );
    }
    if let Some((records, writes)) = report.journal {
        if records > 0 {
            println!(
                "fleet state: {records} record(s) in {writes} group-committed write(s)"
            );
        }
    }
    if report.faults.any() || report.faults.retries > 0 {
        // The CI chaos gate greps this line: scores must stay bit-identical
        // while these counters absorb the injected faults.
        println!(
            "resilience: {} restart(s) ({} transient, {} panicked, {} fatal)",
            report.faults.retries,
            report.faults.transient,
            report.faults.panicked,
            report.faults.fatal
        );
    }
    // Per-platform Pareto fronts — the paper's "counterintuitive wins":
    // a scheme that loses globally can still be the per-platform winner.
    for f in report.pareto(&scenarios) {
        let mut names: Vec<&str> = f.members.iter().map(|(n, _)| n.as_str()).take(6).collect();
        if f.members.len() > names.len() {
            names.push("…");
        }
        println!(
            "pareto {:<20} {:>4} of {:>4} on the front: {}",
            f.group,
            f.members.len(),
            f.total,
            names.join(", ")
        );
    }
    if let Some(st) = report.agent {
        println!(
            "agent batching: {} request(s) in {} provider call(s) (max batch {})",
            st.submitted, st.provider_requests, st.max_batch
        );
    }
    if report.drained {
        // In-flight scenarios finished and were journaled; exit nonzero so
        // harnesses notice, with the resume invocation spelled out.
        let hint = a
            .get("resume")
            .map(|d| format!(" --resume {d}"))
            .unwrap_or_default();
        anyhow::bail!(
            "fleet drained after SIGINT — rerun `haqa fleet {path}{hint}` to finish"
        );
    }
    if a.get_bool("check-serial") {
        // The serial control must run the same agent pipeline: a batched
        // run uses the shared content-seeded pool, whose results are
        // bit-identical across batch sizes but deliberately different
        // from the per-scenario pipeline — so mirror pool mode (at the
        // one-call-per-request control size) whenever the main run
        // batched.
        let mut serial_runner = FleetRunner::new(1);
        if batch.is_some() {
            serial_runner = serial_runner.with_batch(1);
        }
        let serial = serial_runner.run(&scenarios);
        let identical = serial
            .outcomes
            .iter()
            .zip(&report.outcomes)
            .all(|(s, p)| match (s, p) {
                (Ok(a), Ok(b)) => a.best_score.to_bits() == b.best_score.to_bits(),
                (Err(_), Err(_)) => true,
                _ => false,
            });
        anyhow::ensure!(identical, "serial and parallel fleet runs diverged");
        println!("serial check: bit-identical best scores");
    }
    Ok(())
}

/// Run the resident fleet daemon (`haqa serve`): a socket in front of the
/// warm `FleetRunner` substrate.  The eval cache, the optional agent
/// pool, and the fleet-state root stay resident across submissions, so a
/// second identical submission is served almost entirely from the warm
/// cache.  SIGINT (or a remote `drain` request) finishes in-flight
/// scenarios, flushes journals, and exits 0.
fn serve_cmd(rest: Vec<String>) -> Result<()> {
    use haqa::coordinator::fleet::{install_sigint_drain, sigint_drain_requested};
    use haqa::coordinator::serve::{self, FleetDaemon, ServeConfig};

    let a = Args::new(
        "haqa serve",
        "resident fleet daemon: warm caches and agent pools across submissions",
    )
    .opt("addr", "bind address (default: env HAQA_SERVE_ADDR or 127.0.0.1:7436; port 0 = ephemeral)")
    .opt("workers", "worker threads per job (default: env HAQA_WORKERS or 4)")
    .opt("inflight", "agent queries kept in flight per worker (default: env HAQA_INFLIGHT or 1)")
    .opt("batch", "coalesce up to N in-flight proposals into one provider request; the warm pool is shared across submissions (default: env HAQA_BATCH or off)")
    .opt("retries", "restarts granted to transient/panicked scenario failures (default: env HAQA_RETRIES or 0)")
    .opt("queue-cap", "queued jobs admitted before submit answers busy (default: env HAQA_QUEUE_CAP or 16)")
    .opt("state-dir", "fleet-state root for the per-client crash-safe journals (default: <temp>/haqa-serve)")
    .opt("cache-dir", "persist the eval-cache journal here (shared across restarts)")
    .opt("cache-addr", "layer a `haqa cache serve` endpoint under the daemon's cache (default: env HAQA_CACHE_ADDR or off; mutually exclusive with --cache-dir)")
    .opt("cache-cap", "bound the in-memory cache tier to N entries, LRU-evicted (default: env HAQA_CACHE_CAP or unbounded)")
    .parse(rest)?;
    let addr = serve::serve_addr_from_env(a.get("addr"))?;
    let cfg = ServeConfig {
        workers: FleetRunner::workers_from_env(a.get_usize("workers")?)?,
        inflight: FleetRunner::inflight_from_env(a.get_usize("inflight")?)?,
        retries: FleetRunner::retries_from_env(a.get_usize("retries")?)?,
        batch: FleetRunner::batch_from_env(a.get_usize("batch")?)?,
        queue_cap: serve::queue_cap_from_env(a.get_usize("queue-cap")?)?,
    };
    let cap = EvalCache::cap_from_env(a.get_usize("cache-cap")?)?;
    let cache_addr = cache_server::addr_from_env(a.get("cache-addr"))?;
    let cache = match (a.get("cache-dir"), cache_addr, cap) {
        (Some(_), Some(_), _) => anyhow::bail!(
            "--cache-dir and --cache-addr/HAQA_CACHE_ADDR are mutually exclusive: \
             the journal lives on the server (start it with `haqa cache serve --cache-dir …`)"
        ),
        (Some(dir), None, cap) => EvalCache::with_dir_capped(dir, cap)?,
        (None, Some(remote), cap) => EvalCache::with_remote(RemoteCacheTier::new(&remote)?, cap),
        (None, None, Some(c)) => EvalCache::bounded(c),
        (None, None, None) => EvalCache::new(),
    };
    let state_root = match a.get("state-dir") {
        Some(d) => std::path::PathBuf::from(d),
        None => std::env::temp_dir().join("haqa-serve"),
    };
    let daemon = FleetDaemon::spawn(&addr, cache, cfg, &state_root)?;
    println!("fleet daemon listening on {}", daemon.addr());
    println!(
        "submit batches with `haqa submit <batch.json> --addr {}`",
        daemon.addr()
    );
    // Foreground service.  The first SIGINT begins a graceful drain —
    // in-flight scenarios finish and are journaled — and the loop exits 0
    // once the backlog is settled; a remote `drain` request does the same.
    install_sigint_drain();
    let mut drain_started = false;
    loop {
        std::thread::sleep(std::time::Duration::from_millis(100));
        if !drain_started && sigint_drain_requested() {
            eprintln!("drain requested — finishing in-flight scenarios");
            daemon.drain();
            drain_started = true;
        }
        if daemon.drained() {
            break;
        }
    }
    // A beat for drain-initiating clients to fetch their final results.
    std::thread::sleep(std::time::Duration::from_millis(200));
    println!(
        "fleet daemon drained — interrupted jobs resume from {} on the next \
         identical submission",
        state_root.display()
    );
    Ok(())
}

/// Submit a batch to a running daemon and stream its results (`haqa
/// submit`).  Output is line-for-line the `haqa fleet` format for the
/// same batch — CI diffs the score lines — except the Pareto table
/// (outcome histories stay server-side) and the cache line, which reports
/// this submission's slice of the daemon's warm cache.
fn submit_cmd(rest: Vec<String>) -> Result<()> {
    use haqa::coordinator::serve::{self, SubmitClient};
    use haqa::util::json::Json;

    let a = Args::new(
        "haqa submit",
        "submit a scenario batch to a running `haqa serve` daemon",
    )
    .opt("addr", "daemon address (default: env HAQA_SERVE_ADDR or 127.0.0.1:7436)")
    .opt_default("client", "cli", "client scope tag stamped on the daemon's journals")
    .flag("quiet", "skip per-scenario score lines")
    .parse(rest)?;
    let path = a.positional.first().ok_or_else(|| {
        anyhow::anyhow!("usage: haqa submit <scenarios.json> [--addr HOST:PORT] [--client NAME]")
    })?;
    let scenarios = Scenario::load_many(path)?;
    anyhow::ensure!(!scenarios.is_empty(), "no scenarios in {path}");
    let addr = serve::serve_addr_from_env(a.get("addr"))?;
    let client_tag = a.get("client").unwrap().to_string();
    let mut client = SubmitClient::connect(&addr)?;
    let t0 = std::time::Instant::now();
    let reply = client.submit(&client_tag, &scenarios)?;
    let job = reply
        .get("job")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow::anyhow!("daemon reply named no job"))?
        .to_string();
    let mut cursor = 0usize;
    let mut errors = 0usize;
    // Stream the contiguous settled prefix; the daemon serves it in input
    // order, so these lines match `haqa fleet` on the same file.
    let summary = loop {
        let r = client.results(&job, cursor)?;
        if let Some(rows) = r.get("results").and_then(|v| v.as_arr()) {
            for row in rows {
                let Some(sc) = row
                    .get("i")
                    .and_then(|v| v.as_i64())
                    .and_then(|i| usize::try_from(i).ok())
                    .and_then(|i| scenarios.get(i))
                else {
                    continue;
                };
                if row.get("ok").and_then(|v| v.as_bool()) == Some(true) {
                    if !a.get_bool("quiet") {
                        println!(
                            "{:<24} {:?}: best {:.4}  ({} rounds, {} cache hits)",
                            sc.name,
                            sc.track,
                            serve::wire_best(row).unwrap_or(f64::NAN),
                            row.get("rounds").and_then(|v| v.as_i64()).unwrap_or(0),
                            row.get("hits").and_then(|v| v.as_i64()).unwrap_or(0)
                        );
                    }
                } else {
                    errors += 1;
                    println!(
                        "{:<24} {:?}: error: {}",
                        sc.name,
                        sc.track,
                        row.get("error").and_then(|v| v.as_str()).unwrap_or("unknown failure")
                    );
                }
            }
        }
        if let Some(next) = r.get("next").and_then(|v| v.as_i64()) {
            cursor = next as usize;
        }
        if let Some(s) = r.get("summary") {
            break s.clone();
        }
        std::thread::sleep(std::time::Duration::from_millis(60));
    };
    let num = |k: &str| summary.get(k).and_then(|v| v.as_i64()).unwrap_or(0);
    println!(
        "fleet: {} scenarios ({} families) on {} workers (inflight {}) in {:.2}s",
        scenarios.len(),
        num("families"),
        num("workers"),
        num("inflight"),
        t0.elapsed().as_secs_f64()
    );
    if let Some(c) = summary.get("cache") {
        let g = |k: &str| c.get(k).and_then(|v| v.as_i64()).unwrap_or(0);
        let cap_cell = match c.get("cap") {
            Some(Json::Num(n)) => format!("cap {}", *n as usize),
            _ => "unbounded".into(),
        };
        println!(
            "evaluation cache: {} hits / {} misses ({} entries, peak {}, {} evicted, {})",
            g("hits"),
            g("misses"),
            g("entries"),
            g("peak"),
            g("evicted"),
            cap_cell
        );
        if g("journal_records") > 0 {
            println!(
                "journal: {} record(s) in {} group-committed write(s)",
                g("journal_records"),
                g("journal_writes")
            );
        }
        if g("remote_hits") + g("remote_misses") > 0 {
            println!(
                "remote cache: {} hits / {} misses in {} round-trip(s)",
                g("remote_hits"),
                g("remote_misses"),
                g("remote_round_trips")
            );
        }
    }
    if num("resumed") > 0 {
        println!(
            "resumed: {} scenario(s) from the fleet-state journal",
            num("resumed")
        );
    }
    if let Some(jj) = summary.get("journal") {
        let records = jj.get("records").and_then(|v| v.as_i64()).unwrap_or(0);
        let writes = jj.get("writes").and_then(|v| v.as_i64()).unwrap_or(0);
        if records > 0 {
            println!("fleet state: {records} record(s) in {writes} group-committed write(s)");
        }
    }
    if let Some(f) = summary.get("faults") {
        let g = |k: &str| f.get(k).and_then(|v| v.as_i64()).unwrap_or(0);
        if g("retries") + g("transient") + g("panicked") + g("fatal") > 0 {
            println!(
                "resilience: {} restart(s) ({} transient, {} panicked, {} fatal)",
                g("retries"),
                g("transient"),
                g("panicked"),
                g("fatal")
            );
        }
    }
    if let Some(st) = summary.get("agent") {
        let g = |k: &str| st.get(k).and_then(|v| v.as_i64()).unwrap_or(0);
        println!(
            "agent batching: {} request(s) in {} provider call(s) (max batch {})",
            g("submitted"),
            g("provider_requests"),
            g("max_batch")
        );
    }
    let state = summary
        .get("state")
        .and_then(|v| v.as_str())
        .unwrap_or("unknown")
        .to_string();
    match state.as_str() {
        "done" if errors == 0 => Ok(()),
        "done" => anyhow::bail!("{errors} scenario(s) failed"),
        "cancelled" => anyhow::bail!("job {job} was cancelled"),
        "drained" => {
            let dir = summary
                .get("state_dir")
                .and_then(|v| v.as_str())
                .unwrap_or("the daemon's state root");
            anyhow::bail!(
                "fleet daemon drained mid-job — journaled progress is at {dir}; \
                 resubmit the same batch to resume"
            )
        }
        other => anyhow::bail!("job {job} ended in state '{other}'"),
    }
}

/// `haqa scenarios <subcommand>` — scenario-batch tooling.  `gen` expands
/// a compact matrix spec into a concrete `{"scenarios": […]}` batch;
/// expansion is deterministic and the rendering byte-stable, so running it
/// twice with one spec produces identical files (CI diffs them).
fn scenarios_cmd(rest: Vec<String>) -> Result<()> {
    use haqa::coordinator::matrix::{render_batch, MatrixSpec};
    use haqa::util::json;

    let (sub, rest) = match rest.split_first() {
        Some((s, r)) => (s.as_str(), r.to_vec()),
        None => anyhow::bail!(
            "usage: haqa scenarios gen [--spec FILE] [--count N] [--seed N] [--out FILE]"
        ),
    };
    match sub {
        "gen" => {
            let a = Args::new(
                "haqa scenarios gen",
                "expand a scenario-matrix spec into a concrete batch (deterministic)",
            )
            .opt(
                "spec",
                "matrix spec file ({\"matrix\": {…}} or the bare object); \
                 default: the built-in full-preset sweep",
            )
            .opt("count", "override the spec's scenario count")
            .opt("seed", "override the spec's root seed")
            .opt("out", "write the batch here (default: stdout)")
            .parse(rest)?;
            let mut spec = match a.get("spec") {
                Some(path) => {
                    let text = std::fs::read_to_string(path)?;
                    let j = json::parse(&text)
                        .map_err(|e| anyhow::anyhow!("matrix spec {path}: {e}"))?;
                    MatrixSpec::from_json(j.get("matrix").unwrap_or(&j))
                        .map_err(|e| anyhow::anyhow!("matrix spec {path}: {e}"))?
                }
                None => MatrixSpec::default(),
            };
            if let Some(n) = a.get_usize("count")? {
                anyhow::ensure!(n >= 1, "--count must be >= 1");
                spec.count = n;
            }
            if let Some(s) = a.get_f64("seed")? {
                spec.seed = s as u64;
            }
            let scenarios = spec.expand();
            let rendered = render_batch(&scenarios);
            match a.get("out") {
                Some(path) => {
                    std::fs::write(path, rendered.as_bytes())?;
                    println!(
                        "generated {} scenarios ({} per matrix pass, seed {}) -> {path}",
                        scenarios.len(),
                        spec.pass_len(),
                        spec.seed
                    );
                }
                // Stdout stays pure batch JSON so it can be piped/diffed.
                None => print!("{rendered}"),
            }
            Ok(())
        }
        other => anyhow::bail!("unknown scenarios subcommand '{other}' (try `gen`)"),
    }
}

/// The perf trajectory harness (`haqa bench`): run a fixed scenario fleet
/// serial-vs-fleet and cold-vs-warm cache, verify every phase is
/// bit-identical, and emit `BENCH_2.json` so throughput is measured
/// instead of asserted.
///
/// Protocol:
///   1. cold serial — 1 worker, fresh in-memory cache;
///   2. cold fleet  — N workers, persistent cache on a reset journal;
///   3. warm fleet  — N workers, a *new* cache instance that loads the
///      journal phase 2 wrote (the cross-process path, in-process).
/// Plus a batched-measurement microbench (per-call latency-model setup vs
/// one setup per slice), the agent-overlap phase (`BENCH_3.json`), the
/// provider-batching phase (`BENCH_5.json`), the 10k-scenario scale phase
/// (`BENCH_6.json`), the chaos fault-overhead phase (`BENCH_7.json`), the
/// distributed remote-cache phase (`BENCH_8.json`) and the traffic-shaped
/// serving phase (`BENCH_10.json`).
/// Hard-fails if any phase
/// pair diverges, the warm run sees zero cache hits, overlap yields no
/// speedup, or batching does not reduce provider requests — so CI can
/// gate on the exit code.
fn bench_fleet(rest: Vec<String>) -> Result<()> {
    use haqa::coordinator::cache::JOURNAL_FILE;
    use haqa::coordinator::{CacheStats, FleetReport};
    use haqa::util::json::Json;

    let a = Args::new("haqa bench", "fleet/cache throughput harness")
        .opt("workers", "fleet worker threads (default: env HAQA_WORKERS or 4)")
        .opt("cache-dir", "journal directory (reset at start; default: a temp dir)")
        .opt_default("out", "BENCH_2.json", "report output path")
        .opt_default("rounds", "8", "tuning rounds per kernel scenario")
        .opt_default("overlap-out", "BENCH_3.json", "agent-overlap report output path")
        .opt_default("overlap-latency-ms", "12", "simulated agent API latency for the overlap phase")
        .opt_default(
            "evaluator",
            "simulated",
            "kernel-scenario evaluator: simulated | device (per-scenario device:<profile>) | \
             any evaluator spec verbatim",
        )
        .opt_default("batching-out", "BENCH_5.json", "provider-batching report output path")
        .opt("batch", "provider batch size for the batching phase (default: its scenario count)")
        .opt_default("scale-out", "BENCH_6.json", "scale-phase report output path")
        .opt("scale-count", "generated scenario count for the scale phase (default: 10000, or 600 with --quick)")
        .opt("cache-cap", "memory-tier LRU cap for the scale phase's capped runs (default: count/8, min 64)")
        .opt_default("chaos-out", "BENCH_7.json", "chaos fault-overhead report output path")
        .opt_default(
            "distributed-out",
            "BENCH_8.json",
            "distributed remote-cache report output path",
        )
        .opt_default(
            "traffic-out",
            "BENCH_10.json",
            "traffic-shaped serving report output path",
        )
        .flag("skip-overlap", "skip the blocking-vs-pipelined agent-overlap phase")
        .flag("skip-batching", "skip the unbatched-vs-batched provider-request phase")
        .flag("skip-scale", "skip the generated-matrix capped-vs-unbounded scale phase")
        .flag("skip-chaos", "skip the fault-injection overhead/bit-identity phase")
        .flag("skip-distributed", "skip the two-fleets-one-cache-server distributed phase")
        .flag("skip-traffic", "skip the traffic-shaped serving divergence/bit-identity phase")
        .flag("quick", "small scenario set (CI perf smoke)")
        .parse(rest)?;
    let quick = a.get_bool("quick");
    let rounds = a.get_usize("rounds")?.unwrap_or(8).max(1);
    let workers = FleetRunner::workers_from_env(a.get_usize("workers")?)?;
    let scenarios = bench_scenarios(quick, rounds, a.get("evaluator").unwrap());

    let dir = match a.get("cache-dir") {
        Some(d) => std::path::PathBuf::from(d),
        None => std::env::temp_dir().join(format!("haqa_bench_cache_{}", std::process::id())),
    };
    std::fs::create_dir_all(&dir)?;
    let journal = dir.join(JOURNAL_FILE);
    // The protocol measures cold → warm, so the journal starts empty.
    let _ = std::fs::remove_file(&journal);

    let timed = |runner: FleetRunner| -> Result<(f64, Vec<u64>, CacheStats, usize)> {
        let t0 = std::time::Instant::now();
        let report: FleetReport = runner.run(&scenarios);
        let wall = t0.elapsed().as_secs_f64();
        let mut bits = Vec::with_capacity(scenarios.len());
        for (sc, out) in scenarios.iter().zip(&report.outcomes) {
            let o = out.as_ref().map_err(|e| anyhow::anyhow!("{}: {e:#}", sc.name))?;
            bits.push(o.best_score.to_bits());
        }
        Ok((wall, bits, report.cache.unwrap_or_default(), report.families))
    };

    println!(
        "bench: {} scenarios, budget {rounds}, {workers} workers, journal {}",
        scenarios.len(),
        journal.display()
    );
    let (serial_wall, serial_bits, serial_stats, families) =
        timed(FleetRunner::new(1).quiet())?;
    println!("  cold serial : {serial_wall:8.3}s  ({} computed)", serial_stats.misses);
    let (cold_wall, cold_bits, cold_stats, _) = timed(
        FleetRunner::new(workers)
            .quiet()
            .with_cache(EvalCache::with_dir(&dir)?),
    )?;
    println!("  cold fleet  : {cold_wall:8.3}s  ({} computed)", cold_stats.misses);
    // A fresh instance — the process-boundary equivalent — must serve
    // everything from the journal.
    let (warm_wall, warm_bits, warm_stats, _) = timed(
        FleetRunner::new(workers)
            .quiet()
            .with_cache(EvalCache::with_dir(&dir)?),
    )?;
    println!(
        "  warm fleet  : {warm_wall:8.3}s  ({} hits / {} computed)",
        warm_stats.hits, warm_stats.misses
    );

    let bit_identical = serial_bits == cold_bits && serial_bits == warm_bits;
    let warm_hit_rate = warm_stats.hit_rate();
    let batched_speedup = batched_measure_speedup(if quick { 64 } else { 256 });

    let phase = |wall: f64, st: CacheStats| -> Json {
        let total = (st.hits + st.misses) as f64;
        let mut o = Json::obj();
        o.set("wall_s", Json::Num(wall));
        o.set("rounds", Json::Num(total));
        o.set("computed", Json::Num(st.misses as f64));
        o.set("cache_hits", Json::Num(st.hits as f64));
        o.set("evals_per_sec", Json::Num(total / wall.max(1e-9)));
        o
    };
    let mut phases = Json::obj();
    phases.set("cold_serial", phase(serial_wall, serial_stats));
    phases.set("cold_fleet", phase(cold_wall, cold_stats));
    phases.set("warm_fleet", phase(warm_wall, warm_stats));
    let mut speedup = Json::obj();
    speedup.set("cold_fleet_vs_cold_serial", Json::Num(serial_wall / cold_wall.max(1e-9)));
    speedup.set("warm_fleet_vs_cold_serial", Json::Num(serial_wall / warm_wall.max(1e-9)));
    let mut j = Json::obj();
    j.set("bench", Json::str("haqa bench"));
    j.set("quick", Json::Bool(quick));
    j.set("scenarios", Json::Num(scenarios.len() as f64));
    j.set("families", Json::Num(families as f64));
    j.set("workers", Json::Num(workers as f64));
    j.set("rounds_budget", Json::Num(rounds as f64));
    j.set("phases", phases);
    j.set("speedup", speedup);
    j.set("warm_hit_rate", Json::Num(warm_hit_rate));
    j.set("batched_measure_speedup", Json::Num(batched_speedup));
    j.set("bit_identical", Json::Bool(bit_identical));
    let out_path = a.get("out").unwrap_or("BENCH_2.json").to_string();
    std::fs::write(&out_path, j.to_string_pretty())?;

    println!(
        "  speedup     : cold fleet {:.2}x, warm fleet {:.2}x vs cold serial; \
         warm hit rate {:.0}%; batched measurement {:.2}x",
        serial_wall / cold_wall.max(1e-9),
        serial_wall / warm_wall.max(1e-9),
        warm_hit_rate * 100.0,
        batched_speedup
    );
    println!("  report      : {out_path}");
    anyhow::ensure!(bit_identical, "serial / cold-fleet / warm-fleet runs diverged");
    anyhow::ensure!(
        warm_hit_rate > 0.0,
        "warm-cache run saw zero hits — the persistent journal tier is broken"
    );
    if !a.get_bool("skip-overlap") {
        bench_agent_overlap(
            quick,
            a.get_usize("overlap-latency-ms")?.unwrap_or(12).max(1),
            a.get("overlap-out").unwrap_or("BENCH_3.json"),
        )?;
    }
    if !a.get_bool("skip-batching") {
        bench_batching(
            quick,
            a.get_usize("overlap-latency-ms")?.unwrap_or(12).max(1),
            a.get_usize("batch")?,
            a.get("batching-out").unwrap_or("BENCH_5.json"),
        )?;
    }
    if !a.get_bool("skip-scale") {
        bench_scale(
            quick,
            a.get_usize("scale-count")?,
            a.get_usize("cache-cap")?,
            workers,
            a.get("scale-out").unwrap_or("BENCH_6.json"),
        )?;
    }
    if !a.get_bool("skip-chaos") {
        bench_chaos(
            quick,
            rounds,
            workers,
            a.get("chaos-out").unwrap_or("BENCH_7.json"),
        )?;
    }
    if !a.get_bool("skip-distributed") {
        bench_distributed(
            quick,
            rounds,
            workers,
            a.get("distributed-out").unwrap_or("BENCH_8.json"),
        )?;
    }
    if !a.get_bool("skip-traffic") {
        bench_traffic(quick, workers, a.get("traffic-out").unwrap_or("BENCH_10.json"))?;
    }
    Ok(())
}

/// The agent-overlap phase: the same haqa-driven kernel fleet twice behind
/// a simulated-latency backend — blocking (inflight 1) vs pipelined
/// (every scenario's agent query in flight at once) — on ONE worker, so
/// the measured speedup is purely the overlap of in-flight agent queries
/// with other scenarios' evaluations, not thread parallelism.  Hard-fails
/// unless the two paths are bit-identical and the pipelined run is
/// measurably faster; emits `BENCH_3.json` for CI.
fn bench_agent_overlap(quick: bool, latency_ms: usize, out_path: &str) -> Result<()> {
    use haqa::util::json::Json;

    let rounds = if quick { 5 } else { 8 };
    let kernels: &[&str] = if quick {
        &["matmul:64", "softmax:128", "rmsnorm:64", "silu:64"]
    } else {
        &["matmul:64", "matmul:128", "softmax:64", "softmax:128", "silu:64", "rmsnorm:64", "rope:128", "rope:64"]
    };
    let scenarios: Vec<Scenario> = kernels
        .iter()
        .enumerate()
        .map(|(i, kernel)| Scenario {
            name: format!("overlap_{}", kernel.replace(':', "_")),
            track: Track::Kernel,
            kernel: (*kernel).into(),
            optimizer: "haqa".into(),
            budget: rounds,
            seed: 11 + i as u64,
            backend: format!("simulated-slow:{latency_ms}"),
            ..Scenario::default()
        })
        .collect();
    let inflight = scenarios.len();
    println!(
        "agent-overlap: {} haqa scenarios, {rounds} rounds, {latency_ms} ms simulated \
         agent latency, 1 worker",
        scenarios.len()
    );

    let timed = |runner: FleetRunner| -> Result<(f64, Vec<u64>)> {
        let t0 = std::time::Instant::now();
        let report = runner.run(&scenarios);
        let wall = t0.elapsed().as_secs_f64();
        let mut bits = Vec::with_capacity(scenarios.len());
        for (sc, out) in scenarios.iter().zip(&report.outcomes) {
            let o = out.as_ref().map_err(|e| anyhow::anyhow!("{}: {e:#}", sc.name))?;
            bits.push(o.best_score.to_bits());
        }
        Ok((wall, bits))
    };
    // No cache in either path: every round pays its evaluation, so the
    // comparison isolates agent latency handling.
    let (blocking_wall, blocking_bits) = timed(FleetRunner::new(1).without_cache().quiet())?;
    println!("  blocking    : {blocking_wall:8.3}s  (inflight 1)");
    let (pipelined_wall, pipelined_bits) = timed(
        FleetRunner::new(1)
            .without_cache()
            .quiet()
            .with_inflight(inflight),
    )?;
    println!("  pipelined   : {pipelined_wall:8.3}s  (inflight {inflight})");
    let bit_identical = blocking_bits == pipelined_bits;
    let speedup = blocking_wall / pipelined_wall.max(1e-9);
    println!("  speedup     : {speedup:.2}x; bit-identical: {bit_identical}");

    let mut j = Json::obj();
    j.set("bench", Json::str("haqa bench agent-overlap"));
    j.set("quick", Json::Bool(quick));
    j.set("scenarios", Json::Num(scenarios.len() as f64));
    j.set("rounds_budget", Json::Num(rounds as f64));
    j.set("agent_latency_ms", Json::Num(latency_ms as f64));
    j.set("workers", Json::Num(1.0));
    j.set("inflight", Json::Num(inflight as f64));
    let mut phases = Json::obj();
    let phase = |wall: f64| {
        let mut o = Json::obj();
        o.set("wall_s", Json::Num(wall));
        o.set(
            "rounds_per_sec",
            Json::Num((scenarios.len() * rounds) as f64 / wall.max(1e-9)),
        );
        o
    };
    phases.set("blocking", phase(blocking_wall));
    phases.set("pipelined", phase(pipelined_wall));
    j.set("phases", phases);
    j.set("speedup", Json::Num(speedup));
    j.set("bit_identical", Json::Bool(bit_identical));
    std::fs::write(out_path, j.to_string_pretty())?;
    println!("  report      : {out_path}");

    anyhow::ensure!(bit_identical, "blocking and pipelined agent paths diverged");
    anyhow::ensure!(
        speedup > 1.15,
        "pipelined fleet not measurably faster than blocking ({speedup:.2}x) — \
         in-flight agent overlap is broken"
    );
    Ok(())
}

/// The provider-batching phase: the same haqa-driven kernel fleet twice
/// through the shared agent pool behind `simulated-slow:<ms>` — unbatched
/// (`--batch 1`: one provider call per request) vs batched (every parked
/// proposal coalesced per sweep) — on ONE worker, so the only variable is
/// how many provider round-trips serve the same requests.  Hard-fails
/// unless the two paths are bit-identical AND the batched run made
/// strictly fewer provider requests; emits `BENCH_5.json` for CI.
fn bench_batching(
    quick: bool,
    latency_ms: usize,
    batch: Option<usize>,
    out_path: &str,
) -> Result<()> {
    use haqa::agent::BatchStats;
    use haqa::util::json::Json;

    let rounds = if quick { 4 } else { 6 };
    let kernels: &[&str] = if quick {
        &["matmul:64", "softmax:128", "rmsnorm:64", "silu:64"]
    } else {
        &["matmul:64", "matmul:128", "softmax:64", "softmax:128", "silu:64", "rmsnorm:64", "rope:128", "rope:64"]
    };
    let scenarios: Vec<Scenario> = kernels
        .iter()
        .enumerate()
        .map(|(i, kernel)| Scenario {
            name: format!("batching_{}", kernel.replace(':', "_")),
            track: Track::Kernel,
            kernel: (*kernel).into(),
            optimizer: "haqa".into(),
            budget: rounds,
            seed: 31 + i as u64,
            backend: format!("simulated-slow:{latency_ms}"),
            ..Scenario::default()
        })
        .collect();
    let inflight = scenarios.len();
    // A batched phase at size 1 would compare a run against itself, so the
    // floor is 2 — the gate needs a real coalescing path to measure.
    let batch_size = batch
        .unwrap_or(inflight)
        .clamp(2, haqa::coordinator::fleet::MAX_BATCH);
    println!(
        "provider batching: {} haqa scenarios, {rounds} rounds, {latency_ms} ms simulated \
         agent latency, 1 worker, batch {batch_size}",
        scenarios.len()
    );

    let timed = |runner: FleetRunner| -> Result<(f64, Vec<u64>, BatchStats)> {
        let t0 = std::time::Instant::now();
        let report = runner.run(&scenarios);
        let wall = t0.elapsed().as_secs_f64();
        let mut bits = Vec::with_capacity(scenarios.len());
        for (sc, out) in scenarios.iter().zip(&report.outcomes) {
            let o = out.as_ref().map_err(|e| anyhow::anyhow!("{}: {e:#}", sc.name))?;
            bits.push(o.best_score.to_bits());
        }
        let agent = report
            .agent
            .ok_or_else(|| anyhow::anyhow!("batch mode reported no agent stats"))?;
        Ok((wall, bits, agent))
    };
    // No cache in either path, both through the shared pool: the only
    // difference between the runs is the provider batch size.
    let (un_wall, un_bits, un_stats) = timed(
        FleetRunner::new(1)
            .without_cache()
            .quiet()
            .with_inflight(inflight)
            .with_batch(1),
    )?;
    println!(
        "  unbatched   : {un_wall:8.3}s  ({} requests in {} provider calls)",
        un_stats.submitted, un_stats.provider_requests
    );
    let (b_wall, b_bits, b_stats) = timed(
        FleetRunner::new(1)
            .without_cache()
            .quiet()
            .with_inflight(inflight)
            .with_batch(batch_size),
    )?;
    println!(
        "  batched     : {b_wall:8.3}s  ({} requests in {} provider calls, max batch {})",
        b_stats.submitted, b_stats.provider_requests, b_stats.max_batch
    );
    let bit_identical = un_bits == b_bits;
    let speedup = un_wall / b_wall.max(1e-9);
    println!(
        "  speedup     : {speedup:.2}x; provider requests {} -> {}; bit-identical: {bit_identical}",
        un_stats.provider_requests, b_stats.provider_requests
    );

    let mut j = Json::obj();
    j.set("bench", Json::str("haqa bench batching"));
    j.set("quick", Json::Bool(quick));
    j.set("scenarios", Json::Num(scenarios.len() as f64));
    j.set("rounds_budget", Json::Num(rounds as f64));
    j.set("agent_latency_ms", Json::Num(latency_ms as f64));
    j.set("workers", Json::Num(1.0));
    j.set("inflight", Json::Num(inflight as f64));
    j.set("batch", Json::Num(batch_size as f64));
    let mut phases = Json::obj();
    let phase = |wall: f64, st: BatchStats| {
        let mut o = Json::obj();
        o.set("wall_s", Json::Num(wall));
        o.set("agent_requests", Json::Num(st.submitted as f64));
        o.set("provider_requests", Json::Num(st.provider_requests as f64));
        o.set("max_batch", Json::Num(st.max_batch as f64));
        o
    };
    phases.set("unbatched", phase(un_wall, un_stats));
    phases.set("batched", phase(b_wall, b_stats));
    j.set("phases", phases);
    j.set("provider_requests_unbatched", Json::Num(un_stats.provider_requests as f64));
    j.set("provider_requests_batched", Json::Num(b_stats.provider_requests as f64));
    j.set(
        "request_reduction",
        Json::Num(un_stats.provider_requests as f64 / (b_stats.provider_requests as f64).max(1.0)),
    );
    j.set("speedup", Json::Num(speedup));
    j.set("bit_identical", Json::Bool(bit_identical));
    std::fs::write(out_path, j.to_string_pretty())?;
    println!("  report      : {out_path}");

    anyhow::ensure!(bit_identical, "batched and unbatched agent paths diverged");
    anyhow::ensure!(
        un_stats.submitted == b_stats.submitted,
        "the two paths issued different request streams ({} vs {})",
        un_stats.submitted,
        b_stats.submitted
    );
    anyhow::ensure!(
        b_stats.provider_requests < un_stats.provider_requests,
        "batching did not reduce provider requests ({} -> {}) — the \
         aggregation layer is broken",
        un_stats.provider_requests,
        b_stats.provider_requests
    );
    Ok(())
}

/// The scale phase: a generated matrix (10k scenarios by default) through
/// the fleet three ways — cold with an unbounded cache, cold with a
/// tightly capped LRU tier, and warm on the capped journal (a new cache
/// instance streaming the previous run's journal back through the cap).
/// Emits `BENCH_6.json` and hard-fails unless (1) every phase is
/// bit-identical — eviction can change hit rates, never scores; (2) peak
/// resident memory-tier entries stayed within the cap; (3) the cold capped
/// run's journal write calls were strictly fewer than its records — the
/// group-commit win; (4) the warm run was served at least partly from the
/// journal.  Also reports the per-platform Pareto fronts over the
/// generated matrix (the paper's "counterintuitive wins" at scale).
fn bench_scale(
    quick: bool,
    count: Option<usize>,
    cap: Option<usize>,
    workers: usize,
    out_path: &str,
) -> Result<()> {
    use haqa::coordinator::cache::JOURNAL_FILE;
    use haqa::coordinator::{CacheStats, FleetReport, MatrixSpec};
    use haqa::util::json::Json;

    let count = count.unwrap_or(if quick { 600 } else { 10_000 });
    let cap = cap.unwrap_or((count / 8).max(64));
    let spec = MatrixSpec::scale_default(count, 42);
    let scenarios = spec.expand();
    println!(
        "scale: {} generated scenarios ({} per matrix pass), cache cap {cap}, {workers} workers",
        scenarios.len(),
        spec.pass_len()
    );

    let fresh_dir = |tag: &str| -> Result<std::path::PathBuf> {
        let dir = std::env::temp_dir().join(format!(
            "haqa_bench_scale_{tag}_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir)?;
        let _ = std::fs::remove_file(dir.join(JOURNAL_FILE));
        Ok(dir)
    };
    let dir_unbounded = fresh_dir("unbounded")?;
    let dir_capped = fresh_dir("capped")?;

    let timed = |runner: FleetRunner| -> Result<(f64, Vec<u64>, FleetReport)> {
        let t0 = std::time::Instant::now();
        let report = runner.run(&scenarios);
        let wall = t0.elapsed().as_secs_f64();
        let mut bits = Vec::with_capacity(scenarios.len());
        for (sc, out) in scenarios.iter().zip(&report.outcomes) {
            let o = out.as_ref().map_err(|e| anyhow::anyhow!("{}: {e:#}", sc.name))?;
            bits.push(o.best_score.to_bits());
        }
        Ok((wall, bits, report))
    };
    let stats_line = |tag: &str, wall: f64, st: &CacheStats| {
        println!(
            "  {tag}: {wall:8.3}s  ({} hits / {} computed, peak {} entries, \
             {} evicted, {} journal records in {} writes)",
            st.hits, st.misses, st.peak_entries, st.evictions, st.journal_records,
            st.journal_writes
        );
    };

    let (un_wall, un_bits, un_report) = timed(
        FleetRunner::new(workers)
            .quiet()
            .with_cache(EvalCache::with_dir(&dir_unbounded)?),
    )?;
    let un_stats = un_report.cache.unwrap_or_default();
    stats_line("cold unbounded", un_wall, &un_stats);
    let (c_wall, c_bits, c_report) = timed(
        FleetRunner::new(workers)
            .quiet()
            .with_cache(EvalCache::with_dir_capped(&dir_capped, Some(cap))?),
    )?;
    let c_stats = c_report.cache.unwrap_or_default();
    stats_line("cold capped   ", c_wall, &c_stats);
    // A fresh capped instance on the same journal: the process-boundary
    // path, streaming the whole journal back through the cap.
    let (w_wall, w_bits, w_report) = timed(
        FleetRunner::new(workers)
            .quiet()
            .with_cache(EvalCache::with_dir_capped(&dir_capped, Some(cap))?),
    )?;
    let w_stats = w_report.cache.unwrap_or_default();
    stats_line("warm capped   ", w_wall, &w_stats);

    let bit_identical = un_bits == c_bits && un_bits == w_bits;
    let peak_within_cap = c_stats.peak_entries <= cap && w_stats.peak_entries <= cap;
    let journal_coalesced =
        c_stats.journal_records > 0 && c_stats.journal_writes < c_stats.journal_records;
    let fronts = un_report.pareto(&scenarios);
    let front_members: usize = fronts.iter().map(|f| f.members.len()).sum();
    println!(
        "  pareto        : {} platform/track fronts, {} scenarios on them",
        fronts.len(),
        front_members
    );

    let phase = |wall: f64, st: &CacheStats| -> Json {
        let mut o = Json::obj();
        o.set("wall_s", Json::Num(wall));
        o.set("computed", Json::Num(st.misses as f64));
        o.set("cache_hits", Json::Num(st.hits as f64));
        o.set("entries", Json::Num(st.entries as f64));
        o.set("peak_entries", Json::Num(st.peak_entries as f64));
        o.set("evictions", Json::Num(st.evictions as f64));
        o.set("journal_records", Json::Num(st.journal_records as f64));
        o.set("journal_writes", Json::Num(st.journal_writes as f64));
        o
    };
    let mut phases = Json::obj();
    phases.set("cold_unbounded", phase(un_wall, &un_stats));
    phases.set("cold_capped", phase(c_wall, &c_stats));
    phases.set("warm_capped", phase(w_wall, &w_stats));
    let mut pareto = Json::obj();
    pareto.set("groups", Json::Num(fronts.len() as f64));
    pareto.set("front_members", Json::Num(front_members as f64));
    let mut j = Json::obj();
    j.set("bench", Json::str("haqa bench scale"));
    j.set("quick", Json::Bool(quick));
    j.set("scenarios", Json::Num(scenarios.len() as f64));
    j.set("matrix_pass_len", Json::Num(spec.pass_len() as f64));
    j.set("matrix_seed", Json::Num(spec.seed as f64));
    j.set("families", Json::Num(un_report.families as f64));
    j.set("workers", Json::Num(workers as f64));
    j.set("cache_cap", Json::Num(cap as f64));
    j.set("phases", phases);
    j.set("pareto", pareto);
    j.set("bit_identical", Json::Bool(bit_identical));
    j.set("peak_within_cap", Json::Bool(peak_within_cap));
    j.set("journal_writes_coalesced", Json::Bool(journal_coalesced));
    j.set("warm_hits", Json::Num(w_stats.hits as f64));
    std::fs::write(out_path, j.to_string_pretty())?;
    println!("  report        : {out_path}");

    anyhow::ensure!(
        bit_identical,
        "capped/warm fleet runs diverged from unbounded — eviction changed a score"
    );
    anyhow::ensure!(
        peak_within_cap,
        "peak resident entries exceeded the cap (cold {}, warm {} > {cap})",
        c_stats.peak_entries,
        w_stats.peak_entries
    );
    anyhow::ensure!(
        journal_coalesced,
        "journal writes not coalesced ({} writes for {} records) — group commit is broken",
        c_stats.journal_writes,
        c_stats.journal_records
    );
    anyhow::ensure!(
        w_stats.hits > 0,
        "warm capped run saw zero hits — the journal tier is broken under the cap"
    );
    Ok(())
}

/// The chaos phase: the bench kernel/bit-width fleet three ways —
/// fault-free, wrapped in a no-op `chaos:none=simulated` evaluator (pure
/// wrapper overhead), and under a seeded fault plan with retries.  Emits
/// `BENCH_7.json` and hard-fails unless (1) the no-op wrapper and the
/// faulted run are both **bit-identical** to the fault-free baseline —
/// injected faults and the restarts that absorb them must never change a
/// score; (2) the faulted run actually burned restarts (the plan fired);
/// (3) the wrapper overhead stayed within a generous noise-tolerant bound.
fn bench_chaos(quick: bool, rounds: usize, workers: usize, out_path: &str) -> Result<()> {
    use haqa::coordinator::FleetReport;
    use haqa::util::json::Json;

    let base = bench_scenarios(quick, rounds, "simulated");
    let with_eval = |spec: &str| -> Vec<Scenario> {
        base.iter()
            .cloned()
            .map(|mut sc| {
                sc.evaluator = spec.to_string();
                sc
            })
            .collect()
    };
    // Few enough injected faults that the seeded schedule (first fault at
    // call >= 2, gaps 2..=6) always lands inside the fleet's call stream.
    let faults = if quick { 4 } else { 8 };
    let plan = format!("seed:7:{faults}");
    println!(
        "chaos: {} scenarios, plan {plan}, {workers} workers",
        base.len()
    );

    let timed = |scenarios: &[Scenario], retries: usize| -> Result<(f64, Vec<u64>, FleetReport)> {
        let t0 = std::time::Instant::now();
        let report = FleetRunner::new(workers)
            .quiet()
            .with_retries(retries)
            .run(scenarios);
        let wall = t0.elapsed().as_secs_f64();
        let mut bits = Vec::with_capacity(scenarios.len());
        for (sc, out) in scenarios.iter().zip(&report.outcomes) {
            let o = out.as_ref().map_err(|e| anyhow::anyhow!("{}: {e:#}", sc.name))?;
            bits.push(o.best_score.to_bits());
        }
        Ok((wall, bits, report))
    };

    let (base_wall, base_bits, _) = timed(&base, 0)?;
    println!("  fault-free   : {base_wall:8.3}s");
    let (wrap_wall, wrap_bits, _) = timed(&with_eval("chaos:none=simulated"), 0)?;
    println!("  chaos:none   : {wrap_wall:8.3}s");
    let (fault_wall, fault_bits, fault_report) =
        timed(&with_eval(&format!("chaos:{plan}=simulated")), 4)?;
    println!(
        "  seeded faults: {fault_wall:8.3}s  ({} restarts: {} transient, {} panicked, {} fatal)",
        fault_report.faults.retries,
        fault_report.faults.transient,
        fault_report.faults.panicked,
        fault_report.faults.fatal
    );

    let wrapper_identical = base_bits == wrap_bits;
    let faulted_identical = base_bits == fault_bits;
    let overhead = wrap_wall / base_wall.max(1e-9);
    // Wall clocks in --quick mode are tens of milliseconds, so the gate
    // tolerates scheduler noise: 3x relative OR 50ms absolute slack.
    let overhead_ok = wrap_wall <= base_wall * 3.0 + 0.05;

    let phase = |wall: f64| -> Json {
        let mut o = Json::obj();
        o.set("wall_s", Json::Num(wall));
        o
    };
    let mut phases = Json::obj();
    phases.set("fault_free", phase(base_wall));
    phases.set("chaos_none", phase(wrap_wall));
    let mut faulted = phase(fault_wall);
    faulted.set("restarts", Json::Num(fault_report.faults.retries as f64));
    faulted.set(
        "transient_failures",
        Json::Num(fault_report.faults.transient as f64),
    );
    phases.set("faulted", faulted);
    let mut j = Json::obj();
    j.set("bench", Json::str("haqa bench chaos"));
    j.set("quick", Json::Bool(quick));
    j.set("scenarios", Json::Num(base.len() as f64));
    j.set("workers", Json::Num(workers as f64));
    j.set("plan", Json::str(plan.clone()));
    j.set("phases", phases);
    j.set("wrapper_overhead", Json::Num(overhead));
    j.set("wrapper_bit_identical", Json::Bool(wrapper_identical));
    j.set("faulted_bit_identical", Json::Bool(faulted_identical));
    std::fs::write(out_path, j.to_string_pretty())?;
    println!("  report       : {out_path}");

    anyhow::ensure!(
        wrapper_identical,
        "the no-op chaos wrapper changed a score — the wrapper is not transparent"
    );
    anyhow::ensure!(
        faulted_identical,
        "the faulted run diverged from the fault-free baseline — retries must \
         restore bit-identical scores"
    );
    anyhow::ensure!(
        fault_report.faults.retries > 0,
        "the fault plan '{plan}' never fired — the chaos phase gated nothing"
    );
    anyhow::ensure!(
        overhead_ok,
        "chaos:none wrapper overhead {overhead:.2}x exceeds the noise bound"
    );
    Ok(())
}

/// The traffic-shaped serving phase (`BENCH_10.json`), two sub-phases:
///
/// 1. **Analytic sweep** — on the reference deployment (llama2-7b /
///    a6000 / 24 GB) simulate every quantization scheme under every named
///    traffic profile and record the p99-optimal scheme next to the
///    scheme the lone-request roofline (mean token time) would pick.
///    Hard-fails unless at least one profile's p99 winner **differs**
///    from the roofline winner — the reason this phase exists: a batched
///    decode step pays dequant compute per sequence but streams weights
///    once, so the low-bit scheme that wins a lone request can lose the
///    tail under bursty load.
/// 2. **Fleet bit-identity** — a traffic-scored bit-width fleet run with
///    1 worker and with N workers; hard-fails unless the scores are
///    bit-identical, the same gate every other phase applies.
fn bench_traffic(quick: bool, workers: usize, out_path: &str) -> Result<()> {
    use haqa::coordinator::traffic::{simulate, TrafficProfile};
    use haqa::coordinator::FleetReport;
    use haqa::hardware::adaptive;
    use haqa::quant::Scheme;
    use haqa::util::json::Json;

    const MODEL: &str = "llama2-7b";
    const DEVICE: &str = "a6000";
    const LIMIT_GB: f64 = 24.0;
    const SEED: u64 = 11;

    let model = haqa::coordinator::workflow::model_by_name(MODEL)?;
    let dev = haqa::hardware::preset(DEVICE)
        .ok_or_else(|| anyhow::anyhow!("unknown device preset '{DEVICE}'"))?;
    println!("traffic: {MODEL} on {DEVICE} @ {LIMIT_GB} GB, seed {SEED}");

    // The scheme the lone-request roofline ranks first — what a
    // mean-latency objective would deploy.
    let mean_best = Scheme::ALL
        .into_iter()
        .min_by(|a, b| {
            adaptive::token_time_ms(&model, *a, &dev)
                .total_cmp(&adaptive::token_time_ms(&model, *b, &dev))
        })
        .expect("Scheme::ALL is non-empty");

    let mut profiles_json = Json::obj();
    let mut divergent: Vec<&'static str> = Vec::new();
    for profile in TrafficProfile::all() {
        let mut best: Option<(Scheme, f64)> = None;
        let mut schemes_json = Json::obj();
        for scheme in Scheme::ALL {
            let rep = simulate(&model, scheme, &dev, &profile, LIMIT_GB, SEED);
            match best {
                Some((_, incumbent)) if incumbent <= rep.p99_ms => {}
                _ => best = Some((scheme, rep.p99_ms)),
            }
            schemes_json.set(scheme.label(), rep.to_json());
        }
        let (p99_best, p99_ms) = best.expect("Scheme::ALL is non-empty");
        let diverges = p99_best != mean_best;
        if diverges {
            divergent.push(profile.name);
        }
        println!(
            "  {:<18}: p99-optimal {} ({p99_ms:.1}ms)  roofline-optimal {}{}",
            profile.name,
            p99_best.label(),
            mean_best.label(),
            if diverges { "  << diverges" } else { "" }
        );
        let mut p = Json::obj();
        p.set("p99_optimal", Json::str(p99_best.label()));
        p.set("mean_optimal", Json::str(mean_best.label()));
        p.set("diverges", Json::Bool(diverges));
        p.set("schemes", schemes_json);
        profiles_json.set(profile.name, p);
    }

    // Fleet sub-phase: the same traffic-scored scenarios through the
    // full agent round loop, serial vs parallel.
    let models: &[&str] = if quick { &[MODEL] } else { &[MODEL, "tinyllama-1.1b"] };
    let mut scenarios = Vec::new();
    for (i, m) in models.iter().enumerate() {
        for (j, name) in haqa::coordinator::traffic::PROFILE_NAMES.iter().enumerate() {
            scenarios.push(Scenario {
                name: format!("bench_tr_{m}_{name}"),
                track: Track::Bitwidth,
                model: (*m).into(),
                device: DEVICE.into(),
                memory_limit_gb: LIMIT_GB,
                traffic: (*name).into(),
                budget: 6,
                seed: SEED + (i * 16 + j) as u64,
                ..Scenario::default()
            });
        }
    }
    let timed = |workers: usize| -> Result<(f64, Vec<u64>)> {
        let t0 = std::time::Instant::now();
        let report: FleetReport = FleetRunner::new(workers).quiet().run(&scenarios);
        let wall = t0.elapsed().as_secs_f64();
        let mut bits = Vec::with_capacity(scenarios.len());
        for (sc, out) in scenarios.iter().zip(&report.outcomes) {
            let o = out.as_ref().map_err(|e| anyhow::anyhow!("{}: {e:#}", sc.name))?;
            bits.push(o.best_score.to_bits());
        }
        Ok((wall, bits))
    };
    let (serial_wall, serial_bits) = timed(1)?;
    println!("  serial fleet : {serial_wall:8.3}s  ({} scenarios)", scenarios.len());
    let (fleet_wall, fleet_bits) = timed(workers)?;
    println!("  {workers}-worker fleet: {fleet_wall:7.3}s");
    let bit_identical = serial_bits == fleet_bits;

    let mut phases = Json::obj();
    let phase = |wall: f64| -> Json {
        let mut o = Json::obj();
        o.set("wall_s", Json::Num(wall));
        o
    };
    phases.set("serial_fleet", phase(serial_wall));
    phases.set("worker_fleet", phase(fleet_wall));
    let mut j = Json::obj();
    j.set("bench", Json::str("haqa bench traffic"));
    j.set("quick", Json::Bool(quick));
    j.set("model", Json::str(MODEL));
    j.set("device", Json::str(DEVICE));
    j.set("memory_limit_gb", Json::Num(LIMIT_GB));
    j.set("seed", Json::Num(SEED as f64));
    j.set("profiles", profiles_json);
    j.set(
        "divergent_profiles",
        Json::Arr(divergent.iter().map(|n| Json::str(*n)).collect()),
    );
    j.set("fleet_scenarios", Json::Num(scenarios.len() as f64));
    j.set("workers", Json::Num(workers as f64));
    j.set("phases", phases);
    j.set("bit_identical", Json::Bool(bit_identical));
    std::fs::write(out_path, j.to_string_pretty())?;
    println!("  report       : {out_path}");

    anyhow::ensure!(
        !divergent.is_empty(),
        "no traffic profile made the p99-optimal scheme diverge from the \
         lone-request roofline pick — the serving simulator is gating nothing"
    );
    anyhow::ensure!(
        bit_identical,
        "serial and {workers}-worker traffic-scored fleets diverged — serving \
         evaluations must be bit-identical under parallelism"
    );
    Ok(())
}

/// The distributed remote-cache phase (`BENCH_8.json`): two sequential
/// *cold* fleets (fresh in-memory caches, nothing shared locally) pointed
/// at one in-process `haqa cache serve` endpoint, with an isolated
/// baseline fleet for reference.  The server's journal is rotated between
/// the two fleets to exercise generation rotation under live clients.
/// Hard-gates that (1) both remote-tier fleets score bit-identically to
/// the isolated baseline, (2) the second fleet's remote hit rate exceeds
/// 50% on the shared workload, and (3) the second fleet performs strictly
/// fewer real evaluations than the first.
fn bench_distributed(quick: bool, rounds: usize, workers: usize, out_path: &str) -> Result<()> {
    use haqa::coordinator::{CacheStats, FleetReport};
    use haqa::util::json::Json;

    let scenarios = bench_scenarios(quick, rounds, "simulated");
    let dir = std::env::temp_dir().join(format!("haqa_bench_remote_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let _ = std::fs::remove_file(dir.join(haqa::coordinator::cache::JOURNAL_FILE));
    let server = CacheServer::spawn("127.0.0.1:0", EvalCache::with_dir(&dir)?)?;
    let addr = server.addr().to_string();
    println!(
        "distributed: {} scenarios, {workers} workers, cache server on {addr}",
        scenarios.len()
    );

    let timed = |cache: EvalCache| -> Result<(f64, Vec<u64>, CacheStats)> {
        let t0 = std::time::Instant::now();
        let report: FleetReport = FleetRunner::new(workers).quiet().with_cache(cache).run(&scenarios);
        let wall = t0.elapsed().as_secs_f64();
        let mut bits = Vec::with_capacity(scenarios.len());
        for (sc, out) in scenarios.iter().zip(&report.outcomes) {
            let o = out.as_ref().map_err(|e| anyhow::anyhow!("{}: {e:#}", sc.name))?;
            bits.push(o.best_score.to_bits());
        }
        Ok((wall, bits, report.cache.unwrap_or_default()))
    };

    let (base_wall, base_bits, base_stats) = timed(EvalCache::new())?;
    println!("  isolated    : {base_wall:8.3}s  ({} computed)", base_stats.misses);
    let (a_wall, a_bits, a_stats) =
        timed(EvalCache::with_remote(RemoteCacheTier::new(&addr)?, None))?;
    println!(
        "  fleet A     : {a_wall:8.3}s  ({} computed, {} remote hits in {} round-trip(s))",
        a_stats.misses, a_stats.remote_hits, a_stats.remote_round_trips
    );
    // Rotate the server-side journal while the protocol stays live — the
    // second fleet must see every entry through the new generation.
    let rotated = server.rotate()?;
    println!(
        "  rotate      : {} -> {} records",
        rotated.before_records, rotated.after_records
    );
    let (b_wall, b_bits, b_stats) =
        timed(EvalCache::with_remote(RemoteCacheTier::new(&addr)?, None))?;
    println!(
        "  fleet B     : {b_wall:8.3}s  ({} computed, {} remote hits in {} round-trip(s))",
        b_stats.misses, b_stats.remote_hits, b_stats.remote_round_trips
    );

    let bit_identical = base_bits == a_bits && base_bits == b_bits;
    let remote_total = (b_stats.remote_hits + b_stats.remote_misses) as f64;
    let remote_hit_rate = b_stats.remote_hits as f64 / remote_total.max(1.0);
    let fewer_evaluations = b_stats.misses < a_stats.misses;

    let phase = |wall: f64, st: &CacheStats| -> Json {
        let mut o = Json::obj();
        o.set("wall_s", Json::Num(wall));
        o.set("computed", Json::Num(st.misses as f64));
        o.set("remote_hits", Json::Num(st.remote_hits as f64));
        o.set("remote_misses", Json::Num(st.remote_misses as f64));
        o.set("remote_round_trips", Json::Num(st.remote_round_trips as f64));
        o
    };
    let mut phases = Json::obj();
    phases.set("isolated", phase(base_wall, &base_stats));
    phases.set("fleet_a", phase(a_wall, &a_stats));
    phases.set("fleet_b", phase(b_wall, &b_stats));
    let mut j = Json::obj();
    j.set("bench", Json::str("haqa bench distributed"));
    j.set("quick", Json::Bool(quick));
    j.set("scenarios", Json::Num(scenarios.len() as f64));
    j.set("workers", Json::Num(workers as f64));
    j.set("phases", phases);
    j.set("rotated_records", Json::Num(rotated.after_records as f64));
    j.set("remote_hit_rate", Json::Num(remote_hit_rate));
    j.set("bit_identical", Json::Bool(bit_identical));
    j.set("fewer_evaluations", Json::Bool(fewer_evaluations));
    std::fs::write(out_path, j.to_string_pretty())?;
    println!(
        "  remote hit rate {:.0}%; report {out_path}",
        remote_hit_rate * 100.0
    );

    anyhow::ensure!(
        bit_identical,
        "a fleet sharing the remote cache diverged from the isolated baseline — \
         the remote tier must be score-invariant"
    );
    anyhow::ensure!(
        remote_hit_rate > 0.5,
        "second-fleet remote hit rate {remote_hit_rate:.2} <= 0.5 — the shared \
         warm tier is not amortizing across fleets"
    );
    anyhow::ensure!(
        fewer_evaluations,
        "the second fleet computed {} evaluations vs {} in the first — sharing \
         the cache server must strictly reduce real evaluations",
        b_stats.misses,
        a_stats.misses
    );
    Ok(())
}

/// `haqa cache <subcommand>` — journal maintenance (`compact`) and the
/// shared warm-cache server (`serve`).
fn cache_cmd(rest: Vec<String>) -> Result<()> {
    use haqa::coordinator::CompactReport;

    let (sub, rest) = match rest.split_first() {
        Some((s, r)) => (s.as_str(), r.to_vec()),
        None => anyhow::bail!("usage: haqa cache <compact|serve> [--cache-dir DIR]"),
    };
    match sub {
        "compact" => {
            let a = Args::new(
                "haqa cache compact",
                "rewrite the eval-cache journal keeping only live entries",
            )
            .opt("cache-dir", "cache directory holding eval_cache.jsonl")
            .parse(rest)?;
            let dir = a
                .get("cache-dir")
                .map(|s| s.to_string())
                .or_else(|| a.positional.first().cloned())
                .ok_or_else(|| {
                    anyhow::anyhow!("usage: haqa cache compact <dir> (or --cache-dir DIR)")
                })?;
            let r: CompactReport = EvalCache::compact(&dir)?;
            println!(
                "compacted {}/eval_cache.jsonl: {} -> {} records \
                 ({} superseded duplicate(s), {} corrupt line(s) dropped), \
                 {} -> {} bytes",
                dir,
                r.before_records,
                r.after_records,
                r.before_records - r.after_records,
                r.dropped_corrupt,
                r.before_bytes,
                r.after_bytes
            );
            Ok(())
        }
        "serve" => {
            let a = Args::new(
                "haqa cache serve",
                "serve a shared eval-cache endpoint over the JSONL/TCP protocol",
            )
            .opt_default(
                "addr",
                cache_server::DEFAULT_CACHE_ADDR,
                "bind address (port 0 = ephemeral)",
            )
            .opt("cap", "memory-tier LRU cap in entries (default: unbounded)")
            .opt("cache-dir", "back the server with a persistent journal in DIR")
            .parse(rest)?;
            let cap = a.get_usize("cap")?;
            let cache = match (a.get("cache-dir"), cap) {
                (Some(dir), cap) => EvalCache::with_dir_capped(dir, cap)?,
                (None, Some(c)) => EvalCache::bounded(c),
                (None, None) => EvalCache::new(),
            };
            let server = CacheServer::spawn(a.get("addr").unwrap(), cache)?;
            println!("cache server listening on {}", server.addr());
            println!(
                "point fleets at it with `haqa fleet --cache-addr {}` \
                 (or HAQA_CACHE_ADDR={})",
                server.addr(),
                server.addr()
            );
            // Foreground service: the accept loop runs on its background
            // thread until the process is killed.
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        other => anyhow::bail!("unknown cache subcommand '{other}' (try `compact` or `serve`)"),
    }
}

/// `haqa device <serve|ping>` — run or probe a device-measurement server
/// speaking the JSONL protocol documented in `docs/EVALUATORS.md`.
fn device_cmd(rest: Vec<String>) -> Result<()> {
    use haqa::coordinator::DeviceServer;
    use std::io::{BufRead, BufReader, Write};

    let (sub, rest) = match rest.split_first() {
        Some((s, r)) => (s.as_str(), r.to_vec()),
        None => anyhow::bail!("usage: haqa device <serve|ping> [--addr HOST:PORT]"),
    };
    match sub {
        "serve" => {
            let a = Args::new(
                "haqa device serve",
                "serve the JSONL device-measurement protocol (simulator-backed stub)",
            )
            .opt_default("addr", "127.0.0.1:7434", "bind address (port 0 = ephemeral)")
            .parse(rest)?;
            let server = DeviceServer::spawn(a.get("addr").unwrap())?;
            println!(
                "device server listening on {} (profiles: {})",
                server.addr(),
                haqa::hardware::PRESET_NAMES.join(", ")
            );
            println!(
                "point scenarios at it with \"evaluator\": \"remote://{}\"",
                server.addr()
            );
            // Foreground service: the accept loop runs on its background
            // thread until the process is killed.
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        "ping" => {
            let a = Args::new("haqa device ping", "hello round-trip against a device server")
                .opt_default("addr", "127.0.0.1:7434", "server address")
                .parse(rest)?;
            let addr = a.get("addr").unwrap();
            let timeout = std::time::Duration::from_secs(5);
            let sock_addr = std::net::ToSocketAddrs::to_socket_addrs(addr)?
                .next()
                .ok_or_else(|| anyhow::anyhow!("cannot resolve {addr}"))?;
            let mut stream = std::net::TcpStream::connect_timeout(&sock_addr, timeout)?;
            stream.set_read_timeout(Some(timeout))?;
            stream.set_write_timeout(Some(timeout))?;
            stream.write_all(b"{\"op\":\"hello\",\"v\":1}\n")?;
            let mut line = String::new();
            BufReader::new(stream).read_line(&mut line)?;
            anyhow::ensure!(!line.trim().is_empty(), "no reply from {addr}");
            println!("{}", line.trim());
            Ok(())
        }
        other => anyhow::bail!("unknown device subcommand '{other}' (try `serve` or `ping`)"),
    }
}

/// The fixed scenario set `haqa bench` measures: simulator-only tracks
/// (kernel + bit-width) so the harness runs offline, spanning several
/// artifact families (two simulated devices + the bit-width track) and
/// every optimizer class the fleet serves.
///
/// `evaluator` applies to the *kernel* scenarios only (bit-width always
/// evaluates in-process): `simulated` is the default, the special value
/// `device` maps each scenario to `device:<its device>` (stub-server wire
/// path, platform diversity preserved), and anything else is used
/// verbatim.
fn bench_scenarios(quick: bool, rounds: usize, evaluator: &str) -> Vec<Scenario> {
    let kernels: &[&str] = if quick {
        &["matmul:64", "softmax:128"]
    } else {
        &["matmul:64", "matmul:128", "softmax:64", "softmax:128", "silu:64", "rmsnorm:64", "rope:128"]
    };
    let devices: &[&str] = if quick { &["a6000"] } else { &["a6000", "adreno740"] };
    let optimizers: &[&str] = if quick { &["haqa", "random"] } else { &["haqa", "random", "bayesian"] };
    let mut v = Vec::new();
    for device in devices {
        for kernel in kernels {
            for optimizer in optimizers {
                v.push(Scenario {
                    name: format!("bench_{device}_{}_{optimizer}", kernel.replace(':', "_")),
                    track: Track::Kernel,
                    kernel: (*kernel).into(),
                    device: (*device).into(),
                    optimizer: (*optimizer).into(),
                    budget: rounds,
                    seed: 7,
                    evaluator: match evaluator {
                        "device" => format!("device:{device}"),
                        other => other.to_string(),
                    },
                    ..Scenario::default()
                });
            }
        }
    }
    let models: &[&str] = if quick {
        &["llama2-13b", "openllama-3b"]
    } else {
        &["llama2-13b", "llama2-7b", "openllama-3b", "tinyllama-1.1b"]
    };
    for model in models {
        for device in devices {
            v.push(Scenario {
                name: format!("bench_bw_{model}_{device}"),
                track: Track::Bitwidth,
                model: (*model).into(),
                device: (*device).into(),
                memory_limit_gb: 12.0,
                ..Scenario::default()
            });
        }
    }
    v
}

/// Microbench for the batched kernel-measurement path: time a sweep of
/// sampled configs through the per-call path (which re-derives the latency
/// model every call) and through `measure_batch` (one model per slice).
/// Returns the per-call / batched wall-clock ratio (best of 5 reps each).
fn batched_measure_speedup(sweep: usize) -> f64 {
    use haqa::deploy::KernelTuner;
    use haqa::hardware::{DeviceProfile, KernelKind, Workload};
    use haqa::search::spaces;

    let profile = DeviceProfile::a6000();
    let tuner = KernelTuner {
        profile: &profile,
        workload: Workload::new(KernelKind::MatMul, 64),
        noise_seed: 7,
    };
    let space = spaces::kernel_exec();
    let mut rng = Rng::new(21);
    let cfgs: Vec<_> = (0..sweep).map(|_| space.sample(&mut rng)).collect();
    let best_of = |f: &dyn Fn() -> Vec<f64>| -> (f64, Vec<f64>) {
        let mut best = f64::INFINITY;
        let mut out = Vec::new();
        for _ in 0..5 {
            let t0 = std::time::Instant::now();
            let r = f();
            let dt = t0.elapsed().as_secs_f64();
            if dt < best {
                best = dt;
            }
            out = r;
        }
        (best, out)
    };
    let (per_call_s, a) = best_of(&|| cfgs.iter().map(|c| tuner.measure(c)).collect());
    let (batched_s, b) = best_of(&|| tuner.measure_batch(&cfgs));
    // A hard check (this harness gates CI in release builds): the batched
    // path must be bit-identical to the per-call path.
    assert!(
        a.len() == b.len() && a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
        "batched measurement diverged from the per-call path"
    );
    per_call_s / batched_s.max(1e-12)
}

/// L3 coordinator micro-benchmarks (EXPERIMENTS.md §Perf): the coordinator
/// must never be the bottleneck — agent rounds and simulator evaluations
/// are compared against the evaluation substrate they steer.
fn perf() -> Result<()> {
    use haqa::agent::simulated::SimulatedLlm;
    use haqa::agent::{Agent, TaskContext, TaskKind};
    use haqa::deploy::tuner::KernelTuner;
    use haqa::hardware::{DeviceProfile, KernelKind, Workload};
    use haqa::optimizers::Observation;
    use haqa::search::spaces;
    use haqa::util::bench::{bench, bench_batched, BenchConfig};
    use haqa::util::json::Json;

    let cfg = BenchConfig {
        warmup_iters: 3,
        iters: 20,
    };
    // 1. Full agent round: prompt build + policy + validation (w/ history).
    let space = spaces::resnet_qat();
    let mut history: Vec<Observation> = (0..10)
        .map(|i| {
            let mut o = Observation::new(space.default_config(), 0.5 + i as f64 * 0.01);
            o.feedback = "{\"final_loss\": 0.5, \"loss_slope\": -0.01}".into();
            o
        })
        .collect();
    let mut agent = Agent::blocking(SimulatedLlm::new(1).with_failure_rate(0.0));
    let r = bench("agent round (prompt+policy+validate)", cfg, || {
        let ctx = TaskContext {
            kind: TaskKind::Finetune,
            space: &space,
            history: &history,
            rounds_left: 5,
            hardware: None,
            objective: Json::obj(),
        };
        let (cfg_out, _) = agent.propose(&ctx).unwrap();
        history.pop();
        history.push(Observation::new(cfg_out, 0.6));
    });
    println!("{}", r.report());

    // 2. Simulated kernel-latency evaluations (tuner throughput).
    let profile = DeviceProfile::a6000();
    let tuner = KernelTuner {
        profile: &profile,
        workload: Workload::new(KernelKind::MatMul, 64),
        noise_seed: 0,
    };
    let kspace = spaces::kernel_exec();
    let mut rng = haqa::util::rng::Rng::new(2);
    let cfgs: Vec<_> = (0..64).map(|_| kspace.sample(&mut rng)).collect();
    let mut i = 0usize;
    let r = bench_batched("simulated kernel measurement (10 reps)", cfg, 64, || {
        let lat = tuner.measure(&cfgs[i % 64]);
        std::hint::black_box(lat);
        i += 1;
    });
    println!("{}", r.report());

    // 3. PJRT decode step (the evaluation substrate being steered).
    let set = ArtifactSet::load_default()?;
    let exec = set.executor("lm_decode_default")?;
    let mut rng = Rng::new(3);
    let frozen = exec.artifact.init_frozen(&mut rng);
    let mut named = std::collections::HashMap::new();
    let tok = exec
        .artifact
        .inputs
        .iter()
        .find(|s| s.name == "tokens")
        .unwrap();
    let mut t = Tensor::zeros(&tok.shape);
    for p in 0..tok.shape[1] {
        t.data[p * tok.shape[2]] = 1.0;
    }
    named.insert("tokens", t);
    named.insert("rank_mask", Tensor::ones(&[64]));
    named.insert("bits", Tensor::scalar(8.0));
    named.insert("lora_scale", Tensor::scalar(0.5));
    let r = bench("PJRT decode step (evaluation substrate)", cfg, || {
        let _ = exec.step(Vec::new(), &frozen, &named).unwrap();
    });
    println!("{}", r.report());
    println!(
        "\ncoordinator overhead = agent-round / PJRT-step; target < 5% \
         (the agent round also *represents* a 2.34 s GPT-4 call in the paper)"
    );
    Ok(())
}

fn list_artifacts() -> Result<()> {
    let set = ArtifactSet::load_default()?;
    for name in set.names() {
        let art = set.get(&name)?;
        println!(
            "{:32} inputs={:3} state={:3} outputs={}",
            art.name,
            art.inputs.len(),
            art.state_count,
            art.output_shapes.len()
        );
    }
    Ok(())
}

fn smoke(filter: Option<&str>) -> Result<()> {
    let set = ArtifactSet::load_default()?;
    let mut rng = Rng::new(0);
    let mut n_ok = 0;
    for name in set.names() {
        if let Some(f) = filter {
            if !name.contains(f) {
                continue;
            }
        }
        let t0 = std::time::Instant::now();
        let exec = set.executor(&name)?;
        let compile_ms = t0.elapsed().as_millis();

        let art = &exec.artifact;
        let state = art.init_state(&mut rng);
        let frozen = art.init_frozen(&mut rng);
        let mut named = std::collections::HashMap::new();
        for spec in &art.inputs {
            match spec.role {
                InputRole::Data => {
                    let mut t = Tensor::zeros(&spec.shape);
                    rng.fill_uniform(&mut t.data);
                    named.insert(spec.name.as_str(), t);
                }
                InputRole::Scalar => {
                    named.insert(spec.name.as_str(), Tensor::scalar(smoke_scalar(&spec.name)));
                }
                _ => {}
            }
        }
        let t1 = std::time::Instant::now();
        let (new_state, metrics) = exec.step(state, &frozen, &named)?;
        let run_ms = t1.elapsed().as_millis();
        let finite = new_state
            .iter()
            .chain(metrics.iter())
            .all(|t| t.data.iter().all(|x| x.is_finite()));
        anyhow::ensure!(finite, "{name}: non-finite outputs");
        println!(
            "ok {:32} compile {:6} ms  run {:6} ms  outs {}",
            name,
            compile_ms,
            run_ms,
            new_state.len() + metrics.len()
        );
        n_ok += 1;
    }
    println!("smoke: {n_ok} artifacts ok");
    Ok(())
}

fn smoke_scalar(name: &str) -> f32 {
    match name {
        "lr" => 0.01,
        "momentum" => 0.9,
        "weight_decay" => 1e-4,
        "grad_clip" => 1.0,
        "wbits" | "abits" | "bits" => 8.0,
        "lora_scale" => 0.5,
        "dropout_p" => 0.0,
        "bc1" | "bc2" => 1.0,
        _ => 1.0,
    }
}
