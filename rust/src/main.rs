//! `haqa` — the CLI launcher for the HAQA-RS reproduction.
//!
//! ```text
//! haqa smoke [filter]          compile+execute artifacts end-to-end
//! haqa artifacts               list the artifact registry
//! haqa tune   [--flags]        fine-tuning HPO (Table 1/2 single cell)
//! haqa kernel [--flags]        kernel exec-config tuning (Table 3 cell)
//! haqa bitwidth [--flags]      bit-width selection (Table 5 / §4.4)
//! haqa generate [--flags]      serve token generation (llama.cpp analogue)
//! haqa run <scenario.json>     run a scenario file (incl. the joint loop)
//! haqa fleet <scenarios.json>  run a scenario batch across a worker pool
//! ```

use anyhow::Result;
use haqa::coordinator::{FleetRunner, Scenario, Workflow};
use haqa::coordinator::scenario::{parse_precision, Track};
use haqa::optimizers::best;
use haqa::runtime::{ArtifactSet, InputRole, Tensor};
use haqa::trainer::lm::LmBase;
use haqa::util::cli::Args;
use haqa::util::rng::Rng;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => ("help", Vec::new()),
    };
    match cmd {
        "smoke" => smoke(rest.first().map(|s| s.as_str())),
        "artifacts" => list_artifacts(),
        "tune" => tune(rest),
        "kernel" => kernel(rest),
        "bitwidth" => bitwidth(rest),
        "generate" => generate(rest),
        "run" => run_scenario(rest),
        "fleet" => fleet(rest),
        "perf" => perf(),
        "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand '{other}' (try `haqa help`)"),
    }
}

const HELP: &str = "\
haqa — hardware-aware quantization agent (paper reproduction)

  haqa smoke [filter]       compile+execute artifacts (substring filter)
  haqa artifacts            list the artifact registry
  haqa tune                 fine-tuning HPO (haqa vs baselines); --help
  haqa kernel               kernel execution-config tuning; --help
  haqa bitwidth             adaptive bit-width selection; --help
  haqa generate             token-generation engine on PJRT; --help
  haqa run <scenario.json>  run a scenario file (finetune/kernel/bitwidth/joint)
  haqa fleet <batch.json>   run a scenario batch on a worker pool w/ eval cache

Benches regenerating every paper table/figure: `cargo bench` (see DESIGN.md).
";

fn tune(rest: Vec<String>) -> Result<()> {
    let a = Args::new("haqa tune", "fine-tuning hyperparameter optimization")
        .opt_default("track", "lm", "cnn | lm")
        .opt_default("model", "cnn_s", "cnn_s|cnn_m|cnn_l (cnn track)")
        .opt_default("precision", "w4a4", "w8a8|w4a4|w2a2 (cnn track)")
        .opt_default("bits", "8", "LM base bit-width: 4|8|16")
        .opt_default("optimizer", "haqa", "default|human|local|bayesian|random|nsga2|haqa")
        .opt_default("budget", "10", "tuning rounds")
        .opt_default("seed", "0", "rng seed")
        .opt_default("steps-per-epoch", "3", "CNN steps per search-space epoch")
        .opt_default("step-scale", "0.25", "LM fraction of the paper's max_steps")
        .parse(rest)?;
    let mut sc = Scenario {
        name: format!("tune_{}", a.get("optimizer").unwrap()),
        track: if a.get("track") == Some("cnn") {
            Track::FinetuneCnn
        } else {
            Track::FinetuneLm
        },
        model: a.get("model").unwrap().to_string(),
        precision: parse_precision(a.get("precision").unwrap())?,
        bits: a.get_f64("bits")?.unwrap_or(8.0) as f32,
        optimizer: a.get("optimizer").unwrap().to_string(),
        budget: a.get_usize("budget")?.unwrap_or(10),
        seed: a.get_f64("seed")?.unwrap_or(0.0) as u64,
        steps_per_epoch: a.get_usize("steps-per-epoch")?.unwrap_or(3),
        step_scale: a.get_f64("step-scale")?.unwrap_or(0.25),
        ..Scenario::default()
    };
    if sc.track == Track::FinetuneLm {
        sc.model = "tiny-lm".into();
    }
    let set = ArtifactSet::load_default()?;
    let wf = Workflow::new(&set);
    let out = wf.run_finetune(&sc)?;
    for (i, o) in out.history.iter().enumerate() {
        println!("round {i:2}  score {:.4}  {}", o.score, o.feedback);
    }
    println!(
        "best score {:.4} (round {})",
        out.best_score,
        out.history
            .iter()
            .position(|o| o.score == out.best_score)
            .unwrap_or(0)
    );
    if let Some(cost) = &out.cost_report {
        println!("{cost}");
    }
    if let Some(p) = out.log_path {
        println!("task log: {}", p.display());
    }
    Ok(())
}

fn kernel(rest: Vec<String>) -> Result<()> {
    let a = Args::new("haqa kernel", "kernel execution-config tuning")
        .opt_default("kernel", "matmul:64", "kernel:batch, e.g. softmax:128")
        .opt_default("device", "a6000", "a6000 | adreno740 | cpu")
        .opt_default("optimizer", "haqa", "optimizer name")
        .opt_default("budget", "10", "tuning rounds")
        .opt_default("seed", "0", "rng seed")
        .parse(rest)?;
    let sc = Scenario {
        name: format!("kernel_{}", a.get("kernel").unwrap().replace(':', "_")),
        track: Track::Kernel,
        kernel: a.get("kernel").unwrap().to_string(),
        device: a.get("device").unwrap().to_string(),
        optimizer: a.get("optimizer").unwrap().to_string(),
        budget: a.get_usize("budget")?.unwrap_or(10),
        seed: a.get_f64("seed")?.unwrap_or(0.0) as u64,
        ..Scenario::default()
    };
    // Kernel tuning runs on the analytic simulator — no artifacts needed.
    let wf = Workflow::simulated();
    let out = wf.run_kernel(&sc)?;
    for (i, o) in out.history.iter().enumerate() {
        println!("round {i:2}  latency {:9.3} µs", -o.score);
    }
    let b = best(&out.history).unwrap();
    println!("best latency {:.3} µs", -b.score);
    if let Some(cost) = &out.cost_report {
        println!("{cost}");
    }
    Ok(())
}

fn bitwidth(rest: Vec<String>) -> Result<()> {
    let a = Args::new("haqa bitwidth", "adaptive quantization bit-width selection")
        .opt_default("model", "llama2-13b", "deployment model")
        .opt_default("device", "a6000", "a6000 | adreno740")
        .opt_default("memory-gb", "10", "memory limit")
        .parse(rest)?;
    let sc = Scenario {
        name: "bitwidth".into(),
        track: Track::Bitwidth,
        model: a.get("model").unwrap().to_string(),
        device: a.get("device").unwrap().to_string(),
        memory_limit_gb: a.get_f64("memory-gb")?.unwrap_or(10.0),
        ..Scenario::default()
    };
    // Bit-width selection runs on the analytic models — no artifacts needed.
    let wf = Workflow::simulated();
    let out = wf.run_bitwidth(&sc)?;
    let o = &out.history[0];
    println!(
        "agent choice: {:?}  (simulated {:.2} tokens/s)",
        o.config.get("quant"),
        o.score
    );
    println!("feedback: {}", o.feedback);
    Ok(())
}

fn generate(rest: Vec<String>) -> Result<()> {
    let a = Args::new("haqa generate", "token generation on the PJRT engine")
        .opt_default("tokens", "32", "tokens to generate")
        .opt_default("bits", "8", "base bit-width 4|8|16")
        .opt_default("tile", "default", "qmatmul tile variant: default|mm16x16x16|mm32x32x32|mm64x64x64")
        .opt_default("seed", "0", "rng seed")
        .parse(rest)?;
    let set = ArtifactSet::load_default()?;
    let base = LmBase::new(&set, a.get_f64("seed")?.unwrap_or(0.0) as u64)?;
    let art = set.get("lm_train_b8")?;
    let mut rng = Rng::new(1);
    let lora: Vec<Tensor> = art
        .inputs_with_role(InputRole::State)
        .iter()
        .take(8)
        .map(|s| s.init_tensor(&mut rng))
        .collect();
    let engine = haqa::deploy::TokenEngine::new(
        &set,
        &format!("lm_decode_{}", a.get("tile").unwrap()),
        &base.tensors,
        &lora,
        a.get_f64("bits")?.unwrap_or(8.0) as f32,
        16,
        8.0,
    )?;
    let n = a.get_usize("tokens")?.unwrap_or(32);
    let stats = engine.generate(&[1, 2, 3, 4], n)?;
    println!("generated {} tokens: {:?}", stats.tokens.len(), &stats.tokens);
    println!(
        "throughput {:.1} tokens/s, median step {:.0} µs",
        stats.tokens_per_sec(),
        stats.median_token_us()
    );
    Ok(())
}

fn run_scenario(rest: Vec<String>) -> Result<()> {
    let path = rest
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: haqa run <scenario.json>"))?;
    let sc = Scenario::load(path)?;
    // Load the artifact registry only for tracks that train on PJRT.
    let set = if sc.needs_artifacts() {
        Some(ArtifactSet::load_default()?)
    } else {
        None
    };
    let wf = match &set {
        Some(s) => Workflow::new(s),
        None => Workflow::simulated(),
    };
    if sc.track == Track::Joint {
        let (ft, kt, bw) = wf.run_joint(&sc)?;
        println!("finetune best score: {:.4}", ft.best_score);
        println!("kernel best latency: {:.3} µs", -kt.best_score);
        println!("bitwidth choice score: {:.2} tokens/s", bw.best_score);
    } else {
        let out = wf.run(&sc)?;
        println!("best score: {:.4}", out.best_score);
    }
    Ok(())
}

/// Run a scenario batch across a scoped-thread worker pool with the shared
/// content-addressed evaluation cache (`haqa fleet <batch.json>`).
fn fleet(rest: Vec<String>) -> Result<()> {
    let a = Args::new("haqa fleet", "run a scenario batch across a worker pool")
        .opt("workers", "worker threads (default: env HAQA_WORKERS or 4)")
        .flag("no-cache", "disable the content-addressed evaluation cache")
        .flag("check-serial", "re-run serially and verify bit-identical scores")
        .parse(rest)?;
    let path = a
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: haqa fleet <scenarios.json> [--workers N]"))?;
    let scenarios = Scenario::load_many(path)?;
    anyhow::ensure!(!scenarios.is_empty(), "no scenarios in {path}");
    let workers = FleetRunner::workers_from_env(a.get_usize("workers")?);
    let mut runner = FleetRunner::new(workers);
    if a.get_bool("no-cache") {
        runner = runner.without_cache();
    }
    let t0 = std::time::Instant::now();
    let report = runner.run(&scenarios);
    for (sc, out) in scenarios.iter().zip(&report.outcomes) {
        match out {
            Ok(o) => println!(
                "{:<24} {:?}: best {:.4}  ({} rounds, {} cache hits)",
                sc.name,
                sc.track,
                o.best_score,
                o.history.len(),
                o.cache_hits
            ),
            Err(e) => println!("{:<24} {:?}: error: {e:#}", sc.name, sc.track),
        }
    }
    println!(
        "fleet: {} scenarios on {} workers in {:.2}s",
        scenarios.len(),
        workers,
        t0.elapsed().as_secs_f64()
    );
    if let Some(st) = report.cache {
        println!(
            "evaluation cache: {} hits / {} misses ({} entries)",
            st.hits, st.misses, st.entries
        );
    }
    if a.get_bool("check-serial") {
        let serial = FleetRunner::new(1).run(&scenarios);
        let identical = serial
            .outcomes
            .iter()
            .zip(&report.outcomes)
            .all(|(s, p)| match (s, p) {
                (Ok(a), Ok(b)) => a.best_score.to_bits() == b.best_score.to_bits(),
                (Err(_), Err(_)) => true,
                _ => false,
            });
        anyhow::ensure!(identical, "serial and parallel fleet runs diverged");
        println!("serial check: bit-identical best scores");
    }
    Ok(())
}

/// L3 coordinator micro-benchmarks (EXPERIMENTS.md §Perf): the coordinator
/// must never be the bottleneck — agent rounds and simulator evaluations
/// are compared against the evaluation substrate they steer.
fn perf() -> Result<()> {
    use haqa::agent::simulated::SimulatedLlm;
    use haqa::agent::{Agent, TaskContext, TaskKind};
    use haqa::deploy::tuner::KernelTuner;
    use haqa::hardware::{DeviceProfile, KernelKind, Workload};
    use haqa::optimizers::Observation;
    use haqa::search::spaces;
    use haqa::util::bench::{bench, bench_batched, BenchConfig};
    use haqa::util::json::Json;

    let cfg = BenchConfig {
        warmup_iters: 3,
        iters: 20,
    };
    // 1. Full agent round: prompt build + policy + validation (w/ history).
    let space = spaces::resnet_qat();
    let mut history: Vec<Observation> = (0..10)
        .map(|i| {
            let mut o = Observation::new(space.default_config(), 0.5 + i as f64 * 0.01);
            o.feedback = "{\"final_loss\": 0.5, \"loss_slope\": -0.01}".into();
            o
        })
        .collect();
    let mut agent = Agent::new(Box::new(SimulatedLlm::new(1).with_failure_rate(0.0)));
    let r = bench("agent round (prompt+policy+validate)", cfg, || {
        let ctx = TaskContext {
            kind: TaskKind::Finetune,
            space: &space,
            history: &history,
            rounds_left: 5,
            hardware: None,
            objective: Json::obj(),
        };
        let (cfg_out, _) = agent.propose(&ctx).unwrap();
        history.pop();
        history.push(Observation::new(cfg_out, 0.6));
    });
    println!("{}", r.report());

    // 2. Simulated kernel-latency evaluations (tuner throughput).
    let profile = DeviceProfile::a6000();
    let tuner = KernelTuner {
        profile: &profile,
        workload: Workload::new(KernelKind::MatMul, 64),
        noise_seed: 0,
    };
    let kspace = spaces::kernel_exec();
    let mut rng = haqa::util::rng::Rng::new(2);
    let cfgs: Vec<_> = (0..64).map(|_| kspace.sample(&mut rng)).collect();
    let mut i = 0usize;
    let r = bench_batched("simulated kernel measurement (10 reps)", cfg, 64, || {
        let lat = tuner.measure(&cfgs[i % 64]);
        std::hint::black_box(lat);
        i += 1;
    });
    println!("{}", r.report());

    // 3. PJRT decode step (the evaluation substrate being steered).
    let set = ArtifactSet::load_default()?;
    let exec = set.executor("lm_decode_default")?;
    let mut rng = Rng::new(3);
    let frozen = exec.artifact.init_frozen(&mut rng);
    let mut named = std::collections::HashMap::new();
    let tok = exec
        .artifact
        .inputs
        .iter()
        .find(|s| s.name == "tokens")
        .unwrap();
    let mut t = Tensor::zeros(&tok.shape);
    for p in 0..tok.shape[1] {
        t.data[p * tok.shape[2]] = 1.0;
    }
    named.insert("tokens", t);
    named.insert("rank_mask", Tensor::ones(&[64]));
    named.insert("bits", Tensor::scalar(8.0));
    named.insert("lora_scale", Tensor::scalar(0.5));
    let r = bench("PJRT decode step (evaluation substrate)", cfg, || {
        let _ = exec.step(Vec::new(), &frozen, &named).unwrap();
    });
    println!("{}", r.report());
    println!(
        "\ncoordinator overhead = agent-round / PJRT-step; target < 5% \
         (the agent round also *represents* a 2.34 s GPT-4 call in the paper)"
    );
    Ok(())
}

fn list_artifacts() -> Result<()> {
    let set = ArtifactSet::load_default()?;
    for name in set.names() {
        let art = set.get(&name)?;
        println!(
            "{:32} inputs={:3} state={:3} outputs={}",
            art.name,
            art.inputs.len(),
            art.state_count,
            art.output_shapes.len()
        );
    }
    Ok(())
}

fn smoke(filter: Option<&str>) -> Result<()> {
    let set = ArtifactSet::load_default()?;
    let mut rng = Rng::new(0);
    let mut n_ok = 0;
    for name in set.names() {
        if let Some(f) = filter {
            if !name.contains(f) {
                continue;
            }
        }
        let t0 = std::time::Instant::now();
        let exec = set.executor(&name)?;
        let compile_ms = t0.elapsed().as_millis();

        let art = &exec.artifact;
        let state = art.init_state(&mut rng);
        let frozen = art.init_frozen(&mut rng);
        let mut named = std::collections::HashMap::new();
        for spec in &art.inputs {
            match spec.role {
                InputRole::Data => {
                    let mut t = Tensor::zeros(&spec.shape);
                    rng.fill_uniform(&mut t.data);
                    named.insert(spec.name.as_str(), t);
                }
                InputRole::Scalar => {
                    named.insert(spec.name.as_str(), Tensor::scalar(smoke_scalar(&spec.name)));
                }
                _ => {}
            }
        }
        let t1 = std::time::Instant::now();
        let (new_state, metrics) = exec.step(state, &frozen, &named)?;
        let run_ms = t1.elapsed().as_millis();
        let finite = new_state
            .iter()
            .chain(metrics.iter())
            .all(|t| t.data.iter().all(|x| x.is_finite()));
        anyhow::ensure!(finite, "{name}: non-finite outputs");
        println!(
            "ok {:32} compile {:6} ms  run {:6} ms  outs {}",
            name,
            compile_ms,
            run_ms,
            new_state.len() + metrics.len()
        );
        n_ok += 1;
    }
    println!("smoke: {n_ok} artifacts ok");
    Ok(())
}

fn smoke_scalar(name: &str) -> f32 {
    match name {
        "lr" => 0.01,
        "momentum" => 0.9,
        "weight_decay" => 1e-4,
        "grad_clip" => 1.0,
        "wbits" | "abits" | "bits" => 8.0,
        "lora_scale" => 0.5,
        "dropout_p" => 0.0,
        "bc1" | "bc2" => 1.0,
        _ => 1.0,
    }
}
