//! Device-backend evaluators: out-of-process measurement behind the
//! [`Evaluator`] seam.
//!
//! The paper's claim is hardware-*aware* tuning across diverse platforms,
//! which in a real deployment means the measurement does not happen in the
//! tuner's process: it happens on a device — a GPU box across the rack, a
//! phone on a USB farm — behind a measurement service (the AutoTVM
//! pattern).  This module is that seam:
//!
//! * [`EvaluatorSpec`] — the scenario `evaluator` field grammar
//!   (`simulated | device:<profile-name> | remote://host:port` plus
//!   `record:`/`replay:` transcript wrappers), parsed with the same
//!   hard-error discipline as `Scenario.backend`;
//! * [`DeviceEvaluator`] — an [`Evaluator`] whose measurements arrive over
//!   a small JSONL request/response protocol on `std::net::TcpStream`
//!   (timeouts, bounded connect retry with exponential backoff, hard
//!   errors on malformed or torn replies); one batched round-trip per
//!   [`Evaluator::evaluate_batch`] call amortizes connection setup;
//! * [`DeviceServer`] — the in-process stub server that serves
//!   measurements from the existing [`LatencyModel`] simulator, so
//!   `device:` scenarios exercise the full wire path while tier-1 stays
//!   offline and deterministic (`remote://` points the same client at an
//!   external server, e.g. `haqa device serve` on another machine);
//! * [`RecordingEvaluator`] / [`ReplayEvaluator`] — journal a measurement
//!   session to disk (the eval-cache record format, appended through
//!   [`crate::util::jsonl`] hygiene) and replay it offline bit-exactly,
//!   mirroring the agent-side `record:`/`replay:` discipline.
//!
//! The coordinator, cache and fleet need **no changes** to use any of
//! this: a device evaluator is just another [`Evaluator`], and its backend
//! identity is folded into [`Evaluator::scope`] so measurements from
//! different devices (or different remote endpoints) never collide under
//! one cache key.  Results from the stub server are **bit-identical** to
//! the in-process [`KernelEvaluator`]: both sides run the same
//! measurement code, and scores cross the wire as authoritative f64 bit
//! patterns (the `docs/CACHE.md` encoding), never as decimal text.
//!
//! ## Wire format
//!
//! One JSON object per `\n`-terminated line in each direction.  Request:
//!
//! ```json
//! {"op":"measure","v":1,"profile":"mobile-soc","kernel":"matmul",
//!  "batch":64,"noise_seed":7,"configs":[{"griddim_x":32,"blockdim_x":64}]}
//! ```
//!
//! Success reply (`results[i]` corresponds to `configs[i]`; `bits` is the
//! authoritative score, the plain `score` is informational):
//!
//! ```json
//! {"ok":true,"results":[{"score":-36.86,"bits":"c042...","feedback":"{\"latency_us\": 36.860}"}]}
//! ```
//!
//! Error reply: `{"ok":false,"error":"unknown device profile 'tpu-v5'"}`.
//! A `{"op":"hello","v":1}` request answers with the server name, protocol
//! version and known profile names (`haqa device ping`).

use std::collections::HashMap;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::hardware::{preset, DeviceProfile, KernelKind, LatencyModel, Workload, PRESET_NAMES};
use crate::search::{spaces, Config, Space};
use crate::util::json::{self, Json};
use crate::util::retry::{Attempt, Backoff};
use crate::util::{jsonl, lock};

use super::cache::{decode_record, encode_record, EvalCache};
use super::evaluator::{
    kernel_evaluation, parse_kernel_spec, Evaluation, Evaluator, KernelEvaluator,
};
use super::scenario::{Scenario, Track};
use super::wire::{self, decode_result, encode_result, snip, Conn, ErrorPolicy};

/// Wire-protocol version sent in every request and `hello` reply.
pub const PROTOCOL_VERSION: f64 = 1.0;

/// Re-exported from [`super::wire`], which now owns the one copy every
/// connect-retrying client shares.
pub use super::wire::BACKOFF_CAP;

// ---- the evaluator spec -----------------------------------------------------

/// A parsed scenario `evaluator` field: where measurements come from.
///
/// Parsing follows the `Scenario.backend` hard-error discipline — a typo'd
/// spec must fail the scenario, never silently fall back to the simulator.
///
/// ```
/// use haqa::coordinator::device::EvaluatorSpec;
///
/// // `device:` selects a named hardware-profile preset …
/// let spec = EvaluatorSpec::parse("device:mobile-soc").unwrap();
/// assert_eq!(spec.platform_preset(), Some("mobile-soc"));
///
/// // … and malformed specs are hard errors, not simulator runs.
/// assert!(EvaluatorSpec::parse("device:tpu-v5").is_err());
/// assert!(EvaluatorSpec::parse("remote://no-port").is_err());
/// assert!(EvaluatorSpec::parse("remote://:8080").is_err());
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EvaluatorSpec {
    /// The in-process evaluators (the default).
    Simulated,
    /// Measure through the in-process [`DeviceServer`] stub on the named
    /// [`crate::hardware::preset`] platform.
    Device(String),
    /// Measure through an external device server at `host:port`.
    Remote {
        /// Server host name or address.
        host: String,
        /// Server TCP port.
        port: u16,
    },
    /// Journal the inner evaluator's measurements to a transcript file.
    Record {
        /// Transcript journal path.
        path: String,
        /// The evaluator whose measurements are journaled.
        inner: Box<EvaluatorSpec>,
    },
    /// Serve measurements from a recorded transcript, fully offline.
    Replay {
        /// Transcript journal path.
        path: String,
        /// Names the recorded evaluator — replay computes cache keys from
        /// its (track, scope) without ever contacting it.
        inner: Box<EvaluatorSpec>,
    },
    /// Inject deterministic faults ([`super::chaos`]) ahead of the inner
    /// evaluator's calls.  Must be the outermost wrapper.
    Chaos {
        /// The fault plan (see [`super::chaos::FaultPlan::parse`]).
        plan: String,
        /// The evaluator whose calls are faulted.
        inner: Box<EvaluatorSpec>,
    },
}

impl EvaluatorSpec {
    /// Parse an `evaluator` spec string.  Grammar:
    ///
    /// * `simulated` (or empty) — in-process evaluation;
    /// * `device:<profile-name>` — the in-process stub server on a named
    ///   preset (unknown names are a hard error);
    /// * `remote://host:port` — an external device server;
    /// * `record:<path>=<inner-spec>` / `replay:<path>=<inner-spec>` —
    ///   transcript wrappers around any of the above;
    /// * `chaos:<plan>=<inner-spec>` — deterministic fault injection
    ///   ([`super::chaos`]) around any of the above (outermost only).
    pub fn parse(spec: &str) -> Result<EvaluatorSpec> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "simulated" {
            return Ok(EvaluatorSpec::Simulated);
        }
        if let Some(rest) = spec.strip_prefix("chaos:") {
            let (plan, inner_spec) = super::chaos::split_chaos_spec(rest)
                .with_context(|| format!("in evaluator spec '{spec}'"))?;
            let inner = EvaluatorSpec::parse(inner_spec)?;
            ensure!(
                !matches!(inner, EvaluatorSpec::Chaos { .. }),
                "evaluator spec '{spec}' nests chaos wrappers — \
                 chaos takes a plain inner spec"
            );
            return Ok(EvaluatorSpec::Chaos {
                plan: plan.to_string(),
                inner: Box::new(inner),
            });
        }
        if let Some(name) = spec.strip_prefix("device:") {
            let name = name.trim();
            ensure!(
                !name.is_empty(),
                "empty profile in evaluator spec '{spec}' \
                 (expected `device:<profile-name>`, e.g. `device:mobile-soc`)"
            );
            ensure!(
                preset(name).is_some(),
                "unknown device profile '{name}' in evaluator spec '{spec}' \
                 (known presets: {})",
                PRESET_NAMES.join(", ")
            );
            return Ok(EvaluatorSpec::Device(name.to_string()));
        }
        if let Some(authority) = spec.strip_prefix("remote://") {
            ensure!(
                !authority.contains('/'),
                "evaluator spec '{spec}' must be `remote://host:port` with no path"
            );
            let (host, port) = authority
                .rsplit_once(':')
                .ok_or_else(|| anyhow!("missing port in evaluator spec '{spec}'"))?;
            ensure!(!host.is_empty(), "empty host in evaluator spec '{spec}'");
            let port: u16 = port
                .parse()
                .map_err(|_| anyhow!("bad port '{port}' in evaluator spec '{spec}'"))?;
            return Ok(EvaluatorSpec::Remote {
                host: host.to_string(),
                port,
            });
        }
        for (prefix, is_record) in [("record:", true), ("replay:", false)] {
            if let Some(rest) = spec.strip_prefix(prefix) {
                let (path, inner_spec) = rest.split_once('=').ok_or_else(|| {
                    anyhow!(
                        "evaluator spec '{spec}' needs `{prefix}<path>=<inner-spec>` \
                         (the inner spec names the evaluator whose scope keys the transcript)"
                    )
                })?;
                ensure!(!path.trim().is_empty(), "empty path in evaluator spec '{spec}'");
                let inner = EvaluatorSpec::parse(inner_spec)?;
                ensure!(
                    !matches!(inner, EvaluatorSpec::Record { .. } | EvaluatorSpec::Replay { .. }),
                    "evaluator spec '{spec}' nests transcript wrappers — record/replay \
                     take a plain inner spec"
                );
                ensure!(
                    !matches!(inner, EvaluatorSpec::Chaos { .. }),
                    "evaluator spec '{spec}' puts chaos inside a transcript wrapper — \
                     chaos must be the outermost wrapper (chaos:<plan>={prefix}…)"
                );
                return Ok(if is_record {
                    EvaluatorSpec::Record {
                        path: path.trim().to_string(),
                        inner: Box::new(inner),
                    }
                } else {
                    EvaluatorSpec::Replay {
                        path: path.trim().to_string(),
                        inner: Box::new(inner),
                    }
                });
            }
        }
        bail!(
            "unknown evaluator spec '{spec}' (expected simulated | device:<profile-name> | \
             remote://host:port | record:<path>=<spec> | replay:<path>=<spec> | \
             chaos:<plan>=<spec>)"
        )
    }

    /// The hardware-profile preset named by the innermost spec, if any —
    /// what [`Scenario::platform_profile`] resolves the prompt's Fig. 2a
    /// hardware block (and the stub server's latency curves) against.
    pub fn platform_preset(&self) -> Option<&str> {
        match self {
            EvaluatorSpec::Device(name) => Some(name),
            EvaluatorSpec::Record { inner, .. }
            | EvaluatorSpec::Replay { inner, .. }
            | EvaluatorSpec::Chaos { inner, .. } => inner.platform_preset(),
            _ => None,
        }
    }
}

/// Build the scenario's evaluator when its spec is *not* `simulated`
/// (`None` means: use the regular in-process evaluator).  Device-backed
/// measurement serves the kernel track only; any other track with a
/// non-simulated spec is a hard error.
pub fn evaluator_from_scenario(sc: &Scenario) -> Result<Option<Box<dyn Evaluator>>> {
    let spec = EvaluatorSpec::parse(&sc.evaluator)?;
    if spec == EvaluatorSpec::Simulated {
        return Ok(None);
    }
    if sc.track != Track::Kernel {
        return Err(non_kernel_track_error(sc));
    }
    Ok(Some(build_evaluator(&spec, sc)?))
}

/// Hard-error when a scenario that must evaluate in-process carries a
/// non-simulated evaluator spec (also surfaces malformed specs early).
/// `chaos:<plan>=simulated` counts as simulated: fault injection wraps the
/// in-process evaluator ([`wrap_chaos`]) on every track.
pub(crate) fn require_simulated(sc: &Scenario) -> Result<()> {
    let spec = EvaluatorSpec::parse(&sc.evaluator)?;
    let innermost = match &spec {
        EvaluatorSpec::Chaos { inner, .. } => inner.as_ref(),
        s => s,
    };
    if *innermost != EvaluatorSpec::Simulated {
        return Err(non_kernel_track_error(sc));
    }
    Ok(())
}

/// Wrap an in-process evaluator in the scenario's chaos plan when its
/// `evaluator` spec is `chaos:<plan>=simulated`; pass it through untouched
/// otherwise.  This is how the fine-tune and bit-width tracks (which never
/// go through [`build_evaluator`]) get fault injection.
pub(crate) fn wrap_chaos<'s>(
    sc: &Scenario,
    ev: Box<dyn Evaluator + 's>,
) -> Result<Box<dyn Evaluator + 's>> {
    match EvaluatorSpec::parse(&sc.evaluator)? {
        EvaluatorSpec::Chaos { plan, inner } if *inner == EvaluatorSpec::Simulated => {
            Ok(Box::new(super::chaos::ChaosEvaluator::new(&plan, ev)?))
        }
        _ => Ok(ev),
    }
}

/// The one copy of the track-gate message (tests match on its text).
fn non_kernel_track_error(sc: &Scenario) -> anyhow::Error {
    anyhow!(
        "evaluator '{}' is only supported on the kernel track — the fine-tune and \
         bit-width tracks evaluate in-process (set \"evaluator\": \"simulated\")",
        sc.evaluator
    )
}

fn build_evaluator(spec: &EvaluatorSpec, sc: &Scenario) -> Result<Box<dyn Evaluator>> {
    Ok(match spec {
        EvaluatorSpec::Simulated => Box::new(KernelEvaluator::from_scenario(sc)?),
        EvaluatorSpec::Device(_) | EvaluatorSpec::Remote { .. } => {
            Box::new(DeviceEvaluator::from_spec(spec, sc)?)
        }
        EvaluatorSpec::Record { path, inner } => {
            Box::new(RecordingEvaluator::create(path, build_evaluator(inner, sc)?)?)
        }
        EvaluatorSpec::Replay { path, inner } => {
            Box::new(ReplayEvaluator::open(path, build_evaluator(inner, sc)?)?)
        }
        EvaluatorSpec::Chaos { plan, inner } => Box::new(super::chaos::ChaosEvaluator::new(
            plan,
            build_evaluator(inner, sc)?,
        )?),
    })
}

// ---- the client -------------------------------------------------------------

/// Where a [`DeviceEvaluator`] connects.
enum Endpoint {
    /// The process-wide [`DeviceServer`] stub (spawned on first use).
    InProcess,
    /// An external device server.
    Remote { host: String, port: u16 },
}

/// An [`Evaluator`] whose measurements arrive over the JSONL device
/// protocol instead of running in-process.
///
/// Each [`evaluate_batch`](Evaluator::evaluate_batch) call is **one**
/// protocol round-trip — connect, send the batch, read one reply line —
/// so per-connection setup is amortized across the configuration slice.
/// Connect failures are retried with bounded exponential backoff; once the
/// request is on the wire, a torn, truncated or malformed reply is a hard
/// error (measurement transports must fail loudly, not resynthesize data).
///
/// ```
/// use haqa::coordinator::device::DeviceEvaluator;
/// use haqa::coordinator::evaluator::Evaluator;
/// use haqa::coordinator::scenario::{Scenario, Track};
///
/// // Profile-backed construction is offline: nothing connects until the
/// // first evaluation.
/// let sc = Scenario {
///     track: Track::Kernel,
///     kernel: "matmul:64".into(),
///     evaluator: "device:server-gpu".into(),
///     ..Scenario::default()
/// };
/// let ev = DeviceEvaluator::from_scenario(&sc).unwrap();
/// assert_eq!(ev.track(), "kernel");
/// // The backend identity is folded into the cache-key scope.
/// assert!(ev.scope().get("evaluator").is_some());
/// ```
pub struct DeviceEvaluator {
    /// Scope identity: `"device"` for the in-process stub,
    /// `"remote://host:port"` for an external server.
    label: String,
    /// Preset key sent in requests (the server resolves it; real hardware
    /// servers may ignore it and measure whatever they are attached to).
    profile_key: String,
    /// The platform name recorded in the cache-key scope.  For `device:`
    /// specs this is the *resolved* preset's descriptive name (aliases of
    /// one platform share cache entries); for `remote://` it is the
    /// verbatim `profile_key`, because the local registry cannot vouch for
    /// what a remote server's names mean — two unknown names must never
    /// collapse onto one local fallback profile and share a key.
    scope_device: String,
    /// The platform this evaluator claims to measure on (agent prompt).
    profile: DeviceProfile,
    workload: Workload,
    noise_seed: u64,
    space: Space,
    endpoint: Endpoint,
    timeout: Duration,
    max_retries: usize,
    backoff_base: Duration,
}

impl DeviceEvaluator {
    /// Build from a scenario whose `evaluator` is a `device:` or
    /// `remote://` spec.  Construction never touches the network.
    pub fn from_scenario(sc: &Scenario) -> Result<DeviceEvaluator> {
        let spec = EvaluatorSpec::parse(&sc.evaluator)?;
        DeviceEvaluator::from_spec(&spec, sc)
    }

    pub(crate) fn from_spec(spec: &EvaluatorSpec, sc: &Scenario) -> Result<DeviceEvaluator> {
        let (kernel, batch) = parse_kernel_spec(&sc.kernel)?;
        let workload = Workload::new(kernel, batch);
        let (label, profile_key, scope_device, profile, endpoint) = match spec {
            EvaluatorSpec::Device(name) => {
                let profile = preset(name).ok_or_else(|| {
                    anyhow!(
                        "unknown device profile '{name}' (known presets: {})",
                        PRESET_NAMES.join(", ")
                    )
                })?;
                let scope_device = profile.name.clone();
                (
                    "device".to_string(),
                    name.clone(),
                    scope_device,
                    profile,
                    Endpoint::InProcess,
                )
            }
            EvaluatorSpec::Remote { host, port } => (
                format!("remote://{host}:{port}"),
                sc.device.clone(),
                // Verbatim, NOT the resolved local profile: an unknown
                // remote platform name must stay a distinct scope, never
                // collapse onto the A6000 fallback and share cache keys
                // with other unknowns.
                sc.device.clone(),
                sc.device_profile(),
                Endpoint::Remote {
                    host: host.clone(),
                    port: *port,
                },
            ),
            other => bail!("internal: '{other:?}' is not a device evaluator spec"),
        };
        Ok(DeviceEvaluator {
            label,
            profile_key,
            scope_device,
            profile,
            workload,
            noise_seed: sc.seed,
            space: spaces::kernel_exec(),
            endpoint,
            timeout: Duration::from_secs(10),
            max_retries: 2,
            backoff_base: Duration::from_millis(100),
        })
    }

    /// The agent's task-objective block — identical to the in-process
    /// kernel evaluator's so prompts (and therefore proposals) match.
    pub fn objective(&self) -> Json {
        super::evaluator::kernel_objective(&self.workload)
    }

    fn addr(&self) -> Result<SocketAddr> {
        match &self.endpoint {
            Endpoint::InProcess => Ok(shared_stub()?.addr()),
            Endpoint::Remote { host, port } => (host.as_str(), *port)
                .to_socket_addrs()
                .with_context(|| format!("resolving {host}:{port}"))?
                .next()
                .ok_or_else(|| anyhow!("cannot resolve {host}:{port}")),
        }
    }

    fn measure_request(&self, cfgs: &[Config]) -> String {
        let mut o = Json::obj();
        o.set("op", Json::str("measure"));
        o.set("v", Json::Num(PROTOCOL_VERSION));
        o.set("profile", Json::str(self.profile_key.clone()));
        o.set(
            "kernel",
            Json::str(self.workload.kernel.label().to_lowercase()),
        );
        o.set("batch", Json::Num(self.workload.batch as f64));
        o.set("noise_seed", Json::Num(self.noise_seed as f64));
        o.set(
            "configs",
            Json::Arr(cfgs.iter().map(|c| self.space.config_to_json(c)).collect()),
        );
        o.to_string()
    }

    /// One protocol round-trip: connect (with bounded retry/backoff via
    /// [`crate::util::retry::Backoff`]), send the request line, read exactly
    /// one reply line.
    fn round_trip(&self, request: &str) -> Result<String> {
        let addr = self.addr()?;
        let requests = [request.to_string()];
        Backoff::new(self.max_retries, self.backoff_base, BACKOFF_CAP).run(|_| {
            match TcpStream::connect_timeout(&addr, self.timeout) {
                // Past this point nothing is retried: the request may have
                // reached the server, and a torn reply must fail loudly.
                Ok(stream) => {
                    let reply = Conn::new(stream, self.timeout, "device-server")
                        .and_then(|mut conn| conn.exchange(&requests))
                        .map(|mut replies| replies.pop().expect("one reply per request"));
                    match reply {
                        Ok(reply) => Attempt::Done(reply),
                        Err(e) => Attempt::Fatal(e),
                    }
                }
                Err(e) => {
                    Attempt::Retry(anyhow::Error::from(e).context(format!("connecting to {addr}")))
                }
            }
        })
    }
}

impl Evaluator for DeviceEvaluator {
    fn track(&self) -> &'static str {
        "kernel"
    }

    fn space(&self) -> &Space {
        &self.space
    }

    /// The in-process kernel scope plus the backend identity, so
    /// measurements from different devices and transports never collide
    /// under one cache key (`device:mobile` vs `device:server` differ in
    /// `device`; two remote farms differ in `evaluator`).
    fn scope(&self) -> Json {
        let mut o = Json::obj();
        o.set(
            "kernel",
            Json::str(self.workload.kernel.label().to_lowercase()),
        );
        o.set("batch", Json::Num(self.workload.batch as f64));
        o.set("device", Json::Str(self.scope_device.clone()));
        o.set("noise_seed", Json::Num(self.noise_seed as f64));
        o.set("evaluator", Json::str(self.label.clone()));
        o
    }

    fn evaluate(&self, cfg: &Config) -> Result<Evaluation> {
        Ok(self
            .evaluate_batch(std::slice::from_ref(cfg))?
            .pop()
            .expect("reply length checked against batch length"))
    }

    fn evaluate_batch(&self, cfgs: &[Config]) -> Result<Vec<Evaluation>> {
        if cfgs.is_empty() {
            return Ok(Vec::new());
        }
        let request = self.measure_request(cfgs);
        let reply = self
            .round_trip(&request)
            .with_context(|| format!("device evaluator {} ({})", self.label, self.profile.name))?;
        parse_measure_reply(&reply, cfgs.len())
            .with_context(|| format!("device evaluator {} ({})", self.label, self.profile.name))
    }
}

fn parse_measure_reply(line: &str, expected: usize) -> Result<Vec<Evaluation>> {
    let j = json::parse(line.trim_end())
        .map_err(|e| anyhow!("malformed device-server reply ({e}): {}", snip(line)))?;
    let ok = j
        .get("ok")
        .and_then(|v| v.as_bool())
        .ok_or_else(|| anyhow!("malformed device-server reply (no \"ok\"): {}", snip(line)))?;
    if !ok {
        let msg = j
            .get("error")
            .and_then(|v| v.as_str())
            .unwrap_or("unspecified error");
        bail!("device server error: {msg}");
    }
    let results = j
        .get("results")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("malformed device-server reply (no \"results\"): {}", snip(line)))?;
    ensure!(
        results.len() == expected,
        "device server returned {} result(s) for a batch of {expected}",
        results.len()
    );
    results
        .iter()
        .map(|r| {
            decode_result(r).ok_or_else(|| {
                anyhow!("malformed measurement record in device-server reply: {}", snip(line))
            })
        })
        .collect()
}

// ---- the server -------------------------------------------------------------

/// The in-process device-measurement server stub.
///
/// Binds a `TcpListener`, answers the JSONL protocol on a background
/// accept thread (one handler thread per connection, many requests per
/// connection), and serves measurements from the analytic
/// [`LatencyModel`] — so `device:` scenarios and CI exercise the complete
/// wire path with zero hardware and zero network egress.  `haqa device
/// serve` runs the same server in the foreground as a `remote://` target.
pub struct DeviceServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl DeviceServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// serving on a background thread.
    pub fn spawn(bind: &str) -> Result<DeviceServer> {
        let listener = TcpListener::bind(bind).with_context(|| format!("binding {bind}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::spawn(move || accept_loop(listener, stop2));
        Ok(DeviceServer {
            addr,
            stop,
            handle: Some(handle),
        })
    }

    /// The bound address (queried for ephemeral-port binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for DeviceServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(200));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The process-wide stub every `device:` evaluator shares (spawned on
/// first use, lives for the process lifetime).
fn shared_stub() -> Result<&'static DeviceServer> {
    static SHARED: OnceLock<std::result::Result<DeviceServer, String>> = OnceLock::new();
    match SHARED.get_or_init(|| DeviceServer::spawn("127.0.0.1:0").map_err(|e| format!("{e:#}"))) {
        Ok(s) => Ok(s),
        Err(e) => bail!("in-process device server failed to start: {e}"),
    }
}

fn accept_loop(listener: TcpListener, stop: Arc<AtomicBool>) {
    // Every failure becomes an `{"ok":false,"error":…}` reply and the
    // connection stays open — this server never closes a connection in
    // lieu of an answer.
    wire::accept_loop(listener, stop, |stream| {
        wire::serve_conn(stream, ErrorPolicy::ReplyAndContinue, handle_request)
    });
}

/// Dispatch one request line to one reply body (the shared connection
/// loop wraps errors into `{"ok":false,…}` replies).
fn handle_request(line: &str) -> Result<Json> {
    let j = json::parse(line).map_err(|e| anyhow!("malformed request JSON: {e}"))?;
    match j.get("op").and_then(|v| v.as_str()) {
        Some("hello") => Ok(hello_reply()),
        Some("measure") => handle_measure(&j),
        Some(other) => Err(anyhow!("unknown op '{other}'")),
        None => Err(anyhow!("request has no \"op\"")),
    }
}

fn hello_reply() -> Json {
    let mut o = Json::obj();
    o.set("ok", Json::Bool(true));
    o.set("server", Json::str("haqa-device-server"));
    o.set("v", Json::Num(PROTOCOL_VERSION));
    o.set(
        "profiles",
        Json::Arr(PRESET_NAMES.iter().map(|n| Json::str(*n)).collect()),
    );
    o
}

fn handle_measure(j: &Json) -> Result<Json> {
    let profile_name = j.req_str("profile")?;
    let profile = preset(profile_name).ok_or_else(|| {
        anyhow!(
            "unknown device profile '{profile_name}' (known presets: {})",
            PRESET_NAMES.join(", ")
        )
    })?;
    let kernel_name = j.req_str("kernel")?;
    let kernel = KernelKind::parse(kernel_name)
        .ok_or_else(|| anyhow!("unknown kernel '{kernel_name}'"))?;
    let batch = j.req_f64("batch")? as usize;
    ensure!(batch >= 1, "kernel batch must be >= 1, got {batch}");
    let noise_seed = j.req_f64("noise_seed")? as u64;
    let configs = j.req_arr("configs")?;
    // Memoized per (platform, kernel, batch) — the server-side half of
    // the amortization the in-process evaluator gets by building its
    // model once at construction: a device-backed scenario calibrates
    // once per workload, not once per round.
    let model = measurement_model(&profile, kernel, batch);
    let space = spaces::kernel_exec();
    let mut results: Vec<Json> = Vec::with_capacity(configs.len());
    for (i, cj) in configs.iter().enumerate() {
        // Reject malformed config *encodings* instead of silently
        // measuring a defaulted config — the fail-loudly rule the client
        // enforces applies server-side too.  `config_from_json` drops
        // entries that are not numbers/strings/bools, so a length
        // mismatch means the request carried values we would have
        // resynthesized.
        let entries = cj
            .as_obj()
            .ok_or_else(|| anyhow!("config #{i} is not a JSON object"))?;
        let cfg = space.config_from_json(cj);
        ensure!(
            cfg.len() == entries.len(),
            "config #{i} has entries that are not numbers, strings or booleans"
        );
        results.push(encode_result(&kernel_evaluation(&model, noise_seed, &cfg)));
    }
    let mut o = Json::obj();
    o.set("ok", Json::Bool(true));
    o.set("results", Json::Arr(results));
    Ok(o)
}

/// The server's memoized latency models.  A model is deterministic in
/// (resolved platform, kernel, batch) — keying by the *resolved* profile
/// name collapses request aliases — and the key space is bounded by
/// presets × kernels × batch sizes, so the map never needs eviction.
fn measurement_model(profile: &DeviceProfile, kernel: KernelKind, batch: usize) -> LatencyModel {
    type ModelKey = (String, &'static str, usize);
    static MODELS: OnceLock<Mutex<HashMap<ModelKey, LatencyModel>>> = OnceLock::new();
    let map = MODELS.get_or_init(|| Mutex::new(HashMap::new()));
    let key = (profile.name.clone(), kernel.label(), batch);
    lock(map)
        .entry(key)
        .or_insert_with(|| LatencyModel::new(Workload::new(kernel, batch), profile))
        .clone()
}

// ---- record / replay --------------------------------------------------------

/// Wraps any [`Evaluator`] and journals every measurement to a transcript
/// file — one eval-cache record per line (`docs/CACHE.md` encoding), keyed
/// by the inner evaluator's `(track, scope, config)` content hash, with
/// the journal's append-only hygiene (torn tails healed by appending a
/// newline, never truncating).  Record a `remote://` session once, then
/// replay it offline with [`ReplayEvaluator`].
pub struct RecordingEvaluator {
    inner: Box<dyn Evaluator>,
    journal: Mutex<std::fs::File>,
    path: PathBuf,
}

impl RecordingEvaluator {
    /// Open (or create) the transcript at `path` for appending and wrap
    /// `inner`.
    pub fn create(path: &str, inner: Box<dyn Evaluator>) -> Result<RecordingEvaluator> {
        let path = PathBuf::from(path);
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        // Append-only tail healing, as in the eval cache: a torn final
        // record from a crashed writer is newline-terminated, never cut
        // (the shared `jsonl::open_append_healed` implementation).
        let file = jsonl::open_append_healed(&path)?;
        Ok(RecordingEvaluator {
            inner,
            journal: Mutex::new(file),
            path,
        })
    }

    fn append(&self, cfg: &Config, e: &Evaluation) -> Result<()> {
        let key = EvalCache::key(
            self.inner.track(),
            &self.inner.scope(),
            &self.inner.space().config_to_json(cfg),
        );
        let line = encode_record(key, e);
        let mut g = lock(&self.journal);
        g.write_all(line.as_bytes())
            .and_then(|()| g.flush())
            .with_context(|| format!("appending to device transcript {}", self.path.display()))
    }
}

impl Evaluator for RecordingEvaluator {
    fn track(&self) -> &'static str {
        self.inner.track()
    }
    fn space(&self) -> &Space {
        self.inner.space()
    }
    /// Forwards the inner scope unchanged: journaling does not change what
    /// a measurement returns, so it must not split cache keys.
    fn scope(&self) -> Json {
        self.inner.scope()
    }
    fn evaluate(&self, cfg: &Config) -> Result<Evaluation> {
        let e = self.inner.evaluate(cfg)?;
        self.append(cfg, &e)?;
        Ok(e)
    }
    fn evaluate_batch(&self, cfgs: &[Config]) -> Result<Vec<Evaluation>> {
        let es = self.inner.evaluate_batch(cfgs)?;
        for (cfg, e) in cfgs.iter().zip(&es) {
            self.append(cfg, e)?;
        }
        Ok(es)
    }
    fn rounds(&self, budget: usize) -> usize {
        self.inner.rounds(budget)
    }
}

/// Serves measurements from a recorded transcript, fully offline.
///
/// The wrapped evaluator is used **only** for its static descriptors
/// (track, space, scope — the cache-key inputs); its `evaluate` is never
/// called and, for a [`DeviceEvaluator`], nothing ever connects.  A
/// configuration with no recorded measurement is a hard error — a replay
/// that diverges from its recording must fail loudly, exactly like the
/// agent-side `replay:` backends.
pub struct ReplayEvaluator {
    inner: Box<dyn Evaluator>,
    records: HashMap<u128, Evaluation>,
    path: PathBuf,
}

impl ReplayEvaluator {
    /// Load the transcript at `path` (corrupt lines are skipped with a
    /// warning, as in the eval-cache journal) around `inner`'s descriptors.
    pub fn open(path: &str, inner: Box<dyn Evaluator>) -> Result<ReplayEvaluator> {
        let path = PathBuf::from(path);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading device transcript {}", path.display()))?;
        let mut records: HashMap<u128, Evaluation> = HashMap::new();
        let scan = jsonl::scan(&bytes, |j, _| match decode_record(j) {
            Some((key, e)) => {
                records.entry(key).or_insert(e);
                true
            }
            None => false,
        });
        if scan.skipped > 0 {
            eprintln!(
                "device transcript: skipped {} corrupt/truncated record(s) in {}",
                scan.skipped,
                path.display()
            );
        }
        Ok(ReplayEvaluator {
            inner,
            records,
            path,
        })
    }
}

impl Evaluator for ReplayEvaluator {
    fn track(&self) -> &'static str {
        self.inner.track()
    }
    fn space(&self) -> &Space {
        self.inner.space()
    }
    /// Forwards the recorded evaluator's scope so replayed lookups compute
    /// the exact keys the recording wrote.
    fn scope(&self) -> Json {
        self.inner.scope()
    }
    fn evaluate(&self, cfg: &Config) -> Result<Evaluation> {
        let key = EvalCache::key(
            self.inner.track(),
            &self.inner.scope(),
            &self.inner.space().config_to_json(cfg),
        );
        self.records.get(&key).cloned().ok_or_else(|| {
            anyhow!(
                "configuration not in device transcript {} — the replay run diverged \
                 from the recording",
                self.path.display()
            )
        })
    }
    fn rounds(&self, budget: usize) -> usize {
        self.inner.rounds(budget)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::io::{BufRead, BufReader};

    fn kernel_scenario(evaluator: &str) -> Scenario {
        Scenario {
            name: "device_unit".into(),
            track: Track::Kernel,
            kernel: "matmul:64".into(),
            seed: 5,
            evaluator: evaluator.into(),
            ..Scenario::default()
        }
    }

    fn sample_cfgs(space: &Space, n: usize) -> Vec<Config> {
        let mut rng = Rng::new(9);
        (0..n).map(|_| space.sample(&mut rng)).collect()
    }

    /// A raw TCP stub that reads one request line, runs `respond` on the
    /// socket, and hangs up.
    fn one_shot_server(respond: impl FnOnce(&mut TcpStream) + Send + 'static) -> u16 {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let port = listener.local_addr().unwrap().port();
        std::thread::spawn(move || {
            if let Ok((mut stream, _)) = listener.accept() {
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut line = String::new();
                let _ = reader.read_line(&mut line);
                respond(&mut stream);
            }
        });
        port
    }

    fn remote_ev(port: u16) -> DeviceEvaluator {
        let mut ev =
            DeviceEvaluator::from_scenario(&kernel_scenario(&format!("remote://127.0.0.1:{port}")))
                .unwrap();
        // No retries so failure-edge tests are single-shot and fast.
        ev.max_retries = 0;
        ev.timeout = Duration::from_secs(2);
        ev
    }

    #[test]
    fn spec_parsing_grammar_and_hard_errors() {
        assert_eq!(EvaluatorSpec::parse("").unwrap(), EvaluatorSpec::Simulated);
        assert_eq!(
            EvaluatorSpec::parse(" simulated ").unwrap(),
            EvaluatorSpec::Simulated
        );
        assert_eq!(
            EvaluatorSpec::parse("device:mobile-soc").unwrap(),
            EvaluatorSpec::Device("mobile-soc".into())
        );
        assert_eq!(
            EvaluatorSpec::parse("remote://farm.local:7434").unwrap(),
            EvaluatorSpec::Remote {
                host: "farm.local".into(),
                port: 7434
            }
        );
        let rec = EvaluatorSpec::parse("record:/tmp/t.jsonl=device:server-gpu").unwrap();
        assert!(matches!(rec, EvaluatorSpec::Record { .. }));
        assert_eq!(rec.platform_preset(), Some("server-gpu"));

        for bad in [
            "device:",
            "device:tpu-v5",
            "remote://",
            "remote://:8080",
            "remote://hostonly",
            "remote://host:notaport",
            "remote://host:80/path",
            "record:/tmp/t.jsonl",
            "replay:=device:a6000",
            "record:/tmp/t.jsonl=replay:/x=device:a6000",
            "quantum",
        ] {
            let err = EvaluatorSpec::parse(bad);
            assert!(err.is_err(), "'{bad}' must be a hard error");
        }
    }

    #[test]
    fn hello_round_trip_over_the_wire() {
        let server = DeviceServer::spawn("127.0.0.1:0").unwrap();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream.write_all(b"{\"op\":\"hello\",\"v\":1}\n").unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = json::parse(line.trim()).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.req_str("server").unwrap(), "haqa-device-server");
        let profiles = j.req_arr("profiles").unwrap();
        assert!(profiles.iter().any(|p| p.as_str() == Some("a6000")));
    }

    #[test]
    fn stub_measurements_are_bit_identical_to_in_process() {
        let device = DeviceEvaluator::from_scenario(&kernel_scenario("device:mobile-soc")).unwrap();
        let local = KernelEvaluator::from_scenario(&Scenario {
            device: "mobile-soc".into(),
            ..kernel_scenario("simulated")
        })
        .unwrap();
        let cfgs = sample_cfgs(device.space(), 6);
        let over_wire = device.evaluate_batch(&cfgs).unwrap();
        let in_process = local.evaluate_batch(&cfgs).unwrap();
        assert_eq!(over_wire.len(), in_process.len());
        for (a, b) in over_wire.iter().zip(&in_process) {
            assert_eq!(a.score.to_bits(), b.score.to_bits(), "scores cross as bits");
            assert_eq!(a.feedback, b.feedback);
        }
        // Single-evaluation path goes through the same round-trip.
        let single = device.evaluate(&cfgs[0]).unwrap();
        assert_eq!(single.score.to_bits(), in_process[0].score.to_bits());
        // Same objective block as the in-process evaluator (same prompts).
        assert_eq!(
            json::canonical(&device.objective()),
            json::canonical(&local.objective())
        );
    }

    #[test]
    fn cache_keys_split_devices_transports_and_the_simulator() {
        let mobile = DeviceEvaluator::from_scenario(&kernel_scenario("device:mobile")).unwrap();
        let server = DeviceEvaluator::from_scenario(&kernel_scenario("device:server")).unwrap();
        let local = KernelEvaluator::from_scenario(&kernel_scenario("simulated")).unwrap();
        let cfg = mobile.space().default_config();
        let cfg_json = mobile.space().config_to_json(&cfg);
        let k_mobile = EvalCache::key(mobile.track(), &mobile.scope(), &cfg_json);
        let k_server = EvalCache::key(server.track(), &server.scope(), &cfg_json);
        let k_local = EvalCache::key(local.track(), &local.scope(), &cfg_json);
        assert_ne!(k_mobile, k_server, "device:mobile and device:server must not collide");
        assert_ne!(k_mobile, k_local, "device measurements must not collide with the simulator");
        // Aliases of one platform DO share a key (the scope stores the
        // resolved profile, not the user's spelling).
        let mobile2 =
            DeviceEvaluator::from_scenario(&kernel_scenario("device:mobile-soc")).unwrap();
        assert_eq!(
            k_mobile,
            EvalCache::key(mobile2.track(), &mobile2.scope(), &cfg_json)
        );
        // And two different remote endpoints never share one.
        let r1 = remote_ev(10001);
        let r2 = remote_ev(10002);
        assert_ne!(
            EvalCache::key(r1.track(), &r1.scope(), &cfg_json),
            EvalCache::key(r2.track(), &r2.scope(), &cfg_json)
        );
        // Unknown platform names on ONE remote endpoint are distinct
        // scopes too: the scope records the verbatim name, never the
        // local registry's A6000 fallback.
        let remote_named = |dev: &str| {
            let mut sc = kernel_scenario("remote://127.0.0.1:9999");
            sc.device = dev.into();
            DeviceEvaluator::from_scenario(&sc).unwrap()
        };
        let (na, nb) = (remote_named("npu-a"), remote_named("npu-b"));
        assert_ne!(
            EvalCache::key(na.track(), &na.scope(), &cfg_json),
            EvalCache::key(nb.track(), &nb.scope(), &cfg_json),
            "unknown remote platform names must not collapse onto one scope"
        );
        // End to end: both device evaluators land distinct cache entries.
        let cache = EvalCache::new();
        cache.get_or_evaluate(&mobile, &cfg).unwrap();
        let (_, hit) = cache.get_or_evaluate(&server, &cfg).unwrap();
        assert!(!hit, "different device must be a miss");
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn torn_reply_is_a_hard_error() {
        let port = one_shot_server(|stream| {
            // Half a reply, no newline, then hang up.
            let _ = stream.write_all(b"{\"ok\":true,\"resu");
        });
        let ev = remote_ev(port);
        let cfg = ev.space().default_config();
        let err = format!("{:#}", ev.evaluate(&cfg).unwrap_err());
        assert!(err.contains("torn"), "{err}");
        assert!(err.contains("remote://127.0.0.1"), "{err}");
    }

    #[test]
    fn disconnect_before_reply_is_a_hard_error() {
        let port = one_shot_server(|_stream| {
            // Read the request, say nothing, hang up.
        });
        let ev = remote_ev(port);
        let cfg = ev.space().default_config();
        let err = format!("{:#}", ev.evaluate(&cfg).unwrap_err());
        assert!(
            err.contains("before replying") || err.contains("reading device-server reply"),
            "{err}"
        );
    }

    #[test]
    fn malformed_reply_json_is_a_hard_error() {
        let port = one_shot_server(|stream| {
            let _ = stream.write_all(b"not json at all\n");
        });
        let ev = remote_ev(port);
        let cfg = ev.space().default_config();
        let err = format!("{:#}", ev.evaluate(&cfg).unwrap_err());
        assert!(err.contains("malformed device-server reply"), "{err}");
    }

    #[test]
    fn short_result_batch_is_a_hard_error() {
        let port = one_shot_server(|stream| {
            let one = encode_result(&Evaluation {
                score: -1.0,
                extra: Vec::new(),
                feedback: "{}".into(),
            });
            let mut o = Json::obj();
            o.set("ok", Json::Bool(true));
            o.set("results", Json::Arr(vec![one]));
            let mut line = o.to_string();
            line.push('\n');
            let _ = stream.write_all(line.as_bytes());
        });
        let ev = remote_ev(port);
        let cfgs = sample_cfgs(ev.space(), 2);
        let err = format!("{:#}", ev.evaluate_batch(&cfgs).unwrap_err());
        assert!(err.contains("1 result(s) for a batch of 2"), "{err}");
    }

    #[test]
    fn server_rejects_unknown_profile_with_an_error_reply() {
        // A real protocol server, but the client claims a bogus platform
        // (possible via `remote://`, whose profile key is the scenario's
        // free-form `device` field).
        let server = DeviceServer::spawn("127.0.0.1:0").unwrap();
        let mut ev = remote_ev(server.addr().port());
        ev.profile_key = "warp-drive".into();
        let cfg = ev.space().default_config();
        let err = format!("{:#}", ev.evaluate(&cfg).unwrap_err());
        assert!(err.contains("unknown device profile 'warp-drive'"), "{err}");
        assert!(err.contains("device server error"), "{err}");
    }

    #[test]
    fn server_rejects_malformed_config_encodings() {
        // A null parameter value would be silently dropped by
        // config_from_json — the server must refuse to measure a
        // resynthesized default config.
        let server = DeviceServer::spawn("127.0.0.1:0").unwrap();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        let req = concat!(
            "{\"op\":\"measure\",\"v\":1,\"profile\":\"a6000\",",
            "\"kernel\":\"matmul\",\"batch\":64,\"noise_seed\":0,",
            "\"configs\":[{\"griddim_x\":null}]}\n"
        );
        stream.write_all(req.as_bytes()).unwrap();
        let mut line = String::new();
        BufReader::new(stream.try_clone().unwrap())
            .read_line(&mut line)
            .unwrap();
        let j = json::parse(line.trim()).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert!(j.req_str("error").unwrap().contains("config #0"), "{line}");
    }

    #[test]
    fn connect_failure_is_retried_then_surfaced() {
        // Nothing listens on the port: every attempt is a connect error.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let port = listener.local_addr().unwrap().port();
        drop(listener);
        let mut ev = remote_ev(port);
        ev.max_retries = 1;
        ev.backoff_base = Duration::from_millis(1);
        let cfg = ev.space().default_config();
        let err = format!("{:#}", ev.evaluate(&cfg).unwrap_err());
        assert!(err.contains("2 attempt(s)"), "{err}");
    }

    #[test]
    fn record_then_replay_is_bit_exact_and_strict() {
        let dir = std::env::temp_dir().join(format!("haqa_device_rec_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("device_transcript.jsonl");
        let rec_spec = format!("record:{}=device:server-gpu", path.display());
        let rep_spec = format!("replay:{}=device:server-gpu", path.display());

        let rec = evaluator_from_scenario(&kernel_scenario(&rec_spec))
            .unwrap()
            .expect("recording evaluator");
        let cfgs = sample_cfgs(rec.space(), 4);
        let live = rec.evaluate_batch(&cfgs).unwrap();
        let single = rec.evaluate(&cfgs[0]).unwrap();
        assert_eq!(single.score.to_bits(), live[0].score.to_bits());

        let rep = evaluator_from_scenario(&kernel_scenario(&rep_spec))
            .unwrap()
            .expect("replay evaluator");
        for (cfg, want) in cfgs.iter().zip(&live) {
            let got = rep.evaluate(cfg).unwrap();
            assert_eq!(got.score.to_bits(), want.score.to_bits());
            assert_eq!(got.feedback, want.feedback);
        }
        // Scope is forwarded unchanged: recorded and replayed evaluations
        // share cache keys with the plain device evaluator.
        let plain = DeviceEvaluator::from_scenario(&kernel_scenario("device:server-gpu")).unwrap();
        assert_eq!(json::canonical(&rep.scope()), json::canonical(&plain.scope()));
        // A config the recording never saw is a hard error, not a live
        // measurement.  (Sample until the key provably differs from every
        // recorded one — deterministic, and immune to a chance collision.)
        let (track, scope) = (plain.track(), plain.scope());
        let recorded: Vec<u128> = cfgs
            .iter()
            .map(|c| EvalCache::key(track, &scope, &plain.space().config_to_json(c)))
            .collect();
        let mut rng = Rng::new(777);
        let novel = loop {
            let c = rep.space().sample(&mut rng);
            let k = EvalCache::key(track, &scope, &rep.space().config_to_json(&c));
            if !recorded.contains(&k) {
                break c;
            }
        };
        let err = format!("{:#}", rep.evaluate(&novel).unwrap_err());
        assert!(err.contains("not in device transcript"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn non_kernel_tracks_reject_device_evaluators() {
        let sc = Scenario {
            track: Track::Bitwidth,
            evaluator: "device:server-gpu".into(),
            ..Scenario::default()
        };
        let err = format!("{:#}", evaluator_from_scenario(&sc).unwrap_err());
        assert!(err.contains("only supported on the kernel track"), "{err}");
        assert!(require_simulated(&sc).is_err());
        assert!(require_simulated(&Scenario::default()).is_ok());
        // Simulated spec means "no device evaluator" — the caller builds
        // the in-process one.
        assert!(evaluator_from_scenario(&kernel_scenario("simulated"))
            .unwrap()
            .is_none());
    }
}
