//! Content-addressed evaluation cache: lock-striped in memory, with an
//! optional persistent disk tier.
//!
//! Evaluations are deterministic in (track, scenario knobs, configuration)
//! — see [`Evaluator`]'s contract — so repeated configurations across
//! optimizer rounds, method sweeps, bench tables and fleet workers can be
//! evaluated exactly once.  The key is a 128-bit content hash of the
//! canonical-JSON rendering (sorted keys, no whitespace, minimal numbers)
//! of the three components, making it independent of JSON key ordering and
//! stable across runs — and across *processes* and machines, which is what
//! the disk tier builds on.
//!
//! Two layers:
//!
//! * **Lock-striped memory tier.** The map is split into [`SHARD_COUNT`]
//!   shards, each behind its own `Mutex`, selected by key bits.  Fleet
//!   workers hitting different keys no longer serialize on one global lock
//!   (the PR-1 `Arc<Mutex<HashMap>>` was a single convoy point at high
//!   worker counts); hit/miss counters are lock-free atomics.
//! * **Append-only journal tier** ([`EvalCache::with_dir`]).  Every
//!   first-time evaluation is appended as one JSON line to
//!   `<dir>/eval_cache.jsonl` and the whole journal is loaded on startup,
//!   so bench tables, CI runs and fleet processes share evaluations.
//!   Scores round-trip **bit-exactly** (the authoritative fields are f64
//!   bit patterns in hex).  Corrupt or truncated records — a crashed
//!   writer's torn tail, a bad byte — are skipped with a warning, and
//!   healing is append-only (a missing final newline is terminated before
//!   the next record), so concurrent processes sharing a `--cache-dir`
//!   can never destroy each other's records.  See `docs/CACHE.md`.
//!
//! The cache is a cheap cloneable handle shared by every worker of a
//! fleet; counters are surfaced both globally ([`EvalCache::stats`]) and
//! per-track via [`TrackOutcome`](super::workflow::TrackOutcome).

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use anyhow::Result;

use crate::search::Config;
use crate::util::hash;
use crate::util::json::{self, Json};
use crate::util::{jsonl, lock};

use super::evaluator::{Evaluation, Evaluator};

/// Memory-tier stripe count (power of two; key bits select the stripe).
pub const SHARD_COUNT: usize = 16;

/// Journal file name inside a cache directory.
pub const JOURNAL_FILE: &str = "eval_cache.jsonl";

/// `haqa cache compact` summary: what the rewrite kept and dropped.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CompactReport {
    /// Valid records in the journal before the rewrite.
    pub before_records: usize,
    /// Live records kept (first valid write per key).
    pub after_records: usize,
    /// Corrupt/truncated lines dropped.
    pub dropped_corrupt: usize,
    /// Journal size before the rewrite, bytes.
    pub before_bytes: u64,
    /// Journal size after the rewrite, bytes.
    pub after_bytes: u64,
}

/// Aggregate cache counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from the cache.
    pub hits: usize,
    /// Lookups that had to evaluate (first sight of a key).
    pub misses: usize,
    /// Distinct keys currently held in the memory tier.
    pub entries: usize,
}

impl CacheStats {
    /// Fraction of lookups served from the cache (0 when nothing was
    /// looked up).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Journal {
    file: File,
}

struct Inner {
    shards: Vec<Mutex<HashMap<u128, Evaluation>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    /// Disk tier; `None` for a purely in-memory cache.
    journal: Option<Mutex<Journal>>,
    journal_path: Option<PathBuf>,
}

/// Thread-safe content-addressed cache handle (clone to share).
#[derive(Clone)]
pub struct EvalCache {
    inner: Arc<Inner>,
}

impl Default for EvalCache {
    fn default() -> Self {
        EvalCache::new()
    }
}

impl EvalCache {
    /// In-memory cache (no disk tier).
    pub fn new() -> EvalCache {
        EvalCache {
            inner: Arc::new(Inner {
                shards: (0..SHARD_COUNT).map(|_| Mutex::new(HashMap::new())).collect(),
                hits: AtomicUsize::new(0),
                misses: AtomicUsize::new(0),
                journal: None,
                journal_path: None,
            }),
        }
    }

    /// Persistent cache rooted at `dir`: loads `<dir>/eval_cache.jsonl`
    /// (skipping truncated/corrupt records) and appends every fresh
    /// evaluation to it.  Entries loaded from disk count as neither hits
    /// nor misses until they are looked up.
    pub fn with_dir(dir: impl AsRef<Path>) -> Result<EvalCache> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let path = dir.join(JOURNAL_FILE);
        let cache = EvalCache::new();
        if path.exists() {
            cache.load_journal(&path)?;
        }
        // Torn tails are healed by *appending* a newline, never truncating
        // — see `jsonl::open_append_healed` (the one implementation shared
        // with the transcript journals).
        let file = jsonl::open_append_healed(&path)?;
        // Rebuild the Arc with the journal attached (no other handles can
        // exist yet — the cache was created three lines up).
        let inner = Arc::try_unwrap(cache.inner)
            .unwrap_or_else(|_| unreachable!("fresh cache has one handle"));
        Ok(EvalCache {
            inner: Arc::new(Inner {
                journal: Some(Mutex::new(Journal { file })),
                journal_path: Some(path),
                ..inner
            }),
        })
    }

    /// The journal file backing the disk tier, if one is attached.
    pub fn journal_path(&self) -> Option<&Path> {
        self.inner.journal_path.as_deref()
    }

    /// The deterministic cache key: a content hash of
    /// `track \n canonical(scope) \n canonical(config)`.
    pub fn key(track: &str, scope: &Json, config: &Json) -> u128 {
        let payload = format!(
            "{}\n{}\n{}",
            track,
            json::canonical(scope),
            json::canonical(config)
        );
        hash::content_hash_128(payload.as_bytes())
    }

    /// Look the configuration up under the evaluator's (track, scope); on a
    /// miss, evaluate and memoize.  Returns the evaluation and whether it
    /// was served from the cache.
    pub fn get_or_evaluate(&self, ev: &dyn Evaluator, cfg: &Config) -> Result<(Evaluation, bool)> {
        let cfg_json = ev.space().config_to_json(cfg);
        let key = Self::key(ev.track(), &ev.scope(), &cfg_json);
        if let Some(hit) = self.lookup(key) {
            return Ok((hit, true));
        }
        // Evaluate outside any lock: evaluations can be expensive (training
        // runs), and determinism means a racing duplicate computes the
        // identical value, so first-write-wins is safe.
        let fresh = ev.evaluate(cfg)?;
        self.insert(key, &fresh);
        Ok((fresh, false))
    }

    /// Batched lookup/evaluation: misses are deduplicated within the batch
    /// and handed to [`Evaluator::evaluate_batch`] in one call, so
    /// per-evaluation setup (latency-model construction, artifact lookups)
    /// is amortized across the slice.  Result `i` corresponds to `cfgs[i]`.
    pub fn get_or_evaluate_batch(
        &self,
        ev: &dyn Evaluator,
        cfgs: &[Config],
    ) -> Result<Vec<(Evaluation, bool)>> {
        let (track, scope) = (ev.track(), ev.scope());
        let keys: Vec<u128> = cfgs
            .iter()
            .map(|c| Self::key(track, &scope, &ev.space().config_to_json(c)))
            .collect();
        let mut out: Vec<Option<(Evaluation, bool)>> =
            keys.iter().map(|&k| self.lookup(k).map(|e| (e, true))).collect();
        // First occurrence of each missing key gets evaluated; later
        // duplicates are served from the cache after insertion.
        let mut pending: Vec<(u128, usize)> = Vec::new();
        for (i, &k) in keys.iter().enumerate() {
            if out[i].is_none() && !pending.iter().any(|&(pk, _)| pk == k) {
                pending.push((k, i));
            }
        }
        if !pending.is_empty() {
            let miss_cfgs: Vec<Config> = pending.iter().map(|&(_, i)| cfgs[i].clone()).collect();
            let fresh = ev.evaluate_batch(&miss_cfgs)?;
            anyhow::ensure!(
                fresh.len() == miss_cfgs.len(),
                "evaluator '{}' returned {} results for a batch of {}",
                ev.track(),
                fresh.len(),
                miss_cfgs.len()
            );
            for (&(key, i), e) in pending.iter().zip(&fresh) {
                self.insert(key, e);
                out[i] = Some((e.clone(), false));
            }
        }
        Ok(out
            .into_iter()
            .zip(&keys)
            .map(|(slot, &k)| {
                slot.unwrap_or_else(|| {
                    // An in-batch duplicate of a just-evaluated key.
                    (self.lookup(k).expect("inserted above"), true)
                })
            })
            .collect())
    }

    /// Snapshot of the hit/miss counters and the entry count.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.inner.hits.load(Ordering::Relaxed),
            misses: self.inner.misses.load(Ordering::Relaxed),
            entries: self.len(),
        }
    }

    /// Distinct keys currently held in the memory tier.
    pub fn len(&self) -> usize {
        self.inner.shards.iter().map(|s| lock(s).len()).sum()
    }

    /// Whether the memory tier holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn shard(&self, key: u128) -> MutexGuard<'_, HashMap<u128, Evaluation>> {
        // Fold both hash lanes into the stripe index so either lane's
        // entropy suffices.
        let idx = ((key ^ (key >> 64)) as usize) & (SHARD_COUNT - 1);
        lock(&self.inner.shards[idx])
    }

    fn lookup(&self, key: u128) -> Option<Evaluation> {
        let found = self.shard(key).get(&key).cloned();
        if found.is_some() {
            self.inner.hits.fetch_add(1, Ordering::Relaxed);
        }
        found
    }

    /// Memoize a freshly computed evaluation (counted as a miss) and, if it
    /// is the first write for this key, append it to the journal.
    fn insert(&self, key: u128, fresh: &Evaluation) {
        self.inner.misses.fetch_add(1, Ordering::Relaxed);
        let first_write = match self.shard(key).entry(key) {
            Entry::Vacant(v) => {
                v.insert(fresh.clone());
                true
            }
            Entry::Occupied(_) => false,
        };
        if first_write {
            if let Some(j) = &self.inner.journal {
                // One write_all per record keeps concurrent appends from
                // interleaving mid-line; a failed append only loses the
                // disk tier, never the in-memory result.
                let line = encode_record(key, fresh);
                let mut g = lock(j);
                let _ = g.file.write_all(line.as_bytes()).and_then(|()| g.file.flush());
            }
        }
    }

    /// Rewrite `<dir>/eval_cache.jsonl` keeping only live records: the
    /// first valid record per key wins (matching the in-memory
    /// first-write-wins `or_insert` semantics), superseded duplicates and
    /// corrupt/blank lines are dropped, and record order is preserved.
    /// The rewrite is atomic (temp file + rename).  This is an **offline**
    /// maintenance pass (`haqa cache compact`): run it when no process is
    /// appending to the journal, or a concurrent append between read and
    /// rename can be lost.
    pub fn compact(dir: impl AsRef<Path>) -> Result<CompactReport> {
        let path = dir.as_ref().join(JOURNAL_FILE);
        let bytes = std::fs::read(&path)?;
        let mut live: Vec<String> = Vec::new();
        let mut seen: std::collections::HashSet<u128> = std::collections::HashSet::new();
        let mut before_records = 0usize;
        let scan = jsonl::scan(&bytes, |j, raw| match decode_record(j) {
            Some((key, _)) => {
                before_records += 1;
                if seen.insert(key) {
                    live.push(raw.to_string());
                }
                true
            }
            None => false,
        });
        let dropped_corrupt = scan.skipped;
        let after_records = live.len();
        let mut out = live.join("\n");
        if !out.is_empty() {
            out.push('\n');
        }
        let tmp = path.with_extension(format!("jsonl.compact.{}", std::process::id()));
        std::fs::write(&tmp, out.as_bytes())?;
        std::fs::rename(&tmp, &path)?;
        Ok(CompactReport {
            before_records,
            after_records,
            dropped_corrupt,
            before_bytes: bytes.len() as u64,
            after_bytes: std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0),
        })
    }

    /// Load every valid journal record.  Corrupt lines (and a torn,
    /// newline-less tail) are skipped with a warning — never an error, the
    /// cache just recomputes what was lost.
    fn load_journal(&self, path: &Path) -> Result<()> {
        let bytes = std::fs::read(path)?;
        let scan = jsonl::scan(&bytes, |j, _| match decode_record(j) {
            Some((key, e)) => {
                self.shard(key).entry(key).or_insert(e);
                true
            }
            None => false, // corrupt record: skip, keep loading
        });
        if scan.skipped > 0 {
            eprintln!(
                "eval cache: skipped {} corrupt/truncated record(s) in {}",
                scan.skipped,
                path.display()
            );
        }
        Ok(())
    }
}

/// One journal line.  `score`/`extra` carry the authoritative f64 bit
/// patterns in hex (`bits`, `extra`) so cached results stay bit-identical
/// across processes; the plain `score` number is informational.  Shared
/// with the device-transcript journal ([`super::device`]), which records
/// measurements in exactly this format.
pub(crate) fn encode_record(key: u128, e: &Evaluation) -> String {
    let mut o = Json::obj();
    o.set("key", Json::str(hash::hex128(key)));
    o.set(
        "score",
        if e.score.is_finite() {
            Json::Num(e.score)
        } else {
            Json::Null
        },
    );
    o.set("bits", Json::str(format!("{:016x}", e.score.to_bits())));
    if !e.extra.is_empty() {
        o.set(
            "extra",
            Json::Arr(
                e.extra
                    .iter()
                    .map(|x| Json::str(format!("{:016x}", x.to_bits())))
                    .collect(),
            ),
        );
    }
    o.set("feedback", Json::Str(e.feedback.clone()));
    let mut line = o.to_string();
    line.push('\n');
    line
}

/// Parse one journal line back into its key and evaluation (`None` for
/// records that do not match the schema).
pub(crate) fn decode_record(j: &Json) -> Option<(u128, Evaluation)> {
    let key = hash::parse_hex128(j.get("key")?.as_str()?)?;
    let bits = u64::from_str_radix(j.get("bits")?.as_str()?, 16).ok()?;
    let extra = match j.get("extra") {
        None => Vec::new(),
        Some(arr) => arr
            .as_arr()?
            .iter()
            .map(|v| {
                v.as_str()
                    .and_then(|s| u64::from_str_radix(s, 16).ok())
                    .map(f64::from_bits)
            })
            .collect::<Option<Vec<f64>>>()?,
    };
    let feedback = j.get("feedback")?.as_str()?.to_string();
    Some((
        key,
        Evaluation {
            score: f64::from_bits(bits),
            extra,
            feedback,
        },
    ))
}

#[cfg(test)]
mod tests {
    use std::cell::Cell;

    use super::*;
    use crate::search::{spaces, Space};

    /// Counts real evaluations; scores the learning rate so hits are
    /// distinguishable from misses only by the counter.
    struct CountingEval {
        space: Space,
        scope_tag: f64,
        calls: Cell<usize>,
    }

    impl CountingEval {
        fn new(scope_tag: f64) -> CountingEval {
            CountingEval {
                space: spaces::resnet_qat(),
                scope_tag,
                calls: Cell::new(0),
            }
        }
    }

    impl Evaluator for CountingEval {
        fn track(&self) -> &'static str {
            "counting"
        }
        fn space(&self) -> &Space {
            &self.space
        }
        fn scope(&self) -> Json {
            let mut o = Json::obj();
            o.set("tag", Json::Num(self.scope_tag));
            o
        }
        fn evaluate(&self, cfg: &Config) -> Result<Evaluation> {
            self.calls.set(self.calls.get() + 1);
            Ok(Evaluation {
                score: cfg["learning_rate"].as_f64(),
                extra: vec![self.scope_tag],
                feedback: "{\"note\": \"from CountingEval\"}".into(),
            })
        }
    }

    fn temp_cache_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("haqa_cache_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn hit_and_miss_semantics() {
        let cache = EvalCache::new();
        let ev = CountingEval::new(1.0);
        let cfg = ev.space.default_config();
        let (a, hit_a) = cache.get_or_evaluate(&ev, &cfg).unwrap();
        let (b, hit_b) = cache.get_or_evaluate(&ev, &cfg).unwrap();
        assert!(!hit_a && hit_b);
        assert_eq!(a.score.to_bits(), b.score.to_bits());
        assert_eq!(ev.calls.get(), 1, "second lookup must be served cached");
        assert_eq!(
            cache.stats(),
            CacheStats {
                hits: 1,
                misses: 1,
                entries: 1
            }
        );
        assert_eq!(cache.stats().hit_rate(), 0.5);
    }

    #[test]
    fn scope_separates_entries() {
        let cache = EvalCache::new();
        let ev1 = CountingEval::new(1.0);
        let ev2 = CountingEval::new(2.0);
        let cfg = ev1.space.default_config();
        cache.get_or_evaluate(&ev1, &cfg).unwrap();
        let (_, hit) = cache.get_or_evaluate(&ev2, &cfg).unwrap();
        assert!(!hit, "different scope must not hit");
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn key_stable_across_key_orderings() {
        let scope_a = crate::util::json::parse(r#"{"batch": 64, "kernel": "matmul"}"#).unwrap();
        let scope_b = crate::util::json::parse(r#"{"kernel": "matmul", "batch": 64}"#).unwrap();
        let cfg_a = crate::util::json::parse(r#"{"unroll": 2, "tiling_size": 16}"#).unwrap();
        let cfg_b = crate::util::json::parse(r#"{"tiling_size": 16, "unroll": 2}"#).unwrap();
        assert_eq!(
            EvalCache::key("kernel", &scope_a, &cfg_a),
            EvalCache::key("kernel", &scope_b, &cfg_b)
        );
        assert_ne!(
            EvalCache::key("kernel", &scope_a, &cfg_a),
            EvalCache::key("finetune", &scope_a, &cfg_a),
            "track must separate keys"
        );
    }

    #[test]
    fn shared_handle_sees_one_store() {
        let cache = EvalCache::new();
        let clone = cache.clone();
        let ev = CountingEval::new(3.0);
        let cfg = ev.space.default_config();
        clone.get_or_evaluate(&ev, &cfg).unwrap();
        let (_, hit) = cache.get_or_evaluate(&ev, &cfg).unwrap();
        assert!(hit, "clones share the underlying store");
    }

    #[test]
    fn striping_spreads_and_finds_many_keys() {
        // Many distinct configs land across shards and every one is found
        // again (exercises the stripe-selection path end to end).
        let cache = EvalCache::new();
        let ev = CountingEval::new(4.0);
        let mut rng = crate::util::rng::Rng::new(11);
        let cfgs: Vec<Config> = (0..64).map(|_| ev.space.sample(&mut rng)).collect();
        for cfg in &cfgs {
            cache.get_or_evaluate(&ev, cfg).unwrap();
        }
        let computed = ev.calls.get();
        for cfg in &cfgs {
            let (_, hit) = cache.get_or_evaluate(&ev, cfg).unwrap();
            assert!(hit);
        }
        assert_eq!(ev.calls.get(), computed, "second pass is all hits");
        assert_eq!(cache.stats().misses, computed);
    }

    #[test]
    fn batch_dedupes_within_and_against_cache() {
        let cache = EvalCache::new();
        let ev = CountingEval::new(5.0);
        let a = ev.space.default_config();
        let mut rng = crate::util::rng::Rng::new(3);
        let b = ev.space.sample(&mut rng);
        // Seed the cache with `a`, then batch [a, b, b].
        cache.get_or_evaluate(&ev, &a).unwrap();
        let out = cache
            .get_or_evaluate_batch(&ev, &[a.clone(), b.clone(), b.clone()])
            .unwrap();
        assert_eq!(out.len(), 3);
        assert!(out[0].1, "a was already cached");
        assert!(!out[1].1, "first b is computed");
        assert!(out[2].1, "duplicate b is served from the batch insert");
        assert_eq!(ev.calls.get(), 2, "a once, b once");
        assert_eq!(
            out[1].0.score.to_bits(),
            out[2].0.score.to_bits(),
            "duplicates are identical"
        );
    }

    #[test]
    fn journal_round_trips_across_instances() {
        let dir = temp_cache_dir("roundtrip");
        let ev = CountingEval::new(1.5);
        let cfg = ev.space.default_config();
        let first = {
            let cache = EvalCache::with_dir(&dir).unwrap();
            let (e, hit) = cache.get_or_evaluate(&ev, &cfg).unwrap();
            assert!(!hit);
            e
        };
        // A brand-new instance (≈ a new process) must serve the evaluation
        // from the journal without calling the evaluator again.
        let ev2 = CountingEval::new(1.5);
        let cache2 = EvalCache::with_dir(&dir).unwrap();
        assert_eq!(cache2.len(), 1);
        let (e, hit) = cache2.get_or_evaluate(&ev2, &cfg).unwrap();
        assert!(hit, "served from the persistent tier");
        assert_eq!(ev2.calls.get(), 0, "no re-evaluation");
        assert_eq!(e.score.to_bits(), first.score.to_bits(), "bit-exact score");
        assert_eq!(e.extra.len(), 1);
        assert_eq!(e.extra[0].to_bits(), first.extra[0].to_bits());
        assert_eq!(e.feedback, first.feedback);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_journal_tail_is_skipped_and_healed() {
        let dir = temp_cache_dir("corrupt");
        let ev1 = CountingEval::new(1.0);
        let ev2 = CountingEval::new(2.0);
        let cfg = ev1.space.default_config();
        {
            let cache = EvalCache::with_dir(&dir).unwrap();
            cache.get_or_evaluate(&ev1, &cfg).unwrap();
            cache.get_or_evaluate(&ev2, &cfg).unwrap();
        }
        let path = dir.join(JOURNAL_FILE);
        // Simulate a crashed writer: a torn, newline-less tail record.
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"{\"key\":\"00ff\",\"bits\":\"zzz");
        std::fs::write(&path, &bytes).unwrap();

        let cache2 = EvalCache::with_dir(&dir).unwrap();
        assert_eq!(cache2.len(), 2, "the two intact records survive");
        // The torn tail was newline-terminated (append-only healing), so
        // records appended after recovery load cleanly.
        let ev3 = CountingEval::new(3.0);
        cache2.get_or_evaluate(&ev3, &cfg).unwrap();
        let cache3 = EvalCache::with_dir(&dir).unwrap();
        assert_eq!(cache3.len(), 3, "post-recovery appends load cleanly");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_middle_record_is_skipped_not_fatal() {
        let dir = temp_cache_dir("middle");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(JOURNAL_FILE);
        let record = |key: u128| {
            encode_record(
                key,
                &Evaluation {
                    score: -1.25,
                    extra: Vec::new(),
                    feedback: "{}".into(),
                },
            )
        };
        let mut blob = record(42).into_bytes();
        blob.extend_from_slice(b"not json at all\n");
        blob.extend_from_slice(record(43).as_bytes());
        std::fs::write(&path, &blob).unwrap();
        let cache = EvalCache::with_dir(&dir).unwrap();
        // The corrupt line is skipped; records on both sides survive.
        assert_eq!(cache.len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compact_drops_superseded_duplicates_and_corruption() {
        let dir = temp_cache_dir("compact");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(JOURNAL_FILE);
        let record = |key: u128, score: f64| {
            encode_record(
                key,
                &Evaluation {
                    score,
                    extra: Vec::new(),
                    feedback: "{}".into(),
                },
            )
        };
        // Two writers raced on key 42 (first-write-wins ⇒ 1.0 is live),
        // key 43 is unique, and a crashed writer left a torn tail.
        let mut blob = record(42, 1.0).into_bytes();
        blob.extend_from_slice(record(43, 3.0).as_bytes());
        blob.extend_from_slice(record(42, 2.0).as_bytes());
        blob.extend_from_slice(b"{\"key\": \"torn");
        std::fs::write(&path, &blob).unwrap();

        let report = EvalCache::compact(&dir).unwrap();
        assert_eq!(report.before_records, 3);
        assert_eq!(report.after_records, 2);
        assert_eq!(report.dropped_corrupt, 1);
        assert!(report.after_bytes < report.before_bytes);

        // The compacted journal loads cleanly and kept the live values.
        let cache = EvalCache::with_dir(&dir).unwrap();
        assert_eq!(cache.len(), 2);
        let shard_val = |key: u128| cache.shard(key).get(&key).cloned().unwrap();
        assert_eq!(shard_val(42).score.to_bits(), 1.0f64.to_bits(), "first write wins");
        assert_eq!(shard_val(43).score.to_bits(), 3.0f64.to_bits());

        // Compacting a compact journal is a no-op.
        let again = EvalCache::compact(&dir).unwrap();
        assert_eq!(again.before_records, 2);
        assert_eq!(again.after_records, 2);
        assert_eq!(again.dropped_corrupt, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn record_encoding_is_bit_exact() {
        let e = Evaluation {
            score: -36.860000000000014,
            extra: vec![0.1 + 0.2, f64::MIN_POSITIVE],
            feedback: "{\"latency_us\": 36.860}".into(),
        };
        let key = EvalCache::key("kernel", &Json::obj(), &Json::obj());
        let line = encode_record(key, &e);
        let j = json::parse(line.trim_end()).unwrap();
        let (k2, e2) = decode_record(&j).unwrap();
        assert_eq!(k2, key);
        assert_eq!(e2.score.to_bits(), e.score.to_bits());
        assert_eq!(e2.extra.len(), 2);
        assert_eq!(e2.extra[0].to_bits(), e.extra[0].to_bits());
        assert_eq!(e2.extra[1].to_bits(), e.extra[1].to_bits());
        assert_eq!(e2.feedback, e.feedback);
    }
}
