//! Content-addressed evaluation cache.
//!
//! Evaluations are deterministic in (track, scenario knobs, configuration)
//! — see [`Evaluator`]'s contract — so repeated configurations across
//! optimizer rounds, method sweeps, bench tables and fleet workers can be
//! evaluated exactly once.  The key is a 128-bit content hash of the
//! canonical-JSON rendering (sorted keys, no whitespace, minimal numbers)
//! of the three components, making it independent of JSON key ordering and
//! stable across runs.
//!
//! The cache is a cheap cloneable handle (`Arc<Mutex<…>>`) shared by every
//! worker of a fleet; hit/miss counters are surfaced both globally
//! ([`EvalCache::stats`]) and per-track via
//! [`TrackOutcome`](super::workflow::TrackOutcome).

use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard};

use anyhow::Result;

use crate::search::Config;
use crate::util::hash;
use crate::util::json::{self, Json};

use super::evaluator::{Evaluation, Evaluator};

/// Aggregate cache counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: usize,
    pub misses: usize,
    pub entries: usize,
}

struct Inner {
    map: HashMap<u128, Evaluation>,
    hits: usize,
    misses: usize,
}

/// Thread-safe content-addressed cache handle (clone to share).
#[derive(Clone)]
pub struct EvalCache {
    inner: Arc<Mutex<Inner>>,
}

impl Default for EvalCache {
    fn default() -> Self {
        EvalCache::new()
    }
}

impl EvalCache {
    pub fn new() -> EvalCache {
        EvalCache {
            inner: Arc::new(Mutex::new(Inner {
                map: HashMap::new(),
                hits: 0,
                misses: 0,
            })),
        }
    }

    /// The deterministic cache key: a content hash of
    /// `track \n canonical(scope) \n canonical(config)`.
    pub fn key(track: &str, scope: &Json, config: &Json) -> u128 {
        let payload = format!(
            "{}\n{}\n{}",
            track,
            json::canonical(scope),
            json::canonical(config)
        );
        hash::content_hash_128(payload.as_bytes())
    }

    /// Look the configuration up under the evaluator's (track, scope); on a
    /// miss, evaluate and memoize.  Returns the evaluation and whether it
    /// was served from the cache.
    pub fn get_or_evaluate(&self, ev: &dyn Evaluator, cfg: &Config) -> Result<(Evaluation, bool)> {
        let cfg_json = ev.space().config_to_json(cfg);
        let key = Self::key(ev.track(), &ev.scope(), &cfg_json);
        let cached = {
            let mut g = self.lock();
            let found = g.map.get(&key).cloned();
            if found.is_some() {
                g.hits += 1;
            }
            found
        };
        if let Some(hit) = cached {
            return Ok((hit, true));
        }
        // Evaluate outside the lock: evaluations can be expensive (training
        // runs), and determinism means a racing duplicate computes the
        // identical value, so first-write-wins is safe.
        let fresh = ev.evaluate(cfg)?;
        let mut g = self.lock();
        g.misses += 1;
        g.map.entry(key).or_insert_with(|| fresh.clone());
        Ok((fresh, false))
    }

    pub fn stats(&self) -> CacheStats {
        let g = self.lock();
        CacheStats {
            hits: g.hits,
            misses: g.misses,
            entries: g.map.len(),
        }
    }

    pub fn len(&self) -> usize {
        self.lock().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn lock(&self) -> MutexGuard<'_, Inner> {
        // A worker that panicked mid-insert cannot corrupt the map (inserts
        // are single statements); recover instead of propagating poison.
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use std::cell::Cell;

    use super::*;
    use crate::search::{spaces, Space};

    /// Counts real evaluations; scores the learning rate so hits are
    /// distinguishable from misses only by the counter.
    struct CountingEval {
        space: Space,
        scope_tag: f64,
        calls: Cell<usize>,
    }

    impl CountingEval {
        fn new(scope_tag: f64) -> CountingEval {
            CountingEval {
                space: spaces::resnet_qat(),
                scope_tag,
                calls: Cell::new(0),
            }
        }
    }

    impl Evaluator for CountingEval {
        fn track(&self) -> &'static str {
            "counting"
        }
        fn space(&self) -> &Space {
            &self.space
        }
        fn scope(&self) -> Json {
            let mut o = Json::obj();
            o.set("tag", Json::Num(self.scope_tag));
            o
        }
        fn evaluate(&self, cfg: &Config) -> Result<Evaluation> {
            self.calls.set(self.calls.get() + 1);
            Ok(Evaluation {
                score: cfg["learning_rate"].as_f64(),
                extra: Vec::new(),
                feedback: String::new(),
            })
        }
    }

    #[test]
    fn hit_and_miss_semantics() {
        let cache = EvalCache::new();
        let ev = CountingEval::new(1.0);
        let cfg = ev.space.default_config();
        let (a, hit_a) = cache.get_or_evaluate(&ev, &cfg).unwrap();
        let (b, hit_b) = cache.get_or_evaluate(&ev, &cfg).unwrap();
        assert!(!hit_a && hit_b);
        assert_eq!(a.score.to_bits(), b.score.to_bits());
        assert_eq!(ev.calls.get(), 1, "second lookup must be served cached");
        assert_eq!(
            cache.stats(),
            CacheStats {
                hits: 1,
                misses: 1,
                entries: 1
            }
        );
    }

    #[test]
    fn scope_separates_entries() {
        let cache = EvalCache::new();
        let ev1 = CountingEval::new(1.0);
        let ev2 = CountingEval::new(2.0);
        let cfg = ev1.space.default_config();
        cache.get_or_evaluate(&ev1, &cfg).unwrap();
        let (_, hit) = cache.get_or_evaluate(&ev2, &cfg).unwrap();
        assert!(!hit, "different scope must not hit");
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn key_stable_across_key_orderings() {
        let scope_a = crate::util::json::parse(r#"{"batch": 64, "kernel": "matmul"}"#).unwrap();
        let scope_b = crate::util::json::parse(r#"{"kernel": "matmul", "batch": 64}"#).unwrap();
        let cfg_a = crate::util::json::parse(r#"{"unroll": 2, "tiling_size": 16}"#).unwrap();
        let cfg_b = crate::util::json::parse(r#"{"tiling_size": 16, "unroll": 2}"#).unwrap();
        assert_eq!(
            EvalCache::key("kernel", &scope_a, &cfg_a),
            EvalCache::key("kernel", &scope_b, &cfg_b)
        );
        assert_ne!(
            EvalCache::key("kernel", &scope_a, &cfg_a),
            EvalCache::key("finetune", &scope_a, &cfg_a),
            "track must separate keys"
        );
    }

    #[test]
    fn shared_handle_sees_one_store() {
        let cache = EvalCache::new();
        let clone = cache.clone();
        let ev = CountingEval::new(3.0);
        let cfg = ev.space.default_config();
        clone.get_or_evaluate(&ev, &cfg).unwrap();
        let (_, hit) = cache.get_or_evaluate(&ev, &cfg).unwrap();
        assert!(hit, "clones share the underlying store");
    }
}
