//! Content-addressed evaluation cache: lock-striped in memory, with an
//! optional persistent disk tier.
//!
//! Evaluations are deterministic in (track, scenario knobs, configuration)
//! — see [`Evaluator`]'s contract — so repeated configurations across
//! optimizer rounds, method sweeps, bench tables and fleet workers can be
//! evaluated exactly once.  The key is a 128-bit content hash of the
//! canonical-JSON rendering (sorted keys, no whitespace, minimal numbers)
//! of the three components, making it independent of JSON key ordering and
//! stable across runs — and across *processes* and machines, which is what
//! the disk tier builds on.
//!
//! Two layers:
//!
//! * **Lock-striped memory tier.** The map is split into [`SHARD_COUNT`]
//!   shards, each behind its own `Mutex`, selected by key bits.  Fleet
//!   workers hitting different keys no longer serialize on one global lock
//!   (the PR-1 `Arc<Mutex<HashMap>>` was a single convoy point at high
//!   worker counts); hit/miss counters are lock-free atomics.  The tier is
//!   unbounded by default; [`EvalCache::bounded`] /
//!   [`EvalCache::with_dir_capped`] put a global LRU cap on resident
//!   entries (split across the shards, each shard evicting its own
//!   least-recently-touched entry at capacity), which is what lets a
//!   10k-scenario fleet run in bounded memory.  **Eviction can never
//!   change a score**: evaluators are deterministic and the disk tier is
//!   authoritative, so an evicted entry's next lookup recomputes (or
//!   reloads) the bit-identical value — a cap only changes hit rates and
//!   peak residency, both surfaced in [`CacheStats`].
//! * **Append-only journal tier** ([`EvalCache::with_dir`]).  Every
//!   first-time evaluation is appended as one JSON line to
//!   `<dir>/eval_cache.jsonl` and the journal is streamed back on startup
//!   (one line in memory at a time — never the whole file), so bench
//!   tables, CI runs and fleet processes share evaluations.  Appends are
//!   **group-committed**: records accumulate in an in-process buffer and
//!   reach the file in one `write`+flush per group — at the
//!   [`FLUSH_RECORDS`]/[`FLUSH_BYTES`] watermark, at fleet sweep
//!   boundaries ([`EvalCache::flush_journal`]), and when the last cache
//!   handle drops — instead of one syscall pair per record.  Each flush
//!   writes only whole `\n`-terminated lines, so the append-only hygiene
//!   is unchanged: concurrent processes sharing a `--cache-dir` can never
//!   interleave mid-line, corrupt or torn records are skipped on load, and
//!   healing is append-only (a missing final newline is terminated before
//!   the next record).  A crash loses at most the unflushed group, which
//!   determinism recomputes.  Scores round-trip **bit-exactly** (the
//!   authoritative fields are f64 bit patterns in hex).  See
//!   `docs/CACHE.md`.
//! * **Remote tier** ([`EvalCache::with_remote`], `--cache-addr` /
//!   `HAQA_CACHE_ADDR`).  Instead of a local journal, local misses ask a
//!   shared cache server ([`super::cache_server`]) in one batched round
//!   trip per sweep and publish fresh evaluations back, so fleets on
//!   *different machines* share one warm cache.  Mutually exclusive with
//!   the disk tier — the journal lives on the server.
//!
//! The cache is a cheap cloneable handle shared by every worker of a
//! fleet; counters are surfaced both globally ([`EvalCache::stats`]) and
//! per-track via [`TrackOutcome`](super::workflow::TrackOutcome).

use std::collections::{BTreeMap, HashMap, HashSet};
use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use anyhow::{anyhow, Result};

use crate::search::Config;
use crate::util::hash;
use crate::util::json::{self, Json};
use crate::util::knob::Knob;
use crate::util::{jsonl, lock};

use super::cache_server::RemoteCacheTier;
use super::evaluator::{Evaluation, Evaluator};

/// Memory-tier stripe count (power of two; key bits select the stripe).
pub const SHARD_COUNT: usize = 16;

/// Journal file name inside a cache directory.
pub const JOURNAL_FILE: &str = "eval_cache.jsonl";

/// Group-commit record watermark: a buffered journal group is flushed once
/// it holds this many records (or [`FLUSH_BYTES`], whichever trips first).
pub const FLUSH_RECORDS: usize = 256;

/// Group-commit byte watermark (see [`FLUSH_RECORDS`]).
pub const FLUSH_BYTES: usize = 64 * 1024;

/// `haqa cache compact` summary: what the rewrite kept and dropped.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CompactReport {
    /// Valid records in the journal before the rewrite.
    pub before_records: usize,
    /// Live records kept (first valid write per key).
    pub after_records: usize,
    /// Corrupt/truncated lines dropped.
    pub dropped_corrupt: usize,
    /// Journal size before the rewrite, bytes.
    pub before_bytes: u64,
    /// Journal size after the rewrite, bytes.
    pub after_bytes: u64,
}

/// Aggregate cache counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from the cache.
    pub hits: usize,
    /// Lookups that had to evaluate (first sight of a key).
    pub misses: usize,
    /// Distinct keys currently held in the memory tier.
    pub entries: usize,
    /// Entries dropped from the memory tier by the LRU cap (0 when
    /// unbounded).  Evictions never change scores — the disk tier and
    /// evaluator determinism are authoritative — only hit rates.
    pub evictions: usize,
    /// High-water mark of resident memory-tier entries.
    pub peak_entries: usize,
    /// The configured global LRU cap (`None` = unbounded).
    pub capacity: Option<usize>,
    /// Records appended to the journal by this process (0 without a disk
    /// tier).
    pub journal_records: usize,
    /// `write` syscalls that carried those records — group commit makes
    /// this strictly smaller than `journal_records` under load.
    pub journal_writes: usize,
    /// Local misses served by the remote cache tier (0 without
    /// `--cache-addr`).  A remote hit also counts in [`CacheStats::hits`]:
    /// it was served from the cache, just not from this process.
    pub remote_hits: usize,
    /// Keys the remote tier was asked for and did not have — each one
    /// became a real evaluation (and was published back to the server).
    pub remote_misses: usize,
    /// Protocol round trips to the remote tier.  Batching keeps this far
    /// below `remote_hits + remote_misses`: one `batch_get` per sweep plus
    /// one pipelined `put` flight per sweep with fresh results.
    pub remote_round_trips: usize,
}

impl CacheStats {
    /// Fraction of lookups served from the cache (0 when nothing was
    /// looked up).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Counters accrued since the `before` snapshot: monotonic counters
    /// subtract (saturating, so a rotated/rebuilt cache can't underflow),
    /// gauges (`entries`, `peak_entries`, `capacity`) carry the current
    /// value.  `haqa serve` reports a per-submission cache line this way —
    /// the daemon's cache is warm and shared, so absolute counters span
    /// every job it ever ran.
    pub fn delta_from(&self, before: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits.saturating_sub(before.hits),
            misses: self.misses.saturating_sub(before.misses),
            entries: self.entries,
            evictions: self.evictions.saturating_sub(before.evictions),
            peak_entries: self.peak_entries,
            capacity: self.capacity,
            journal_records: self.journal_records.saturating_sub(before.journal_records),
            journal_writes: self.journal_writes.saturating_sub(before.journal_writes),
            remote_hits: self.remote_hits.saturating_sub(before.remote_hits),
            remote_misses: self.remote_misses.saturating_sub(before.remote_misses),
            remote_round_trips: self
                .remote_round_trips
                .saturating_sub(before.remote_round_trips),
        }
    }
}

/// Buffered journal writer: records accumulate in `buf` and reach the file
/// as one `write_all` + `flush` per group.  Every flush writes only whole
/// newline-terminated lines, preserving the one-record-per-line append
/// hygiene `docs/CACHE.md` guarantees to concurrent processes.
struct Journal {
    file: File,
    buf: String,
    /// Records currently buffered (not yet on disk).
    buffered: usize,
    /// Total records appended by this process (buffered or flushed).
    records: usize,
    /// `write_all` calls issued (the group-commit win is `writes` ≪
    /// `records`).
    writes: usize,
}

impl Journal {
    fn new(file: File) -> Journal {
        Journal {
            file,
            buf: String::new(),
            buffered: 0,
            records: 0,
            writes: 0,
        }
    }

    /// Buffer one `\n`-terminated record, flushing at the group watermark.
    fn append(&mut self, line: &str) {
        self.buf.push_str(line);
        self.buffered += 1;
        self.records += 1;
        if self.buffered >= FLUSH_RECORDS || self.buf.len() >= FLUSH_BYTES {
            self.flush();
        }
    }

    /// Write the buffered group (one syscall pair).  A failed append only
    /// loses the disk tier, never the in-memory results.
    fn flush(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        let _ = self
            .file
            .write_all(self.buf.as_bytes())
            .and_then(|()| self.file.flush());
        self.writes += 1;
        self.buf.clear();
        self.buffered = 0;
    }
}

/// One lock stripe: the entry map plus the LRU book-keeping for this
/// shard's slice of the global cap.
#[derive(Default)]
struct Shard {
    /// Key → (evaluation, recency stamp of the last touch).
    map: HashMap<u128, (Evaluation, u64)>,
    /// Recency index: stamp → key, oldest first (stamps are unique within
    /// a shard, so `BTreeMap` gives O(log n) touch and evict-oldest).
    recency: BTreeMap<u64, u128>,
    /// Monotonic per-shard touch counter.
    stamp: u64,
    /// Keys already carried by the journal (loaded or appended), so an
    /// evicted-then-recomputed key is never appended twice.  Populated
    /// only when a disk tier is attached.
    journaled: HashSet<u128>,
    /// This shard's slice of the global cap (`None` = unbounded).
    cap: Option<usize>,
}

/// What a shard-level store did (drives the global counters).
struct StoreEffect {
    /// The entry is now resident (false for duplicates and cap-0 shards).
    stored: bool,
    /// First time the journal should carry this key.
    newly_journaled: bool,
    /// Entries removed from the map to make room (0 or 1).
    dropped: usize,
    /// A cap-0 shard suppressed the store entirely (counts as an
    /// eviction: the entry was admitted and immediately displaced).
    suppressed: bool,
}

impl Shard {
    /// Look up and touch: a hit moves the entry to most-recently-used.
    fn touch(&mut self, key: u128) -> Option<Evaluation> {
        let (e, stamp) = self.map.get_mut(&key)?;
        let found = e.clone();
        let old = *stamp;
        self.stamp += 1;
        *stamp = self.stamp;
        let new = self.stamp;
        self.recency.remove(&old);
        self.recency.insert(new, key);
        Some(found)
    }

    /// First-write-wins store under this shard's cap slice, evicting the
    /// least-recently-touched entry first when at capacity (so residency
    /// never exceeds the cap, even transiently).
    fn store(&mut self, key: u128, e: &Evaluation, track_journal: bool) -> StoreEffect {
        let newly_journaled = track_journal && self.journaled.insert(key);
        let mut eff = StoreEffect {
            stored: false,
            newly_journaled,
            dropped: 0,
            suppressed: false,
        };
        if self.map.contains_key(&key) {
            return eff;
        }
        match self.cap {
            Some(0) => {
                // A zero-cap shard holds nothing; determinism (and the
                // disk tier) make the next lookup recompute identically.
                eff.suppressed = true;
                return eff;
            }
            Some(c) => {
                while self.map.len() >= c {
                    let (&stamp, &victim) = self.recency.iter().next().expect("len >= 1");
                    self.recency.remove(&stamp);
                    self.map.remove(&victim);
                    eff.dropped += 1;
                }
            }
            None => {}
        }
        self.stamp += 1;
        self.map.insert(key, (e.clone(), self.stamp));
        self.recency.insert(self.stamp, key);
        eff.stored = true;
        eff
    }
}

struct Inner {
    shards: Vec<Mutex<Shard>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    evictions: AtomicUsize,
    /// Resident-entry counter driving `peak`: updated with at most one
    /// atomic op per store (net delta 0 or +1), so it never overstates the
    /// true residency — which keeps `peak_entries <= capacity` exact.
    resident: AtomicUsize,
    peak: AtomicUsize,
    /// Global LRU cap (`None` = unbounded); split across shards.
    capacity: Option<usize>,
    /// Disk tier; `None` for a purely in-memory cache.
    journal: Option<Mutex<Journal>>,
    journal_path: Option<PathBuf>,
    /// Remote tier (`--cache-addr`); mutually exclusive with the disk
    /// tier — the journal lives on the server.
    remote: Option<RemoteCacheTier>,
}

impl Drop for Inner {
    fn drop(&mut self) {
        // The last handle is gone: commit the tail group so a process that
        // exits cleanly never loses buffered records.
        if let Some(j) = &self.journal {
            lock(j).flush();
        }
    }
}

/// Thread-safe content-addressed cache handle (clone to share).
#[derive(Clone)]
pub struct EvalCache {
    inner: Arc<Inner>,
}

impl Default for EvalCache {
    fn default() -> Self {
        EvalCache::new()
    }
}

/// Split a global cap across the shards so the slices sum exactly to the
/// cap: shard `i` gets `cap/16`, plus one of the `cap % 16` remainder
/// slots.
fn shard_cap(cap: usize, i: usize) -> usize {
    cap / SHARD_COUNT + usize::from(i < cap % SHARD_COUNT)
}

impl EvalCache {
    /// In-memory cache (no disk tier, no cap).
    pub fn new() -> EvalCache {
        Self::build(None, None, None, None)
    }

    /// In-memory cache whose memory tier holds at most `cap` entries
    /// (clamped to ≥ 1), evicting least-recently-used.  Without a disk
    /// tier an evicted entry is simply recomputed on its next miss — the
    /// bit-identical value, per the [`Evaluator`] determinism contract.
    pub fn bounded(cap: usize) -> EvalCache {
        Self::build(Some(cap.max(1)), None, None, None)
    }

    /// Memory tier (optionally `cap`ped) in front of a **remote** cache
    /// tier (`--cache-addr` / `HAQA_CACHE_ADDR`): local misses ask the
    /// cache server in one batched round trip per sweep, fresh
    /// evaluations are published back, and hot keys never re-cross the
    /// wire.  No local journal — the authoritative disk tier lives on the
    /// server.  Scores are bit-identical with or without the remote tier
    /// (the wire carries f64 bit patterns and evaluators are
    /// deterministic); only hit rates and evaluation counts change.
    pub fn with_remote(tier: RemoteCacheTier, cap: Option<usize>) -> EvalCache {
        Self::build(cap.map(|c| c.max(1)), None, None, Some(tier))
    }

    fn build(
        cap: Option<usize>,
        journal: Option<Journal>,
        path: Option<PathBuf>,
        remote: Option<RemoteCacheTier>,
    ) -> EvalCache {
        EvalCache {
            inner: Arc::new(Inner {
                shards: (0..SHARD_COUNT)
                    .map(|i| {
                        Mutex::new(Shard {
                            cap: cap.map(|c| shard_cap(c, i)),
                            ..Shard::default()
                        })
                    })
                    .collect(),
                hits: AtomicUsize::new(0),
                misses: AtomicUsize::new(0),
                evictions: AtomicUsize::new(0),
                resident: AtomicUsize::new(0),
                peak: AtomicUsize::new(0),
                capacity: cap,
                journal: journal.map(Mutex::new),
                journal_path: path,
                remote,
            }),
        }
    }

    /// Persistent cache rooted at `dir`: streams `<dir>/eval_cache.jsonl`
    /// back into the memory tier (skipping truncated/corrupt records) and
    /// group-commits every fresh evaluation to it.  Entries loaded from
    /// disk count as neither hits nor misses until they are looked up.
    pub fn with_dir(dir: impl AsRef<Path>) -> Result<EvalCache> {
        Self::with_dir_capped(dir, None)
    }

    /// [`EvalCache::with_dir`] with an optional global LRU cap on the
    /// *memory* tier (clamped to ≥ 1).  The journal is still loaded in
    /// full — entries past the cap evict on the way in — and stays
    /// authoritative, so a capped cache returns exactly the scores an
    /// unbounded one does; only hit rates and peak residency differ.
    pub fn with_dir_capped(dir: impl AsRef<Path>, cap: Option<usize>) -> Result<EvalCache> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let path = dir.join(JOURNAL_FILE);
        // Heal-then-open *before* loading: a torn tail is terminated by an
        // appended newline (never truncation — a concurrent writer may be
        // mid-append), so the load below sees only whole lines.
        let file = jsonl::open_append_healed(&path)?;
        let cache = Self::build(
            cap.map(|c| c.max(1)),
            Some(Journal::new(file)),
            Some(path.clone()),
            None,
        );
        cache.load_journal(&path)?;
        Ok(cache)
    }

    /// Resolve the memory-tier cap: explicit CLI value, else
    /// `HAQA_CACHE_CAP`, else `None` (unbounded).  House [`Knob`] rules,
    /// and a cap of 0 — from either source — is itself a hard error rather
    /// than a silent "off": a zero-entry cache is always a typo.
    pub fn cap_from_env(cli: Option<usize>) -> Result<Option<usize>> {
        Knob::counter("HAQA_CACHE_CAP", "a positive integer").require_nonzero(
            cli,
            "the cache capacity must be >= 1 (omit --cache-cap/HAQA_CACHE_CAP \
             for an unbounded memory tier)",
        )
    }

    /// The journal file backing the disk tier, if one is attached.
    pub fn journal_path(&self) -> Option<&Path> {
        self.inner.journal_path.as_deref()
    }

    /// The configured global LRU cap (`None` = unbounded).
    pub fn capacity(&self) -> Option<usize> {
        self.inner.capacity
    }

    /// Commit the buffered journal group now (no-op when empty or without
    /// a disk tier).  The fleet runner calls this at sweep boundaries —
    /// and [`Drop`] calls it for the last handle — so the on-disk journal
    /// is complete whenever a run hands it to the next process.
    pub fn flush_journal(&self) {
        if let Some(j) = &self.inner.journal {
            lock(j).flush();
        }
    }

    /// The deterministic cache key: a content hash of
    /// `track \n canonical(scope) \n canonical(config)`.
    pub fn key(track: &str, scope: &Json, config: &Json) -> u128 {
        let payload = format!(
            "{}\n{}\n{}",
            track,
            json::canonical(scope),
            json::canonical(config)
        );
        hash::content_hash_128(payload.as_bytes())
    }

    /// Look the configuration up under the evaluator's (track, scope); on a
    /// miss, evaluate and memoize.  Returns the evaluation and whether it
    /// was served from the cache.
    pub fn get_or_evaluate(&self, ev: &dyn Evaluator, cfg: &Config) -> Result<(Evaluation, bool)> {
        let cfg_json = ev.space().config_to_json(cfg);
        let key = Self::key(ev.track(), &ev.scope(), &cfg_json);
        if let Some(hit) = self.fetch(key)? {
            return Ok((hit, true));
        }
        // Evaluate outside any lock: evaluations can be expensive (training
        // runs), and determinism means a racing duplicate computes the
        // identical value, so first-write-wins is safe.
        let fresh = ev.evaluate(cfg)?;
        self.publish(key, &fresh)?;
        Ok((fresh, false))
    }

    /// Tiered lookup: the local memory tier first, then — on a local miss,
    /// when a remote tier is attached — one `get` round trip to the cache
    /// server.  A remote hit is admitted into the memory tier (hot keys
    /// never re-cross the wire) and counted as a hit.
    pub(crate) fn fetch(&self, key: u128) -> Result<Option<Evaluation>> {
        if let Some(hit) = self.lookup(key) {
            return Ok(Some(hit));
        }
        if let Some(remote) = &self.inner.remote {
            if let Some(e) = remote.get(key)? {
                self.store(key, &e);
                self.inner.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(Some(e));
            }
        }
        Ok(None)
    }

    /// Memoize a fresh evaluation ([`insert`](Self::insert): counted as a
    /// miss, journaled once) and — when a remote tier is attached —
    /// publish it to the cache server.  Losing the server-side
    /// first-write race is fine (the racing value is bit-identical);
    /// a *transport* failure is a hard error, like any evaluator failure.
    pub(crate) fn publish(&self, key: u128, fresh: &Evaluation) -> Result<()> {
        self.insert(key, fresh);
        if let Some(remote) = &self.inner.remote {
            remote.put_many(&[(key, fresh)])?;
        }
        Ok(())
    }

    /// Batched lookup/evaluation: misses are deduplicated within the batch
    /// and handed to [`Evaluator::evaluate_batch`] in one call, so
    /// per-evaluation setup (latency-model construction, artifact lookups)
    /// is amortized across the slice.  Result `i` corresponds to `cfgs[i]`.
    pub fn get_or_evaluate_batch(
        &self,
        ev: &dyn Evaluator,
        cfgs: &[Config],
    ) -> Result<Vec<(Evaluation, bool)>> {
        let (track, scope) = (ev.track(), ev.scope());
        let keys: Vec<u128> = cfgs
            .iter()
            .map(|c| Self::key(track, &scope, &ev.space().config_to_json(c)))
            .collect();
        let mut out: Vec<Option<(Evaluation, bool)>> =
            keys.iter().map(|&k| self.lookup(k).map(|e| (e, true))).collect();
        // First occurrence of each missing key gets evaluated; later
        // duplicates are served from the batch's own results.
        let mut pending: Vec<(u128, usize)> = Vec::new();
        for (i, &k) in keys.iter().enumerate() {
            if out[i].is_none() && !pending.iter().any(|&(pk, _)| pk == k) {
                pending.push((k, i));
            }
        }
        let mut fresh_by_key: HashMap<u128, Evaluation> = HashMap::new();
        // The remote tier sees the whole sweep's misses as ONE `batch_get`
        // round trip; keys it serves skip evaluation entirely and are
        // admitted into the memory tier so repeats stay local.
        if !pending.is_empty() {
            if let Some(remote) = &self.inner.remote {
                let miss_keys: Vec<u128> = pending.iter().map(|&(k, _)| k).collect();
                let served = remote.batch_get(&miss_keys)?;
                let mut still: Vec<(u128, usize)> = Vec::new();
                for (&(key, i), slot) in pending.iter().zip(served) {
                    match slot {
                        Some(e) => {
                            self.store(key, &e);
                            self.inner.hits.fetch_add(1, Ordering::Relaxed);
                            fresh_by_key.insert(key, e.clone());
                            out[i] = Some((e, true));
                        }
                        None => still.push((key, i)),
                    }
                }
                pending = still;
            }
        }
        if !pending.is_empty() {
            let miss_cfgs: Vec<Config> = pending.iter().map(|&(_, i)| cfgs[i].clone()).collect();
            let fresh = ev.evaluate_batch(&miss_cfgs)?;
            anyhow::ensure!(
                fresh.len() == miss_cfgs.len(),
                "evaluator '{}' returned {} results for a batch of {}",
                ev.track(),
                fresh.len(),
                miss_cfgs.len()
            );
            for (&(key, i), e) in pending.iter().zip(&fresh) {
                self.insert(key, e);
                fresh_by_key.insert(key, e.clone());
                out[i] = Some((e.clone(), false));
            }
            // Publish the sweep's fresh evaluations back in one pipelined
            // flight so the next fleet (or machine) is served remotely.
            if let Some(remote) = &self.inner.remote {
                let records: Vec<(u128, &Evaluation)> =
                    pending.iter().map(|&(k, _)| k).zip(&fresh).collect();
                remote.put_many(&records)?;
            }
        }
        Ok(out
            .into_iter()
            .zip(&keys)
            .map(|(slot, &k)| {
                slot.unwrap_or_else(|| {
                    // An in-batch duplicate of a just-evaluated key: served
                    // from the memory tier, or — if the LRU cap already
                    // evicted it — from the batch's own results.
                    let e = self.lookup(k).unwrap_or_else(|| {
                        self.inner.hits.fetch_add(1, Ordering::Relaxed);
                        fresh_by_key[&k].clone()
                    });
                    (e, true)
                })
            })
            .collect())
    }

    /// Snapshot of the counters and the entry count.
    pub fn stats(&self) -> CacheStats {
        let (journal_records, journal_writes) = match &self.inner.journal {
            Some(j) => {
                let g = lock(j);
                (g.records, g.writes)
            }
            None => (0, 0),
        };
        let (remote_hits, remote_misses, remote_round_trips) = match &self.inner.remote {
            Some(r) => r.counters(),
            None => (0, 0, 0),
        };
        CacheStats {
            hits: self.inner.hits.load(Ordering::Relaxed),
            misses: self.inner.misses.load(Ordering::Relaxed),
            entries: self.len(),
            evictions: self.inner.evictions.load(Ordering::Relaxed),
            peak_entries: self.inner.peak.load(Ordering::Relaxed),
            capacity: self.inner.capacity,
            journal_records,
            journal_writes,
            remote_hits,
            remote_misses,
            remote_round_trips,
        }
    }

    /// The remote tier's `host:port`, if one is attached (the fleet's
    /// stats line).
    pub fn remote_addr(&self) -> Option<&str> {
        self.inner.remote.as_ref().map(|r| r.addr())
    }

    /// Distinct keys currently held in the memory tier.
    pub fn len(&self) -> usize {
        self.inner.shards.iter().map(|s| lock(s).map.len()).sum()
    }

    /// Whether the memory tier holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn shard(&self, key: u128) -> MutexGuard<'_, Shard> {
        // Fold both hash lanes into the stripe index so either lane's
        // entropy suffices.
        let idx = ((key ^ (key >> 64)) as usize) & (SHARD_COUNT - 1);
        lock(&self.inner.shards[idx])
    }

    fn lookup(&self, key: u128) -> Option<Evaluation> {
        let found = self.shard(key).touch(key);
        if found.is_some() {
            self.inner.hits.fetch_add(1, Ordering::Relaxed);
        }
        found
    }

    /// Store under the shard's cap slice and keep the global residency /
    /// peak / eviction counters in step.  The update applies at most one
    /// atomic increment per store (evict-then-insert is net 0), so the
    /// counter never overstates true residency and the peak can never
    /// exceed the cap.
    fn store(&self, key: u128, e: &Evaluation) -> StoreEffect {
        let track_journal = self.inner.journal.is_some();
        let eff = self.shard(key).store(key, e, track_journal);
        if eff.stored && eff.dropped == 0 {
            let now = self.inner.resident.fetch_add(1, Ordering::Relaxed) + 1;
            self.inner.peak.fetch_max(now, Ordering::Relaxed);
        }
        let evictions = eff.dropped + usize::from(eff.suppressed);
        if evictions > 0 {
            self.inner.evictions.fetch_add(evictions, Ordering::Relaxed);
        }
        eff
    }

    /// Memoize a freshly computed evaluation (counted as a miss) and, the
    /// first time the journal sees this key, buffer it for group commit.
    fn insert(&self, key: u128, fresh: &Evaluation) {
        self.inner.misses.fetch_add(1, Ordering::Relaxed);
        let eff = self.store(key, fresh);
        if eff.newly_journaled {
            if let Some(j) = &self.inner.journal {
                let line = encode_record(key, fresh);
                lock(j).append(&line);
            }
        }
    }

    /// Server-side lookup (the cache-server `get`/`batch_get` path):
    /// touches LRU recency like any lookup but counts neither a hit nor a
    /// miss — the server keeps its own protocol counters, and this
    /// cache's hit/miss pair must keep meaning "served locally" /
    /// "really evaluated".
    pub(crate) fn peek(&self, key: u128) -> Option<Evaluation> {
        self.shard(key).touch(key)
    }

    /// Server-side first-write-wins admit (the cache-server `put` path):
    /// store under the cap, journal the first sight of the key, count
    /// neither a hit nor a miss.  Returns whether this write won.  With a
    /// disk tier the journaled set is the authority (an evicted key's
    /// repeat put still loses); in-memory servers fall back to residency,
    /// so after an eviction a repeat put can "win" again — harmless, the
    /// value is bit-identical by determinism.
    pub(crate) fn admit(&self, key: u128, e: &Evaluation) -> bool {
        let eff = self.store(key, e);
        if eff.newly_journaled {
            if let Some(j) = &self.inner.journal {
                lock(j).append(&encode_record(key, e));
            }
            return true;
        }
        self.inner.journal.is_none() && (eff.stored || eff.suppressed)
    }

    /// Rewrite `<dir>/eval_cache.jsonl` keeping only live records: the
    /// first valid record per key wins (matching the in-memory
    /// first-write-wins semantics), superseded duplicates and
    /// corrupt/blank lines are dropped, and record order is preserved.
    /// The rewrite is atomic (temp file + rename).  This is an **offline**
    /// maintenance pass (`haqa cache compact`): run it when no process is
    /// appending to the journal, or a concurrent append between read and
    /// rename can be lost.  A cache *server* runs the same rewrite
    /// **online** via [`EvalCache::rotate_journal`] (the `rotate` op),
    /// which holds the journal lock across the swap.
    pub fn compact(dir: impl AsRef<Path>) -> Result<CompactReport> {
        rewrite_live(&dir.as_ref().join(JOURNAL_FILE))
    }

    /// Rotate the journal generation in place — the server-side form of
    /// [`EvalCache::compact`], safe while this process keeps appending:
    /// under the journal lock, commit the buffered group, run the
    /// first-write-wins rewrite (atomic temp file + rename), and reopen
    /// the append handle onto the new file.  Concurrent `put`s block on
    /// the lock for the duration of the rewrite; lookups are unaffected
    /// (the memory tier never goes away).  Errors without a disk tier.
    pub fn rotate_journal(&self) -> Result<CompactReport> {
        let path = self.inner.journal_path.as_deref().ok_or_else(|| {
            anyhow!("journal rotation requires a disk tier (serve with --cache-dir)")
        })?;
        let j = self
            .inner
            .journal
            .as_ref()
            .expect("a journal path implies a journal");
        let mut g = lock(j);
        g.flush();
        let report = rewrite_live(path)?;
        // The old handle points at the renamed-over inode; reopen onto
        // the new generation so later appends land in the live file.
        g.file = jsonl::open_append_healed(path)?;
        Ok(report)
    }

    /// Stream every valid journal record into the memory tier (under the
    /// cap, if one is set) without materializing the file.  Corrupt lines
    /// are skipped with a warning — never an error, the cache just
    /// recomputes what was lost.  Loaded keys are marked journaled so they
    /// are never re-appended, even after eviction.
    fn load_journal(&self, path: &Path) -> Result<()> {
        let scan = jsonl::scan_file(path, |j, _| match decode_record(j) {
            Some((key, e)) => {
                self.store(key, &e);
                true
            }
            None => false, // corrupt record: skip, keep loading
        })?;
        if scan.skipped > 0 {
            eprintln!(
                "eval cache: skipped {} corrupt/truncated record(s) in {}",
                scan.skipped,
                path.display()
            );
        }
        Ok(())
    }
}

/// The first-write-wins journal rewrite shared by [`EvalCache::compact`]
/// (offline CLI pass) and [`EvalCache::rotate_journal`] (online, under
/// the journal lock): keep the first valid record per key in order, drop
/// superseded duplicates and corrupt lines, swap atomically.
fn rewrite_live(path: &Path) -> Result<CompactReport> {
    let bytes = std::fs::read(path)?;
    let mut live: Vec<String> = Vec::new();
    let mut seen: HashSet<u128> = HashSet::new();
    let mut before_records = 0usize;
    let scan = jsonl::scan(&bytes, |j, raw| match decode_record(j) {
        Some((key, _)) => {
            before_records += 1;
            if seen.insert(key) {
                live.push(raw.to_string());
            }
            true
        }
        None => false,
    });
    let dropped_corrupt = scan.skipped;
    let after_records = live.len();
    let mut out = live.join("\n");
    if !out.is_empty() {
        out.push('\n');
    }
    let tmp = path.with_extension(format!("jsonl.compact.{}", std::process::id()));
    std::fs::write(&tmp, out.as_bytes())?;
    std::fs::rename(&tmp, path)?;
    Ok(CompactReport {
        before_records,
        after_records,
        dropped_corrupt,
        before_bytes: bytes.len() as u64,
        after_bytes: std::fs::metadata(path).map(|m| m.len()).unwrap_or(0),
    })
}

/// One journal line.  `score`/`extra` carry the authoritative f64 bit
/// patterns in hex (`bits`, `extra`) so cached results stay bit-identical
/// across processes; the plain `score` number is informational.  Shared
/// with the device-transcript journal ([`super::device`]), which records
/// measurements in exactly this format.
pub(crate) fn encode_record(key: u128, e: &Evaluation) -> String {
    let mut o = Json::obj();
    o.set("key", Json::str(hash::hex128(key)));
    o.set(
        "score",
        if e.score.is_finite() {
            Json::Num(e.score)
        } else {
            Json::Null
        },
    );
    o.set("bits", Json::str(format!("{:016x}", e.score.to_bits())));
    if !e.extra.is_empty() {
        o.set(
            "extra",
            Json::Arr(
                e.extra
                    .iter()
                    .map(|x| Json::str(format!("{:016x}", x.to_bits())))
                    .collect(),
            ),
        );
    }
    o.set("feedback", Json::Str(e.feedback.clone()));
    let mut line = o.to_string();
    line.push('\n');
    line
}

/// Parse one journal line back into its key and evaluation (`None` for
/// records that do not match the schema).
pub(crate) fn decode_record(j: &Json) -> Option<(u128, Evaluation)> {
    let key = hash::parse_hex128(j.get("key")?.as_str()?)?;
    let bits = u64::from_str_radix(j.get("bits")?.as_str()?, 16).ok()?;
    let extra = match j.get("extra") {
        None => Vec::new(),
        Some(arr) => arr
            .as_arr()?
            .iter()
            .map(|v| {
                v.as_str()
                    .and_then(|s| u64::from_str_radix(s, 16).ok())
                    .map(f64::from_bits)
            })
            .collect::<Option<Vec<f64>>>()?,
    };
    let feedback = j.get("feedback")?.as_str()?.to_string();
    Some((
        key,
        Evaluation {
            score: f64::from_bits(bits),
            extra,
            feedback,
        },
    ))
}

#[cfg(test)]
mod tests {
    use std::cell::Cell;

    use super::*;
    use crate::search::{spaces, Space};

    /// Counts real evaluations; scores the learning rate so hits are
    /// distinguishable from misses only by the counter.
    struct CountingEval {
        space: Space,
        scope_tag: f64,
        calls: Cell<usize>,
    }

    impl CountingEval {
        fn new(scope_tag: f64) -> CountingEval {
            CountingEval {
                space: spaces::resnet_qat(),
                scope_tag,
                calls: Cell::new(0),
            }
        }
    }

    impl Evaluator for CountingEval {
        fn track(&self) -> &'static str {
            "counting"
        }
        fn space(&self) -> &Space {
            &self.space
        }
        fn scope(&self) -> Json {
            let mut o = Json::obj();
            o.set("tag", Json::Num(self.scope_tag));
            o
        }
        fn evaluate(&self, cfg: &Config) -> Result<Evaluation> {
            self.calls.set(self.calls.get() + 1);
            Ok(Evaluation {
                score: cfg["learning_rate"].as_f64(),
                extra: vec![self.scope_tag],
                feedback: "{\"note\": \"from CountingEval\"}".into(),
            })
        }
    }

    fn temp_cache_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("haqa_cache_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn hit_and_miss_semantics() {
        let cache = EvalCache::new();
        let ev = CountingEval::new(1.0);
        let cfg = ev.space.default_config();
        let (a, hit_a) = cache.get_or_evaluate(&ev, &cfg).unwrap();
        let (b, hit_b) = cache.get_or_evaluate(&ev, &cfg).unwrap();
        assert!(!hit_a && hit_b);
        assert_eq!(a.score.to_bits(), b.score.to_bits());
        assert_eq!(ev.calls.get(), 1, "second lookup must be served cached");
        assert_eq!(
            cache.stats(),
            CacheStats {
                hits: 1,
                misses: 1,
                entries: 1,
                peak_entries: 1,
                ..CacheStats::default()
            }
        );
        assert_eq!(cache.stats().hit_rate(), 0.5);
    }

    #[test]
    fn delta_from_isolates_one_submissions_counters() {
        let cache = EvalCache::new();
        let ev = CountingEval::new(1.0);
        let cfg = ev.space.default_config();
        cache.get_or_evaluate(&ev, &cfg).unwrap(); // miss
        let before = cache.stats();
        cache.get_or_evaluate(&ev, &cfg).unwrap(); // hit
        cache.get_or_evaluate(&ev, &cfg).unwrap(); // hit
        let d = cache.stats().delta_from(&before);
        assert_eq!((d.hits, d.misses), (2, 0), "warm window: all hits");
        assert_eq!(d.entries, 1, "entries is a gauge, not a delta");
        assert_eq!(d.hit_rate(), 1.0);
        // A stale (larger) snapshot saturates instead of underflowing.
        let zero = CacheStats::default().delta_from(&cache.stats());
        assert_eq!((zero.hits, zero.misses), (0, 0));
    }

    #[test]
    fn scope_separates_entries() {
        let cache = EvalCache::new();
        let ev1 = CountingEval::new(1.0);
        let ev2 = CountingEval::new(2.0);
        let cfg = ev1.space.default_config();
        cache.get_or_evaluate(&ev1, &cfg).unwrap();
        let (_, hit) = cache.get_or_evaluate(&ev2, &cfg).unwrap();
        assert!(!hit, "different scope must not hit");
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn key_stable_across_key_orderings() {
        let scope_a = crate::util::json::parse(r#"{"batch": 64, "kernel": "matmul"}"#).unwrap();
        let scope_b = crate::util::json::parse(r#"{"kernel": "matmul", "batch": 64}"#).unwrap();
        let cfg_a = crate::util::json::parse(r#"{"unroll": 2, "tiling_size": 16}"#).unwrap();
        let cfg_b = crate::util::json::parse(r#"{"tiling_size": 16, "unroll": 2}"#).unwrap();
        assert_eq!(
            EvalCache::key("kernel", &scope_a, &cfg_a),
            EvalCache::key("kernel", &scope_b, &cfg_b)
        );
        assert_ne!(
            EvalCache::key("kernel", &scope_a, &cfg_a),
            EvalCache::key("finetune", &scope_a, &cfg_a),
            "track must separate keys"
        );
    }

    #[test]
    fn shared_handle_sees_one_store() {
        let cache = EvalCache::new();
        let clone = cache.clone();
        let ev = CountingEval::new(3.0);
        let cfg = ev.space.default_config();
        clone.get_or_evaluate(&ev, &cfg).unwrap();
        let (_, hit) = cache.get_or_evaluate(&ev, &cfg).unwrap();
        assert!(hit, "clones share the underlying store");
    }

    #[test]
    fn striping_spreads_and_finds_many_keys() {
        // Many distinct configs land across shards and every one is found
        // again (exercises the stripe-selection path end to end).
        let cache = EvalCache::new();
        let ev = CountingEval::new(4.0);
        let mut rng = crate::util::rng::Rng::new(11);
        let cfgs: Vec<Config> = (0..64).map(|_| ev.space.sample(&mut rng)).collect();
        for cfg in &cfgs {
            cache.get_or_evaluate(&ev, cfg).unwrap();
        }
        let computed = ev.calls.get();
        for cfg in &cfgs {
            let (_, hit) = cache.get_or_evaluate(&ev, cfg).unwrap();
            assert!(hit);
        }
        assert_eq!(ev.calls.get(), computed, "second pass is all hits");
        assert_eq!(cache.stats().misses, computed);
        assert_eq!(cache.stats().peak_entries, computed, "unbounded: peak = all");
        assert_eq!(cache.stats().evictions, 0);
    }

    #[test]
    fn batch_dedupes_within_and_against_cache() {
        let cache = EvalCache::new();
        let ev = CountingEval::new(5.0);
        let a = ev.space.default_config();
        let mut rng = crate::util::rng::Rng::new(3);
        let b = ev.space.sample(&mut rng);
        // Seed the cache with `a`, then batch [a, b, b].
        cache.get_or_evaluate(&ev, &a).unwrap();
        let out = cache
            .get_or_evaluate_batch(&ev, &[a.clone(), b.clone(), b.clone()])
            .unwrap();
        assert_eq!(out.len(), 3);
        assert!(out[0].1, "a was already cached");
        assert!(!out[1].1, "first b is computed");
        assert!(out[2].1, "duplicate b is served from the batch insert");
        assert_eq!(ev.calls.get(), 2, "a once, b once");
        assert_eq!(
            out[1].0.score.to_bits(),
            out[2].0.score.to_bits(),
            "duplicates are identical"
        );
    }

    #[test]
    fn lru_cap_bounds_residency_and_never_changes_scores() {
        // The same config stream through an unbounded and a tightly capped
        // cache: identical score bits everywhere (evaluator determinism
        // makes evicted entries recompute exactly), bounded peak, counted
        // evictions.
        let unbounded = EvalCache::new();
        let capped = EvalCache::bounded(4);
        assert_eq!(capped.capacity(), Some(4));
        let ev_u = CountingEval::new(6.0);
        let ev_c = CountingEval::new(6.0);
        let mut rng = crate::util::rng::Rng::new(17);
        let cfgs: Vec<Config> = (0..48).map(|_| ev_u.space.sample(&mut rng)).collect();
        // Two passes so the capped cache revisits evicted keys.
        for cfg in cfgs.iter().chain(cfgs.iter()) {
            let (a, _) = unbounded.get_or_evaluate(&ev_u, cfg).unwrap();
            let (b, _) = capped.get_or_evaluate(&ev_c, cfg).unwrap();
            assert_eq!(a.score.to_bits(), b.score.to_bits(), "eviction changed a score");
        }
        let st = capped.stats();
        assert!(st.entries <= 4, "resident entries exceed the cap: {st:?}");
        assert!(st.peak_entries <= 4, "peak exceeds the cap: {st:?}");
        assert!(st.evictions > 0, "a 4-entry cap over 48 keys must evict");
        assert!(
            ev_c.calls.get() > ev_u.calls.get(),
            "the capped cache recomputes evicted entries"
        );
        assert_eq!(unbounded.stats().evictions, 0);
        assert_eq!(unbounded.stats().peak_entries, unbounded.len());
    }

    #[test]
    fn lru_evicts_least_recently_touched() {
        // Keys 0, 16, 32 share stripe 0 (stripe = key & 15 for small
        // keys); a cap of 32 gives every shard a 2-entry slice.  Touching
        // key 0 before storing key 32 must make key 16 the victim.
        let cache = EvalCache::bounded(32);
        let e = Evaluation {
            score: 1.0,
            extra: Vec::new(),
            feedback: String::new(),
        };
        cache.store(0u128, &e);
        cache.store(16u128, &e);
        assert!(cache.shard(0).touch(0).is_some(), "touch moves 0 to MRU");
        cache.store(32u128, &e);
        let shard = cache.shard(0);
        assert!(shard.map.contains_key(&0), "recently touched survives");
        assert!(!shard.map.contains_key(&16), "LRU entry evicted");
        assert!(shard.map.contains_key(&32));
        drop(shard);
        assert_eq!(cache.stats().evictions, 1);
    }

    #[test]
    fn cap_env_parsing_hard_errors_on_zero_and_garbage() {
        assert_eq!(EvalCache::cap_from_env(None).unwrap(), None, "off by default");
        assert_eq!(EvalCache::cap_from_env(Some(500)).unwrap(), Some(500));
        assert!(
            EvalCache::cap_from_env(Some(0)).is_err(),
            "--cache-cap 0 is a typo, not 'off'"
        );
        // Env fallback with hard-error parsing (serialized in one test,
        // like the HAQA_WORKERS / HAQA_BATCH tests).
        std::env::set_var("HAQA_CACHE_CAP", "plenty");
        let err = EvalCache::cap_from_env(None);
        std::env::remove_var("HAQA_CACHE_CAP");
        let msg = format!("{:#}", err.expect_err("garbage must not be swallowed"));
        assert!(msg.contains("HAQA_CACHE_CAP") && msg.contains("plenty"), "{msg}");

        std::env::set_var("HAQA_CACHE_CAP", "0");
        let err = EvalCache::cap_from_env(None);
        std::env::remove_var("HAQA_CACHE_CAP");
        assert!(err.is_err(), "HAQA_CACHE_CAP=0 is a hard error");

        std::env::set_var("HAQA_CACHE_CAP", "2048");
        let ok = EvalCache::cap_from_env(None);
        std::env::remove_var("HAQA_CACHE_CAP");
        assert_eq!(ok.unwrap(), Some(2048));

        std::env::set_var("HAQA_CACHE_CAP", "99");
        let ok = EvalCache::cap_from_env(Some(7));
        std::env::remove_var("HAQA_CACHE_CAP");
        assert_eq!(ok.unwrap(), Some(7), "explicit CLI value wins over env");
    }

    #[test]
    fn journal_round_trips_across_instances() {
        let dir = temp_cache_dir("roundtrip");
        let ev = CountingEval::new(1.5);
        let cfg = ev.space.default_config();
        let first = {
            let cache = EvalCache::with_dir(&dir).unwrap();
            let (e, hit) = cache.get_or_evaluate(&ev, &cfg).unwrap();
            assert!(!hit);
            e
            // Dropping the last handle group-commits the buffered record.
        };
        // A brand-new instance (≈ a new process) must serve the evaluation
        // from the journal without calling the evaluator again.
        let ev2 = CountingEval::new(1.5);
        let cache2 = EvalCache::with_dir(&dir).unwrap();
        assert_eq!(cache2.len(), 1);
        let (e, hit) = cache2.get_or_evaluate(&ev2, &cfg).unwrap();
        assert!(hit, "served from the persistent tier");
        assert_eq!(ev2.calls.get(), 0, "no re-evaluation");
        assert_eq!(e.score.to_bits(), first.score.to_bits(), "bit-exact score");
        assert_eq!(e.extra.len(), 1);
        assert_eq!(e.extra[0].to_bits(), first.extra[0].to_bits());
        assert_eq!(e.feedback, first.feedback);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn group_commit_buffers_flushes_and_drops() {
        let dir = temp_cache_dir("groupcommit");
        let ev = CountingEval::new(2.5);
        let mut rng = crate::util::rng::Rng::new(5);
        let cfgs: Vec<Config> = (0..6).map(|_| ev.space.sample(&mut rng)).collect();
        let path = dir.join(JOURNAL_FILE);
        {
            let cache = EvalCache::with_dir(&dir).unwrap();
            for cfg in &cfgs[..4] {
                cache.get_or_evaluate(&ev, cfg).unwrap();
            }
            // Below both watermarks: everything is still buffered.
            let st = cache.stats();
            assert_eq!(st.journal_records, 4);
            assert_eq!(st.journal_writes, 0, "no write before the watermark");
            assert_eq!(std::fs::read(&path).unwrap(), b"", "file untouched");
            // An explicit sweep-boundary flush commits the group in ONE
            // write call.
            cache.flush_journal();
            let st = cache.stats();
            assert_eq!(st.journal_writes, 1, "one syscall for the whole group");
            let cache_check = EvalCache::with_dir(&dir).unwrap();
            assert_eq!(cache_check.len(), 4, "flushed group is on disk");
            drop(cache_check);
            // Two more records stay buffered until the handle drops.
            for cfg in &cfgs[4..] {
                cache.get_or_evaluate(&ev, cfg).unwrap();
            }
            assert_eq!(cache.stats().journal_records, 6);
            assert_eq!(cache.stats().journal_writes, 1);
        }
        // Drop committed the tail group.
        let cache2 = EvalCache::with_dir(&dir).unwrap();
        assert_eq!(cache2.len(), 6, "drop flushed the tail group");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn group_commit_watermark_flushes_by_itself() {
        let dir = temp_cache_dir("watermark");
        std::fs::create_dir_all(&dir).unwrap();
        let cache = EvalCache::with_dir(&dir).unwrap();
        let e = Evaluation {
            score: 0.5,
            extra: Vec::new(),
            feedback: "{}".into(),
        };
        for key in 0..(FLUSH_RECORDS as u128 + 10) {
            cache.insert(key, &e);
        }
        let st = cache.stats();
        assert_eq!(st.journal_records, FLUSH_RECORDS + 10);
        assert!(st.journal_writes >= 1, "the record watermark must trip");
        assert!(
            st.journal_writes < st.journal_records,
            "group commit coalesces: {} writes for {} records",
            st.journal_writes,
            st.journal_records
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn capped_disk_tier_stays_authoritative() {
        // A tiny cap (1 ⇒ one shard slice of 1, fifteen of 0) must not
        // lose journal records: the disk tier carries everything, and an
        // unbounded instance on the same dir sees every record.
        let dir = temp_cache_dir("cappeddisk");
        let ev = CountingEval::new(3.5);
        let mut rng = crate::util::rng::Rng::new(9);
        let cfgs: Vec<Config> = (0..8).map(|_| ev.space.sample(&mut rng)).collect();
        {
            let capped = EvalCache::with_dir_capped(&dir, Some(1)).unwrap();
            for cfg in &cfgs {
                capped.get_or_evaluate(&ev, cfg).unwrap();
            }
            assert!(capped.len() <= 1, "cap 1 holds at most one entry");
        }
        let full = EvalCache::with_dir(&dir).unwrap();
        assert_eq!(full.len(), 8, "every record reached the journal once");
        let ev2 = CountingEval::new(3.5);
        for cfg in &cfgs {
            let (_, hit) = full.get_or_evaluate(&ev2, cfg).unwrap();
            assert!(hit, "served from the authoritative disk tier");
        }
        assert_eq!(ev2.calls.get(), 0);
        // …and a capped *reload* still loads the full journal through the
        // cap (evicting on the way in) without duplicating records.
        let capped2 = EvalCache::with_dir_capped(&dir, Some(4)).unwrap();
        assert!(capped2.len() <= 4);
        assert!(capped2.stats().evictions > 0, "load-time eviction is counted");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn eviction_never_duplicates_journal_records() {
        // An evicted key that gets recomputed must not be appended again:
        // the journaled set, not residency, gates appends.
        let dir = temp_cache_dir("nodup");
        let ev = CountingEval::new(4.5);
        let mut rng = crate::util::rng::Rng::new(13);
        let cfgs: Vec<Config> = (0..12).map(|_| ev.space.sample(&mut rng)).collect();
        {
            let capped = EvalCache::with_dir_capped(&dir, Some(2)).unwrap();
            for cfg in cfgs.iter().chain(cfgs.iter()) {
                capped.get_or_evaluate(&ev, cfg).unwrap();
            }
            assert!(
                ev.calls.get() > 12,
                "the second pass recomputed at least one evicted key"
            );
            assert_eq!(
                capped.stats().journal_records,
                12,
                "exactly one journal record per distinct key"
            );
        }
        let full = EvalCache::with_dir(&dir).unwrap();
        assert_eq!(full.len(), 12);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_journal_tail_is_skipped_and_healed() {
        let dir = temp_cache_dir("corrupt");
        let ev1 = CountingEval::new(1.0);
        let ev2 = CountingEval::new(2.0);
        let cfg = ev1.space.default_config();
        {
            let cache = EvalCache::with_dir(&dir).unwrap();
            cache.get_or_evaluate(&ev1, &cfg).unwrap();
            cache.get_or_evaluate(&ev2, &cfg).unwrap();
        }
        let path = dir.join(JOURNAL_FILE);
        // Simulate a crashed writer: a torn, newline-less tail record —
        // exactly what an interrupted group commit leaves behind.
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"{\"key\":\"00ff\",\"bits\":\"zzz");
        std::fs::write(&path, &bytes).unwrap();

        let cache2 = EvalCache::with_dir(&dir).unwrap();
        assert_eq!(cache2.len(), 2, "the two intact records survive");
        // The torn tail was newline-terminated (append-only healing), so
        // records appended after recovery load cleanly.
        let ev3 = CountingEval::new(3.0);
        cache2.get_or_evaluate(&ev3, &cfg).unwrap();
        drop(cache2);
        let cache3 = EvalCache::with_dir(&dir).unwrap();
        assert_eq!(cache3.len(), 3, "post-recovery appends load cleanly");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_middle_record_is_skipped_not_fatal() {
        let dir = temp_cache_dir("middle");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(JOURNAL_FILE);
        let record = |key: u128| {
            encode_record(
                key,
                &Evaluation {
                    score: -1.25,
                    extra: Vec::new(),
                    feedback: "{}".into(),
                },
            )
        };
        let mut blob = record(42).into_bytes();
        blob.extend_from_slice(b"not json at all\n");
        blob.extend_from_slice(record(43).as_bytes());
        std::fs::write(&path, &blob).unwrap();
        let cache = EvalCache::with_dir(&dir).unwrap();
        // The corrupt line is skipped; records on both sides survive.
        assert_eq!(cache.len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compact_drops_superseded_duplicates_and_corruption() {
        let dir = temp_cache_dir("compact");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(JOURNAL_FILE);
        let record = |key: u128, score: f64| {
            encode_record(
                key,
                &Evaluation {
                    score,
                    extra: Vec::new(),
                    feedback: "{}".into(),
                },
            )
        };
        // Two writers raced on key 42 (first-write-wins ⇒ 1.0 is live),
        // key 43 is unique, and a crashed writer left a torn tail.
        let mut blob = record(42, 1.0).into_bytes();
        blob.extend_from_slice(record(43, 3.0).as_bytes());
        blob.extend_from_slice(record(42, 2.0).as_bytes());
        blob.extend_from_slice(b"{\"key\": \"torn");
        std::fs::write(&path, &blob).unwrap();

        let report = EvalCache::compact(&dir).unwrap();
        assert_eq!(report.before_records, 3);
        assert_eq!(report.after_records, 2);
        assert_eq!(report.dropped_corrupt, 1);
        assert!(report.after_bytes < report.before_bytes);

        // The compacted journal loads cleanly and kept the live values.
        let cache = EvalCache::with_dir(&dir).unwrap();
        assert_eq!(cache.len(), 2);
        let shard_val = |key: u128| cache.shard(key).map.get(&key).cloned().unwrap().0;
        assert_eq!(shard_val(42).score.to_bits(), 1.0f64.to_bits(), "first write wins");
        assert_eq!(shard_val(43).score.to_bits(), 3.0f64.to_bits());

        // Compacting a compact journal is a no-op.
        let again = EvalCache::compact(&dir).unwrap();
        assert_eq!(again.before_records, 2);
        assert_eq!(again.after_records, 2);
        assert_eq!(again.dropped_corrupt, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn record_encoding_is_bit_exact() {
        let e = Evaluation {
            score: -36.860000000000014,
            extra: vec![0.1 + 0.2, f64::MIN_POSITIVE],
            feedback: "{\"latency_us\": 36.860}".into(),
        };
        let key = EvalCache::key("kernel", &Json::obj(), &Json::obj());
        let line = encode_record(key, &e);
        let j = json::parse(line.trim_end()).unwrap();
        let (k2, e2) = decode_record(&j).unwrap();
        assert_eq!(k2, key);
        assert_eq!(e2.score.to_bits(), e.score.to_bits());
        assert_eq!(e2.extra.len(), 2);
        assert_eq!(e2.extra[0].to_bits(), e.extra[0].to_bits());
        assert_eq!(e2.extra[1].to_bits(), e.extra[1].to_bits());
        assert_eq!(e2.feedback, e.feedback);
    }
}
